"""Generate EXPERIMENTS.md (§Dry-run, §Roofline, §Perf) from the dry-run
JSONs in experiments/dryrun plus the hillclimb log in
experiments/perf_log.json.

Adds the floor-efficiency metric: for each cell,
  t_floor = max( MODEL_FLOPS / (chips * peak),
                 min_bytes_moved / (chips * hbm_bw) )
where min_bytes_moved is the active parameter bytes (every weight read at
least once per step) plus, for decode, the KV cache bytes (read once).
efficiency = t_floor / t_bound — how close the compiled program's dominant
roofline term is to the physical minimum for the workload.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config, all_cells
from repro.roofline.analysis import HW

HWC = HW()


def floor_seconds(arch: str, shape_name: str, devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        flops = 6.0 * n_active * shape.seq_len * shape.global_batch
        min_bytes = 3 * 2 * n_active          # read W (fwd+bwd) + write upd
    elif shape.kind == "prefill":
        flops = 2.0 * n_active * shape.seq_len * shape.global_batch
        min_bytes = 2 * n_active
    else:
        flops = 2.0 * n_active * shape.global_batch
        kv = (cfg.kv_bytes_per_token_layer() * len(cfg.attn_layer_indices())
              * shape.seq_len * shape.global_batch)
        min_bytes = 2 * n_active + kv
    t_c = flops / (devices * HWC.peak_flops)
    t_m = min_bytes / (devices * HWC.hbm_bw)
    return max(t_c, t_m)


def load_rows(d: Path, variant=None):
    rows = []
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if variant and r.get("variant") != variant:
            continue
        rows.append(r)
    return rows


def fmt_table(rows):
    out = ["| arch | shape | mesh | mem/dev (raw / TPU-adj) | compute s | "
           "memory s | collective s | bound | useful | floor-eff | note |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                       f"| — | — | — | — | — | SKIP: {r['reason'][:46]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                       f"| — | — | — | — | — | ERROR |")
            continue
        fl = floor_seconds(r["arch"], r["shape"], r["devices"])
        eff = fl / max(1e-12, r["t_bound_s"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['bytes_per_device']/2**30:.1f} / "
            f"{r['tpu_bytes_per_device']/2**30:.1f} GiB "
            f"| {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} "
            f"| {r['t_collective_s']:.3f} | {r['bottleneck']} "
            f"| {r['flops_useful_ratio']:.2f} | {eff:.1%} | |")
    return "\n".join(out)


def main():
    d = Path("experiments/dryrun")
    base = load_rows(d, variant="base")
    variants = [r for r in load_rows(d) if r.get("variant") != "base"]
    single = [r for r in base if r["mesh"] == "pod16x16"]
    multi = [r for r in base if r["mesh"] == "pod2x16x16"]
    ok = [r for r in base if r["status"] == "ok"]
    perf_log = Path("experiments/perf_log.json")
    perf = json.loads(perf_log.read_text()) if perf_log.exists() else None

    doc = []
    doc.append("""# EXPERIMENTS

Hardware model (targets; this container is CPU-only so figures derive from
compiled per-device HLO, not wall clocks): TPU v5e-like — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI, ~25 GB/s DCN across pods.  Meshes:
single-pod (16,16)=("data","model") 256 chips; multi-pod
(2,16,16)=("pod","data","model") 512 chips.

## §Dry-run

Every (architecture x shape) cell is lowered with ShapeDtypeStructs (no
allocation), jit-compiled with explicit in/out shardings + donation, on
BOTH production meshes.  Status: **all runnable cells compile on both
meshes** (see tables), with 7 documented `long_500k` skips (pure
full-attention archs per assignment; run for mamba2 / jamba / gemma3 whose
mixers are sub-quadratic).

Memory columns: `raw` is XLA:CPU `memory_analysis()` (arg+temp+out-alias);
`TPU-adj` subtracts f32 shadow copies of bf16 dot operands that XLA:CPU
materializes (and hoists out of loops) because it lacks native bf16 dots —
the MXU consumes bf16 directly, so those buffers do not exist on TPU
(quantified per-cell via `f32_shadow_bytes`; barriers were tried and are
stripped by the CPU pipeline).  Headline fits (TPU-adj, 16 GiB HBM):
every decode/prefill cell fits; the three >100B trains (deepseek-v3,
jamba-1.5, qwen2-vl) land at ~17 GiB on 256 chips — within reach of the
hillclimbed variants and comfortably fitting at 512 chips with the
factored-second-moment optimizer (see §Perf iteration log and
optim/adafactor.py; fp32 Adam moments alone would need 21 GiB/chip for
deepseek-v3, which is why Adafactor is auto-selected > 60B).

Collective schedule summary: ring attention rotates K/V via
`collective-permute`; FSDP weight gathers are `all-gather`; EP MoE uses
symmetric tiled `all-to-all` (train) and a single fused psum combine
(decode); CE/embedding use psum over the vocab-sharded axis; DP gradient
reduction is `all-reduce` (pod axis classified as DCN in the collective
term).  Per-cell breakdowns are in experiments/dryrun/*.json
(`coll_breakdown`).
""")
    doc.append("### Single-pod (16x16, 256 chips) — all 40 cells\n")
    doc.append(fmt_table(single))
    doc.append("\n### Multi-pod (2x16x16, 512 chips) — all 40 cells\n")
    doc.append(fmt_table(multi))

    doc.append("""

## §Roofline

Method: `cost_analysis()` counts while-loop bodies once (verified), so the
three terms are derived by parsing the compiled per-device HLO: the
computation call graph is walked with `while` trip counts from
`known_trip_count`; FLOPs = dot/conv ops (2*out*contraction); HBM bytes =
operand+output bytes per top-level instruction (fusion internals excluded
— a fusion reads inputs and writes outputs once); collective link bytes
use ring factors (AG: T(P-1)/P, AR: 2T(P-1)/P, RS: T(P-1), A2A: T(P-1)/P,
permute: T) with group sizes parsed from `replica_groups`, DCN rate for
pod-spanning groups.  MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D
(prefill) / 2·N_active·b (decode).

Reading the table (these are the FINAL numbers, i.e. after the §Perf
iterations below landed; the §Perf log records the before/after of each):
 * nearly every cell is memory-bound — expected for an un-fused jnp
   program (attention score tensors hit HBM each layer; the validated
   Pallas flash/decode kernels keep them in VMEM on real TPUs and are the
   documented next lever);
 * `useful` (MODEL_FLOPS / HLO_FLOPS) is 0.6-0.9 for trains (remat
   recompute + ring-attention causal waste) and collapses to 0.02-0.2 for
   MoE decodes — the dispatch buffer computes capacity=T rows per expert
   while only T·k/E are real (documented, with the capacity-factor fix
   napkin'd in §Perf);
 * `floor-eff` compares the dominant term against the physical floor
   (weights+KV read once, or peak-FLOPs): decode cells sit at 5-40% of
   floor after the §Perf pass (from <1% at first lowering);
 * `useful` > 1 on SSM decode cells (mamba2 long_500k) is a counting
   artifact: the FLOP model counts dot/conv ops only, and the SSD decode
   recurrence is elementwise — its FLOPs are invisible to the counter
   while MODEL_FLOPS still charges 2·N_active·b.

The three hillclimb cells (selection per spec, from the first-lowering
baseline): **qwen2-vl-72b decode_32k** (worst decode roofline fraction +
most paper-representative: decode = KV-load + weight-load vs compute) —
§Perf A; **gemma3-4b decode_32k** (the only collective-bound cell) —
§Perf B; **deepseek-v3-671b train_4k** (worst absolute time; EP + MLA +
ZeRO-3 = the paper's Appendix-D story at pod scale) — §Perf C.
""")

    if perf:
        doc.append("\n## §Perf — hypothesis -> change -> measure log\n")
        for entry in perf:
            doc.append(f"### {entry['title']}\n")
            doc.append(entry["body"])
    else:
        doc.append("\n## §Perf\n\n(perf log pending — see experiments/"
                   "perf_log.json)\n")

    if variants:
        doc.append("\n### Beyond-paper variant rows (vs `base` above)\n")
        doc.append(fmt_table(variants))

    doc.append("""

## §Benchmarks (paper-claims validation, CPU container)

`python -m benchmarks.run` reproduces every PIPO table/figure at reduced
scale (bench_output.txt).  Directional validation against the paper:

| paper claim | paper figure | this repro (CPU, 1 core) |
|---|---|---|
| pipelined offload beats sequential-sync | 2-3.1x (Fig5/9) | 1.3-1.7x where transfer is real (fig5 disk cold-reads, fig9, fig12); parity on page-cached placements (no transfer to hide). 1 CPU core caps overlap — I/O threads share the compute core. |
| compute-busy fraction rises | <40% -> >90% (Fig8) | 0.87-0.92 -> 0.95-0.99 (engine busy fraction; idle base is smaller on CPU because compute itself is slow) |
| pipeline scheduling is the largest single win | 1.97x of 2.66x (Fig9) | fig9: +pipeline contributes the bulk of the stack (see bench_output) |
| transfer suite beats naive I/O | +26% (Fig7) | directional mismatch on this container: its virtual NVMe saturates with one sequential stream, so 3-thread chunked reads lose to one fromfile (fig7, cold-cache); the suite's win needs queue-depth-sensitive NVMe (paper's laptop). The merging part of the suite is exercised by every engine load. |
| fused INT4 kernel avoids dequant pass | §3.4 | 17x vs dequant-then-matmul at b=8 (kernel_int4) |
| TTFT improves | -42.5% (Table3/C.6) | -12..-22% (table3; prefill is compute-heavy on CPU) |
| MoE: overlap expert loads with shared-expert compute | C.4 | fig12: 1.4x + busy 0.90->0.99 |
| autoconfig picks placement per Eq. 1 | §3.5 | tests/test_properties.py::test_autoconfig_placements |

Differences are explained by the container (1 CPU core: transfer threads
and compute share a core; disk is page-cached NVMe): where the paper's
regime is transfer-bound with a free DMA engine, gains match directionally
but compress in magnitude.  The pipeline/ablation ordering matches the
paper everywhere.
""")
    Path("EXPERIMENTS.md").write_text("\n".join(doc))
    print(f"EXPERIMENTS.md written: {len(ok)} ok cells, "
          f"{sum(1 for r in base if r['status'] == 'skip')} skips")


if __name__ == "__main__":
    main()
