"""Benchmark harness: one function per PIPO table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  All benches run on CPU with
reduced model sizes; the *comparisons* (pipelined vs sequential, suite vs
naive, INT4 fused vs dequant-first) mirror the paper's figures and are
validated directionally against its claims in EXPERIMENTS.md.

  fig5_throughput    — tokens/s by weight placement x batch (Fig. 5)
  fig6_blocksize     — transfer bandwidth vs block size (Fig. 6 / Appx A)
  fig7_transfer      — suite vs naive disk->device bandwidth (Fig. 7)
  fig8_utilization   — compute-busy fraction, PIPO vs sequential (Fig. 8)
  fig9_ablation      — +pipeline, +suite, +int4-kernel cumulative (Fig. 9)
  table3_latency     — TTFT + decode latency vs context (Table 3)
  table6_memory      — memory footprint by placement (Table 6)
  fig12_moe          — MoE offloading with expert-load overlap (Fig. 12)
  serving_offload    — continuous-batching decode: seq/cold/warm/warm+INT4
  serving_offload_depth — warm preload-depth sweep {1,2,3} x {fp32,int4}
  serving_kv_quant   — KV streaming sweep: kv_mode {fp32,int4} x depth {1,2}
  pipelined_kv_quant — batch-generation KV streaming: kv_mode on PipelinedLM
  serving_spec_decode — k-token draft-then-verify vs plain decode (ours)
  replay_validate    — trace-replay predicted vs measured step time (ours)
  kernel_int4        — fused INT4 kernel vs dequant-then-matmul (§3.4)
  roofline           — aggregate dry-run roofline table (ours)
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

ROWS: list[str] = []

# --steps N overrides the KV-streaming scenarios' decode length (CI
# smoke runs `serving_kv_quant --steps 2` and `pipelined_kv_quant
# --steps 2` so they can't rot without paying the full sweep); None =
# the scenario's default
STEPS: "int | None" = None

# --seed plumbs into workload generation (arrival traces, prompts) and is
# stamped into every serving_traffic row so a figure names the workload
# that produced it
SEED: int = 0


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _bench_cfg(layers=4, d=256, ff=1024, vocab=2048):
    from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig
    return ModelConfig(name="bench", num_layers=layers, d_model=d,
                       num_heads=8, num_kv_heads=4, head_dim=d // 8, d_ff=ff,
                       vocab_size=vocab, pattern=(LayerSpec(ATTN, DENSE),))


def _run_engine(placement, pipeline, batch=4, gen=8, prompt_len=32,
                quant=None, **kw):
    from repro.serving.spec import EngineSpec, build_lm
    cfg = _bench_cfg()
    # disk placement: evict page cache per load — the paper's NVMe regime
    # (page-cached "disk" reads are memcpys and hide the pipeline's win)
    kw.setdefault("cold_reads", placement == "disk")
    spec = EngineSpec(
        arch=cfg.name, cfg=cfg, offload=True, placement=placement,
        pipeline=pipeline, quant=quant, b_max=batch,
        max_len=prompt_len + gen + 2, depth=1,
        disk_root=f"/tmp/pipo_bench_{placement}_{pipeline}_{quant}", **kw)
    lm = build_lm(spec)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (batch, prompt_len)).astype(
        np.int32)
    toks, stats = lm.generate(prompt, gen_len=gen)
    return stats


def fig5_throughput():
    """Paper Fig. 5: throughput by weight placement and batch size."""
    for placement, tag in (("device", "G"), ("host", "C"), ("disk", "D")):
        for batch in (4, 8):
            seq = _run_engine(placement, "sequential", batch=batch)
            pipo = _run_engine(placement, "performance", batch=batch)
            speedup = pipo["throughput_tok_s"] / max(1e-9,
                                                     seq["throughput_tok_s"])
            emit(f"fig5_{tag}-{batch}_seq",
                 1e6 / max(1e-9, seq["throughput_tok_s"]),
                 f"tok_s={seq['throughput_tok_s']:.2f}")
            emit(f"fig5_{tag}-{batch}_pipo",
                 1e6 / max(1e-9, pipo["throughput_tok_s"]),
                 f"tok_s={pipo['throughput_tok_s']:.2f};speedup={speedup:.2f}x")


def fig6_blocksize():
    """Appendix A: transfer bandwidth vs block size."""
    from repro.core.offload import DiskStore
    from repro.core.transfer import sweep_block_size
    disk = DiskStore("/tmp/pipo_bench_blk")
    arr = np.zeros((64 << 20,), np.uint8)  # 64MB
    disk.put("w", arr)
    for bs, bw in sweep_block_size(disk, "w",
                                   sizes=[1 << 20, 4 << 20, 8 << 20,
                                          32 << 20, 64 << 20]):
        emit(f"fig6_block_{bs >> 20}MB", 64 * 2**20 / bw * 1e6,
             f"GBps={bw / 1e9:.2f}")


def fig7_transfer():
    """Fig. 7: suite vs naive disk->device transfer speed."""
    from repro.core.offload import DiskStore
    from repro.core.transfer import (blockwise_disk_to_host, host_to_device,
                                     naive_disk_to_host,
                                     pipelined_disk_to_device)
    disk = DiskStore("/tmp/pipo_bench_tx")
    for mb in (4, 16, 64):
        arr = np.random.default_rng(0).integers(
            0, 255, (mb << 20,)).astype(np.uint8)
        disk.put(f"w{mb}", arr)
        reps = 3

        def t_naive():
            disk.drop_cache(f"w{mb}")   # cold reads = the paper's regime
            t0 = time.perf_counter()
            host_to_device(naive_disk_to_host(disk, f"w{mb}"))
            return time.perf_counter() - t0

        def t_suite():
            disk.drop_cache(f"w{mb}")
            t0 = time.perf_counter()
            pipelined_disk_to_device(disk, f"w{mb}", block_bytes=8 << 20)
            return time.perf_counter() - t0

        tn = min(t_naive() for _ in range(reps))
        ts = min(t_suite() for _ in range(reps))
        emit(f"fig7_naive_{mb}MB", tn * 1e6,
             f"GBps={mb / 1024 / tn:.2f}")
        emit(f"fig7_suite_{mb}MB", ts * 1e6,
             f"GBps={mb / 1024 / ts:.2f};gain={tn / ts:.2f}x")


def fig8_utilization():
    """Fig. 8: compute-busy fraction (the GPU-utilization analogue)."""
    seq = _run_engine("disk", "sequential", gen=6)
    pipo = _run_engine("disk", "performance", gen=6)
    emit("fig8_util_sequential", seq["total_s"] * 1e6,
         f"busy={seq['compute_busy']:.2f}")
    emit("fig8_util_pipo", pipo["total_s"] * 1e6,
         f"busy={pipo['compute_busy']:.2f}")


def fig9_ablation():
    """Fig. 9: cumulative component gains over the sequential baseline."""
    base = _run_engine("disk", "sequential", quant="int4", fused_int4=False)
    t0 = base["throughput_tok_s"]
    pipe = _run_engine("disk", "performance", quant="int4", fused_int4=False,
                       block_bytes=1 << 30, n_io_threads=1)
    suite = _run_engine("disk", "performance", quant="int4",
                        fused_int4=False)
    kernel = _run_engine("disk", "performance", quant="int4",
                         fused_int4=True)
    emit("fig9_flexgen_like", 1e6 / max(1e-9, t0), "rel=1.00")
    for name, s in (("pipo_base", pipe), ("plus_suite", suite),
                    ("plus_kernel", kernel)):
        emit(f"fig9_{name}", 1e6 / max(1e-9, s["throughput_tok_s"]),
             f"rel={s['throughput_tok_s'] / max(1e-9, t0):.2f}")


def table3_latency():
    """Table 3: TTFT and per-token decode latency vs context length."""
    for ctx in (64, 128, 256):
        seq = _run_engine("disk", "sequential", batch=1, prompt_len=ctx,
                          gen=4)
        pipo = _run_engine("disk", "performance", batch=1, prompt_len=ctx,
                           gen=4)
        dec_seq = (seq["total_s"] - seq["ttft_s"]) / 3
        dec_pipo = (pipo["total_s"] - pipo["ttft_s"]) / 3
        emit(f"table3_ctx{ctx}_seq", seq["ttft_s"] * 1e6,
             f"ttft_s={seq['ttft_s']:.3f};decode_s={dec_seq:.3f}")
        emit(f"table3_ctx{ctx}_pipo", pipo["ttft_s"] * 1e6,
             f"ttft_s={pipo['ttft_s']:.3f};decode_s={dec_pipo:.3f}")


def table6_memory():
    """Table 6: device/host peak memory by placement."""
    for placement in ("device", "host", "disk"):
        s = _run_engine(placement, "performance", gen=4)
        emit(f"table6_{placement}", s["total_s"] * 1e6,
             f"dev_gb={s['device_peak_gb']:.3f};host_gb={s['host_peak_gb']:.3f};"
             f"tok_s={s['throughput_tok_s']:.2f}")


def fig12_moe():
    """Fig. 12 / Appx C.4: MoE offloading with expert-load overlap."""
    from repro.configs.base import ATTN, MOE, LayerSpec, ModelConfig, MoEConfig
    from repro.serving.spec import EngineSpec, build_lm
    cfg = ModelConfig(name="bench-moe", num_layers=3, d_model=256,
                      num_heads=8, num_kv_heads=4, head_dim=32, d_ff=512,
                      vocab_size=2048, pattern=(LayerSpec(ATTN, MOE),),
                      moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=512,
                                    num_shared=1, shared_d_ff=512))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    for mode in ("sequential", "performance"):
        lm = build_lm(EngineSpec(
            arch=cfg.name, cfg=cfg, offload=True, placement="disk",
            pipeline=mode, b_max=2, max_len=32, depth=1,
            disk_root=f"/tmp/pipo_bench_moe_{mode}"))
        toks, s = lm.generate(prompt, gen_len=6)
        emit(f"fig12_moe_{mode}", 1e6 / max(1e-9, s["throughput_tok_s"]),
             f"tok_s={s['throughput_tok_s']:.2f};busy={s['compute_busy']:.2f}")


def serving_offload():
    """Serving through the PIPO pipeline (tentpole scenario): continuous-
    batching decode under the deterministic ``sim_bw`` link floor,
    comparing four configurations on the same model:

      sequential  — FlexGen-like full serialization (baseline)
      cold        — performance pipeline, scheduler drained per decode
                    step (the PR-1 behavior: every step pays a cold w[0])
      warm        — performance + cross-step preload (step t+1's first
                    weight/KV loads submitted during step t's tail)
      warm_int4   — warm + INT4 weight streaming (~1/4 the bytes over
                    the same link; dequant overlapped on a pool thread)

    sim_bw rationale: on this CPU-only container transfers are memcpys
    whose speed swings with CPU contention and page-cache state, which
    would make the overlap gap pure noise.  The floor sleeps out the
    remainder like a DMA engine (GIL released), so sequential pays
    (weights + KV + compute) per layer while the pipeline hides the link
    time — the paper's transfer-bound serving regime, deterministic run
    to run.  The shape (d=512, ff=2048, b=16) keeps the link
    weight-dominated — the PIPO weight-offload regime, and the one where
    INT4's byte reduction shows (KV streams FP32 either way, so a
    KV-dominated link would mask it)."""
    cfg = _bench_cfg(layers=6, d=512, ff=2048)
    # depth pinned to 1 (the paper's two-resident-layer invariant) so rows
    # stay comparable across PRs; serving_offload_depth sweeps depth.
    variants = (
        ("sequential", dict(pipeline="sequential")),
        ("cold", dict(pipeline="performance", warm=False, depth=1)),
        ("warm", dict(pipeline="performance", warm=True, depth=1)),
        # fused_int4 pinned True for row continuity: the §3.5 auto rule
        # would disable the fused kernel at this b_max=16 shape
        ("warm_int4", dict(pipeline="performance", warm=True, depth=1,
                           quant="int4", fused_int4=True)),
    )
    results = {}
    for name, kw in variants:
        eng = _serving_engine(cfg, b_max=16, max_len=96, placement="host",
                              sim_bw=0.3e9, **kw)
        tok_s, step_s, rep, _ = _serve_steady_state(eng)
        results[name] = (tok_s, step_s, rep)
        emit(f"serving_offload_{name}", step_s * 1e6,
             f"decode_tok_s={tok_s:.2f};"
             f"step_ms={step_s * 1e3:.1f};"
             f"util={rep['compute_util']:.2f};"
             f"bubble={rep['bubble_frac']:.2f}")
    emit("serving_offload_speedup", 0.0,
         f"perf_vs_seq={results['warm'][0] / max(1e-9, results['sequential'][0]):.2f}x;"
         f"warm_vs_cold={results['warm'][0] / max(1e-9, results['cold'][0]):.2f}x;"
         f"int4_vs_fp32={results['warm_int4'][0] / max(1e-9, results['warm'][0]):.2f}x;"
         f"warm_step_ms={results['warm'][1] * 1e3:.1f};"
         f"cold_step_ms={results['cold'][1] * 1e3:.1f}")


def _serving_engine(cfg, **kw):
    """Serving engines are built through the one construction path:
    EngineSpec -> resolve -> create_engine (the spec carries the ad-hoc
    bench config as its cfg override)."""
    from repro.serving.spec import EngineSpec, create_engine
    return create_engine(EngineSpec(arch=cfg.name, cfg=cfg, offload=True,
                                    **kw))


def _serve_steady_state(eng, prompt_len=32, max_new=12):
    """Shared serving-offload measurement: fill all of the engine's slots,
    one untimed jit-warm decode step, then time steady-state decode to
    drain.  Returns (decode tok/s, s/step, pipeline report — empty for
    the resident engine, which has no pipeline, and (i0, i1): the global
    scheduler-iteration window the timing covered, so the timed steps
    can be sliced out of the engine's trace for ``core.replay``
    predicted-vs-measured validation; (None, None) when the engine has
    no scheduler)."""
    from repro.serving import Request
    rng = np.random.default_rng(0)
    for i in range(eng.b_max):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, eng.cfg.vocab_size, (prompt_len,)).astype(np.int32),
            max_new=max_new))
    eng._admit()                      # prefill all slots
    done = []
    eng._decode_step(done)           # warm the jit caches untimed
    i0 = eng.sched._iter0 if hasattr(eng, "sched") else None
    t0 = time.perf_counter()
    n0 = eng.stats["tokens_out"]
    s0 = eng.stats["decode_steps"]
    while any(s is not None for s in eng.slots):
        eng._decode_step(done)
    dt = time.perf_counter() - t0
    i1 = eng.sched._iter0 if hasattr(eng, "sched") else None
    ntok = eng.stats["tokens_out"] - n0
    nstep = eng.stats["decode_steps"] - s0
    rep = eng.pipeline_report() if hasattr(eng, "pipeline_report") else {}
    eng.shutdown()
    return ntok / dt, dt / max(1, nstep), rep, (i0, i1)


def _serve_ramping(eng, prompt_len=24, max_new=24, wave=2,
                   steps_per_wave=4):
    """Ramping-load measurement for the adaptive-depth sweep: start with
    ``wave`` requests and admit ``wave`` more every ``steps_per_wave``
    decode steps until all slots have been offered work, then drain.
    Returns (tok/s, s/step, depth_min, depth_max, resizes) — the depth
    fields track ``stats['preload_depth']`` across the ramp."""
    from repro.serving import Request
    rng = np.random.default_rng(0)
    rid = 0

    def submit(n):
        nonlocal rid
        for _ in range(n):
            eng.submit(Request(rid=rid, prompt=rng.integers(
                0, eng.cfg.vocab_size, (prompt_len,)).astype(np.int32),
                max_new=max_new))
            rid += 1

    submit(wave)
    eng._admit()
    done = []
    eng._decode_step(done)            # warm the jit caches untimed
    depths = [eng.stats["preload_depth"]]
    t0 = time.perf_counter()
    n0, s0 = eng.stats["tokens_out"], eng.stats["decode_steps"]
    steps = 0
    while eng.queue or any(s is not None for s in eng.slots) \
            or rid < eng.b_max:
        if rid < eng.b_max and steps and steps % steps_per_wave == 0:
            submit(min(wave, eng.b_max - rid))
        eng._admit()
        eng._decode_step(done)
        depths.append(eng.stats["preload_depth"])
        steps += 1
    dt = time.perf_counter() - t0
    ntok = eng.stats["tokens_out"] - n0
    nstep = eng.stats["decode_steps"] - s0
    eng.shutdown()
    return (ntok / dt, dt / max(1, nstep), min(depths), max(depths),
            eng.stats["depth_resizes"])


def serving_offload_depth():
    """Preload-depth sweep on the warm serving pipeline: depth D in
    {1, 2, 3} x {fp32, int4} on the serving_offload model/link.  Depth 1
    is the paper's two-resident-layer invariant (weight loads serialized
    one ahead); deeper windows keep up to D loads in flight across the
    depth+2 transfer workers.  b=8 (vs serving_offload's 16) keeps the
    shape firmly weight-dominated so the depth signal is transfer
    scheduling, not 2-core compute contention; max_new=24 lengthens the
    steady-state window.  Expected shape of the results: fp32 (17MB/layer
    over the link) gains through d2-d3; INT4's packed bytes make the link
    cheap, so its depth curve is flat-to-negative on this container — the
    overlapped dequants contend with main-thread compute on 2 cores (on a
    real GPU the fused dequant is on-device).  The summary row carries
    the headline ratios for docs/BENCHMARKS.md."""
    cfg = _bench_cfg(layers=6, d=512, ff=2048)
    results = {}
    for quant in (None, "int4"):
        tag = "int4" if quant else "fp32"
        for depth in (1, 2, 3):
            eng = _serving_engine(
                cfg, b_max=8, max_len=96, placement="host", sim_bw=0.3e9,
                pipeline="performance", warm=True, depth=depth, quant=quant)
            tok_s, step_s, rep, _ = _serve_steady_state(eng, max_new=24)
            results[(tag, depth)] = step_s
            emit(f"serving_offload_depth_{tag}_d{depth}", step_s * 1e6,
                 f"decode_tok_s={tok_s:.2f};"
                 f"step_ms={step_s * 1e3:.1f};"
                 f"util={rep['compute_util']:.2f};"
                 f"bubble={rep['bubble_frac']:.2f}")
    emit("serving_offload_depth_summary", 0.0,
         f"fp32_d2_vs_d1={results[('fp32', 1)] / results[('fp32', 2)]:.2f}x;"
         f"fp32_d3_vs_d1={results[('fp32', 1)] / results[('fp32', 3)]:.2f}x;"
         f"int4_d2_vs_d1={results[('int4', 1)] / results[('int4', 2)]:.2f}x;"
         f"int4_d3_vs_d1={results[('int4', 1)] / results[('int4', 3)]:.2f}x")


def serving_kv_quant():
    """KV-cache streaming sweep (tiered KV store): kv_mode {fp32, int4}
    x depth {1, 2} on the sim link, weights pinned INT4 so the step is
    KV-dominated — the regime the PR-3 depth sweep exposed ("INT4 is
    KV-dominated on the sim link: quantized cache is the next byte
    win").  All arms serve the same warm continuous-batching workload
    with prompt_len=64 of the 96-position extent live, so the KV rows
    (not the packed weights) carry most of the link bytes and the
    kv_mode delta is the dominant term at depth 1.  Live-row slicing is
    on everywhere (it is the store's only load path), so the fp32 rows
    already ship live rows, and the int4 rows additionally pack them
    ~3.2x (bf16 -> nibbles + group scales).  The
    derived fields carry the mean traced DECODE KV_LOAD payload —
    prefill loads carry 0 bytes and are excluded, so the figure is the
    real per-load link cost.  Record the table in docs/BENCHMARKS.md."""
    cfg = _bench_cfg(layers=6, d=512, ff=2048)
    max_new = (STEPS + 1) if STEPS else 16
    results = {}
    for kv_mode in ("fp32", "int4"):
        for depth in (1, 2):
            eng = _serving_engine(
                cfg, b_max=8, max_len=96, placement="host", sim_bw=0.3e9,
                pipeline="performance", warm=True, depth=depth,
                quant="int4", fused_int4=True, kv_mode=kv_mode)
            slab_kb = eng.kvstore.slab_nbytes(0) / 2**10
            trace = eng.trace              # survives engine shutdown
            tok_s, step_s, rep, _ = _serve_steady_state(eng, prompt_len=64,
                                                        max_new=max_new)
            loads = [e.nbytes for e in trace.events()
                     if e.kind == "kv_load" and e.nbytes]
            kv_kb_load = sum(loads) / max(1, len(loads)) / 2**10
            results[(kv_mode, depth)] = step_s
            emit(f"serving_kv_quant_{kv_mode}_d{depth}", step_s * 1e6,
                 f"decode_tok_s={tok_s:.2f};"
                 f"step_ms={step_s * 1e3:.1f};"
                 f"kv_KB_per_load={kv_kb_load:.0f};"
                 f"slab_KB={slab_kb:.0f};"
                 f"util={rep['compute_util']:.2f};"
                 f"bubble={rep['bubble_frac']:.2f}")
    emit("serving_kv_quant_summary", 0.0,
         f"int4_vs_fp32_d1="
         f"{results[('fp32', 1)] / results[('int4', 1)]:.2f}x;"
         f"int4_vs_fp32_d2="
         f"{results[('fp32', 2)] / results[('int4', 2)]:.2f}x;"
         f"fp32_d2_vs_d1={results[('fp32', 1)] / results[('fp32', 2)]:.2f}x;"
         f"int4_d2_vs_d1={results[('int4', 1)] / results[('int4', 2)]:.2f}x")


def pipelined_kv_quant():
    """Batch-generation twin of serving_kv_quant: ``PipelinedLM``'s host
    KV cache now lives in the SAME tiered KV store serving uses, so
    kv_mode {fp32, int4} applies to batch generation too (the PR-6
    unification; before it the engine kept a bespoke fp32 host dict and
    silently ignored --kv-mode).  Depth 1 on the sim link, weights
    pinned INT4 so the decode step is KV-dominated; both arms ship only
    the live (slots, positions) extent, int4 additionally packs it ~6x
    (f32 -> nibbles + group scales) with the dequant on the transfer
    thread.  The derived fields carry the mean traced decode KV_LOAD
    payload vs the full-slab bytes the pre-PR-6 engine would have moved.
    CI smoke runs `pipelined_kv_quant --steps 2`."""
    from repro.serving.spec import EngineSpec, build_lm
    cfg = _bench_cfg(layers=6, d=512, ff=2048)
    batch, prompt_len = 8, 32
    gen = (STEPS + 1) if STEPS else 12
    results = {}
    for kv_mode in ("fp32", "int4"):
        spec = EngineSpec(
            arch=cfg.name, cfg=cfg, offload=True, placement="host",
            pipeline="performance", quant="int4", kv_mode=kv_mode,
            b_max=batch, max_len=prompt_len + gen + 2, depth=1,
            sim_bw=0.3e9, disk_root=f"/tmp/pipo_bench_pkv_{kv_mode}")
        lm = build_lm(spec)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, cfg.vocab_size,
                              (batch, prompt_len)).astype(np.int32)
        toks, stats = lm.generate(prompt, gen_len=gen)
        loads = [e.nbytes for e in lm.trace.events()
                 if e.kind == "kv_load" and e.nbytes]
        kv_kb_load = sum(loads) / max(1, len(loads)) / 2**10
        slab_kb = lm.kvstore.slab_nbytes(0) / 2**10
        step_s = batch / max(1e-9, stats["decode_tok_s"])
        results[kv_mode] = step_s
        emit(f"pipelined_kv_quant_{kv_mode}_d1", step_s * 1e6,
             f"decode_tok_s={stats['decode_tok_s']:.2f};"
             f"step_ms={step_s * 1e3:.1f};"
             f"kv_KB_per_load={kv_kb_load:.0f};"
             f"slab_KB={slab_kb:.0f};"
             f"compute_busy={stats['compute_busy']:.2f}")
    emit("pipelined_kv_quant_summary", 0.0,
         f"int4_vs_fp32_d1={results['fp32'] / results['int4']:.2f}x")


def serving_spec_decode():
    """Speculative decoding through the offload pipeline: k-token
    draft-then-verify vs plain decode on the sim link, weights {fp32,
    int4}.  The verify scores all k+1 positions in ONE ragged pass, so
    a speculative step moves the same weight bytes over the link as a
    plain step but can emit up to k+1 tokens per slot — on a
    weight-dominated link decode tok/s scales with the mean acceptance
    length.  Two proposal sources bound the range: an oracle draft
    replaying the baseline's own emitted stream (acceptance = k, the
    best case) and a seeded random draft (acceptance ~ 0, the overhead
    floor).  Greedy accept/reject keeps the emitted tokens
    bit-identical to the baseline either way — draft quality moves the
    speed, never the text — and the summary row carries a live
    ``bit_exact`` check of exactly that.  CI smoke:
    `serving_spec_decode --steps 2`."""
    from repro.serving import Request
    cfg = _bench_cfg(layers=6, d=512, ff=2048)
    b, prompt_len, k = 8, 32, 3
    max_new = STEPS * (k + 1) if STEPS else 16

    class _OracleDraft:
        """Proposes the recorded baseline stream — full acceptance."""

        def __init__(self, streams):
            self.streams = streams

        def prefill_slot(self, slot, prompt):
            pass

        def propose(self, tokens, pos, kk):
            pos = np.asarray(pos).reshape(-1)
            out = np.zeros((len(pos), kk), np.int32)
            for r, st in enumerate(self.streams):
                # prefill emitted stream[0] while pos still sat at
                # prompt_len, so the next unemitted stream index is
                # pos - prompt_len + 1
                i0 = int(pos[r]) - prompt_len + 1
                for t in range(kk):
                    out[r, t] = st[i0 + t] if 0 <= i0 + t < len(st) else 0
            return out

    class _NoisyDraft:
        """Seeded random proposals — the ~zero-acceptance floor."""

        def __init__(self):
            self.rng = np.random.default_rng(7)

        def prefill_slot(self, slot, prompt):
            pass

        def propose(self, tokens, pos, kk):
            rows = len(np.asarray(pos).reshape(-1))
            return self.rng.integers(0, cfg.vocab_size,
                                     (rows, kk)).astype(np.int32)

    def run(quant, make_draft):
        eng = _serving_engine(cfg, b_max=b, max_len=96, placement="host",
                              sim_bw=0.3e9, pipeline="performance",
                              warm=True, depth=1, quant=quant,
                              fused_int4=bool(quant))
        if make_draft is not None:
            eng.attach_draft(make_draft(), k)
        rng = np.random.default_rng(0)
        for i in range(b):
            eng.submit(Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, (prompt_len,)).astype(np.int32),
                max_new=max_new))
        eng._admit()
        done = []
        eng._decode_step(done)        # untimed jit warm
        t0 = time.perf_counter()
        n0, s0 = eng.stats["tokens_out"], eng.stats["decode_steps"]
        while any(s is not None for s in eng.slots):
            eng._decode_step(done)
        dt = time.perf_counter() - t0
        ntok = eng.stats["tokens_out"] - n0
        nstep = eng.stats["decode_steps"] - s0
        accept = (eng.stats.get("spec_accepted", 0)
                  / max(1, eng.stats.get("spec_steps", 0) * b))
        out = {r.rid: [int(t) for t in r.out] for r in done}
        eng.shutdown()
        return dict(tok_s=ntok / max(1e-9, dt), step_s=dt / max(1, nstep),
                    steps=nstep, accept=accept, out=out)

    results = {}
    for quant in (None, "int4"):
        tag = "int4" if quant else "fp32"
        base = run(quant, None)
        streams = [base["out"][i] for i in range(b)]
        oracle = run(quant, lambda: _OracleDraft(streams))
        noisy = run(quant, _NoisyDraft)
        results[tag] = (base, oracle, noisy)
        for name, r in (("base", base), ("oracle", oracle),
                        ("random", noisy)):
            emit(f"serving_spec_decode_{tag}_{name}", r["step_s"] * 1e6,
                 f"decode_tok_s={r['tok_s']:.2f};"
                 f"step_ms={r['step_s'] * 1e3:.1f};"
                 f"steps={r['steps']};accept={r['accept']:.2f}")
    bit_exact = all(results[t][1]["out"] == results[t][0]["out"]
                    and results[t][2]["out"] == results[t][0]["out"]
                    for t in results)
    emit("serving_spec_decode_summary", 0.0,
         f"k={k};bit_exact={int(bit_exact)};"
         f"oracle_vs_base_fp32="
         f"{results['fp32'][1]['tok_s'] / max(1e-9, results['fp32'][0]['tok_s']):.2f}x;"
         f"oracle_vs_base_int4="
         f"{results['int4'][1]['tok_s'] / max(1e-9, results['int4'][0]['tok_s']):.2f}x;"
         f"random_vs_base_fp32="
         f"{results['fp32'][2]['tok_s'] / max(1e-9, results['fp32'][0]['tok_s']):.2f}x")


def serving_traffic():
    """Traffic subsystem: arrival traces x scheduling policies with
    TTFT/p99 accounting, in two parts.

    Part 1 — policy latency on the deterministic traffic simulator
    (``serving.workload.TrafficSim``, virtual clock, identical numbers
    on any machine): a ramp arrival trace (load building from 0.3 to
    3 req/s) through monolithic prefill vs OnlineSLO (chunk cap 16) vs
    OfflineThroughput.  Monolithic pays a dedicated weight sweep per
    admission; chunked prefill rides the decode batch's sweeps, so
    under queue buildup the chunked policies drain faster: OnlineSLO's
    p99 TTFT lands strictly below monolithic while its chunk cap keeps
    p99 TBT bounded at ~one sweep; OfflineThroughput (whole prompt
    rides one sweep) posts the best tok/s at the worst TBT tail.

    Part 2 — token parity on the REAL engines: the same seeded ramp
    trace served through the offloaded engine under each policy x
    kv_mode {fp32, int4}; chunked prefill must be BIT-IDENTICAL to
    monolithic (any chunk size — the chunk-attention + per-chunk KV
    append path is exact, asserted live in the bit_exact field), with
    wall-clock p99 TTFT reported for scale.  ``--seed`` regenerates
    both parts' workloads; the seed is stamped into every row.  CI
    smoke: `serving_traffic --steps 2`."""
    from repro.core.replay import replay_traffic
    from repro.serving.workload import (SimCosts, TrafficSim, latency_series,
                                        ramp_trace, run_trace)
    from repro.core.tasks import percentile

    # -- part 1: deterministic policy comparison ----------------------------
    sim_trace = ramp_trace(16, 0.3, 3.0, seed=SEED, prompt_len=(24, 48),
                           max_new=8)
    costs = SimCosts(sweep_s=1.0, tok_s=0.02, prefill_tok_s=0.05)
    sims = {}
    for name, sched, chunk in (("monolithic", "monolithic", 0),
                               ("online", "online", 16),
                               ("offline", "offline", 0)):
        r = TrafficSim(sim_trace, b_max=2, sched=sched, chunk=chunk,
                       costs=costs).run()
        lat = r.trace.report()["latency"]
        sims[name] = (r, lat)
        emit(f"serving_traffic_sim_{name}", lat["ttft"]["p99_s"] * 1e6,
             f"ttft_p50_s={lat['ttft']['p50_s']:.2f};"
             f"ttft_p99_s={lat['ttft']['p99_s']:.2f};"
             f"tbt_p99_s={lat['tbt']['p99_s']:.2f};"
             f"tok_s={r.tok_per_s:.2f};sweeps={r.sweeps};seed={SEED}")
    # what-if replay closes the loop: the recorded monolithic traffic
    # re-run under OnlineSLO knobs must equal the live online simulation
    what_if = replay_traffic(sims["monolithic"][0].trace,
                             sched="online", chunk=16)
    replay_ok = (what_if.trace.meta["latency"]
                 == sims["online"][0].trace.meta["latency"])
    p99 = lambda n: sims[n][1]["ttft"]["p99_s"]
    emit("serving_traffic_sim_summary", 0.0,
         f"online_vs_mono_p99="
         f"{p99('online') / max(1e-9, p99('monolithic')):.2f}x;"
         f"online_p99_below_mono={int(p99('online') < p99('monolithic'))};"
         f"offline_tok_s_best="
         f"{int(sims['offline'][0].tok_per_s >= max(sims['monolithic'][0].tok_per_s, sims['online'][0].tok_per_s))};"
         f"replay_matches_live={int(replay_ok)};seed={SEED}")

    # -- part 2: real-engine token parity under traffic ---------------------
    cfg = _bench_cfg()
    n_req = 4
    max_new = (STEPS + 1) if STEPS else 6
    eng_trace = ramp_trace(n_req, 5.0, 50.0, seed=SEED, prompt_len=(6, 12),
                           max_new=max_new, vocab=cfg.vocab_size)
    outs = {}
    for kv_mode in ("fp32", "int4"):
        for name, kw in (("monolithic", dict(sched="monolithic")),
                         ("online", dict(sched="online", prefill_chunk=3)),
                         ("offline", dict(sched="offline"))):
            eng = _serving_engine(cfg, b_max=2, max_len=64,
                                  placement="host", pipeline="performance",
                                  warm=True, depth=1, kv_mode=kv_mode, **kw)
            done = run_trace(eng, eng_trace, time_scale=1e-3)
            lat = latency_series(done)
            outs[(kv_mode, name)] = {r.rid: [int(t) for t in r.out]
                                     for r in done}
            chunks = eng.stats["prefill_chunks"]
            eng.shutdown()
            emit(f"serving_traffic_{kv_mode}_{name}",
                 percentile(lat["ttft"], 99) * 1e6,
                 f"ttft_p99_ms={percentile(lat['ttft'], 99) * 1e3:.1f};"
                 f"tbt_p99_ms={percentile(lat['tbt'], 99) * 1e3:.1f};"
                 f"reqs={len(done)};chunks={chunks};seed={SEED}")
    bit_exact = all(outs[(kv, n)] == outs[(kv, "monolithic")]
                    for kv in ("fp32", "int4")
                    for n in ("online", "offline"))
    emit("serving_traffic_summary", 0.0,
         f"bit_exact={int(bit_exact)};reqs={n_req};seed={SEED}")


def serving_adaptive_depth():
    """AdaptiveDepth vs static windows under RAMPING request load: the
    engine starts near-empty (2 requests) and admits 2 more every 4
    decode steps until all 8 slots have been offered work.  Static
    windows (d in {1,2,3}) pay the same depth throughout; the adaptive
    policy re-sizes between steps from live KV/spill pressure — deep
    while load is light, shrinking as slots fill (the ROADMAP "depth is
    static per engine" gap, measured).

    The device budget is pinned tight (depth-0 peak at the worst case +
    5 MiB of headroom) so the memory model actually binds at this bench
    scale, and quant is INT4 so the per-layer in-flight cost is
    KV-sensitive (packed weights ~1.6 MiB/layer vs a live KV slab
    growing past that) — the regime where a consumer device wants the
    window to breathe: live_depth resolves 8 -> 7 -> 5 -> 2 as the ramp
    fills.  The summary row carries the headline ratios for
    docs/BENCHMARKS.md."""
    from repro.core.memory_model import estimate
    from repro.core.offload import MemoryBudget
    from repro.serving.spec import EngineSpec, create_engine
    cfg = _bench_cfg(layers=6, d=512, ff=2048)
    est0 = estimate(cfg, batch=8, seq=56, p=4, preload=0)
    budget = MemoryBudget(
        device=max(est0.peak_prefill, est0.peak_decode) + (5 << 20))
    results = {}
    for name, kw in (("static_d1", dict(depth=1)),
                     ("static_d2", dict(depth=2)),
                     ("static_d3", dict(depth=3)),
                     ("adaptive", dict(depth_policy="adaptive"))):
        spec = EngineSpec(arch=cfg.name, cfg=cfg, offload=True,
                          placement="host", pipeline="performance",
                          warm=True, quant="int4", b_max=8, max_len=56,
                          sim_bw=0.3e9, **kw)
        eng = create_engine(spec.resolve(budget))
        tok_s, step_s, d_min, d_max, resizes = _serve_ramping(eng)
        results[name] = step_s
        emit(f"serving_adaptive_{name}", step_s * 1e6,
             f"decode_tok_s={tok_s:.2f};step_ms={step_s * 1e3:.1f};"
             f"depth={d_min}..{d_max};resizes={resizes}")
    emit("serving_adaptive_summary", 0.0,
         f"adaptive_vs_d1={results['static_d1'] / results['adaptive']:.2f}x;"
         f"adaptive_vs_d2={results['static_d2'] / results['adaptive']:.2f}x;"
         f"adaptive_vs_d3={results['static_d3'] / results['adaptive']:.2f}x")


def serving_pp():
    """Pipeline-parallel offload (--stages): the layer stack split into
    contiguous stages, each with its own tiered weight/KV store and
    transfer pool over its own sim link — so aggregate host->device
    bandwidth scales with the stage count while activations microbatch
    stage to stage.  Sweeps stages {1, 2, 4} x weights {fp32, int4} on
    the weight-dominated serving_offload shape; each row carries the
    tok/s ratio vs its single-stage arm and a bit_exact column checking
    the staged tokens against the single-stage tokens (staging must be
    a scheduling change only).  CI smoke: `serving_pp --steps 2`."""
    from repro.serving import Request
    cfg = _bench_cfg(layers=6, d=512, ff=2048)
    max_new = (STEPS + 1) if STEPS else 12

    def serve(eng):
        """_serve_steady_state, plus the emitted tokens (for bit_exact)."""
        rng = np.random.default_rng(0)
        for i in range(eng.b_max):
            eng.submit(Request(rid=i, prompt=rng.integers(
                0, eng.cfg.vocab_size, (32,)).astype(np.int32),
                max_new=max_new))
        eng._admit()
        done = []
        eng._decode_step(done)        # warm the jit caches untimed
        t0 = time.perf_counter()
        n0, s0 = eng.stats["tokens_out"], eng.stats["decode_steps"]
        while any(s is not None for s in eng.slots):
            eng._decode_step(done)
        dt = time.perf_counter() - t0
        ntok = eng.stats["tokens_out"] - n0
        nstep = eng.stats["decode_steps"] - s0
        rep = eng.pipeline_report()
        eng.shutdown()
        tokens = {r.rid: tuple(r.out) for r in done}
        return ntok / dt, dt / max(1, nstep), rep, tokens

    base = {}
    for wq in (None, "int4"):
        tag = wq or "fp32"
        for stages in (1, 2, 4):
            kw = dict(pipeline="performance", warm=True, depth=1,
                      stages=stages)
            if wq:
                kw.update(quant=wq, fused_int4=True)
            eng = _serving_engine(cfg, b_max=16, max_len=96,
                                  placement="host", sim_bw=0.3e9, **kw)
            tok_s, step_s, rep, tokens = serve(eng)
            if stages == 1:
                base[tag] = (tok_s, tokens)
            ratio = tok_s / max(1e-9, base[tag][0])
            emit(f"serving_pp_s{stages}_{tag}", step_s * 1e6,
                 f"decode_tok_s={tok_s:.2f};step_ms={step_s * 1e3:.1f};"
                 f"util={rep['compute_util']:.2f};"
                 f"vs_s1={ratio:.2f}x;"
                 f"bit_exact={int(tokens == base[tag][1])}")
            assert tokens == base[tag][1], \
                f"staged tokens diverged at stages={stages} quant={tag}"


def replay_validate():
    """Predicted-vs-measured validation of the trace-replay cost model
    (``core.replay``): each arm serves a warm continuous-batching decode
    workload on the sim link (the serving_offload / serving_kv_quant
    regimes), slices the timed steady-state iteration window out of the
    engine's trace, replays it with UNCHANGED knobs, and reports the
    replay's steady step time against the wall-clock measurement.  The
    residual error is real unmodeled time — per-step engine bookkeeping
    (sampling, numpy round-trips) outside the traced tasks, plus real
    thread-pool queueing the virtual pool idealizes — so the err_pct
    column is the honest accuracy figure for trace-driven resolve
    (strict <10%% bounds are asserted on the deterministic virtual-clock
    workloads in tests/test_replay.py, where wall-clock noise can't
    flake CI).  The depth_pick rows close the loop: the simulated-argmin
    depth from the d=1 recording vs the measured-best static depth
    across the d1/d2 arms.  CI smoke: `replay_validate --steps 2`."""
    from repro.core.replay import best_depth, replay
    cfg = _bench_cfg(layers=6, d=512, ff=2048)
    max_new = (STEPS + 1) if STEPS else 12
    arms = (
        ("offload_warm_fp32_d1", 32,
         dict(pipeline="performance", warm=True, depth=1, b_max=16)),
        ("kv_fp32_d1", 64,
         dict(pipeline="performance", warm=True, depth=1, b_max=8,
              quant="int4", fused_int4=True, kv_mode="fp32")),
        ("kv_fp32_d2", 64,
         dict(pipeline="performance", warm=True, depth=2, b_max=8,
              quant="int4", fused_int4=True, kv_mode="fp32")),
        ("kv_int4_d1", 64,
         dict(pipeline="performance", warm=True, depth=1, b_max=8,
              quant="int4", fused_int4=True, kv_mode="int4")),
        ("kv_int4_d2", 64,
         dict(pipeline="performance", warm=True, depth=2, b_max=8,
              quant="int4", fused_int4=True, kv_mode="int4")),
    )
    measured = {}
    traces = {}
    for name, prompt_len, kw in arms:
        eng = _serving_engine(cfg, max_len=96, placement="host",
                              sim_bw=0.3e9, **kw)
        trace = eng.trace              # survives engine shutdown
        tok_s, step_s, rep, (i0, i1) = _serve_steady_state(
            eng, prompt_len=prompt_len, max_new=max_new)
        res = replay(trace, start_iter=i0, stop_iter=i1)
        err = abs(res.steady_step_s - step_s) / max(1e-9, step_s)
        measured[name] = step_s
        traces[name] = (trace, i0, i1)
        emit(f"replay_validate_{name}", step_s * 1e6,
             f"measured_ms={step_s * 1e3:.1f};"
             f"predicted_ms={res.steady_step_s * 1e3:.1f};"
             f"err_pct={err * 100:.1f};"
             f"steps={i1 - i0}")
    for kv in ("fp32", "int4"):
        trace, i0, i1 = traces[f"kv_{kv}_d1"]
        picked, preds = best_depth(trace, depth_cap=2,
                                   start_iter=i0, stop_iter=i1)
        best_measured = min((1, 2), key=lambda d: measured[f"kv_{kv}_d{d}"])
        emit(f"replay_validate_depth_pick_{kv}", 0.0,
             f"picked_d={picked};measured_best_d={best_measured};"
             f"pred_d1_ms={preds[1] * 1e3:.1f};"
             f"pred_d2_ms={preds[2] * 1e3:.1f};"
             f"agree={int(picked == best_measured)}")


def kernel_int4():
    """§3.4: fused INT4 matmul vs dequantize-then-matmul."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.ref import int4_matmul_ref
    from repro.quant.int4 import dequantize_int4, quantize_int4
    M, K, N = 8, 2048, 2048
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32) * 0.1
    packed, scale = quantize_int4(w)

    fused = jax.jit(int4_matmul_ref)              # dequant fused by XLA

    def unfused(x, packed, scale):
        wd = jax.device_put(np.asarray(dequantize_int4(packed, scale,
                                                       jnp.float32)))
        return x @ wd
    fused(x, packed, scale).block_until_ready()

    reps = 10
    t0 = time.perf_counter()
    for _ in range(reps):
        fused(x, packed, scale).block_until_ready()
    tf = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        unfused(x, packed, scale).block_until_ready()
    tu = (time.perf_counter() - t0) / reps
    emit("kernel_int4_fused", tf * 1e6, f"GFLOPs={2 * M * K * N / tf / 1e9:.1f}")
    emit("kernel_int4_unfused", tu * 1e6, f"gain={tu / tf:.2f}x")


def roofline():
    """Aggregate the dry-run roofline table (reads experiments/dryrun)."""
    d = Path("experiments/dryrun")
    if not d.exists():
        emit("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    n = 0
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        n += 1
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}_{r['variant']}",
             r["t_bound_s"] * 1e6,
             f"bound={r['bottleneck']};mem_gb={r['tpu_bytes_per_device']/2**30:.2f};"
             f"useful={r['flops_useful_ratio']:.2f}")
    emit("roofline_cells_ok", float(n), "")


BENCHES = [fig5_throughput, fig6_blocksize, fig7_transfer, fig8_utilization,
           fig9_ablation, table3_latency, table6_memory, fig12_moe,
           serving_offload, serving_offload_depth, serving_kv_quant,
           pipelined_kv_quant, serving_spec_decode, serving_traffic,
           serving_adaptive_depth, serving_pp, replay_validate,
           kernel_int4, roofline]


def run_spec_scenario(path: str):
    """Ad-hoc serving scenario from an EngineSpec JSON: resolve, build
    through create_engine, and measure steady-state decode — the same
    harness the named serving scenarios use."""
    from repro.serving.spec import EngineSpec, create_engine
    spec = EngineSpec.from_json(Path(path).read_text())
    plan = spec.resolve()
    eng = create_engine(plan)
    tok_s, step_s, rep, _ = _serve_steady_state(eng)
    derived = (f"decode_tok_s={tok_s:.2f};step_ms={step_s * 1e3:.1f};"
               f"engine={plan.engine};placement={plan.placement};"
               f"depth={plan.depth}")
    if rep:
        derived += (f";util={rep['compute_util']:.2f};"
                    f"bubble={rep['bubble_frac']:.2f}")
    emit(f"spec_{plan.arch}{'_scaled' if plan.scaled else ''}",
         step_s * 1e6, derived)


def main(argv=None) -> "int | None":
    import argparse
    by_name = {b.__name__: b for b in BENCHES}
    ap = argparse.ArgumentParser(
        description="PIPO benchmark harness: one function per paper "
                    "table/figure (see docs/BENCHMARKS.md for methodology "
                    "and how to read the output)")
    ap.add_argument("scenarios", nargs="*", metavar="scenario",
                    help="scenario names to run (default: all; see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list scenarios and exit")
    ap.add_argument("--spec-json", metavar="FILE",
                    help="run an ad-hoc serving scenario from an "
                         "EngineSpec JSON (resolve -> create_engine -> "
                         "steady-state decode), then exit")
    ap.add_argument("--steps", type=int, metavar="N",
                    help="decode steps for the KV-streaming, speculative "
                         "and replay scenarios (smoke runs: CI uses "
                         "'serving_kv_quant --steps 2', 'pipelined_kv_quant "
                         "--steps 2', 'serving_spec_decode --steps 2' and "
                         "'replay_validate --steps 2', "
                         "'serving_traffic --steps 2' and "
                         "'serving_pp --steps 2'); other scenarios "
                         "run their documented full length")
    ap.add_argument("--seed", type=int, default=0, metavar="N",
                    help="workload-generation seed (arrival traces, "
                         "prompts); stamped into every serving_traffic "
                         "row so figures name their workload")
    args = ap.parse_args(argv)
    if args.steps is not None and args.steps < 1:
        ap.error(f"--steps must be >= 1, got {args.steps}")
    global STEPS, SEED
    STEPS = args.steps
    SEED = args.seed
    if args.list:
        for b in BENCHES:
            doc = (b.__doc__ or "").strip().splitlines()[0]
            print(f"{b.__name__:20s} {doc}")
        return
    if args.spec_json:
        import json
        from repro.serving.spec import SpecError
        print("name,us_per_call,derived")
        try:
            run_spec_scenario(args.spec_json)
        except (SpecError, OSError, json.JSONDecodeError) as e:
            ap.error(str(e))
        return
    unknown = [n for n in args.scenarios if n not in by_name]
    if unknown:
        ap.error(f"unknown scenario(s) {unknown}; see --list")
    benches = [by_name[n] for n in args.scenarios] if args.scenarios \
        else BENCHES
    print("name,us_per_call,derived")
    failed = []
    for b in benches:
        t0 = time.perf_counter()
        try:
            b()
        except Exception as e:  # keep the harness alive per-table
            emit(f"{b.__name__}_ERROR", 0.0, repr(e)[:120])
            failed.append(b.__name__)
        print(f"# {b.__name__} done in {time.perf_counter()-t0:.1f}s",
              flush=True)
    if failed and args.scenarios:
        # explicitly-requested scenarios must not rot silently (the CI
        # smoke relies on a nonzero exit); full runs stay best-effort
        return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
