#!/usr/bin/env python
"""Regenerate the golden trace fixtures in tests/fixtures/.

Each fixture is a ``Trace.to_json`` dump of a virtual-clock fake-model
run (``tests/fake_model.run_virtual``): fully deterministic — fixed
per-task-type costs/bytes, virtual timeline, no wall clock — so the
files are byte-stable across machines and the replayer's bit-for-bit
regression tests (tests/test_replay.py) can assert against them.

Run after changing the scheduler, the fake model's cost tables, or the
trace schema:  PYTHONPATH=src python tools/make_trace_fixtures.py
(then review the diff — a changed fixture means the recorded schedule
changed, which is exactly what the regression tests exist to catch).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT / "tests"))

FIXTURES = ROOT / "tests" / "fixtures"

# (filename, runner kwargs): a warm depth-1 serving-style pipeline
# (3 calls of 1 iteration — the per-decode-step drain pattern), a warm
# depth-2 window over a longer single call, and a speculative
# draft-then-verify step sequence (runner="spec" dispatches to
# fake_model.run_virtual_spec; a rejection mid-run drops stale KV
# preloads, so the fixture records the truncate-path schedule too)
CASES = (
    ("trace_warm_d1.json",
     dict(mode="performance", n_layers=3, iters=1, warm=True, calls=3,
          depth=1)),
    ("trace_warm_d2.json",
     dict(mode="performance", n_layers=3, iters=4, warm=True, calls=1,
          depth=2)),
    ("trace_spec_d2.json",
     dict(runner="spec", iters=4, n_layers=3, depth=2, reject=(2,))),
    # mixed prefill+decode traffic: steps 1-2 carry a chunked-prefill
    # leg through the same generate() call as the decode batch
    # (runner="traffic" -> fake_model.run_virtual_traffic), recording
    # the shared-WEIGHT_LOAD schedule the traffic tests assert on
    ("trace_traffic_d1.json",
     dict(runner="traffic", n_layers=3, steps=4, depth=1,
          chunk_steps=(1, 2))),
    # 2-stage pipeline-parallel run (runner="pp" ->
    # fake_model.run_virtual_pp): per-stage pools over one trace,
    # stage-tagged events, microbatched handoff — the staged replay
    # path's bit-for-bit golden
    ("trace_pp_s2.json",
     dict(runner="pp", n_layers=3, stages=2, iters=4, depth=1)),
)


def build(kwargs) -> dict:
    from fake_model import (run_virtual, run_virtual_pp, run_virtual_spec,
                            run_virtual_traffic)
    kwargs = dict(kwargs)
    runner = kwargs.pop("runner", "plain")
    fn = {"spec": run_virtual_spec,
          "traffic": run_virtual_traffic,
          "pp": run_virtual_pp}.get(runner, run_virtual)
    _, trace, _ = fn(**kwargs)
    return trace.to_json()


def main() -> int:
    FIXTURES.mkdir(parents=True, exist_ok=True)
    changed = 0
    for name, kwargs in CASES:
        path = FIXTURES / name
        text = json.dumps(build(kwargs), indent=1, sort_keys=True) + "\n"
        if not path.exists() or path.read_text() != text:
            path.write_text(text)
            changed += 1
            print(f"wrote {path.relative_to(ROOT)}")
        else:
            print(f"up-to-date {path.relative_to(ROOT)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
