#!/usr/bin/env python
"""Docs health check: fail CI when the docs rot.

Four checks over README.md and docs/*.md:

1. markdown links: every relative `[text](path)` target exists;
2. inline code paths: every backtick-quoted repo path (`docs/...`,
   `tests/...`, `benchmarks/...`, `src/...`, or a `src/repro`-relative
   module path like `core/pipeline.py`, optionally with a `::symbol`
   suffix) resolves to a real file;
3. quickstart commands: every `PYTHONPATH=src python ...` command found
   in fenced code blocks is executed in --help / --list / compile-only
   form, so a renamed flag or moved entry point fails the check instead
   of rotting silently;
4. CLI flags: every `--flag` token the docs mention (in inline code or
   fenced blocks) must appear in a live `add_argument` definition in the
   repo's CLI sources (`src/repro/launch/*.py`, `benchmarks/*.py`,
   `tests/conftest.py`), in the spec flag table
   (`serving/spec.py::CLI_FLAGS` — `launch.serve` generates its argparse
   from it), or in the small argparse built-in allowlist — a renamed
   serving/benchmark knob fails the check instead of leaving the tuning
   guide pointing at a flag that no longer exists.

Plus structural checks:

5. flag<->spec three-way consistency: `serving.spec.CLI_FLAGS` (the
   single flag<->field table), the LIVE `launch.serve` argparse (built
   via `build_parser()`), and the `EngineSpec` dataclass fields must
   agree — every table flag is a real parser flag, every parser flag is
   either in the table or a declared workload flag, every table field is
   a real spec field, and every spec field is either in the table or in
   the declared no-flag set.  A knob added in one place but not the
   others fails CI.

6. benchmark scenarios: the scenario table in `docs/BENCHMARKS.md` and
   `benchmarks/run.py::BENCHES` must list the same names, both ways — a
   scenario added to the harness without a methodology row (or a
   documented scenario that was renamed/removed) fails CI.

Run locally:  python tools/check_docs.py
"""
from __future__ import annotations

import dataclasses
import os
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)#\s]+)\)")
CODEPATH_RE = re.compile(
    r"`((?:src|docs|tests|benchmarks|examples|tools|core|serving|models|"
    r"quant|launch|kernels|configs)/[A-Za-z0-9_./-]+\.(?:py|md|yml|yaml))"
    r"(?:::[A-Za-z0-9_.]+)?`")
FENCE_RE = re.compile(r"```[a-z]*\n(.*?)```", re.S)
INLINE_CODE_RE = re.compile(r"`([^`\n]+)`")
FLAG_RE = re.compile(r"(?<![\w/-])--[a-z][a-z0-9-]*")
FLAG_DEF_RE = re.compile(
    r"(?:add_argument|addoption)\(\s*['\"](--[a-z][a-z0-9-]*)['\"]")
# where CLI flags are defined (argparse entry points)
CLI_SOURCES = [*sorted((ROOT / "src" / "repro" / "launch").glob("*.py")),
               *sorted((ROOT / "benchmarks").glob("*.py")),
               ROOT / "tests" / "conftest.py"]
# argparse/pytest built-ins the docs may reference without defining
FLAG_ALLOWLIST = {"--help"}


def known_cli_flags():
    flags = set(FLAG_ALLOWLIST)
    for src in CLI_SOURCES:
        flags.update(FLAG_DEF_RE.findall(src.read_text()))
    from repro.serving.spec import CLI_FLAGS
    flags.update(f.flag for f in CLI_FLAGS)
    return flags


def check_spec_cli_consistency(errors: list):
    """Check 5: the flag<->field table vs the LIVE launch.serve argparse
    vs the EngineSpec dataclass, three ways."""
    from repro.launch.serve import build_parser
    from repro.serving.spec import (CLI_FLAGS, NO_FLAG_FIELDS,
                                    WORKLOAD_FLAGS, EngineSpec)
    parser_flags = {s for a in build_parser()._actions
                    for s in a.option_strings if s.startswith("--")}
    table_flags = {f.flag for f in CLI_FLAGS}
    table_fields = [f.field for f in CLI_FLAGS]
    spec_fields = {f.name for f in dataclasses.fields(EngineSpec)}
    for fl in sorted(table_flags - parser_flags):
        errors.append(f"spec table flag {fl} not defined by "
                      f"launch.serve's argparse")
    for fl in sorted(parser_flags - table_flags - WORKLOAD_FLAGS):
        errors.append(f"launch.serve flag {fl} neither in "
                      f"serving.spec.CLI_FLAGS nor WORKLOAD_FLAGS")
    for fd in sorted(set(table_fields) - spec_fields):
        errors.append(f"spec table field {fd!r} is not an EngineSpec "
                      f"dataclass field")
    for fd in sorted(spec_fields - set(table_fields) - NO_FLAG_FIELDS):
        errors.append(f"EngineSpec field {fd!r} has no CLI flag and is "
                      f"not in NO_FLAG_FIELDS")
    dup = {f for f in table_fields if table_fields.count(f) > 1}
    if dup:
        errors.append(f"spec table maps multiple flags to field(s) "
                      f"{sorted(dup)}")


def check_bench_scenarios(errors: list):
    """Check 6: docs/BENCHMARKS.md's scenario table vs
    ``benchmarks/run.py::BENCHES``, both directions.  run.py's top level
    imports numpy/argparse only, so loading it here is cheap."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "_pipo_bench_run", ROOT / "benchmarks" / "run.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    bench_names = {b.__name__ for b in mod.BENCHES}
    # scenario-table rows: the only BENCHMARKS.md table whose first
    # column is a backticked identifier
    table_names = set(re.findall(
        r"^\|\s*`([a-z0-9_]+)`", (ROOT / "docs" / "BENCHMARKS.md")
        .read_text(), re.M))
    for n in sorted(bench_names - table_names):
        errors.append(f"benchmarks/run.py scenario {n!r} has no row in "
                      f"docs/BENCHMARKS.md's scenario table")
    for n in sorted(table_names - bench_names):
        errors.append(f"docs/BENCHMARKS.md scenario `{n}` is not in "
                      f"benchmarks/run.py BENCHES")


def doc_flags(text: str):
    """(flag, snippet) pairs from inline code spans and fenced blocks —
    prose is skipped so an em-dash or option-like phrase can't trip it."""
    out = []
    for block in FENCE_RE.findall(text):
        out += [(f, block.strip().splitlines()[0])
                for f in FLAG_RE.findall(block)]
    for span in INLINE_CODE_RE.findall(FENCE_RE.sub("", text)):
        out += [(f, span) for f in FLAG_RE.findall(span)]
    return out


def resolve_code_path(p: str):
    for base in (ROOT, ROOT / "src" / "repro"):
        if (base / p).exists():
            return base / p
    return None


def extract_commands(block: str):
    """`PYTHONPATH=src python ...` lines, with backslash continuations
    folded in."""
    out = []
    lines = block.splitlines()
    i = 0
    while i < len(lines):
        line = lines[i].strip()
        if line.startswith("PYTHONPATH=src python"):
            cmd = line
            while cmd.endswith("\\") and i + 1 < len(lines):
                i += 1
                cmd = cmd[:-1].rstrip() + " " + lines[i].strip()
            out.append(cmd.split(" # ")[0].rstrip())   # drop trailing comment
        i += 1
    return out


def check_file(md: Path, errors: list, cli_flags: set):
    text = md.read_text()
    rel = md.relative_to(ROOT)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (md.parent / target).exists() and not (ROOT / target).exists():
            errors.append(f"{rel}: dead link -> {target}")
    for m in CODEPATH_RE.finditer(text):
        if resolve_code_path(m.group(1)) is None:
            errors.append(f"{rel}: dead code path -> `{m.group(1)}`")
    for flag, snippet in doc_flags(text):
        if flag not in cli_flags:
            errors.append(f"{rel}: unknown CLI flag {flag} "
                          f"(in `{snippet[:60]}`) — not defined by any "
                          f"argparse source")
    cmds = []
    for block in FENCE_RE.findall(text):
        cmds += extract_commands(block)
    return cmds


def dry_form(cmd: str):
    """Map a quickstart command to a cheap dry invocation (argparse
    --help exits before heavy imports; benchmarks use --list; a serve
    --plan-json command runs AS-IS — resolving the plan without
    building an engine is itself the dry-run, and it exercises the
    whole spec->plan path in docs CI)."""
    argv = cmd.split()
    assert argv[0] == "PYTHONPATH=src" and argv[1] == "python"
    rest = argv[2:]
    if rest[0] == "-m" and rest[1] == "pytest":
        return None                       # running the suite is CI's job
    if rest[0] == "-m" and rest[1] == "repro.launch.serve" \
            and "--plan-json" in rest:
        return [sys.executable, "-m", *rest[1:]]
    if rest[0] == "-m":
        return [sys.executable, "-m", rest[1], "--help"]
    if rest[0].endswith("benchmarks/run.py"):
        return [sys.executable, rest[0], "--list"]
    if rest[0].endswith(".py"):
        # plain script: syntax-check only (examples may run long)
        return [sys.executable, "-m", "py_compile", rest[0]]
    return None


def main() -> int:
    errors: list[str] = []
    commands: list[str] = []
    cli_flags = known_cli_flags()
    check_spec_cli_consistency(errors)
    check_bench_scenarios(errors)
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"missing doc file: {md.relative_to(ROOT)}")
            continue
        commands += check_file(md, errors, cli_flags)
    if not any(md.name == "ARCHITECTURE.md" for md in DOC_FILES):
        errors.append("docs/ARCHITECTURE.md missing")
    if not any(md.name == "BENCHMARKS.md" for md in DOC_FILES):
        errors.append("docs/BENCHMARKS.md missing")

    env = {**os.environ, "PYTHONPATH": "src"}
    seen = set()
    for cmd in commands:
        dry = dry_form(cmd)
        if dry is None or tuple(dry) in seen:
            continue
        seen.add(tuple(dry))
        try:
            r = subprocess.run(dry, cwd=ROOT, capture_output=True,
                               text=True, env=env, timeout=180)
        except subprocess.TimeoutExpired:
            errors.append(f"quickstart dry-run timed out: {' '.join(dry)}")
            continue
        if r.returncode != 0:
            errors.append(f"quickstart dry-run failed ({' '.join(dry)}):\n"
                          f"{r.stderr.strip()[-400:]}")

    if errors:
        print("docs check FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print(f"docs check OK: {len(DOC_FILES)} files, {len(seen)} quickstart "
          f"commands dry-run")
    return 0


if __name__ == "__main__":
    sys.exit(main())
