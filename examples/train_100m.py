"""End-to-end training driver: ~100M-param llama-style model, a few hundred
steps on synthetic data with checkpoint/restart and straggler stats.

  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig
from repro.data import DataConfig, DataPipeline, SyntheticSource
from repro.models import Dist, build_model
from repro.optim import AdamW, apply_updates, cosine_schedule
from repro.runtime.fault_tolerance import RunnerConfig, TrainRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/train100m_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="lm-100m", num_layers=args.layers, d_model=args.d_model,
        num_heads=8, num_kv_heads=4, head_dim=args.d_model // 8,
        d_ff=4 * args.d_model, vocab_size=32000,
        pattern=(LayerSpec(ATTN, DENSE),))
    print(f"params: {cfg.param_count() / 1e6:.1f}M")

    m = build_model(cfg)
    dist = Dist.local()
    opt = AdamW(lr=cosine_schedule(3e-4, warmup=20, total=args.steps),
                weight_decay=0.1)

    def init_state():
        params = m.init(jax.random.PRNGKey(0))
        return params, opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: m.train_loss(p, batch, dist))(params)
        upd, opt_state, gn = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, \
            {"loss": loss, "grad_norm": gn}

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size)
    data = DataPipeline(SyntheticSource(dcfg), dcfg)
    runner = TrainRunner(
        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=50,
                     max_steps=args.steps),
        step, init_state, data)

    t0 = time.time()
    out = runner.run()
    dt = time.time() - t0
    losses = out["losses"]
    toks = args.steps * args.batch * args.seq
    print(f"steps: {out['final_step']}  wall: {dt:.0f}s  "
          f"tok/s: {toks / dt:.0f}")
    print(f"loss: first={losses[0]:.3f} "
          f"mid={losses[len(losses) // 2]:.3f} last={losses[-1]:.3f}")
    print(f"timing: {out['timing']}")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
