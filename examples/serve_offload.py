"""End-to-end serving driver: continuous batching over a small model with
batched requests, ragged decode, and PIPO KV offload at slot granularity.
Engine construction goes through the one declarative path — EngineSpec ->
resolve() -> create_engine (see docs/ARCHITECTURE.md "Execution plans").

  PYTHONPATH=src python examples/serve_offload.py
"""
import time

import numpy as np

from repro.configs import get_config, scaled_down
from repro.serving import EngineSpec, Request, create_engine


def main():
    cfg = scaled_down(get_config("tinyllama-1.1b"), d_model=128,
                      num_heads=8, num_kv_heads=4, vocab_size=1024)
    spec = EngineSpec(arch="tinyllama-1.1b", cfg=cfg, b_max=4, max_len=128)
    plan = spec.resolve()             # placement/engine from the memory model
    print(f"resolved plan      : {plan.summary()}")
    eng = create_engine(plan)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size,
                              (8 + 4 * (i % 4),)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=8 + (i % 5)))
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    dt = time.perf_counter() - t0

    total_new = sum(len(r.out) for r in done)
    ttfts = [r.t_first - r.t_submit for r in done]
    print(f"requests completed : {len(done)}/10")
    print(f"engine stats       : {eng.stats}")
    print(f"decode steps shared: {eng.stats['decode_steps']} "
          f"(vs {total_new} tokens -> "
          f"{total_new / max(1, eng.stats['decode_steps']):.2f} tok/step)")
    print(f"throughput         : {total_new / dt:.1f} tok/s")
    print(f"TTFT p50/p95       : {np.percentile(ttfts, 50):.2f}s / "
          f"{np.percentile(ttfts, 95):.2f}s")
    print(f"KV offloaded (host): {eng.host.bytes_used / 2**20:.1f} MiB")
    for r in done[:3]:
        print(f"  rid={r.rid} prompt_len={len(r.prompt)} out={r.out}")


if __name__ == "__main__":
    main()
