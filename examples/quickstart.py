"""Quickstart: autoconfig -> pipelined offloaded generation (the paper's
Algorithm 2 workflow, end to end, on a laptop-class budget).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config, scaled_down
from repro.core import MemoryBudget, configure
from repro.serving import EngineSpec, build_lm


def main():
    # 1. Pick a model and describe the hardware (paper laptop: 6GB VRAM,
    #    16GB DRAM, NVMe SSD).
    full_cfg = get_config("llama3.1-8b")
    budget = MemoryBudget()

    # 2. Automatic configuration (Eq. 1): weight placement + pipeline mode.
    ac = configure(full_cfg, batch=4, prompt_len=512, gen_len=32,
                   budget=budget, quant="int4")
    est = ac.est
    print("=== PIPO autoconfig (llama3.1-8b, RTX3060-class budget) ===")
    print(f" weights W (bf16)   : {est.weights / 2**30:6.1f} GiB"
          f"   (int4: {est.weights / 4 / 2**30:.1f} GiB)")
    print(f" kv cache C         : {est.kv_cache / 2**30:6.1f} GiB")
    print(f" peak M (prefill)   : {est.peak_prefill / 2**30:6.1f} GiB")
    print(f" placement          : {ac.weight_placement}  ({ac.reason})")
    print(f" pipeline           : {ac.pipeline}")
    print(f" int4 fused kernel  : {ac.use_int4_kernel}")

    # 3. Generate with a reduced same-family model on this CPU container,
    #    using the chosen placement/pipeline.
    cfg = scaled_down(full_cfg, d_model=256, num_heads=8, num_kv_heads=4,
                      d_ff=1024, vocab_size=2048)
    spec = EngineSpec(arch=full_cfg.name, cfg=cfg, offload=True,
                      placement=ac.weight_placement, pipeline=ac.pipeline,
                      b_max=2, max_len=96, depth=ac.preload_depth,
                      quant="int4" if ac.use_int4_kernel else None,
                      disk_root="/tmp/quickstart_disk")
    lm = build_lm(spec)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 32)).astype(np.int32)
    toks, stats = lm.generate(prompt, gen_len=16)
    print("\n=== generation ===")
    print(f" tokens[0]       : {toks[0].tolist()}")
    print(f" throughput      : {stats['throughput_tok_s']:.1f} tok/s")
    print(f" TTFT            : {stats['ttft_s'] * 1e3:.0f} ms")
    print(f" compute busy    : {stats['compute_busy']:.0%}")
    print(f" device peak     : {stats['device_peak_gb']:.3f} GiB")


if __name__ == "__main__":
    main()
