"""Trace schema edge cases + JSON round-trip.

``Trace.report()`` / ``bytes_moved()`` / ``busy_fraction()`` feed the
benchmark harness and the AdaptiveDepth feedback loop, so the degenerate
inputs — empty trace, zero-duration events, unknown task kinds, byte
totals with zero busy time — must yield zeros, not ZeroDivisionErrors.
The JSON round-trip half pins the golden-fixture schema ``core.replay``
consumes (meta + events, extents surviving the tuple<->list hop).
"""
import json

import pytest

from repro.core.tasks import Task, TaskType, Trace, TraceEvent, VirtualClock


def _trace(events=()):
    tr = Trace(clock=VirtualClock())
    tr._events.extend(events)
    return tr


def _ev(kind="compute", name="c[0,0]", t0=0.0, t1=1.0, thread="main",
        nbytes=0, extent=None):
    return TraceEvent(kind, name, t0, t1, thread, nbytes, extent)


# ---------------------------------------------------------------------------
# report() / bytes_moved() edge cases
# ---------------------------------------------------------------------------


def test_empty_trace_report_is_all_zero():
    rep = _trace().report()
    assert rep["span_s"] == 0.0
    assert rep["compute_util"] == 0.0
    assert rep["bubble_s"] == 0.0
    assert rep["bubble_frac"] == 0.0
    for kind in (t.value for t in TaskType):
        pk = rep["per_kind"][kind]
        assert pk == {"busy_s": 0.0, "count": 0, "busy_frac": 0.0,
                      "bytes": 0, "bw_Bps": 0.0}


def test_empty_trace_span_and_busy():
    tr = _trace()
    assert tr.span() == 0.0
    assert tr.busy_time("compute") == 0.0
    assert tr.busy_fraction() == 0.0
    assert tr.bytes_moved("weight_load") == 0


def test_zero_duration_events_no_division_error():
    # a 0-s transfer that still moved bytes: busy time is 0, so the
    # measured bandwidth must clamp to 0.0 instead of dividing by zero
    tr = _trace([_ev(kind="weight_load", name="w[0]", t0=1.0, t1=1.0,
                     thread="pool-0", nbytes=4096)])
    rep = tr.report()
    pk = rep["per_kind"]["weight_load"]
    assert pk["busy_s"] == 0.0
    assert pk["count"] == 1
    assert pk["bytes"] == 4096
    assert pk["bw_Bps"] == 0.0              # the divide-by-zero guard
    assert rep["span_s"] == 0.0             # single instant: no span
    assert rep["compute_util"] == 0.0
    assert tr.bytes_moved("weight_load") == 4096


def test_unknown_task_kind_gets_its_own_bucket():
    tr = _trace([_ev(kind="compute", t0=0.0, t1=2.0),
                 _ev(kind="prefetch", name="pf[0]", t0=0.0, t1=1.0,
                     thread="pool-0", nbytes=100)])
    rep = tr.report()
    # the four schema kinds are always present...
    for kind in (t.value for t in TaskType):
        assert kind in rep["per_kind"]
    # ...and the unknown kind is reported, not silently dropped
    pf = rep["per_kind"]["prefetch"]
    assert pf["count"] == 1
    assert pf["busy_s"] == 1.0
    assert pf["bytes"] == 100
    assert pf["bw_Bps"] == 100.0
    assert tr.bytes_moved("prefetch") == 100


def test_bw_guard_when_bytes_but_no_busy_across_kinds():
    tr = _trace([_ev(kind="kv_load", name="kv[0,0]", t0=3.0, t1=3.0,
                     thread="pool-1", nbytes=7),
                 _ev(kind="compute", t0=0.0, t1=4.0)])
    rep = tr.report()
    assert rep["per_kind"]["kv_load"]["bw_Bps"] == 0.0
    assert rep["per_kind"]["compute"]["busy_frac"] == 1.0


def test_bytes_moved_name_prefix_filter():
    tr = _trace([_ev(kind="weight_load", name="w[u[0][0]/exp[1]]",
                     t0=0, t1=1, nbytes=10),
                 _ev(kind="weight_load", name="w[u[0][0]/exp[2]]",
                     t0=1, t1=2, nbytes=20),
                 _ev(kind="weight_load", name="w[u[1][0]]", t0=2, t1=3,
                     nbytes=40)])
    assert tr.bytes_moved("weight_load") == 70
    assert tr.bytes_moved("weight_load", "w[u[0][0]/exp") == 30


# ---------------------------------------------------------------------------
# to_json / from_json
# ---------------------------------------------------------------------------


def test_json_round_trip_events_meta_and_report():
    tr = _trace([_ev(kind="kv_load", name="kv[2,4]", t0=0.5, t1=2.25,
                     thread="vpool-1", nbytes=640, extent=(2, 7)),
                 _ev(kind="compute", name="c[2,4]", t0=2.25, t1=6.0)])
    tr.meta.update(mode="performance", warm=True, depth=2, n_units=6,
                   pool_size=3, calls=[1, 1], sim_bw=None, quant="int4")
    d = tr.to_json()
    # through an actual JSON string, like a committed fixture
    back = Trace.from_json(json.dumps(d))
    assert back.meta == tr.meta
    assert back.events() == tr.events()     # extent tuple survived
    assert back.events()[0].extent == (2, 7)
    assert back.report() == tr.report()
    assert back.to_json() == d              # stable re-dump


def test_from_json_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown Trace JSON"):
        Trace.from_json({"meta": {}, "events": [], "bogus": 1})


def test_from_json_tolerates_missing_optional_event_fields():
    back = Trace.from_json({"events": [
        {"kind": "compute", "name": "c[0,0]", "t_start": 0.0,
         "t_end": 1.0}]})
    (e,) = back.events()
    assert (e.thread, e.nbytes, e.extent) == ("", 0, None)
    assert back.meta == {}


def test_live_trace_round_trip_through_pool():
    # a trace recorded by the real virtual transport round-trips whole
    from repro.core.pipeline import VirtualPool
    pool = VirtualPool(2, cost_fn=lambda t: 3.0)
    t = Task(TaskType.WEIGHT_LOAD, "w[0]", lambda: "h")
    t.nbytes = 123
    pool.submit(t)
    t.wait()
    back = Trace.from_json(json.dumps(pool.trace.to_json()))
    assert back.events() == pool.trace.events()
    assert back.span() == pool.trace.span() == 3.0
