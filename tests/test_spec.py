"""EngineSpec/ResolvedPlan API: resolution, provenance, JSON round-trip,
CLI parity, deprecation shims, unsupported-model fallback, and the
preload/quant policy seams."""
import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core.offload import MemoryBudget
from repro.serving import (AdaptiveDepth, EngineSpec, OffloadedServingEngine,
                           Pressure, Request, ResolvedPlan, ServingEngine,
                           SpecError, StaticDepth, UnsupportedModelError,
                           build_lm, create_engine)
from repro.serving.spec import (CLI_FLAGS, NO_FLAG_FIELDS, WORKLOAD_FLAGS,
                                preload_policy_for, quant_policy_for)


def _cfg():
    return scaled_down(get_config("tinyllama-1.1b"))


def _spec(**kw):
    kw.setdefault("arch", "tinyllama-1.1b")
    kw.setdefault("scaled", True)
    return EngineSpec(**kw)


# ---------------------------------------------------------------------------
# resolution + provenance + JSON round-trip
# ---------------------------------------------------------------------------


def test_resolved_plan_json_roundtrip():
    plan = _spec(offload=True, b_max=2, max_len=64, quant="int4").resolve()
    js = json.dumps(plan.to_json())
    plan2 = ResolvedPlan.from_json(js)
    assert plan2 == plan
    assert plan2.to_json() == plan.to_json()
    # and a reconstructed plan still resolves to a real config
    assert plan2.model_config() == plan.model_config()


def test_spec_json_roundtrip():
    spec = _spec(offload=True, depth=2, sim_bw=0.5e9)
    assert EngineSpec.from_json(json.dumps(spec.to_json())) == spec


def test_plan_json_rejects_unknown_and_missing_fields():
    plan = _spec().resolve()
    d = plan.to_json()
    d["bogus"] = 1
    with pytest.raises(SpecError):
        ResolvedPlan.from_json(d)
    d = plan.to_json()
    d.pop("depth")
    with pytest.raises(SpecError):
        ResolvedPlan.from_json(d)


def test_provenance_present_for_every_auto_field():
    """Every field left on auto records a non-empty why string."""
    plan = _spec(offload=True, b_max=2, max_len=64).resolve()
    for fld in ("engine", "placement", "warm", "depth", "fused_int4",
                "block_bytes", "disk_root"):
        assert plan.provenance.get(fld), f"no provenance for {fld}"
    # explicit fields say so
    plan2 = _spec(offload=True, placement="disk", depth=2,
                  warm=False).resolve()
    assert plan2.provenance["placement"].startswith("explicit")
    assert plan2.provenance["depth"].startswith("explicit")
    assert plan2.provenance["warm"].startswith("explicit")


def test_resolution_matches_memory_model():
    """The auto depth is the serving_preload_depth the engines used to
    compute inline, and the budget the plan resolved under is recorded."""
    from repro.core.autoconfig import serving_preload_depth
    spec = _spec(offload=True, b_max=2, max_len=64)
    plan = spec.resolve()
    want = serving_preload_depth(_cfg(), b_max=2, max_len=64, spill_cap=32)
    assert plan.depth == want
    assert plan.device_budget == MemoryBudget.device
    tight = MemoryBudget(device=1 << 12, host=1 << 40)
    plan_tight = spec.resolve(tight)
    assert plan_tight.depth == 1
    assert plan_tight.device_budget == 1 << 12


def test_validation_typed_errors():
    with pytest.raises(SpecError):
        _spec(pipeline="warp").resolve()
    with pytest.raises(SpecError):
        _spec(quant="int8").resolve()
    with pytest.raises(SpecError):
        _spec(depth=0).resolve()
    with pytest.raises(SpecError):
        _spec(offload=False, quant="int4").resolve()      # old CLI error
    with pytest.raises(SpecError):
        _spec(depth_policy="adaptive", pipeline="memory").resolve()
    with pytest.raises(SpecError):
        EngineSpec(arch="no-such-arch").resolve()


# ---------------------------------------------------------------------------
# CLI parity: flag table <-> argparse <-> dataclass (the check_docs
# invariant, asserted in-tree so a plain pytest run catches drift)
# ---------------------------------------------------------------------------


def test_cli_flag_table_three_way_parity():
    from repro.launch.serve import build_parser
    parser_flags = {s for a in build_parser()._actions
                    for s in a.option_strings if s.startswith("--")}
    table_flags = [f.flag for f in CLI_FLAGS]
    table_fields = [f.field for f in CLI_FLAGS]
    spec_fields = {f.name for f in dataclasses.fields(EngineSpec)}
    # every serve flag maps to exactly one spec field, or is workload
    assert set(table_flags) <= parser_flags
    assert parser_flags - set(table_flags) - WORKLOAD_FLAGS == set()
    assert len(set(table_flags)) == len(table_flags)
    # and vice versa: every spec field has exactly one flag, or is
    # declared flag-less
    assert set(table_fields) <= spec_fields
    assert spec_fields - set(table_fields) - NO_FLAG_FIELDS == set()
    assert len(set(table_fields)) == len(table_fields)


def test_cli_flags_build_the_spec():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args(
        ["--arch", "tinyllama-1.1b", "--scaled", "--offload",
         "--quant", "int4", "--preload-depth", "2", "--no-warm",
         "--b-max", "2"])
    from repro.serving.spec import spec_from_args
    spec = spec_from_args(args)
    assert spec.quant == "int4" and spec.depth == 2 and spec.warm is False
    assert spec.b_max == 2 and spec.offload is True
    assert spec.max_len == 128           # the CLI's historical default


# ---------------------------------------------------------------------------
# deprecation shims: old kwargs -> identical plans
# ---------------------------------------------------------------------------


def test_legacy_offload_kwargs_shim_identical_plan():
    from repro.serving.spec import reset_deprecation_warnings
    cfg = _cfg()
    spec = EngineSpec(arch=cfg.name, cfg=cfg, offload=True, b_max=2,
                      max_len=64, placement="host", quant="int4", depth=2,
                      fused_int4=True)
    eng = create_engine(spec)
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning):
        leg = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                     placement="host", quant="int4",
                                     depth=2)
    assert leg.plan == eng.plan
    assert leg.plan.to_json() == eng.plan.to_json()
    eng.shutdown()
    leg.shutdown()


def test_legacy_shim_warns_once_per_process():
    """The legacy-kwarg DeprecationWarning is deduped: a serving loop
    constructing shimmed engines warns on the FIRST construction only
    (reset_deprecation_warnings reopens it, for tests)."""
    import warnings as w
    from repro.serving.spec import reset_deprecation_warnings
    cfg = _cfg()
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning):
        OffloadedServingEngine(cfg, b_max=1, max_len=32,
                               placement="host").shutdown()
    with w.catch_warnings():
        w.simplefilter("error", DeprecationWarning)
        OffloadedServingEngine(cfg, b_max=1, max_len=32,
                               placement="host").shutdown()


def test_legacy_pipelined_lm_shim_identical_plan():
    from repro.core.engine import PipelinedLM
    from repro.serving.spec import reset_deprecation_warnings
    cfg = _cfg()
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning):
        leg = PipelinedLM(cfg, batch=2, max_len=32, placement="host")
    spec = EngineSpec(arch=cfg.name, cfg=cfg, offload=True,
                      placement="host", b_max=2, max_len=32, depth=1,
                      disk_root="/tmp/pipo_disk")
    lm = build_lm(spec)
    assert leg.plan == lm.plan
    assert leg.plan.to_json() == lm.plan.to_json()


def test_plan_construction_rejects_stray_kwargs():
    plan = _spec(offload=True, b_max=1, max_len=32).resolve()
    with pytest.raises(TypeError):
        OffloadedServingEngine(plan, b_max=4)


# ---------------------------------------------------------------------------
# unsupported models: typed error + resident fallback
# ---------------------------------------------------------------------------


def test_unsupported_model_typed_error():
    from repro.serving.spec import reset_deprecation_warnings
    whisper = scaled_down(get_config("whisper-base"))
    reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning):
        with pytest.raises(UnsupportedModelError) as ei:
            OffloadedServingEngine(whisper, b_max=1, max_len=32)
    assert ei.value.capability == "enc_dec"


@pytest.mark.parametrize("arch,cap", [("whisper-base", "enc_dec"),
                                      ("qwen2-vl-72b", "embeds_frontend")])
def test_unsupported_falls_back_to_resident_and_serves(arch, cap):
    """The satellite: enc-dec/embeds configs get a serving path again —
    resolve downgrades to the resident engine (recording the failing
    capability) and create_engine serves requests through it."""
    plan = EngineSpec(arch=arch, scaled=True, offload=True, b_max=2,
                      max_len=48).resolve()
    assert plan.engine == "resident"
    assert cap in plan.provenance["engine"]
    eng = create_engine(plan)
    assert isinstance(eng, ServingEngine)
    rng = np.random.default_rng(0)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, eng.cfg.vocab_size, (5 + i,)).astype(np.int32), max_new=4))
    done = eng.run()
    eng.shutdown()
    assert len(done) == 2 and all(len(r.out) == 4 for r in done)


def test_enc_dec_serving_is_deterministic_per_enc_embeds():
    """Whisper serving: same request -> same tokens; different encoder
    frames -> (almost surely) different continuation, i.e. the encoder
    actually participates."""
    cfg = scaled_down(get_config("whisper-base"))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    enc = rng.standard_normal(
        (cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)

    def serve_one(enc_embeds):
        eng = ServingEngine(cfg, b_max=1, max_len=48)
        eng.submit(Request(rid=0, prompt=prompt.copy(), max_new=6,
                           enc_embeds=enc_embeds))
        out = eng.run()[0].out
        eng.shutdown()
        return out

    base = serve_one(None)
    assert serve_one(None) == base            # zero-frame stub is stable
    assert serve_one(enc) != base             # frames reach the decoder


# ---------------------------------------------------------------------------
# policy seams
# ---------------------------------------------------------------------------


def test_static_policy_reproduces_prespec_engine():
    """StaticDepth(D) via the spec path matches the resident engine
    token for token (depth x quant parity matrix rides in
    tests/test_serving_offload.py; this is the spec-path spot check)."""
    cfg = _cfg()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (6 + i,)).astype(np.int32)
               for i in range(3)]

    def serve(eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new=5))
        done = eng.run()
        eng.shutdown()
        return {r.rid: r.out for r in done}

    ref = serve(ServingEngine(cfg, b_max=2, max_len=64))
    eng = create_engine(EngineSpec(arch=cfg.name, cfg=cfg, offload=True,
                                   b_max=2, max_len=64, placement="host",
                                   depth=2))
    assert isinstance(eng.preload_policy, StaticDepth)
    assert serve(eng) == ref


def test_adaptive_policy_token_parity():
    """AdaptiveDepth is a scheduling change only: tokens still match the
    resident engine exactly while the window re-sizes."""
    cfg = _cfg()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (6 + i,)).astype(np.int32)
               for i in range(3)]

    def serve(eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new=5))
        done = eng.run()
        eng.shutdown()
        return {r.rid: r.out for r in done}

    ref = serve(ServingEngine(cfg, b_max=2, max_len=64))
    eng = create_engine(EngineSpec(arch=cfg.name, cfg=cfg, offload=True,
                                   b_max=2, max_len=64, placement="host",
                                   depth_policy="adaptive"))
    assert isinstance(eng.preload_policy, AdaptiveDepth)
    assert serve(eng) == ref
    assert eng.stats["preload_depth"] >= 1


def test_adaptive_policy_responds_to_pressure():
    """More requests in flight / longer contexts / more retained spills
    => a monotonically non-deeper window, bottoming at 1."""
    cfg = get_config("tinyllama-1.1b")            # full size: model binds
    from repro.core.memory_model import estimate
    est0 = estimate(cfg, batch=8, seq=2048, p=4, preload=0)
    budget = MemoryBudget(
        device=max(est0.peak_prefill, est0.peak_decode) + (1 << 30))
    pol = AdaptiveDepth(cfg, b_max=8, max_len=2048, budget=budget)
    d_light = pol.depth(Pressure(active=1, max_pos=16))
    d_mid = pol.depth(Pressure(active=4, max_pos=1024))
    d_heavy = pol.depth(Pressure(active=8, max_pos=2040))
    assert d_light >= d_mid >= d_heavy >= 1
    assert d_light > d_heavy, (d_light, d_mid, d_heavy)
    # host spill saturation forces depth 1 regardless of device headroom
    small_host = MemoryBudget(device=budget.device, host=1 << 28)
    pol2 = AdaptiveDepth(cfg, b_max=8, max_len=2048, budget=small_host)
    assert pol2.depth(Pressure(active=1, max_pos=16, spills=64)) == 1


def test_preload_policy_for_uses_plan_budget():
    plan = _spec(offload=True, depth_policy="adaptive").resolve(
        MemoryBudget(device=123 << 20, host=7 << 30))
    pol = preload_policy_for(plan)
    assert isinstance(pol, AdaptiveDepth)
    assert pol.budget.device == 123 << 20 and pol.budget.host == 7 << 30


def test_build_lm_int4_kv():
    """PipelinedLM streams quantized KV through the tiered store (the
    PR-5 gap, now closed): a kv_mode='int4' host-cache plan builds, and
    the nonsensical combination — int4 KV with a device-resident cache,
    where nothing ever crosses the link — is rejected, not silently
    downgraded (plans are obeyed or refused)."""
    lm = build_lm(_spec(offload=True, b_max=1, max_len=32, kv_mode="int4"))
    assert lm.kv_mode == "int4" and lm.kvstore is not None
    with pytest.raises(SpecError, match="kv_mode"):
        build_lm(_spec(offload=True, b_max=1, max_len=32, kv_mode="int4",
                       cache_on="device"))
    # the default (auto -> fp32) builds fine
    build_lm(_spec(offload=True, b_max=1, max_len=32))


def test_quant_policy_seam():
    import numpy as np
    none = quant_policy_for(None)
    int4 = quant_policy_for("int4")
    assert none.weight_mode is None and none.kv_mode == "fp32"
    assert int4.weight_mode == "int4" and int4.kv_mode == "fp32"
    t = {"w": np.zeros((128, 64), np.float32)}
    assert none.prepare_unit(t) is t
    packed = int4.prepare_unit(t)
    assert "w#q" in packed and "w#s" in packed
    # the kv_mode seam is live: every weight mode composes with INT4 KV
    assert quant_policy_for(None, "int4").kv_mode == "int4"
    assert quant_policy_for("int4", "int4").weight_mode == "int4"
    assert quant_policy_for("int4", None).kv_mode == "fp32"   # auto


# ---------------------------------------------------------------------------
# entry points speak the plan
# ---------------------------------------------------------------------------


def test_serve_plan_json_dry_run(tmp_path, capsys):
    """launch.serve --plan-json resolves and dumps the plan without
    building an engine (the docs-CI dry-run path)."""
    from repro.launch import serve
    out = tmp_path / "plan.json"
    serve.main(["--arch", "tinyllama-1.1b", "--scaled", "--offload",
                "--quant", "int4", "--plan-json", str(out)])
    plan = ResolvedPlan.from_json(out.read_text())
    assert plan.engine == "offloaded" and plan.quant == "int4"
    assert plan.provenance["depth"]


def test_serve_spec_json_base_with_flag_override(tmp_path):
    from repro.launch.serve import build_parser
    from repro.serving.spec import spec_from_args
    f = tmp_path / "spec.json"
    f.write_text(json.dumps(_spec(offload=True, b_max=2,
                                  quant="int4").to_json()))
    args = build_parser().parse_args(["--spec-json", str(f),
                                      "--b-max", "3"])
    spec = spec_from_args(args, base=EngineSpec.from_json(f.read_text()))
    assert spec.quant == "int4"          # from the file
    assert spec.b_max == 3               # flag overrides


# ---------------------------------------------------------------------------
# pipeline-parallel staging: resolution, carve-outs, JSON round-trip
# ---------------------------------------------------------------------------


def test_stages_resolve_to_stage_plan():
    plan = _spec(offload=True, b_max=2, max_len=64, stages=2).resolve()
    assert plan.stages == 2 and plan.stage_axis == "layer"
    assert len(plan.stage_plan) == 2
    lo = 0
    for s, sp in enumerate(plan.stage_plan):
        assert sp.stage == s and sp.layer_lo == lo
        lo = sp.layer_hi
        assert sp.depth >= 1 and sp.device_budget > 0
        assert "1/2 budget split" in sp.why
    assert "stage_plan" in plan.provenance


def test_stage_plan_json_roundtrip():
    """StagePlan entries survive to_json/from_json (rehydrated from
    dicts back to the frozen dataclass)."""
    plan = _spec(offload=True, b_max=2, max_len=64, stages=2).resolve()
    plan2 = ResolvedPlan.from_json(json.dumps(plan.to_json()))
    assert plan2 == plan
    assert plan2.stage_plan == plan.stage_plan
    assert plan2.to_json() == plan.to_json()


def test_stages_default_is_single():
    plan = _spec(offload=True, b_max=2, max_len=64).resolve()
    assert plan.stages == 1 and plan.stage_plan == ()


@pytest.mark.parametrize("arch", ["whisper-base", "qwen2-vl-72b"])
def test_stages_dropped_on_resident_fallback(arch):
    """The satellite carve-out: enc-dec/embeds configs asked to stage
    still fall back to the resident engine, with a typed drop recording
    what happened to the stages request — and still serve."""
    plan = EngineSpec(arch=arch, scaled=True, offload=True, b_max=2,
                      max_len=48, stages=2).resolve()
    assert plan.engine == "resident"
    assert plan.stages == 1 and plan.stage_plan == ()
    assert "dropped (2)" in plan.provenance["stages"]
    eng = create_engine(plan)
    assert isinstance(eng, ServingEngine)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, eng.cfg.vocab_size, (5,)).astype(np.int32), max_new=3))
    done = eng.run()
    eng.shutdown()
    assert len(done) == 1 and len(done[0].out) == 3


def test_stages_dropped_under_sparse_attention():
    """Staging needs a dense global-attention stack (sliding-window
    layers read cross-stage history) — a mixtral-style config drops the
    request with provenance instead of mis-serving."""
    plan = _spec(arch="mixtral-8x7b", offload=True, b_max=2, max_len=64,
                 stages=2).resolve()
    assert plan.stages == 1
    assert "dense global-attention" in plan.provenance["stages"]


def test_stages_validation():
    with pytest.raises(ValueError, match="stages"):
        _spec(offload=True, stages=0).validate()
    with pytest.raises(ValueError, match="stage_axis"):
        _spec(offload=True, stages=2, stage_axis="tensor").validate()
