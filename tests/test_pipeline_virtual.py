"""PipelineScheduler ordering invariants on the virtual clock.

Unlike tests/test_pipeline.py (real threads + sleeps), these drive the
real scheduler through ``VirtualPool``: execution is single-threaded and
deterministic, timestamps are virtual, and every assertion is on Trace
event order — the invariants hold on every run by construction, not
probabilistically.
"""
import pytest

from fake_model import (COSTS, DRAFT_NAME, FakeMoEModel, run_virtual,
                        run_virtual_moe, run_virtual_spec)
from repro.core.tasks import TaskType


def _by_name(trace):
    """name -> list of events in submission order (w[j]/c[i,j] repeat
    across iterations; kv/sv names are unique per (i, j))."""
    out = {}
    for e in trace.events():
        out.setdefault(e.name, []).append(e)
    return out


def _one(ev_map, name):
    evs = ev_map[name]
    assert len(evs) == 1, f"{name} expected once, got {len(evs)}"
    return evs[0]


@pytest.mark.parametrize("mode", ["performance", "memory", "sequential"])
def test_virtual_run_is_deterministic(mode):
    runs = []
    for _ in range(2):
        model, trace, outs = run_virtual(mode, n_layers=3, iters=3)
        assert outs == [model.n] * 3
        runs.append(([(e.kind, e.name, e.t_start, e.t_end, e.thread)
                      for e in trace.events()], list(model.calls)))
    assert runs[0] == runs[1], "virtual schedule not reproducible"


@pytest.mark.parametrize("mode", ["performance", "memory", "sequential"])
def test_all_tasks_execute_in_every_mode_virtual(mode):
    model, trace, outs = run_virtual(mode, n_layers=3, iters=2)
    ev = _by_name(trace)
    for i in range(2):
        for j in range(model.n):
            assert [e for e in ev[f"c[{i},{j}]"]], (i, j)
            if model.is_mha(j):
                assert f"kv[{i},{j}]" in ev
                assert f"sv[{i},{j}]" in ev


def test_performance_mode_preloads_next_layer_during_compute():
    """Performance invariant (§3.1.2): while layer j computes in iteration
    i, layer j+1's weight load is already in flight — the load's virtual
    interval overlaps the compute's."""
    model, trace, _ = run_virtual("performance", n_layers=4, iters=2)
    ev = _by_name(trace)
    n = model.n
    for i in range(2):
        for j in range(n - 1):
            c = _one(ev, f"c[{i},{j}]")
            loads = ev[f"w[{j + 1}]"]
            assert any(w.t_start < c.t_end and w.t_end > c.t_start
                       for w in loads), \
                f"w[{j+1}] not in flight during c[{i},{j}]"


def test_performance_mode_weight_load_starts_at_compute_start():
    """Stronger form: the preload is submitted *before* the compute task
    runs, so its virtual start is <= the compute's start."""
    model, trace, _ = run_virtual("performance", n_layers=3, iters=1)
    ev = _by_name(trace)
    for j in range(model.n - 1):
        c = _one(ev, f"c[0,{j}]")
        w = ev[f"w[{j + 1}]"][0]
        assert w.t_start <= c.t_start


def test_kv_save_completes_before_next_iteration_load_all_modes():
    """KV-save(i-1, j) must complete before KV-load(i, j) starts — the
    paper's advanced-by-one-layer completion check (§3.2.1)."""
    for mode in ("performance", "memory", "sequential"):
        model, trace, _ = run_virtual(mode, n_layers=3, iters=3)
        ev = _by_name(trace)
        for i in range(1, 3):
            for j in range(model.n):
                if not model.is_mha(j):
                    continue
                save = _one(ev, f"sv[{i - 1},{j}]")
                load = _one(ev, f"kv[{i},{j}]")
                assert save.t_end <= load.t_start, \
                    (mode, i, j, save.t_end, load.t_start)


def test_memory_mode_holds_single_layer_resident():
    """Memory invariant: layer j+1's weight load starts only after layer
    j's compute finished (previous layer's memory released) — never two
    weight buffers in flight."""
    model, trace, _ = run_virtual("memory", n_layers=3, iters=2)
    ev = _by_name(trace)
    for i in range(2):
        for j in range(model.n - 1):
            c = _one(ev, f"c[{i},{j}]")
            w = ev[f"w[{j + 1}]"][i]          # i-th load = iteration i
            assert w.t_start >= c.t_end, \
                f"memory mode preloaded w[{j+1}] during c[{i},{j}]"
    # weight loads never overlap each other either
    loads = sorted([e for e in trace.events() if e.kind == "weight_load"],
                   key=lambda e: e.t_start)
    for a, b in zip(loads, loads[1:]):
        assert b.t_start >= a.t_end


def test_memory_mode_syncs_kv_save():
    """Memory invariant: each KV-save completes before the pipeline moves
    on (next task on the main thread starts after the save ends)."""
    model, trace, _ = run_virtual("memory", n_layers=3, iters=2)
    ev = _by_name(trace)
    for i in range(2):
        for j in range(model.n):
            if not model.is_mha(j):
                continue
            save = _one(ev, f"sv[{i},{j}]")
            nxt = (f"c[{i},{j + 1}]" if j + 1 < model.n
                   else (f"c[{i + 1},0]" if i + 1 < 2 else None))
            if nxt is None:
                continue
            nxt_ev = _one(ev, nxt)
            assert save.t_end <= nxt_ev.t_start, (i, j)


def test_sequential_mode_fully_serializes():
    """Sequential baseline: no two task intervals overlap at all (FlexGen
    device-level sync)."""
    model, trace, _ = run_virtual("sequential", n_layers=3, iters=2)
    evs = sorted(trace.events(), key=lambda e: (e.t_start, e.t_end))
    for a, b in zip(evs, evs[1:]):
        assert b.t_start >= a.t_end, (a.name, b.name)


def test_performance_beats_sequential_on_virtual_makespan():
    """The pipeline's raison d'etre, asserted on virtual time: overlapping
    transfers with compute strictly shrinks the makespan."""
    _, t_perf, _ = run_virtual("performance", n_layers=4, iters=3)
    _, t_seq, _ = run_virtual("sequential", n_layers=4, iters=3)
    assert t_perf.span() < t_seq.span()
    assert (t_perf.busy_fraction("compute")
            > t_seq.busy_fraction("compute"))


# ---------------------------------------------------------------------------
# Warm pipeline: cross-call ("cross decode step") preloading
# ---------------------------------------------------------------------------


def test_warm_pipeline_preloads_next_call_first_weight():
    """Warm invariant (the serving tentpole): with warm=True, two
    single-iteration generate() calls behave like one continuous pipeline
    — call t+1's w[0] load is in flight during call t's tail compute, so
    call t+1 starts with zero cold-start weight bubble."""
    model, trace, _ = run_virtual("performance", n_layers=2, iters=1,
                                  warm=True, calls=2)
    ev = _by_name(trace)
    n = model.n
    tail_c = _one(ev, f"c[0,{n - 1}]")         # call 0's tail compute
    w0_loads = ev["w[0]"]
    # one per call plus the final call's dangling preload for a call that
    # never arrives (steady-state serving amortizes that single load)
    assert len(w0_loads) == 3
    preload = w0_loads[1]                      # call 1's w[0]
    assert preload.t_start <= tail_c.t_start, \
        "cross-step w[0] preload not submitted before the tail compute"
    assert preload.t_start < tail_c.t_end and \
        preload.t_end > tail_c.t_start, \
        "cross-step w[0] preload does not overlap the tail compute"
    # call 1's first compute starts without waiting a full weight load:
    # the preload completed (or mostly completed) during call 0's tail.
    c10 = _one(ev, f"c[1,0]")
    assert c10.t_start >= preload.t_end        # sync honored
    assert c10.t_start - tail_c.t_end < COSTS[TaskType.WEIGHT_LOAD], \
        "warm call still paid a full cold w[0] load after the tail"


def test_warm_pipeline_preloads_next_call_first_kv():
    """The first KV load of call t+1 is likewise pre-submitted during
    call t's tail compute, after call t's save of the same layer."""
    model, trace, _ = run_virtual("performance", n_layers=2, iters=1,
                                  warm=True, calls=2)
    ev = _by_name(trace)
    n = model.n
    tail_c = _one(ev, f"c[0,{n - 1}]")
    kv_pre = _one(ev, "kv[1,0]")               # call 1's first KV load
    sv_prev = _one(ev, "sv[0,0]")
    assert kv_pre.t_start <= tail_c.t_start
    assert sv_prev.t_end <= kv_pre.t_start, \
        "preloaded KV overtook the previous call's save of the same layer"


def test_warm_beats_cold_on_virtual_makespan():
    """The bubble being shaved is real virtual time: N warm single-token
    calls finish strictly earlier than N cold ones."""
    _, t_warm, _ = run_virtual("performance", n_layers=3, iters=1,
                               warm=True, calls=4)
    _, t_cold, _ = run_virtual("performance", n_layers=3, iters=1,
                               warm=False, calls=4)
    assert t_warm.span() < t_cold.span()


def test_warm_pipeline_tokens_match_cold():
    """Warm is a scheduling change only: outputs are identical."""
    m_w, _, outs_w = run_virtual("performance", n_layers=3, iters=2,
                                 warm=True, calls=3)
    m_c, _, outs_c = run_virtual("performance", n_layers=3, iters=2,
                                 warm=False, calls=3)
    assert outs_w == outs_c == [m_w.n] * 2


def test_warm_disabled_for_memory_and_sequential():
    """Memory mode's single-layer-residency (and sequential's full
    serialization) forbid cross-call preloads: warm is a no-op there."""
    from repro.core.pipeline import PipelineScheduler
    for mode in ("memory", "sequential"):
        assert not PipelineScheduler(4, mode, warm=True).warm


# ---------------------------------------------------------------------------
# Depth-D preload window
# ---------------------------------------------------------------------------


def _paired_residency(model, trace):
    """[(position, load_event, release_t)] pairing each weight load with
    the compute that consumes it (the k-th w[j] event belongs to global
    iteration k; release = that compute's end).  Dangling warm preloads
    (no compute ever consumed them) are skipped."""
    ev = _by_name(trace)
    out = []
    for j in range(model.n):
        for k, w in enumerate(ev.get(f"w[{j}]", [])):
            name = f"c[{k},{j}]"
            if name in ev:
                out.append((k * model.n + j, w, _one(ev, name).t_end))
    return sorted(out, key=lambda p: p[0])


def test_depth_window_loads_start_in_stack_order():
    """No preload overtakes an unevicted resident layer: weight loads
    start in schedulable-position order even when ``depth`` of them are
    in flight across the transfer workers."""
    model, trace, _ = run_virtual("performance", n_layers=4, iters=2,
                                  depth=3)
    starts = [w.t_start for _, w, _ in _paired_residency(model, trace)]
    assert starts == sorted(starts)


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_depth_window_bounds_weight_residency(depth):
    """At most depth+1 weight buffers are ever resident (interval = load
    start -> consuming compute's end, when the layer is released), and a
    deep window actually reaches that bound — the depth knob is real."""
    model, trace, _ = run_virtual("performance", n_layers=4, iters=3,
                                  depth=depth)
    events = []
    for _, w, release in _paired_residency(model, trace):
        events.append((w.t_start, 1))
        events.append((release, -1))
    cur = peak = 0
    for _, delta in sorted(events):      # (t, -1) sorts before (t, +1)
        cur += delta
        peak = max(peak, cur)
    assert peak <= depth + 1, f"depth {depth} held {peak} layers resident"
    assert peak == depth + 1, f"depth {depth} window never filled ({peak})"


def test_depth_tokens_and_call_order_match_depth1():
    """Depth is a scheduling change only: outputs and the compute call
    sequence are identical at every depth."""
    ref, _, ref_outs = run_virtual("performance", n_layers=3, iters=2,
                                   depth=1)
    ref_computes = [c for c in ref.calls if c[0] == "compute"]
    for depth in (2, 3, 5):
        m, _, outs = run_virtual("performance", n_layers=3, iters=2,
                                 depth=depth)
        assert outs == ref_outs == [m.n] * 2
        assert [c for c in m.calls if c[0] == "compute"] == ref_computes


def test_kv_save_before_load_holds_at_depth():
    """The save(i-1,j)-before-load(i,j) invariant survives deep windows:
    a KV preload is deferred until the save it trails has been issued
    (structural n-1 bound) and completed (non-blocking skip)."""
    model, trace, _ = run_virtual("performance", n_layers=3, iters=3,
                                  depth=4)
    ev = _by_name(trace)
    for i in range(1, 3):
        for j in range(model.n):
            if not model.is_mha(j):
                continue
            save = _one(ev, f"sv[{i - 1},{j}]")
            for load in ev[f"kv[{i},{j}]"]:
                assert save.t_end <= load.t_start, (i, j)


def test_warm_depth2_beats_warm_depth1_beats_cold():
    """The acceptance-criterion shape on the virtual clock: a deeper
    warm window strictly shrinks the makespan of a decode-step sequence
    (weight-dominated costs; 3 virtual transfer slots)."""
    spans = {}
    for depth in (1, 2, 3):
        _, t, _ = run_virtual("performance", n_layers=3, iters=1,
                              warm=True, calls=4, depth=depth)
        spans[depth] = t.span()
    _, t_cold, _ = run_virtual("performance", n_layers=3, iters=1,
                               warm=False, calls=4, depth=1)
    assert spans[2] < spans[1] < t_cold.span()
    assert spans[3] <= spans[2]


def test_warm_depth_window_preloads_next_call_layers():
    """With depth=3 the tail of call t has the next call's first THREE
    weight loads in flight before the tail compute finishes — not just
    w[0]."""
    model, trace, _ = run_virtual("performance", n_layers=3, iters=1,
                                  warm=True, calls=2, depth=3)
    ev = _by_name(trace)
    tail_c = _one(ev, f"c[0,{model.n - 1}]")
    for j in range(3):
        loads = ev[f"w[{j}]"]
        assert len(loads) >= 2, f"w[{j}] not preloaded for call 1"
        assert loads[1].t_start <= tail_c.t_end, \
            f"w[{j}] preload missed call 0's tail window"


def test_drop_kv_preloads_discards_all_depth_preloads():
    """depth > 1 leaves SEVERAL cross-call KV preloads pending at a warm
    call's tail; drop_kv_preloads must discard all of them, and the next
    call must reload fresh while still honoring save-before-load."""
    from repro.core.pipeline import PipelineScheduler, VirtualPool
    from fake_model import FakeModel, cost_fn
    model = FakeModel(3)
    pool = VirtualPool(3, cost_fn=cost_fn)
    sched = PipelineScheduler(model.n, "performance", pool=pool,
                              trace=pool.trace, warm=True, depth=4)
    outs = sched.generate(model, lambda i: 0, 1)
    assert len(sched._kv_tasks) >= 2, \
        "depth-4 warm tail should leave multiple KV preloads in flight"
    sched.drop_kv_preloads()
    assert not sched._kv_tasks
    outs2 = sched.generate(model, lambda i: 0, 1)
    assert outs2 == outs
    sched.shutdown()
    ev = _by_name(pool.trace)
    for j in range(model.n):
        if not model.is_mha(j):
            continue
        save = _one(ev, f"sv[0,{j}]")
        loads = ev[f"kv[1,{j}]"]       # dropped preload + fresh reload
        assert loads and all(save.t_end <= l.t_start for l in loads), j


def _residency_peak(model, trace, positions=None):
    """Peak simultaneously-resident weight buffers over the paired
    load->release intervals (optionally restricted to a set of
    schedulable positions)."""
    events = []
    for pos, w, release in _paired_residency(model, trace):
        if positions is not None and pos not in positions:
            continue
        events.append((w.t_start, 1))
        events.append((release, -1))
    cur = peak = 0
    for _, delta in sorted(events):      # (t, -1) sorts before (t, +1)
        cur += delta
        peak = max(peak, cur)
    return peak


def test_set_depth_resizes_window_between_calls():
    """The AdaptiveDepth hook: ``set_depth`` between warm generate()
    calls re-sizes the window — growth takes effect immediately, and
    after a shrink the steady state honors the NEW depth+1 residency
    bound (in-flight wide-window loads drain through the transition
    call)."""
    from fake_model import FakeModel, cost_fn
    from repro.core.pipeline import PipelineScheduler, VirtualPool
    model = FakeModel(3)                       # 6 schedulable positions
    pool = VirtualPool(6, cost_fn=cost_fn)
    sched = PipelineScheduler(model.n, "performance", pool=pool,
                              trace=pool.trace, warm=True, depth=3)
    outs = [sched.generate(model, lambda i: 0, 1)]
    assert sched.set_depth(1) == 1
    outs.append(sched.generate(model, lambda i: 0, 1))   # transition call
    outs.append(sched.generate(model, lambda i: 0, 1))   # steady at d=1
    sched.shutdown()
    n = model.n
    # whole run never exceeded the WIDE bound...
    assert _residency_peak(model, pool.trace) <= 3 + 1
    # ...and the steady-state call at depth 1 honors the narrow one
    # (its loads: positions 2n..3n-1 plus the next call's dangling
    # preload, which _paired_residency drops as unconsumed)
    steady = set(range(2 * n, 3 * n))
    assert _residency_peak(model, pool.trace, steady) <= 1 + 1
    assert outs[0] == outs[1] == outs[2]       # scheduling change only


def test_adaptive_depth_scheduler_pressure_run():
    """The acceptance-criterion shape on the virtual clock: drive the
    scheduler across warm calls while an AdaptiveDepth-style controller
    shrinks the window under ramping pressure (3 -> 2 -> 1); every
    post-shrink steady call stays within its depth+1 residency bound and
    tokens never change."""
    from fake_model import FakeModel, cost_fn
    from repro.core.pipeline import PipelineScheduler, VirtualPool
    model = FakeModel(3)
    pool = VirtualPool(6, cost_fn=cost_fn)
    sched = PipelineScheduler(model.n, "performance", pool=pool,
                              trace=pool.trace, warm=True, depth=3)
    outs = []
    schedule = [3, 3, 2, 2, 1, 1]              # depth per decode step
    for d in schedule:
        sched.set_depth(d)
        outs.append(sched.generate(model, lambda i: 0, 1))
    sched.shutdown()
    assert all(o == outs[0] for o in outs)
    n = model.n
    assert _residency_peak(model, pool.trace) <= max(schedule) + 1
    for call, d in enumerate(schedule[1:], start=1):
        # calls whose PRELOADS were issued at depth d (the previous
        # call's tail ran after set_depth(d)) must fit d+1
        if schedule[call - 1] == d:
            span = set(range(call * n, (call + 1) * n))
            assert _residency_peak(model, pool.trace, span) <= d + 1, \
                (call, d)


def test_moe_union_invariant_holds_at_depth():
    """Deep weight windows don't disturb routed-union expert streaming:
    per (iteration, MoE unit) exactly the routed union loads, once."""
    model, trace, _ = run_virtual_moe("performance", n_layers=2, iters=2,
                                      depth=3)
    for i in range(2):
        for j in range(model.n):
            if not model.is_moe(j):
                continue
            loaded = [e for (ii, jj, e) in model.expert_loads
                      if (ii, jj) == (i, j)]
            assert loaded == model.routed(i, j), (i, j, loaded)


# ---------------------------------------------------------------------------
# Speculative draft-then-verify schedule
# ---------------------------------------------------------------------------


def test_spec_prime_streams_weights_during_draft():
    """The speculative overlap, on the virtual clock: a cold step's
    ``prime_weights`` pre-submits the verify pass's first ``depth``
    weight loads, and their transfer intervals overlap the draft's
    main-thread compute — the otherwise-idle link streams the target
    while the draft proposes."""
    model, trace, steps = run_virtual_spec(iters=3, depth=2)
    ev = _by_name(trace)
    d0, d1 = steps[0]["draft"]
    assert steps[0]["primed"] == 2
    for j in range(2):
        w = ev[f"w[{j}]"][0]
        assert w.t_start <= d0, f"w[{j}] primed after the draft started"
        assert w.t_start < d1 and w.t_end > d0, \
            f"w[{j}] does not stream during the draft compute"
    # a warm tail already has the next verify's window in flight:
    # priming is a no-op on every later step
    assert [s["primed"] for s in steps[1:]] == [0, 0]
    assert all(s["outs"] == [model.n] for s in steps)


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_spec_residency_bound_holds_at_depth(depth):
    """Priming the verify pass never over-fills the window: across a
    run of speculative steps at most depth+1 weight buffers are ever
    resident, same bound as plain decode."""
    model, trace, _ = run_virtual_spec(iters=4, depth=depth)
    peak = _residency_peak(model, trace)
    assert 0 < peak <= depth + 1, \
        f"spec steps at depth {depth} held {peak} layers resident"


def test_spec_reject_drops_stale_kv_preloads():
    """A rejection invalidates rows the warm tail's KV preloads already
    priced: the engines drain saves and drop the preloads, and the next
    step's fresh reload still honors save-before-load.  Outputs are
    untouched — rejection is KV/scheduling bookkeeping only."""
    model, trace, steps = run_virtual_spec(iters=3, depth=2, reject=(1,))
    ev = _by_name(trace)
    for j in range(model.n):
        if not model.is_mha(j):
            continue
        save = _one(ev, f"sv[1,{j}]")
        loads = ev[f"kv[2,{j}]"]
        assert loads, f"kv[2,{j}] never reloaded after the drop"
        assert all(save.t_end <= l.t_start for l in loads), j
    # the warm tail's preload of kv[2,0] ran before the drop; the fresh
    # reload is a second event — both on the trace
    assert len(ev["kv[2,0]"]) == 2
    # and a no-reject run issues it exactly once
    _, t2, _ = run_virtual_spec(iters=3, depth=2)
    assert len(_by_name(t2)["kv[2,0]"]) == 1
    assert [s["outs"] for s in steps] == [[model.n]] * 3


def test_spec_schedule_matches_plain_decode_structure():
    """The verify pass is ONE trip through the layer stack: per step the
    scheduler runs the same w/kv/sv/c task sequence as a plain warm
    decode step, with only the draft COMPUTE events added."""
    _, trace_s, _ = run_virtual_spec(iters=3, depth=1)
    _, trace_p, _ = run_virtual("performance", n_layers=3, iters=1,
                                warm=True, calls=3, depth=1)
    named = lambda t: sorted(e.name for e in t.events()
                             if not e.name.startswith(DRAFT_NAME))
    assert named(trace_s) == named(trace_p)
    drafts = [e for e in trace_s.events() if e.name.startswith(DRAFT_NAME)]
    assert len(drafts) == 3
    assert all(e.kind == "compute" for e in drafts)


# ---------------------------------------------------------------------------
# MoE routed-union expert streaming
# ---------------------------------------------------------------------------


def test_moe_union_loads_only_routed_experts():
    """Only the routed union's experts are loaded per (iteration, MoE
    unit) — never the whole bank — and each exactly once."""
    model, trace, _ = run_virtual_moe("performance", n_layers=2, iters=2)
    for i in range(2):
        for j in range(model.n):
            if not model.is_moe(j):
                continue
            loaded = [e for (ii, jj, e) in model.expert_loads
                      if (ii, jj) == (i, j)]
            assert loaded == model.routed(i, j), (i, j, loaded)
            assert len(loaded) < model.n_experts       # union < bank


def test_moe_union_load_bytes_below_bank_bytes():
    """The acceptance-criterion form: expert WEIGHT_LOAD bytes on the
    trace equal union-size * per-expert bytes — strictly below the
    whole-bank volume a naive loader would move."""
    model, trace, _ = run_virtual_moe("performance", n_layers=2, iters=2)
    n_union = sum(len(model.routed(i, j)) for i in range(2)
                  for j in range(model.n) if model.is_moe(j))
    n_bank = sum(model.n_experts for i in range(2)
                 for j in range(model.n) if model.is_moe(j))
    got = trace.bytes_moved("weight_load", "exp[")
    assert got == n_union * FakeMoEModel.EXPERT_NBYTES
    assert got < n_bank * FakeMoEModel.EXPERT_NBYTES


def test_moe_expert_loads_overlap_unit_compute():
    """Expert loads are submitted from inside the MoE unit's compute
    (after the gate) and stream while it runs — their intervals start
    within the compute window, not after it."""
    model, trace, _ = run_virtual_moe("performance", n_layers=2, iters=1)
    ev = _by_name(trace)
    for j in range(model.n):
        if not model.is_moe(j):
            continue
        c = _one(ev, f"c[0,{j}]")
        for e in model.routed(0, j):
            w = _one(ev, f"exp[{j}][{e}]")
            assert c.t_start <= w.t_start <= c.t_end, (j, e)


def test_trace_report_accounts_per_kind_bytes_and_extents():
    """Per-kind byte totals on the trace are exact: every task kind's
    reported bytes equal count x the model's per-payload constant —
    including KV_SAVE, which used to go unaccounted (the quantized-KV
    accounting satellite) — and KV_LOAD events carry the live extent."""
    from fake_model import KV_EXTENT, NBYTES
    model, trace, _ = run_virtual("performance", n_layers=3, iters=3)
    rep = trace.report()
    for kind in (TaskType.WEIGHT_LOAD, TaskType.KV_LOAD, TaskType.KV_SAVE):
        pk = rep["per_kind"][kind.value]
        assert pk["count"] > 0
        assert pk["bytes"] == pk["count"] * NBYTES[kind], kind
        # measured per-kind bandwidth is derivable from the same trace
        assert pk["bw_Bps"] == pytest.approx(pk["bytes"] / pk["busy_s"])
    kv_loads = [e for e in trace.events() if e.kind == "kv_load"]
    assert kv_loads and all(e.extent == KV_EXTENT for e in kv_loads)
    weight = [e for e in trace.events() if e.kind == "weight_load"]
    assert all(e.extent is None for e in weight)


def test_trace_report_accounts_busy_time():
    model, trace, _ = run_virtual("sequential", n_layers=2, iters=1)
    rep = trace.report()
    # sequential: span is exactly the sum of all task durations
    n_mha = sum(1 for j in range(model.n) if model.is_mha(j))
    expect = (model.n * (COSTS[TaskType.WEIGHT_LOAD]
                         + COSTS[TaskType.COMPUTE])
              + n_mha * (COSTS[TaskType.KV_LOAD] + COSTS[TaskType.KV_SAVE]))
    assert abs(rep["span_s"] - expect) < 1e-9
    assert abs(rep["per_kind"]["compute"]["busy_s"]
               - model.n * COSTS[TaskType.COMPUTE]) < 1e-9
    assert rep["bubble_s"] > 0
    assert abs(rep["compute_util"] + rep["bubble_frac"] - 1.0) < 1e-9
