"""core.replay: deterministic trace replay + simulated depth argmin.

All workloads here run on the virtual clock (tests/fake_model.py), so
every assertion is exact — no wall-clock, no tolerance fudging except
where the ISSUE's <10% predicted-vs-measured criterion is itself the
contract.  Coverage:

  * golden-fixture regression: replaying a committed recording with
    unchanged knobs reproduces its step times AND its full event
    multiset bit-for-bit (plus a freshness check that the fixtures
    still match what tools/make_trace_fixtures.py would emit);
  * property tests (hypothesis, skipped when not installed): replay is
    deterministic across runs, monotone in ``sim_bw``, and
    ``best_depth``/``replay_depth_decision`` never exceed the cap;
  * predicted vs measured on byte-driven virtual workloads at depth
    {1,2} x kv_mode {fp32,int4}: relative error < 10%;
  * ``EngineSpec.resolve(budget, trace=...)`` picks the same depth as
    the measured-best static depth, with ``replay`` provenance, and
    falls back to the heuristic on an unreplayable trace.
"""
import dataclasses
import importlib.util
import itertools
import json
from pathlib import Path

import pytest

from fake_model import COSTS, NBYTES, FakeModel, run_virtual, run_virtual_moe
from repro.core.autoconfig import replay_depth_decision
from repro.core.memory_model import quant_kv_ratio
from repro.core.pipeline import PipelineScheduler, VirtualPool
from repro.core.replay import (ReplayError, ReplayKnobs, best_depth,
                               best_stage_depth, replay, steady_step_s,
                               step_times)
from repro.core.tasks import TaskType, Trace
from repro.serving import EngineSpec

try:                                  # optional test dep: only the
    from hypothesis import given, settings, strategies as st
except ImportError:                   # property tests need it
    given = None

FIXTURES = Path(__file__).parent / "fixtures"

# recorded step times of the committed golden fixtures (first step
# includes the pipeline fill) — regenerate with
# PYTHONPATH=src python tools/make_trace_fixtures.py
GOLDEN = {
    "trace_warm_d1.json": [64.0, 60.0, 60.0],
    "trace_warm_d2.json": [44.0, 30.0, 30.0, 30.0],
    # 2-stage pipeline-parallel recording: the staged replay path must
    # reproduce the per-stage schedule (stage-tagged events and all)
    "trace_pp_s2.json": [58.0, 30.0, 30.0, 30.0],
}

# fixtures checked against the generator but NOT replayed bit-for-bit:
# the speculative recording carries draft[i] main-thread COMPUTE events
# that replay() folds out, so its replayed timeline is legitimately
# faster than the recording (asserted separately below); the traffic
# recording's mixed prefill+decode steps replay as plain decode steps
# (the composite x is opaque to the replayer)
FIXTURE_NAMES = sorted(GOLDEN) + ["trace_spec_d2.json",
                                  "trace_traffic_d1.json"]


def _load(name):
    return Trace.from_json((FIXTURES / name).read_text())


def _ev_key(e):
    return (e.kind, e.name, e.t_start, e.t_end, e.nbytes, e.extent)


# ---------------------------------------------------------------------------
# golden-fixture regression: bit-for-bit with unchanged knobs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_fixture_replay_bit_for_bit(name):
    rec = _load(name)
    assert step_times(rec) == GOLDEN[name]
    res = replay(rec)                      # no knobs: as recorded
    assert res.step_times_s == GOLDEN[name]
    assert res.steady_step_s == steady_step_s(rec)
    # the entire simulated timeline matches the recording, not just the
    # step boundaries (threads differ only in pool-worker naming, which
    # the recording also used, so compare full event multisets)
    assert (sorted(map(_ev_key, res.trace.events()))
            == sorted(map(_ev_key, rec.events())))
    assert res.trace.meta["replayed"] is True


@pytest.mark.parametrize("name", FIXTURE_NAMES)
def test_fixture_matches_generator(name):
    """The committed fixture is exactly what the generator would write —
    scheduler or fake-model changes that alter the recorded timeline
    must show up as a reviewed fixture diff, not silent drift."""
    spec = importlib.util.spec_from_file_location(
        "make_trace_fixtures",
        Path(__file__).parent.parent / "tools" / "make_trace_fixtures.py")
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)
    kwargs = dict(gen.CASES)[name]
    want = json.dumps(gen.build(kwargs), indent=1, sort_keys=True) + "\n"
    assert (FIXTURES / name).read_text() == want


def test_replay_deterministic_twice():
    rec = _load("trace_warm_d2.json")
    k = ReplayKnobs(depth=3, kv_mode="int4", sim_bw=200.0)
    a, b = replay(rec, k), replay(rec, k)
    assert a.step_times_s == b.step_times_s
    assert a.bytes_by_kind == b.bytes_by_kind
    assert (list(map(_ev_key, a.trace.events()))
            == list(map(_ev_key, b.trace.events())))


# ---------------------------------------------------------------------------
# knob semantics: byte scaling, windows, depth sweep
# ---------------------------------------------------------------------------


def test_int4_knobs_scale_bytes_by_pack_ratio():
    rec = _load("trace_warm_d2.json")
    base = replay(rec)
    kv = replay(rec, ReplayKnobs(kv_mode="int4"))
    w = replay(rec, ReplayKnobs(quant="int4"))
    # int4 vs fp32 packing is 1/8 of the 4-byte baseline (0.5/4); the
    # fake payloads (1000/40/8 B) round exactly
    assert kv.bytes_by_kind["kv_load"] * 8 == base.bytes_by_kind["kv_load"]
    assert kv.bytes_by_kind["kv_save"] * 8 == base.bytes_by_kind["kv_save"]
    assert kv.bytes_by_kind["weight_load"] == base.bytes_by_kind["weight_load"]
    assert w.bytes_by_kind["weight_load"] * 8 == base.bytes_by_kind["weight_load"]
    assert w.bytes_by_kind["kv_load"] == base.bytes_by_kind["kv_load"]


def test_iteration_window_slices_steady_steps():
    rec = _load("trace_warm_d1.json")       # 3 calls x 1 iteration
    res = replay(rec, start_iter=1)         # drop the cold first step
    assert len(res.step_times_s) == 2
    assert res.step_times_s[-1] == 60.0
    assert res.profile.calls == [1, 1]
    with pytest.raises(ReplayError, match="iteration window"):
        replay(rec, start_iter=99)


def test_best_depth_fixture_sweep():
    rec = _load("trace_warm_d1.json")
    d, preds = best_depth(rec, depth_cap=4)
    assert preds == {1: 60.0, 2: 30.0, 3: 24.0, 4: 24.0}
    assert d == 3                           # tie at 24.0 breaks shallow
    assert replay(rec, ReplayKnobs(depth=3)).steady_step_s == 24.0


def test_replay_depth_decision_capped_and_sourced():
    rec = _load("trace_warm_d1.json")
    d, why = replay_depth_decision(rec, depth_cap=2)
    assert 1 <= d <= 2
    assert "source=replay" in why and "simulated argmin" in why


def test_spec_trace_replays_with_draft_folded():
    """A speculative recording replays through the same machinery: the
    draft[i] main-thread COMPUTE events carry names the replayer skips,
    so the replayed schedule is the verify-only pipeline — strictly no
    slower than the recording (which serialized draft compute between
    steps) — and replaying the replay is a fixed point."""
    rec = _load("trace_spec_d2.json")
    res = replay(rec)
    assert len(res.step_times_s) == 4
    assert res.steady_step_s > 0.0
    assert not any(e.name.startswith("draft")
                   for e in res.trace.events())
    assert res.steady_step_s < steady_step_s(rec)
    again = replay(res.trace)
    assert again.step_times_s == res.step_times_s


def test_moe_trace_replays_with_experts_folded():
    # expert loads carry engine-minted names the replayer skips; their
    # cost stays inside the recorded compute durations, so the replay
    # still reproduces the step structure
    _, rec, _ = run_virtual_moe(iters=3)
    rec.meta.setdefault("calls", [3])
    res = replay(rec)
    assert len(res.step_times_s) == 3
    assert res.steady_step_s > 0.0


# ---------------------------------------------------------------------------
# hypothesis property tests (skipped when hypothesis is not installed)
# ---------------------------------------------------------------------------

if given is not None:
    _knobs = st.builds(
        ReplayKnobs,
        depth=st.one_of(st.none(), st.integers(min_value=1, max_value=6)),
        sim_bw=st.one_of(st.none(),
                         st.floats(min_value=10.0, max_value=1e4)),
        quant=st.sampled_from([None, "fp32", "int4"]),
        kv_mode=st.sampled_from([None, "fp32", "int4"]))

    @given(knobs=_knobs)
    @settings(max_examples=25, deadline=None)
    def test_replay_deterministic_property(knobs):
        rec = _load("trace_warm_d2.json")
        a, b = replay(rec, knobs), replay(rec, knobs)
        assert a.step_times_s == b.step_times_s
        assert a.bytes_by_kind == b.bytes_by_kind

    @given(bw_lo=st.floats(min_value=1.0, max_value=1e3),
           ratio=st.floats(min_value=1.0, max_value=100.0),
           depth=st.integers(min_value=1, max_value=5))
    @settings(max_examples=25, deadline=None)
    def test_replay_monotone_in_sim_bw(bw_lo, ratio, depth):
        # a slower hypothetical link can never predict a faster run:
        # transfer costs fall monotonically with bw and the virtual
        # makespan is monotone in task durations
        rec = _load("trace_warm_d2.json")
        slow = replay(rec, ReplayKnobs(depth=depth, sim_bw=bw_lo))
        fast = replay(rec, ReplayKnobs(depth=depth, sim_bw=bw_lo * ratio))
        assert slow.span_s >= fast.span_s - 1e-9
        assert slow.steady_step_s >= fast.steady_step_s - 1e-9

    @given(cap=st.integers(min_value=1, max_value=8))
    @settings(max_examples=10, deadline=None)
    def test_best_depth_respects_cap(cap):
        rec = _load("trace_warm_d1.json")
        d, preds = best_depth(rec, depth_cap=cap)
        assert 1 <= d <= cap
        assert sorted(preds) == list(range(1, cap + 1))
        dd, _ = replay_depth_decision(rec, depth_cap=cap)
        assert dd == d
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_replay_deterministic_property():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_replay_monotone_in_sim_bw():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_best_depth_respects_cap():
        pass


# ---------------------------------------------------------------------------
# predicted vs measured: byte-driven virtual workloads
# ---------------------------------------------------------------------------

_BW = 100.0                 # virtual link: bytes per virtual second
_OH = {TaskType.WEIGHT_LOAD: 1.0, TaskType.KV_LOAD: 0.5,
       TaskType.KV_SAVE: 0.25}
_B = {TaskType.WEIGHT_LOAD: 1024, TaskType.KV_LOAD: 64,
      TaskType.KV_SAVE: 16}


class _ByteModel(FakeModel):
    """FakeModel whose KV payloads honour ``kv_mode`` through the same
    §3.5 packing ratio the replayer applies, so a measured int4 run and
    a replayed fp32->int4 prediction price identical byte streams."""

    def __init__(self, n_layers=3, kv_mode="fp32"):
        super().__init__(n_layers)
        self.rkv = quant_kv_ratio(4, kv_mode) / quant_kv_ratio(4, "fp32")

    def weight_nbytes(self, j):
        return _B[TaskType.WEIGHT_LOAD]

    def kv_nbytes(self, i, j):
        return int(round(_B[TaskType.KV_LOAD] * self.rkv))

    def kv_save_nbytes(self, i, j):
        return int(round(_B[TaskType.KV_SAVE] * self.rkv))


def _byte_cost(task):
    # transfers: fixed per-kind overhead + bytes over the virtual link;
    # compute: constant
    if task.kind is TaskType.COMPUTE:
        return COSTS[TaskType.COMPUTE]
    return _OH[task.kind] + task.nbytes / _BW


def _run_byte_workload(depth, kv_mode="fp32", iters=6):
    """One measured virtual run at (depth, kv_mode), pool sized the way
    an engine (and the replayer's depth override) would size it."""
    model = _ByteModel(kv_mode=kv_mode)
    pool = VirtualPool(PipelineScheduler.pool_size(depth),
                       cost_fn=_byte_cost)
    sched = PipelineScheduler(model.n, "performance", pool=pool,
                              trace=pool.trace, warm=True, depth=depth)
    sched.generate(model, lambda i: 0, iters)
    sched.shutdown()
    return pool.trace


def test_replay_error_under_10pct_depth_x_kv_mode():
    """ISSUE acceptance: record once (depth 1, fp32 KV), predict every
    (depth, kv_mode) in {1,2} x {fp32, int4}, and check the prediction
    against an independent measured virtual run of that configuration.
    On the virtual clock the cost model is exact, so the <10% bound is
    loose — assert the contract, then pin near-equality."""
    rec = _run_byte_workload(depth=1, kv_mode="fp32")
    # the engines stamp link + precisions; mirror that on the recording
    rec.meta.update(sim_bw=_BW, quant="fp32", kv_mode="fp32")
    for depth, kv in itertools.product((1, 2), ("fp32", "int4")):
        pred = replay(rec, ReplayKnobs(depth=depth, kv_mode=kv))
        meas = steady_step_s(_run_byte_workload(depth=depth, kv_mode=kv))
        err = abs(pred.steady_step_s - meas) / meas
        assert err < 0.10, (depth, kv, pred.steady_step_s, meas)
        assert pred.steady_step_s == pytest.approx(meas, rel=1e-9)


def test_replay_predicts_int4_kv_speedup_at_depth1():
    # sanity on the direction, not just the magnitude: packed KV moves
    # 1/8 of the bytes so the depth-1 steady step must not get slower
    rec = _run_byte_workload(depth=1, kv_mode="fp32")
    rec.meta.update(sim_bw=_BW, quant="fp32", kv_mode="fp32")
    base = replay(rec).steady_step_s
    packed = replay(rec, ReplayKnobs(kv_mode="int4")).steady_step_s
    assert packed <= base


# ---------------------------------------------------------------------------
# EngineSpec.resolve(budget, trace=...)
# ---------------------------------------------------------------------------


def _spec(**kw):
    kw.setdefault("arch", "tinyllama-1.1b")
    kw.setdefault("scaled", True)
    return EngineSpec(**kw)


def test_resolve_trace_picks_measured_best_static_depth():
    """The resolved depth equals the argmin over measured static runs
    (same workload re-run at every depth the heuristic cap allows, each
    with the pool an engine would build), and the provenance names the
    replay source."""
    _, rec, _ = run_virtual("performance", n_layers=3, iters=6, warm=True,
                            calls=1, depth=1)
    spec = _spec(offload=True, b_max=2, max_len=64)
    cap = spec.resolve().depth                # heuristic depth = the cap
    assert cap >= 2

    from fake_model import cost_fn
    measured = {}
    for d in range(1, cap + 1):
        model = FakeModel(3)
        pool = VirtualPool(PipelineScheduler.pool_size(d), cost_fn=cost_fn)
        sched = PipelineScheduler(model.n, "performance", pool=pool,
                                  trace=pool.trace, warm=True, depth=d)
        sched.generate(model, lambda i: 0, 6)
        sched.shutdown()
        measured[d] = steady_step_s(pool.trace)
    best_measured = min(measured, key=lambda d: (measured[d], d))

    plan = spec.resolve(trace=rec)
    assert plan.depth == best_measured
    why = plan.provenance["depth"]
    assert why.startswith("replay:") and "source=replay" in why


def test_resolve_unreplayable_trace_keeps_heuristic():
    spec = _spec(offload=True, b_max=2, max_len=64)
    heuristic = spec.resolve()
    plan = spec.resolve(trace=Trace())        # no events: not replayable
    assert plan.depth == heuristic.depth
    assert "not replayable" in plan.provenance["depth"]
    assert "kept the heuristic depth" in plan.provenance["depth"]


def test_resolve_trace_ignored_with_explicit_depth():
    rec = _load("trace_warm_d1.json")
    plan = _spec(offload=True, b_max=2, max_len=64,
                 depth=2).resolve(trace=rec)
    assert plan.depth == 2
    assert plan.provenance["depth"].startswith("explicit:")


# ---------------------------------------------------------------------------
# staged (pipeline-parallel) replay: stages knob + joint planner
# ---------------------------------------------------------------------------


def test_pp_fixture_carries_stage_topology():
    rec = _load("trace_pp_s2.json")
    assert rec.meta["stages"] == 2
    assert rec.meta["stage_units"] == [[0, 3], [3, 6]]
    assert {e.stage for e in rec.events()} == {0, 1}


def test_replay_stages_knob_on_single_stage_recording():
    """What-if staging a single-stage recording: per-stage links give
    aggregate bandwidth, so the weight-bound steady step halves at
    stages=2 — and replaying a staged recording back at stages=1
    recovers the single-link figure."""
    rec = _load("trace_warm_d2.json")            # 1-stage, depth 2
    base = steady_step_s(rec)
    assert replay(rec, ReplayKnobs(stages=2)).steady_step_s < base
    pp = _load("trace_pp_s2.json")
    assert replay(pp, ReplayKnobs(stages=1)).steady_step_s \
        > steady_step_s(pp)


def test_best_stage_depth_on_pp_fixture():
    (stages, depth), preds = best_stage_depth(_load("trace_pp_s2.json"),
                                              stage_cap=3, depth_cap=2)
    assert (stages, depth) == (2, 2)
    assert set(preds) == {(s, d) for s in (1, 2, 3) for d in (1, 2)}
    assert preds[(2, 2)] == min(preds.values())
    # ties break toward fewer stages, then shallower windows
    assert preds[(2, 1)] == preds[(1, 2)]


def test_resolve_joint_stage_depth_from_staged_trace():
    """resolve(budget, trace=...) argmins over (stages, depth) jointly
    when the recording is itself staged — the spec layer's entry point
    to the planner."""
    rec = _load("trace_pp_s2.json")
    plan = _spec(offload=True, b_max=2, max_len=64).resolve(trace=rec)
    assert plan.stages == 2
    assert "joint (stages, depth)" in plan.provenance["stages"]
    assert plan.provenance["depth"].startswith("replay:")
