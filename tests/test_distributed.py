"""Distributed semantics under 8 fake devices (subprocess — device count
locks at first jax init, so these run in a child python).

The gold check: train loss / prefill outputs computed on a (2, 4) mesh
with full sharding (ring attention, sequence-sharded SSD, EP MoE,
vocab-sharded CE) must equal the single-device reference to float
tolerance, for a dense-GQA, an MoE, an SSM-hybrid and a local-window arch.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ASSIGNED, scaled_down
from repro.launch.sharding import make_dist, param_pspecs, batch_pspecs
from repro.models import build_model, Dist

mesh = jax.make_mesh((2, 4), ("data", "model"))

failures = []
for arch in ("granite-8b", "gemma3-4b", "deepseek-v3-671b", "jamba-1.5-large-398b", "mamba2-1.3b"):
    # scaled config with dims divisible by the test mesh
    cfg = scaled_down(ASSIGNED[arch], d_model=64, num_heads=4, num_kv_heads=4,
                      vocab_size=256)
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key, jnp.float32)
    b, s = 4, 32
    batch = {"labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "tokens": jax.random.randint(jax.random.fold_in(key, 1), (b, s),
                                          0, cfg.vocab_size)}
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model)) * 0.05

    loss_ref = float(m.train_loss(params, batch, Dist.local()))
    dist = Dist(mesh=mesh, data_axes=("data",), model_axis="model")
    loss_dist = float(jax.jit(
        lambda p, bt: m.train_loss(p, bt, dist))(params, batch))
    rel = abs(loss_dist - loss_ref) / max(1e-9, abs(loss_ref))
    status = "OK" if rel < 2e-4 else "FAIL"
    if status == "FAIL":
        failures.append((arch, "train", loss_ref, loss_dist))
    print(f"{status} {arch} train: ref={loss_ref:.6f} dist={loss_dist:.6f} rel={rel:.2e}")

    # prefill + one decode step parity
    pre = {k: v for k, v in batch.items() if k != "labels"}
    nt_ref, caches_ref = m.prefill(params, pre, Dist.local(), cache_len=s + 4)
    dist_kv = Dist(mesh=mesh, data_axes=("data",), model_axis="model",
                   kv_axes=("model",))
    nt_dist, caches_dist = jax.jit(
        lambda p, bt: m.prefill(p, bt, dist_kv, s + 4))(params, pre)
    same_tok = bool((np.asarray(nt_ref) == np.asarray(nt_dist)).all())
    d_ref, _ = m.decode_step(params, {"token": nt_ref[:, None],
                                      "pos": jnp.int32(s)}, caches_ref,
                             Dist.local())
    d_dist, _ = jax.jit(lambda p, t, c: m.decode_step(
        p, {"token": t, "pos": jnp.int32(s)}, c, dist_kv))(
        params, nt_dist[:, None], caches_dist)
    same_dec = bool((np.asarray(d_ref) == np.asarray(d_dist)).all())
    status = "OK" if (same_tok and same_dec) else "FAIL"
    if status == "FAIL":
        failures.append((arch, "serve", nt_ref, nt_dist))
    print(f"{status} {arch} serve: prefill_tok_match={same_tok} decode_tok_match={same_dec}")

print("FAILURES:", len(failures))
assert not failures, failures
"""


@pytest.mark.slow
def test_distributed_parity_8dev():
    env = dict(os.environ)
    root = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = str(root / "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1200)
    print(r.stdout)
    print(r.stderr[-3000:] if r.returncode else "")
    assert r.returncode == 0, f"distributed parity failed:\n{r.stdout}\n{r.stderr[-3000:]}"
