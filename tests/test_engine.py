"""PipelinedLM end-to-end: placements/pipeline modes agree token-for-token;
INT4 engine runs; memory accounting sane."""
import numpy as np
import pytest

from repro.configs.base import (ATTN, DENSE, MOE, LayerSpec, ModelConfig,
                                MoEConfig)
from repro.core.engine import PipelinedLM

CFG = ModelConfig(name="pipo-tiny", num_layers=3, d_model=128, num_heads=4,
                  num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
                  pattern=(LayerSpec(ATTN, DENSE),))


def _gen(placement, pipeline, tmp, quant=None, **kw):
    lm = PipelinedLM(CFG, batch=2, max_len=48, placement=placement,
                     pipeline=pipeline, quant=quant,
                     disk_root=str(tmp / f"{placement}_{pipeline}_{quant}"),
                     **kw)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 512, (2, 12)).astype(np.int32)
    return lm.generate(prompt, gen_len=6)


def test_modes_agree(tmp_path):
    toks_seq, _ = _gen("host", "sequential", tmp_path)
    toks_perf, stats = _gen("host", "performance", tmp_path)
    toks_mem, _ = _gen("host", "memory", tmp_path)
    np.testing.assert_array_equal(toks_seq, toks_perf)
    np.testing.assert_array_equal(toks_seq, toks_mem)
    assert 0 < stats["compute_busy"] <= 1.0


def test_placements_agree(tmp_path):
    toks_dev, _ = _gen("device", "performance", tmp_path)
    toks_host, _ = _gen("host", "performance", tmp_path)
    toks_disk, _ = _gen("disk", "performance", tmp_path)
    np.testing.assert_array_equal(toks_dev, toks_host)
    np.testing.assert_array_equal(toks_dev, toks_disk)


def test_int4_engine_runs(tmp_path):
    toks, stats = _gen("host", "performance", tmp_path, quant="int4")
    assert toks.shape == (2, 6)
    assert (toks >= 0).all() and (toks < 512).all()


def test_moe_engine(tmp_path):
    cfg = ModelConfig(name="pipo-moe", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
                      pattern=(LayerSpec(ATTN, MOE),),
                      moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128,
                                    num_shared=1, shared_d_ff=128))
    lm = PipelinedLM(cfg, batch=2, max_len=32, placement="host",
                     pipeline="performance", disk_root=str(tmp_path / "moe"))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 256, (2, 8)).astype(np.int32)
    toks, stats = lm.generate(prompt, gen_len=4)
    assert toks.shape == (2, 4)

    lm2 = PipelinedLM(cfg, batch=2, max_len=32, placement="host",
                      pipeline="sequential", disk_root=str(tmp_path / "moe2"))
    toks2, _ = lm2.generate(prompt, gen_len=4)
    np.testing.assert_array_equal(toks, toks2)
