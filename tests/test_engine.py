"""PipelinedLM end-to-end: placements/pipeline modes/cache tiers agree
token-for-token; INT4 weights and INT4-streamed KV hold parity; the
tiered-KV trace accounts live-extent bytes and dequant cost."""
import numpy as np
import pytest

from repro.configs.base import (ATTN, DENSE, MOE, LayerSpec, ModelConfig,
                                MoEConfig)
from repro.core.engine import PipelinedLM
from repro.core.kvstore import kv_group, kv_roundtrip_rows
from repro.core.pipeline import VirtualPool
from repro.core.tasks import TaskType

CFG = ModelConfig(name="pipo-tiny", num_layers=3, d_model=128, num_heads=4,
                  num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
                  pattern=(LayerSpec(ATTN, DENSE),))


def _gen(placement, pipeline, tmp, quant=None, **kw):
    lm = PipelinedLM(CFG, batch=2, max_len=48, placement=placement,
                     pipeline=pipeline, quant=quant,
                     disk_root=str(tmp / f"{placement}_{pipeline}_{quant}"),
                     **kw)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 512, (2, 12)).astype(np.int32)
    return lm.generate(prompt, gen_len=6)


def test_modes_agree(tmp_path):
    toks_seq, _ = _gen("host", "sequential", tmp_path)
    toks_perf, stats = _gen("host", "performance", tmp_path)
    toks_mem, _ = _gen("host", "memory", tmp_path)
    np.testing.assert_array_equal(toks_seq, toks_perf)
    np.testing.assert_array_equal(toks_seq, toks_mem)
    assert 0 < stats["compute_busy"] <= 1.0


def test_placements_agree(tmp_path):
    toks_dev, _ = _gen("device", "performance", tmp_path)
    toks_host, _ = _gen("host", "performance", tmp_path)
    toks_disk, _ = _gen("disk", "performance", tmp_path)
    np.testing.assert_array_equal(toks_dev, toks_host)
    np.testing.assert_array_equal(toks_dev, toks_disk)


def test_cache_tiers_agree(tmp_path):
    """cache_on='device' (KV never crosses the link) generates the same
    tokens as the tiered host cache — the device path's KV_SAVE really
    persists the updated cache."""
    toks_host, _ = _gen("host", "performance", tmp_path)
    toks_dev, _ = _gen("host", "performance", tmp_path, cache_on="device")
    np.testing.assert_array_equal(toks_host, toks_dev)
    toks_dev_seq, _ = _gen("host", "sequential", tmp_path,
                           cache_on="device")
    np.testing.assert_array_equal(toks_host, toks_dev_seq)


class _RoundtripKVLM(PipelinedLM):
    """fp32-cache engine whose saves roundtrip rows through the INT4
    quantize->dequantize — the bit-exact reference for kv_mode='int4'
    (mirrors serving's KVRoundtripServingEngine)."""

    def save_kv(self, i, j, new_kv):
        phase, k, v, pos, length = new_kv

        def rt(r):
            r = np.asarray(r, np.float32)
            b, s = r.shape[:2]
            F = int(np.prod(r.shape[2:]))
            flat = r.reshape(b, s, F)
            return np.asarray(kv_roundtrip_rows(flat, kv_group(F))
                              ).reshape(r.shape)

        super().save_kv(i, j, (phase, rt(k), rt(v), pos, length))


def test_int4_kv_parity(tmp_path):
    """kv_mode='int4' decode == fp32 decode over roundtripped cache rows:
    quantize-at-save / transfer-thread-dequant-at-load is the ONLY
    difference from the fp32 path, so tokens match bit-for-bit."""
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 512, (2, 12)).astype(np.int32)
    ref = _RoundtripKVLM(CFG, batch=2, max_len=48, placement="host",
                         pipeline="performance",
                         disk_root=str(tmp_path / "ref"))
    toks_ref, _ = ref.generate(prompt, gen_len=6)
    lm = PipelinedLM(CFG, batch=2, max_len=48, placement="host",
                     pipeline="performance", kv_mode="int4",
                     disk_root=str(tmp_path / "int4"))
    toks, _ = lm.generate(prompt, gen_len=6)
    np.testing.assert_array_equal(toks_ref, toks)
    # and the quantization is real: plain fp32 tokens may differ
    assert lm.kvstore.dequant_bytes_total > 0


def _virtual_gen(tmp, kv_mode, cost_fn=None, gen_len=6):
    lm = PipelinedLM(CFG, batch=2, max_len=48, placement="host",
                     pipeline="performance", kv_mode=kv_mode,
                     disk_root=str(tmp / f"v_{kv_mode}_{id(cost_fn)}"))
    pool = VirtualPool(3, cost_fn=cost_fn)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 512, (2, 12)).astype(np.int32)
    toks, _ = lm.generate(prompt, gen_len=gen_len, pool=pool)
    return lm, pool.trace, toks


def test_kv_trace_live_extent_bytes(tmp_path):
    """Virtual-clock byte accounting for PipelinedLM-through-the-store:
    every KV_LOAD event carries the live (batch, positions) extent, its
    bytes equal the store's live-row answer (never the slab), the
    per-kind report derives a bandwidth, and INT4 shrinks the same
    events' bytes."""
    lm, trace, _ = _virtual_gen(tmp_path, None)
    kv_loads = [e for e in trace.events()
                if e.kind == "kv_load" and e.extent is not None]
    assert kv_loads
    prompt_len = 12
    for e in kv_loads:
        i, j = map(int, e.name[3:-1].split(","))
        live = min(prompt_len + i - 1, lm.max_len)
        assert e.extent == (2, live), e.name
        assert e.nbytes == lm.kvstore.load_nbytes(j, 2, live)
        assert e.nbytes < lm.kvstore.load_nbytes(j)          # < slab
    rep = trace.report()["per_kind"]
    assert rep["kv_load"]["bytes"] == sum(e.nbytes for e in kv_loads)
    assert rep["kv_load"]["bw_Bps"] > 0
    # saves are accounted too: one prefill payload + one row per step
    assert rep["kv_save"]["bytes"] > 0

    lm4, trace4, _ = _virtual_gen(tmp_path, "int4")
    kv4 = [e for e in trace4.events()
           if e.kind == "kv_load" and e.extent is not None]
    assert [e.name for e in kv4] == [e.name for e in kv_loads]
    for e, e4 in zip(kv_loads, kv4):
        assert e4.nbytes < e.nbytes // 4      # packed rows + scales
    # transfer-thread dequant cost is bounded by the live extents the
    # trace recorded — not by the slab
    expect = sum(lm4.kvstore.dequant_nbytes(
        int(e.name[3:-1].split(",")[1]), *e.extent) for e in kv4)
    assert lm4.kvstore.dequant_bytes_total == expect
    slab_priced = sum(lm4.kvstore.dequant_nbytes(
        int(e.name[3:-1].split(",")[1])) for e in kv4)
    assert lm4.kvstore.dequant_bytes_total < slab_priced


def test_int4_kv_wins_at_depth1_on_virtual_clock(tmp_path):
    """The PR-5 inversion, fixed: with KV_LOAD priced as link time +
    transfer-thread dequant time, INT4 KV at depth 1 is strictly faster
    than fp32 — because the dequant now costs the live extent.  Pricing
    the dequant at the slab (the old in-jit ``device_cache`` behaviour)
    reproduces the inversion."""
    BW, DEQ_BW = 1e9, 4e9

    def price(lm, slab):
        def cost(task):
            if task.kind == TaskType.KV_LOAD and task.nbytes:
                j = int(task.name[3:-1].split(",")[1])
                deq = (lm.kvstore.dequant_nbytes(j) if slab
                       else lm.kvstore.dequant_nbytes(j, *task.extent))
                return task.nbytes / BW + deq / DEQ_BW
            if task.kind == TaskType.COMPUTE:
                return 2e-6
            return 1e-6              # KV-bound link: KV transfers dominate
        return cost

    def run(kv_mode, slab=False):
        lm = PipelinedLM(CFG, batch=2, max_len=48, placement="host",
                         pipeline="performance", kv_mode=kv_mode,
                         disk_root=str(tmp_path / f"w_{kv_mode}_{slab}"))
        pool = VirtualPool(3, cost_fn=price(lm, slab))
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, 512, (2, 12)).astype(np.int32)
        lm.generate(prompt, gen_len=6, pool=pool)
        return pool.trace.report()

    fp32 = run(None)
    int4 = run("int4")
    int4_slab = run("int4", slab=True)
    busy = lambda r: r["per_kind"]["kv_load"]["busy_s"]
    assert busy(int4) < busy(fp32)                  # the recovered win
    assert int4["span_s"] < fp32["span_s"]
    assert busy(int4_slab) > busy(fp32)             # the old inversion


def test_int4_engine_runs(tmp_path):
    toks, stats = _gen("host", "performance", tmp_path, quant="int4")
    assert toks.shape == (2, 6)
    assert (toks >= 0).all() and (toks < 512).all()


def test_moe_engine(tmp_path):
    cfg = ModelConfig(name="pipo-moe", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
                      pattern=(LayerSpec(ATTN, MOE),),
                      moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128,
                                    num_shared=1, shared_d_ff=128))
    lm = PipelinedLM(cfg, batch=2, max_len=32, placement="host",
                     pipeline="performance", disk_root=str(tmp_path / "moe"))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 256, (2, 8)).astype(np.int32)
    toks, stats = lm.generate(prompt, gen_len=4)
    assert toks.shape == (2, 4)

    lm2 = PipelinedLM(cfg, batch=2, max_len=32, placement="host",
                      pipeline="sequential", disk_root=str(tmp_path / "moe2"))
    toks2, _ = lm2.generate(prompt, gen_len=4)
    np.testing.assert_array_equal(toks, toks2)
