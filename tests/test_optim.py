"""Optimizers: convergence on a quadratic, clipping, factored state shapes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, apply_updates, cosine_schedule, global_norm
from repro.optim.adafactor import Adafactor


def _opt_run(opt, steps=300):
    params = {"w": jnp.ones((8, 4)) * 3.0, "b": jnp.ones((4,)) * -2.0}
    target = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(
            lambda p: sum(jnp.sum((p[k] - target[k]) ** 2) for k in p))(params)
        upd, state, gn = opt.update(grads, state, params)
        return apply_updates(params, upd), state, loss

    for _ in range(steps):
        params, state, loss = step(params, state)
    return float(loss)


def test_adamw_converges():
    assert _opt_run(AdamW(lr=0.05, weight_decay=0.0)) < 1e-3


def test_adafactor_converges():
    assert _opt_run(Adafactor(lr=0.05, weight_decay=0.0)) < 1e-2


def test_adafactor_state_is_factored():
    opt = Adafactor()
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = opt.init(params)
    assert st["s"]["w"]["vr"].shape == (64,)
    assert st["s"]["w"]["vc"].shape == (32,)
    assert st["s"]["w"]["m"].dtype == jnp.bfloat16
    assert st["s"]["b"]["v"].shape == (32,)
    # factored state is tiny vs fp32 adam
    adam_bytes = 2 * 64 * 32 * 4
    fact_bytes = (64 + 32) * 4 + 64 * 32 * 2
    assert fact_bytes < adam_bytes


def test_global_norm_clip():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    grads = {"w": jnp.full((4,), 100.0)}
    upd, state, gn = opt.update(grads, state, params)
    assert float(gn) == 200.0
    # post-clip effective grad has norm 1 -> first-step adam update ~ lr
    assert np.all(np.isfinite(np.asarray(upd["w"])))


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(110)) <= 0.11
    assert float(lr(60)) < float(lr(20))
