"""Roofline instrument calibration: the §Perf pass depends on the static
HLO model being right, so its corrections are pinned by tests against
known-cost compiled programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import (analyze_hlo, f32_shadow_bytes,
                                     roofline_report)
from repro.roofline.profile import profile_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_trip_counts():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out
    c = _compile(f, jnp.zeros((128, 128)), jnp.zeros((128, 128)))
    acc = analyze_hlo(c.as_text(), total_devices=1)
    assert acc["flops"] == 2 * 128 ** 3 * 10


def test_dus_counts_in_place():
    """dynamic-update-slice writes the update region, not the buffer
    (donated input: without donation XLA inserts a real defensive copy,
    which the instrument correctly charges)."""
    def f(buf, val):
        return jax.lax.dynamic_update_slice(buf, val, (0, 0))
    buf = jnp.zeros((4096, 1024))      # 16 MB
    val = jnp.zeros((1, 1024))         # 4 KB
    c = jax.jit(f, donate_argnums=(0,)).lower(buf, val).compile()
    acc = analyze_hlo(c.as_text(), total_devices=1)
    # traffic must be ~update-sized (+ small), far below buffer read+write
    assert acc["hbm_bytes"] < buf.nbytes, acc["hbm_bytes"]


def test_sliced_stack_reads_slice_not_stack():
    """scan over stacked weights reads one slice per step, not the stack."""
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out
    ws = jnp.zeros((8, 256, 256))      # 2 MB stack
    x = jnp.zeros((256, 256))
    c = _compile(f, x, ws)
    acc = analyze_hlo(c.as_text(), total_devices=1)
    # slice-sized model: dots (6.3 MB) + carry copies (4.7 MB) + slice
    # reads (4.2 MB) ~= 15 MB; the full-stack miscount would charge
    # 8 x 2.1 MB stack reads on top (> 23 MB).
    assert acc["hbm_bytes"] < 18e6, acc["hbm_bytes"]


def test_cast_bucket_separated():
    """bf16 dot on CPU materializes f32 copies -> cast bucket, not hbm."""
    def f(x, w):
        return x @ w
    x = jnp.zeros((512, 512), jnp.bfloat16)
    w = jnp.zeros((512, 512), jnp.bfloat16)
    c = _compile(f, x, w)
    acc = analyze_hlo(c.as_text(), total_devices=1)
    assert acc["cast_bytes"] > 0          # CPU-only f32 copies detected
    assert f32_shadow_bytes(c.as_text()) > 0
    rep = roofline_report(acc)
    assert rep["t_memory_cpu_cast_s"] > 0


def test_vreg_fused_scope_skipped():
    """values produced under a vreg_fused_* scope don't count as HBM."""
    from repro.quant.int4 import dequantize_int4, quantize_int4
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 512)) * 0.1
    packed, scale = quantize_int4(w)

    def f_fused(x, packed, scale):
        with jax.named_scope("vreg_fused_int4"):
            wd = dequantize_int4(packed, scale, jnp.float32)
        return x @ wd

    def f_plain(x, packed, scale):
        wd = dequantize_int4(packed, scale, jnp.float32)
        return x @ wd

    x = jnp.zeros((8, 512))
    acc_f = analyze_hlo(_compile(f_fused, x, packed, scale).as_text(), 1)
    acc_p = analyze_hlo(_compile(f_plain, x, packed, scale).as_text(), 1)
    assert acc_f["hbm_bytes"] < acc_p["hbm_bytes"]
    # both must compute the same flops
    assert acc_f["flops"] == acc_p["flops"] > 0


def test_profile_rows_sum_to_analysis():
    def f(x, w):
        return jnp.tanh(x @ w) @ w
    x = jnp.zeros((256, 256))
    c = _compile(f, x, x)
    txt = c.as_text()
    rows = profile_hlo(txt, top=10_000)
    assert rows and all(r["bytes"] >= 0 for r in rows)
    assert sum(r["flops"] for r in rows) == 2 * 2 * 256 ** 3
