"""Traffic subsystem: chunked prefill through the pipeline + scheduling
policies + workload layer.

Four groups:

  * token parity — chunked prefill (OnlineSLO / OfflineThroughput, any
    chunk size) must be BIT-IDENTICAL to monolithic prefill on the real
    offloaded engine, across depth x kv_mode, composing with
    speculative decoding;
  * scheduling invariants on the virtual clock — a prefill chunk rides
    the decode batch's generate() call, so the per-layer WEIGHT_LOAD
    schedule is IDENTICAL with or without a chunk in flight (the
    tentpole invariant), window residency stays bounded, and the real
    engine's chunked runs stream strictly fewer weight bytes than
    monolithic;
  * traffic simulation / workload — deterministic arrival traces
    (seeded, JSON round-trip), TrafficSim policy comparisons (OnlineSLO
    p99 TTFT below monolithic under ramp load, bounded TBT, no decode
    starvation, TTFT monotone in chunk cap), and replay_traffic what-if
    identity;
  * serving behavior under traffic — FIFO admission under bursts,
    preemption/resume composing with chunked prefill, per-request
    timing fields on both engines, chunk/prefill stat separation.
"""
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core.replay import ReplayError, replay_traffic
from repro.core.tasks import latency_summary, percentile
from repro.serving import (EngineSpec, Request, ServingEngine, SpecError,
                           create_engine)
from repro.serving.workload import (Arrival, ArrivalTrace, SimCosts,
                                    TrafficSim, latency_series,
                                    poisson_trace, ramp_trace, run_trace)

from fake_model import run_virtual_traffic


def _cfg():
    return scaled_down(get_config("tinyllama-1.1b"))


def _prompts(cfg, n=4, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    return [rng.integers(0, cfg.vocab_size, (6 + i,)).astype(np.int32)
            for i in range(n)]


def _build(cfg, **kw):
    kw.setdefault("b_max", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("placement", "host")
    kw.setdefault("pipeline", "performance")
    return create_engine(EngineSpec(arch="tinyllama-1.1b", scaled=True,
                                    cfg=cfg, offload=True, **kw))


def _serve(eng, prompts, max_new=5):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new=max_new))
    done = eng.run()
    out = {r.rid: list(r.out) for r in done}
    if hasattr(eng, "shutdown"):
        eng.shutdown()
    return out, done


# ---------------------------------------------------------------------------
# token parity: chunked == monolithic, bit for bit
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mono_tokens():
    """Monolithic-prefill reference per kv_mode (the INT4 tier is lossy,
    so chunked INT4 compares against monolithic INT4, not fp32)."""
    cfg = _cfg()
    out = {}
    for kv in ("fp32", "int4"):
        eng = _build(cfg, kv_mode=kv, sched="monolithic")
        out[kv], _ = _serve(eng, _prompts(cfg))
    return out


@pytest.mark.parametrize("kv_mode", ["fp32", "int4"])
@pytest.mark.parametrize("sched,chunk", [("online", 2), ("online", 3),
                                         ("offline", 0)])
def test_chunked_prefill_token_parity(mono_tokens, kv_mode, sched, chunk):
    cfg = _cfg()
    eng = _build(cfg, kv_mode=kv_mode, sched=sched,
                 prefill_chunk=chunk or None)
    got, _ = _serve(eng, _prompts(cfg))
    assert got == mono_tokens[kv_mode]


@pytest.mark.parametrize("kv_mode", ["fp32", "int4"])
def test_chunked_prefill_parity_depth2(mono_tokens, kv_mode):
    cfg = _cfg()
    eng = _build(cfg, kv_mode=kv_mode, sched="online", prefill_chunk=2,
                 depth=2)
    got, _ = _serve(eng, _prompts(cfg))
    assert got == mono_tokens[kv_mode]


@pytest.mark.parametrize("kv_mode", ["fp32", "int4"])
def test_chunked_prefill_composes_with_spec_decode(mono_tokens, kv_mode):
    """Speculative decoding pauses while a chunk is in flight and
    resumes at completion; the emitted stream stays bit-identical."""
    cfg = _cfg()
    eng = _build(cfg, kv_mode=kv_mode, sched="online", prefill_chunk=2,
                 spec_k=2, draft_arch="tinyllama-1.1b")
    got, _ = _serve(eng, _prompts(cfg))
    assert eng.stats["prefill_chunks"] > 0
    assert eng.stats["spec_steps"] > 0
    assert got == mono_tokens[kv_mode]


def test_resident_engine_drops_sched(mono_tokens):
    """The resident engine never chunks: an explicitly resident spec
    rejects sched outright, and a plan that *falls back* to resident
    (unsupported offload target) drops it with provenance and serves
    with the shared timing fields stamped."""
    cfg = _cfg()
    with pytest.raises(SpecError):
        EngineSpec(arch="tinyllama-1.1b", scaled=True, cfg=cfg,
                   offload=False, b_max=2, max_len=64,
                   sched="online", prefill_chunk=4).validate()
    plan = EngineSpec(arch="whisper-base", scaled=True, offload=True,
                      b_max=2, max_len=48, sched="online",
                      prefill_chunk=4).resolve()
    assert plan.engine == "resident"
    assert plan.sched == "monolithic" and plan.prefill_chunk == 0
    assert "dropped" in plan.provenance["sched"]
    eng = create_engine(plan)
    got, done = _serve(eng, _prompts(eng.cfg))
    assert eng.stats["prefill_chunks"] == 0
    # timing fields are stamped on the resident engine too
    for r in done:
        assert r.t_arrive > 0 and r.t_first_token >= r.t_arrive
        assert r.t_done >= r.t_first_token
        assert len(r.t_tokens) == len(r.out)


# ---------------------------------------------------------------------------
# scheduling invariants (virtual clock + real-engine trace)
# ---------------------------------------------------------------------------


def _w_counts(trace):
    counts = {}
    for e in trace.events():
        if e.kind == "weight_load":
            counts[e.name] = counts.get(e.name, 0) + 1
    return counts


def test_virtual_mixed_step_weight_loads_do_not_double():
    """The tentpole invariant: a generate() call carrying BOTH a decode
    batch and a prefill chunk streams each layer's weights exactly once
    — the weight-load schedule is identical to the same steps with no
    chunk in flight."""
    _, tr_mixed, outs = run_virtual_traffic(n_layers=3, steps=4,
                                            chunk_steps=(1, 2))
    _, tr_plain, _ = run_virtual_traffic(n_layers=3, steps=4,
                                         chunk_steps=())
    wm, wp = _w_counts(tr_mixed), _w_counts(tr_plain)
    assert wm == wp                      # same count per layer, no doubling
    # one load per layer per step; the depth-1 warm tail pre-submits
    # only the NEXT step's first layer, hence the lone +1 on w[0]
    assert wm == {f"w[{j}]": 4 + (1 if j == 0 else 0) for j in range(6)}
    # both legs of the composite x advanced through every layer
    assert outs[1][0] == (6, 6)          # 0 + one increment per unit


def test_virtual_mixed_step_window_residency_bounded():
    """No more than depth+1 weight loads overlap at any virtual time
    (the in-flight window plus the load being consumed)."""
    for depth in (1, 2):
        _, tr, _ = run_virtual_traffic(n_layers=3, steps=4, depth=depth,
                                       chunk_steps=(1, 2))
        ivals = sorted((e.t_start, e.t_end) for e in tr.events()
                       if e.kind == "weight_load")
        for i, (s, t) in enumerate(ivals):
            overlap = sum(1 for s2, t2 in ivals if s2 < t and t2 > s)
            assert overlap <= depth + 1


def test_real_engine_chunked_streams_fewer_weight_bytes():
    """On the real engine, chunked prefill rides the decode batch's
    sweeps while monolithic pays a dedicated b=1 sweep per admission —
    strictly fewer WEIGHT_LOADs for the same served tokens."""
    cfg = _cfg()
    loads = {}
    for sched in ("monolithic", "offline"):
        eng = _build(cfg, sched=sched)
        trace = eng.trace
        got, _ = _serve(eng, _prompts(cfg))
        loads[sched] = sum(1 for e in trace.events()
                           if e.kind == "weight_load")
    assert loads["offline"] < loads["monolithic"]


def test_chunk_stats_separate_from_prefills():
    """stats['prefills'] counts WHOLE prefills; chunk steps count in
    stats['prefill_chunks'] (ceil(plen/cap) per request)."""
    cfg = _cfg()
    prompts = _prompts(cfg)              # lengths 6, 7, 8, 9
    eng = _build(cfg, sched="online", prefill_chunk=4)
    _serve(eng, prompts)
    assert eng.stats["prefills"] == len(prompts)
    want = sum(-(-len(p) // 4) for p in prompts)
    assert eng.stats["prefill_chunks"] == want


# ---------------------------------------------------------------------------
# workload layer: arrival traces + TrafficSim + replay
# ---------------------------------------------------------------------------


def test_arrival_trace_deterministic_and_json_roundtrip():
    a = ramp_trace(8, 0.5, 4.0, seed=11, prompt_len=(4, 9), max_new=3)
    b = ramp_trace(8, 0.5, 4.0, seed=11, prompt_len=(4, 9), max_new=3)
    assert a.to_json() == b.to_json()
    assert a.to_json() != ramp_trace(8, 0.5, 4.0, seed=12,
                                     prompt_len=(4, 9)).to_json()
    rt = ArrivalTrace.from_json(a.to_json())
    assert rt.to_json() == a.to_json()
    ts = [x.t for x in a.arrivals]
    assert ts == sorted(ts) and all(t > 0 for t in ts)
    p = poisson_trace(5, 2.0, seed=3, prompt_len=6)
    assert all(len(x.prompt) == 6 for x in p.arrivals)
    assert p.meta["kind"] == "poisson"


_COSTS = SimCosts(sweep_s=1.0, tok_s=0.02, prefill_tok_s=0.05)


def _ramp():
    return ramp_trace(16, 0.3, 3.0, seed=7, prompt_len=(24, 48), max_new=8)


def test_sim_online_p99_ttft_below_monolithic():
    """Under ramp load the queue builds; monolithic's dedicated prefill
    sweeps inflate everyone's wait while OnlineSLO's chunks ride sweeps
    that happen anyway — p99 TTFT strictly below monolithic."""
    mono = TrafficSim(_ramp(), b_max=2, sched="monolithic",
                      costs=_COSTS).run()
    onl = TrafficSim(_ramp(), b_max=2, sched="online", chunk=16,
                     costs=_COSTS).run()
    p99 = lambda r: r.trace.report()["latency"]["ttft"]["p99_s"]
    assert p99(onl) < p99(mono)


def test_sim_offline_best_throughput():
    res = {s: TrafficSim(_ramp(), b_max=2, sched=s,
                         chunk=(16 if s == "online" else 0),
                         costs=_COSTS).run()
           for s in ("monolithic", "online", "offline")}
    assert res["offline"].tok_per_s >= res["monolithic"].tok_per_s
    assert res["offline"].tok_per_s >= res["online"].tok_per_s
    assert res["offline"].sweeps <= res["monolithic"].sweeps


def test_sim_online_no_decode_starvation():
    """OnlineSLO's chunk cap bounds the per-step compute add, so active
    requests keep emitting every step: every TBT gap is at most the
    capped step time (sweep_s vs decode+chunk compute), while offline's
    whole-prompt rides blow past it."""
    onl = TrafficSim(_ramp(), b_max=2, sched="online", chunk=16,
                     costs=_COSTS).run()
    cap_step = max(_COSTS.sweep_s,
                   2 * _COSTS.tok_s + 16 * _COSTS.prefill_tok_s)
    assert max(onl.trace.meta["latency"]["tbt"]) <= cap_step + 1e-9
    off = TrafficSim(_ramp(), b_max=2, sched="offline",
                     costs=_COSTS).run()
    assert max(off.trace.meta["latency"]["tbt"]) > cap_step


def test_sim_ttft_monotone_in_chunk_cap():
    prev = None
    for cap in (2, 4, 8, 16, 32, 64):
        r = TrafficSim(_ramp(), b_max=2, sched="online", chunk=cap,
                       costs=_COSTS).run()
        worst = max(r.trace.meta["latency"]["ttft"])
        if prev is not None:
            assert worst <= prev + 1e-9
        prev = worst


def test_sim_fifo_first_tokens_under_burst():
    """Bursty admission: all requests arrive at t=0; first tokens land
    in arrival (rid) order under every policy — FIFO, no overtaking."""
    prompt = tuple(range(8))
    burst = ArrivalTrace([Arrival(t=0.0, rid=i, prompt=prompt, max_new=4)
                          for i in range(6)])
    for sched, chunk in (("monolithic", 0), ("online", 4), ("offline", 0)):
        r = TrafficSim(burst, b_max=2, sched=sched, chunk=chunk,
                       costs=_COSTS).run()
        firsts = {d["rid"]: d["t_first"] for d in r.done}
        order = sorted(firsts, key=lambda rid: (firsts[rid], rid))
        assert order == list(range(6))
        assert len(r.done) == 6


def test_replay_traffic_identity_and_what_if():
    rec = TrafficSim(_ramp(), b_max=2, sched="monolithic",
                     costs=_COSTS).run()
    again = replay_traffic(rec.trace)
    assert again.trace.meta["latency"] == rec.trace.meta["latency"]
    assert again.span_s == rec.span_s
    live = TrafficSim(_ramp(), b_max=2, sched="online", chunk=16,
                      costs=_COSTS).run()
    what_if = replay_traffic(rec.trace, sched="online", chunk=16)
    assert what_if.trace.meta["latency"] == live.trace.meta["latency"]
    faster = replay_traffic(rec.trace, costs={"sweep_s": 0.5})
    assert faster.span_s < rec.span_s
    from repro.core.tasks import Trace, VirtualClock
    with pytest.raises(ReplayError):
        replay_traffic(Trace(clock=VirtualClock()))   # no traffic block


def test_latency_percentiles():
    xs = list(range(1, 101))
    assert percentile(xs, 50) == 50.5
    assert percentile(xs, 99) == pytest.approx(99.01)
    assert percentile([], 99) == 0.0
    assert percentile([7.0], 99) == 7.0
    s = latency_summary(xs)
    assert s["count"] == 100 and s["mean_s"] == 50.5
    assert s["p50_s"] == 50.5 and s["p95_s"] == pytest.approx(95.05)


def test_trace_report_latency_section():
    from repro.core.tasks import Trace, VirtualClock
    tr = Trace(clock=VirtualClock())
    assert "latency" not in tr.report()
    tr.meta["latency"] = {"ttft": [1.0, 2.0, 3.0], "tbt": []}
    rep = tr.report()["latency"]
    assert rep["ttft"]["p50_s"] == 2.0 and rep["ttft"]["count"] == 3
    assert rep["tbt"]["count"] == 0


# ---------------------------------------------------------------------------
# real engines under traffic
# ---------------------------------------------------------------------------


def test_run_trace_real_engine_parity_and_latency():
    """run_trace drives the offloaded engine through a seeded arrival
    trace: tokens match a plain _serve of the same prompts, latency
    fields are coherent, and the series land in trace.meta."""
    cfg = _cfg()
    at = ramp_trace(4, 5.0, 50.0, seed=1, prompt_len=(6, 10), max_new=4,
                    vocab=cfg.vocab_size)
    eng = _build(cfg, sched="online", prefill_chunk=3)
    done = run_trace(eng, at, time_scale=1e-3)
    got = {r.rid: list(r.out) for r in done}
    eng.shutdown()
    ref_eng = _build(cfg, sched="monolithic")
    for a in sorted(at.arrivals, key=lambda a: a.t):
        ref_eng.submit(Request(rid=a.rid,
                               prompt=np.asarray(a.prompt, np.int32),
                               max_new=a.max_new))
    ref = {r.rid: list(r.out) for r in ref_eng.run()}
    ref_eng.shutdown()
    assert got == ref
    assert len(done) == 4
    for r in done:
        assert r.t_arrive <= r.t_submit + 1e-9
        assert r.t_first_token >= r.t_arrive
        assert r.t_done >= r.t_first_token
        assert len(r.t_tokens) == len(r.out)
    lat = latency_series(done)
    assert all(x >= 0 for x in lat["ttft"] + lat["tbt"] + lat["e2e"])


def test_burst_fifo_and_preemption_with_chunked_prefill():
    """Bursty admission on the real engine: more requests than slots
    under OnlineSLO; admission stays FIFO, a mid-run preemption of a
    DECODING slot (never the chunk slot) restores losslessly, and the
    final streams match monolithic serving bit for bit."""
    cfg = _cfg()
    prompts = _prompts(cfg, n=4)
    ref_eng = _build(cfg, sched="monolithic")
    ref, _ = _serve(ref_eng, prompts, max_new=6)

    eng = _build(cfg, sched="online", prefill_chunk=2)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new=6))
    eng._epoch += 1
    done = []
    preempted = False
    for _ in range(200):
        if eng.idle():
            break
        eng.step(done)
        # while a chunked prefill is in flight its slot is guarded
        cslot = eng._chunk_slot()
        if cslot is not None and not preempted:
            with pytest.raises(AssertionError):
                eng.preempt_slot(cslot)
        # once both slots decode (no chunk in flight), preempt slot 0
        if (not preempted and eng._chunk_slot() is None
                and all(x is not None for x in eng.slots)
                and all(x.out for x in eng.slots)):
            eng.preempt_slot(0)
            preempted = True
    eng.shutdown()
    assert preempted
    got = {r.rid: list(r.out) for r in done}
    assert got == ref
    # FIFO: rid 0/1 started before 2/3 (first token timestamps ordered)
    t_first = {r.rid: r.t_first_token for r in done}
    assert max(t_first[0], t_first[1]) <= min(t_first[2], t_first[3])


def test_online_bounded_ttft_under_burst_sim():
    """Under OnlineSLO the k-th queued request's TTFT is bounded by its
    drain position: with all prompts equal and max_new fixed, TTFT grows
    linearly with queue position, never superlinearly (no starvation of
    queued prefills behind long decodes)."""
    prompt = tuple(range(16))
    burst = ArrivalTrace([Arrival(t=0.0, rid=i, prompt=prompt, max_new=3)
                          for i in range(8)])
    r = TrafficSim(burst, b_max=2, sched="online", chunk=8,
                   costs=_COSTS).run()
    ttfts = sorted(d["ttft"] for d in r.done)
    gaps = [b - a for a, b in zip(ttfts, ttfts[1:])]
    # successive first tokens arrive at a bounded cadence: each gap is
    # at most one request's full service time (prefill rides + decodes)
    per_req = (2 + 3) * max(_COSTS.sweep_s, 16 * _COSTS.prefill_tok_s)
    assert max(gaps) <= per_req
    assert max(ttfts) <= 8 * per_req
