import os

import pytest

# persistent XLA compilation cache: the suite is compile-bound on CPU, so
# repeat runs (local dev loops, warm CI caches) skip most of the work.
# Opt out with JAX_COMPILATION_CACHE_DIR="".
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR",
                      "/tmp/pipo_jax_compile_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked slow (model-smoke matrix, "
                          "subprocess/e2e, sweeps)")


def pytest_configure(config):
    config.addinivalue_line("markers",
                            "slow: long-running (subprocess / e2e) tests")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture(autouse=True)
def _fresh_deprecation_warnings():
    """Deprecation warnings are deduped once per process
    (``warn_deprecated_once``); reset the dedup set per TEST so every
    test observes the warnings its own calls trigger, regardless of
    which test touched the legacy path first."""
    from repro.serving.spec import reset_deprecation_warnings
    reset_deprecation_warnings()
    yield
