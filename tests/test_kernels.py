"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention_int4_kernel,
                                            decode_attention_kernel)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.ref import (decode_attention_ref, flash_attention_ref,
                               int4_matmul_ref)
from repro.quant.int4 import quantize_int4

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("M,K,N", [(128, 256, 128), (8, 128, 256),
                                   (256, 512, 128), (64, 384, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int4_matmul_sweep(M, K, N, dtype):
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (M, K), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (K, N),
                          jnp.float32) * 0.1
    packed, scale = quantize_int4(w)
    ref = int4_matmul_ref(x, packed, scale)
    out = int4_matmul(x, packed, scale, block_m=min(128, M),
                      block_n=min(128, N), interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol,
                               atol=tol * np.abs(np.asarray(ref)).max())


@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2), (4, 1)])
@pytest.mark.parametrize("window", [0, 13])
@pytest.mark.parametrize("blocks", [(16, 16), (32, 64), (64, 32)])
def test_flash_attention_sweep(h, hkv, window, blocks):
    bq, bk = blocks
    b, s, dh = 2, 64, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, hkv, dh))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    b, s, h, hkv, dh = 1, 64, 4, 2, 32
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, hkv, dh), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("pos", [0, 63, 127])
@pytest.mark.parametrize("block_s", [32, 128])
@pytest.mark.parametrize("h,hkv", [(8, 2), (4, 4)])
def test_decode_kernel_sweep(pos, block_s, h, hkv):
    b, S, dh = 2, 128, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 5), (b, h, dh))
    kc = jax.random.normal(jax.random.fold_in(KEY, 6), (b, S, hkv, dh))
    vc = jax.random.normal(jax.random.fold_in(KEY, 7), (b, S, hkv, dh))
    out = decode_attention_kernel(q, kc, vc, pos, block_s=block_s,
                                  interpret=True)
    ref = decode_attention_ref(q[:, None], kc, vc, pos)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("pos", [0, 63, 127])
@pytest.mark.parametrize("h,hkv", [(8, 2), (4, 4)])
def test_decode_int4_kernel_matches_dequantized(pos, h, hkv):
    """The INT4-KV kernel (packed rows + in-VREG dequant) is numerically
    identical to the fp kernel over the pre-dequantized cache — the two
    renderings of kv_mode='int4' (TPU kernel vs XLA-fused jit) must
    agree bit-for-bit on the same packed layout."""
    from repro.core.kvstore import (dequantize_kv_rows, kv_group,
                                    quantize_kv_rows)
    b, S, dh = 2, 128, 16
    F = hkv * dh
    g = kv_group(F)
    q = jax.random.normal(jax.random.fold_in(KEY, 5), (b, h, dh))
    kc = jax.random.normal(jax.random.fold_in(KEY, 6), (b, S, hkv, dh))
    vc = jax.random.normal(jax.random.fold_in(KEY, 7), (b, S, hkv, dh))
    kq, ks = quantize_kv_rows(np.asarray(kc).reshape(b, S, F), g)
    vq, vs = quantize_kv_rows(np.asarray(vc).reshape(b, S, F), g)
    out = decode_attention_int4_kernel(
        q, jnp.asarray(kq), jnp.asarray(ks), jnp.asarray(vq),
        jnp.asarray(vs), pos, hkv=hkv, group=g, block_s=32, interpret=True)
    kd = dequantize_kv_rows(kq, ks, g, jnp.float32).reshape(b, S, hkv, dh)
    vd = dequantize_kv_rows(vq, vs, g, jnp.float32).reshape(b, S, hkv, dh)
    ref = decode_attention_kernel(q, jnp.asarray(kd), jnp.asarray(vd), pos,
                                  block_s=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    # and against the oracle over the roundtripped cache
    oracle = decode_attention_ref(q[:, None], jnp.asarray(kd),
                                  jnp.asarray(vd), pos)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=2e-5)
