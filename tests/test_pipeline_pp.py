"""StagedScheduler (pipeline-parallel offload) on the virtual clock.

Staging is a *scheduling* change only: the staged scheduler must emit
bit-identical outputs to the single-stage ``PipelineScheduler`` for any
(stages, depth, warm, mode) combination, while each stage's private
transfer pool gives the pipeline aggregate host->device bandwidth — the
whole point of the tentpole.  Assertions are on Trace event order and
virtual timestamps, so they hold on every run by construction.
"""
import json

import pytest

from fake_model import run_virtual, run_virtual_pp, stage_split
from repro.core.replay import (ReplayKnobs, best_stage_depth, replay,
                               steady_step_s, step_times)
from repro.core.tasks import TaskType, Trace


def _span(trace):
    return max(e.t_end for e in trace.events())


def _ev_key(e):
    return (e.kind, e.name, e.t_start, e.t_end, e.nbytes, e.extent)


# ---------------------------------------------------------------------------
# stage tiling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,stages", [(4, 2), (6, 2), (6, 3), (7, 3),
                                      (8, 4), (5, 5)])
def test_stage_split_tiles_contiguously(n, stages):
    cuts = stage_split(n, stages)
    assert cuts[0][0] == 0 and cuts[-1][1] == n
    for (_, hi), (lo, _) in zip(cuts, cuts[1:]):
        assert hi == lo
    sizes = [hi - lo for lo, hi in cuts]
    assert max(sizes) - min(sizes) <= 1


# ---------------------------------------------------------------------------
# token parity: staged == single-stage, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["performance", "memory"])
@pytest.mark.parametrize("stages", [2, 3])
@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("warm", [False, True])
def test_token_parity_with_single_stage(mode, stages, depth, warm):
    m1, _, o1 = run_virtual(mode, n_layers=4, iters=4, warm=warm,
                            calls=2, depth=depth)
    m2, _, o2 = run_virtual_pp(n_layers=4, stages=stages, iters=4,
                               warm=warm, calls=2, depth=depth, mode=mode)
    assert o1 == o2
    # every (compute, i, j) runs exactly once per stack in both runs;
    # only the wall-clock interleaving across stages may differ (and a
    # warm staged pipeline preloads a window at the head of EACH stage,
    # so dangling load counts legitimately diverge)
    assert (sorted(c for c in m1.calls if c[0] == "compute")
            == sorted(c for c in m2.calls if c[0] == "compute"))


def test_staged_trace_meta():
    _, tr, _ = run_virtual_pp(n_layers=4, stages=2, iters=3, depth=1)
    assert tr.meta["stages"] == 2
    assert tr.meta["stage_units"] == [[0, 4], [4, 8]]
    assert tr.meta["stage_depths"] == [1, 1]
    assert {e.stage for e in tr.events()} == {0, 1}


# ---------------------------------------------------------------------------
# perf: aggregate bandwidth — the acceptance criterion of the tentpole
# ---------------------------------------------------------------------------


def test_two_stage_speedup_weight_dominated():
    """On the weight-dominated fake workload (WEIGHT_LOAD cost 10 vs
    COMPUTE 4) two stages with private transfer pools must cut the
    span by >= 1.6x: each stage streams only half the stack over its
    own link, concurrently."""
    _, tr1, o1 = run_virtual("performance", n_layers=4, iters=6, depth=1)
    _, tr2, o2 = run_virtual_pp(n_layers=4, stages=2, iters=6, depth=1)
    assert o1 == o2
    assert _span(tr1) / _span(tr2) >= 1.6


def test_no_cross_stage_load_serialization():
    """Downstream stages prime their preload window at t=0 — weight
    loads never gate on upstream activations (a serialized pipeline
    would start stage 1's first load only after stage 0's handoff)."""
    _, tr, _ = run_virtual_pp(n_layers=4, stages=2, iters=4, depth=1)
    s1_loads = [e for e in tr.events()
                if e.kind == TaskType.WEIGHT_LOAD.value and e.stage == 1]
    assert s1_loads and min(e.t_start for e in s1_loads) == 0.0


def test_per_stage_residency_bounds():
    """Each stage honors its own preload window: at most depth+1 weight
    buffers resident per stage (the +1 is the layer currently under
    compute), independent of the other stages' traffic."""
    depth = 2
    model, tr, _ = run_virtual_pp(n_layers=4, stages=2, iters=4,
                                  depth=depth)
    ev = {}
    for e in tr.events():
        ev.setdefault(e.name, []).append(e)
    for lo, hi in stage_split(model.n, 2):
        points = []
        for j in range(lo, hi):
            for k, w in enumerate(ev.get(f"w[{j}]", [])):
                comp = ev.get(f"c[{k},{j}]")
                if comp:
                    points.append((w.t_start, 1))
                    points.append((comp[0].t_end, -1))
        cur = peak = 0
        for _, d in sorted(points):      # (t, -1) sorts before (t, +1)
            cur += d
            peak = max(peak, cur)
        assert 0 < peak <= depth + 1, (lo, hi, peak)


# ---------------------------------------------------------------------------
# fill/drain accounting + stage-tag round-trip
# ---------------------------------------------------------------------------


def test_stage_bubbles_report():
    _, tr, _ = run_virtual_pp(n_layers=4, stages=2, iters=6, depth=1)
    sb = tr.report()["stage_bubbles"]
    assert set(sb) == {0, 1}
    span = _span(tr)
    for s, b in sb.items():
        assert b["span_s"] == span
        assert b["busy_s"] > 0.0
        assert b["fill_s"] >= 0.0 and b["drain_s"] >= 0.0
    # stage 1 waits for the first microbatch (fill), stage 0 finishes
    # while stage 1 still flushes the last one (drain)
    assert sb[1]["fill_s"] > sb[0]["fill_s"]
    assert sb[0]["drain_s"] > sb[1]["drain_s"] == 0.0


def test_stage_tag_survives_json_round_trip():
    _, tr, _ = run_virtual_pp(n_layers=3, stages=2, iters=2, depth=1)
    rt = Trace.from_json(json.dumps(tr.to_json()))
    assert ([(e.name, e.stage) for e in rt.events()]
            == [(e.name, e.stage) for e in tr.events()])
    assert rt.report()["stage_bubbles"] == tr.report()["stage_bubbles"]


def test_single_stage_json_has_no_stage_keys():
    """Fixtures recorded before pipeline parallelism stay byte-stable:
    the stage tag is emitted only when set."""
    _, tr, _ = run_virtual("performance", n_layers=3, iters=2)
    assert all("stage" not in ev for ev in tr.to_json()["events"])


# ---------------------------------------------------------------------------
# staged replay: bit-for-bit and the (stages, depth) planner
# ---------------------------------------------------------------------------


def test_staged_replay_bit_for_bit():
    _, tr, _ = run_virtual_pp(n_layers=4, stages=2, iters=6, depth=1)
    res = replay(tr)                       # no knobs: as recorded
    assert res.step_times_s == step_times(tr)
    assert (sorted(map(_ev_key, res.trace.events()))
            == sorted(map(_ev_key, tr.events())))


def test_replay_stages_knob_halves_weight_bound_steps():
    """What-if: replaying a single-stage weight-bound recording at
    stages=2 predicts the aggregate-bandwidth steady step."""
    _, tr, _ = run_virtual("performance", n_layers=4, iters=6, depth=1)
    res = replay(tr, ReplayKnobs(stages=2))
    assert res.steady_step_s == steady_step_s(tr) / 2


def test_best_stage_depth_beats_single_stage():
    _, tr, _ = run_virtual("performance", n_layers=4, iters=6, depth=1)
    (stages, depth), preds = best_stage_depth(tr, stage_cap=3, depth_cap=3)
    assert set(preds) == {(s, d) for s in (1, 2, 3) for d in (1, 2, 3)}
    assert preds[(stages, depth)] == min(preds.values())
    assert stages > 1                       # weight-bound: staging wins
    assert preds[(2, 2)] < preds[(1, 2)] < preds[(1, 1)]
