"""Speculative decoding through the offload pipeline, proven bit-exact.

Greedy accept/reject makes speculative decode a *scheduling* change
only: for ANY proposal stream the emitted tokens are bit-identical to
non-speculative greedy decode.  This file asserts that promise across
the full parity matrix — engine {OffloadedServingEngine, PipelinedLM}
x depth {1, 2} x weights {fp32, int4} x kv_mode {fp32, int4} — with a
deliberately BAD draft (seeded pseudo-random proposals exercising the
rejection/truncate path), plus an oracle draft forcing full acceptance
(the truncate-is-a-no-op boundary), the real device-resident
``ResidentDraft`` end-to-end, the DraftPolicy/EngineSpec resolution
seam, and a hypothesis property suite for the shared accept kernel.
"""
import argparse

import numpy as np
import pytest

from fake_model import FakeDraft, OracleDraft
from repro.configs import get_config, scaled_down
from repro.configs.base import (ATTN, DENSE, MOE, LayerSpec, ModelConfig,
                                MoEConfig)
from repro.core.draft import ResidentDraft, accept_length, accepted_tokens
from repro.core.engine import PipelinedLM
from repro.serving import (EngineSpec, OffloadedServingEngine, Request,
                           create_engine)
from repro.serving.spec import (DraftPolicy, SpecError,
                                UnsupportedModelError, add_spec_args,
                                build_lm, draft_policy_for,
                                spec_decode_capability, spec_from_args)

try:                                  # optional test dep
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

CFG = ModelConfig(name="pipo-tiny", num_layers=3, d_model=128, num_heads=4,
                  num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
                  pattern=(LayerSpec(ATTN, DENSE),))

MOE_CFG = ModelConfig(name="pipo-moe", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
                      pattern=(LayerSpec(ATTN, MOE),),
                      moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128,
                                    num_shared=1, shared_d_ff=128))


# ---------------------------------------------------------------------------
# serving parity matrix: FakeDraft vs non-speculative reference
# ---------------------------------------------------------------------------


def _prompts(n=3, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, (5 + i,)).astype(np.int32)
            for i in range(n)]


def _serve_engine(quant, kv, depth=1):
    plan = EngineSpec(arch=CFG.name, cfg=CFG, offload=True,
                      placement="host", pipeline="performance", b_max=2,
                      max_len=64, quant=quant, kv_mode=kv,
                      depth=depth).resolve()
    return create_engine(plan)


def _serve(eng, prompts, max_new=6):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new=max_new))
    done = eng.run()
    out = {r.rid: r.out for r in done}
    eng.shutdown()
    return out


_REF = {}                         # (quant, kv) -> non-speculative tokens


def _ref_tokens(quant, kv):
    key = (quant, kv)
    if key not in _REF:
        _REF[key] = _serve(_serve_engine(quant, kv), _prompts())
    return _REF[key]


@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("kv", ["fp32", "int4"])
@pytest.mark.parametrize("quant", [None, "int4"])
def test_serving_spec_parity_matrix(quant, kv, depth):
    """The acceptance criterion: speculative greedy decode emits the
    SAME token stream as non-speculative greedy decode — with a bad
    draft (mostly-rejected proposals), at every depth, under INT4
    weight streaming and INT4 KV streaming.  Rejections exercise the
    truncate + drop-stale-preloads path every few steps; 3 requests
    through 2 slots exercise slot reuse with a live draft cache."""
    eng = _serve_engine(quant, kv, depth)
    eng.attach_draft(FakeDraft(CFG.vocab_size, seed=3), 3)
    got = _serve(eng, _prompts())
    assert got == _ref_tokens(quant, kv)
    assert eng.stats["spec_steps"] > 0
    assert eng.stats["spec_proposed"] > 0
    # a bad draft rejects most proposals but parity never depends on it
    assert eng.stats["spec_accepted"] <= eng.stats["spec_proposed"]


def test_serving_oracle_draft_full_acceptance():
    """OracleDraft replays the recorded non-speculative stream, so the
    target agrees with every proposal: acceptance == proposals, each
    verify pass emits k+1 tokens, truncate is a no-op — and the stream
    still matches bit-for-bit."""
    prompt = _prompts(1)[:1]
    ref = _serve(_serve_engine(None, "fp32"), prompt, max_new=8)
    eng = _serve_engine(None, "fp32")
    eng.attach_draft(OracleDraft([ref[0]], prompt_len=len(prompt[0])), 3)
    got = _serve(eng, prompt, max_new=8)
    assert got == ref
    assert eng.stats["spec_steps"] > 0
    assert eng.stats["spec_accepted"] == eng.stats["spec_proposed"] > 0
    for s in eng.trace.meta["spec_steps"]:
        assert s["accepts"] == [s["k"]] * len(s["accepts"])


def test_serving_spec_trace_meta_stamped():
    """The trace carries what replay()/benchmarks need to cost a
    speculative schedule: spec_k plus one spec_steps record per verify
    pass (k, primed weight loads, draft seconds, per-slot acceptance
    lengths), consistent with the engine's stats counters."""
    eng = _serve_engine(None, "fp32")
    eng.attach_draft(FakeDraft(CFG.vocab_size, seed=1), 3)
    _serve(eng, _prompts(2))
    meta = eng.trace.meta
    assert meta["spec_k"] == 3
    steps = meta["spec_steps"]
    assert len(steps) == eng.stats["spec_steps"] > 0
    for s in steps:
        assert 1 <= s["k"] <= 3
        assert s["primed"] >= 0 and s["draft_s"] >= 0.0
        assert all(0 <= a <= s["k"] for a in s["accepts"])
    assert (sum(sum(s["accepts"]) for s in steps)
            == eng.stats["spec_accepted"])


def test_serving_draft_prefilled_on_admission():
    """Every admitted request's prompt is prefilled into the draft's
    device cache (the draft is slaved to the engine's slot state)."""
    eng = _serve_engine(None, "fp32")
    draft = FakeDraft(CFG.vocab_size)
    eng.attach_draft(draft, 2)
    prompts = _prompts(3)
    _serve(eng, prompts)
    assert sorted(n for _, n in draft.prefills) == sorted(
        len(p) for p in prompts)
    assert all(0 <= slot < 2 for slot, _ in draft.prefills)


def test_serving_resident_draft_end_to_end():
    """The real path, no fakes: a plan with draft_arch builds a
    device-resident ResidentDraft in the engine constructor and the
    emitted stream still matches the non-speculative engine exactly
    (the draft's quality only moves acceptance, never tokens)."""
    cfg = scaled_down(get_config("tinyllama-1.1b"))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)]
    ref = _serve(create_engine(EngineSpec(
        arch="tinyllama-1.1b", scaled=True, cfg=cfg, offload=True,
        placement="host", b_max=1, max_len=64)), prompts, max_new=5)
    eng = create_engine(EngineSpec(
        arch="tinyllama-1.1b", scaled=True, cfg=cfg, offload=True,
        placement="host", b_max=1, max_len=64,
        draft_arch="tinyllama-1.1b", spec_k=2))
    assert isinstance(eng, OffloadedServingEngine)
    assert isinstance(eng.draft, ResidentDraft)
    assert eng._spec_k == 2
    got = _serve(eng, prompts, max_new=5)
    assert got == ref
    assert eng.stats["spec_steps"] > 0


# ---------------------------------------------------------------------------
# PipelinedLM parity matrix
# ---------------------------------------------------------------------------


def _lm_plan(kv, depth, quant=None):
    return EngineSpec(arch=CFG.name, cfg=CFG, offload=True,
                      placement="host", pipeline="performance", b_max=2,
                      max_len=48, quant=quant, kv_mode=kv,
                      depth=depth).resolve()


_LM_REF = {}


def _lm_ref(kv):
    if kv not in _LM_REF:
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, 512, (2, 10)).astype(np.int32)
        toks, _ = build_lm(_lm_plan(kv, 1)).generate(prompt, gen_len=8)
        _LM_REF[kv] = (prompt, toks)
    return _LM_REF[kv]


@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("kv", ["fp32", "int4"])
def test_lm_spec_parity_matrix(kv, depth):
    """Batch generation through the same tiered stores: the uniform
    batch accepts min-over-rows proposals per step, and the stream is
    bit-identical to non-speculative generation at every depth and KV
    precision."""
    prompt, ref = _lm_ref(kv)
    lm = build_lm(_lm_plan(kv, depth))
    lm.attach_draft(FakeDraft(512, seed=5), 3)
    toks, stats = lm.generate(prompt, gen_len=8)
    np.testing.assert_array_equal(toks, ref)
    assert stats["spec_steps"] > 0
    assert stats["spec_accepted"] <= stats["spec_proposed"]


def test_lm_oracle_draft_full_acceptance():
    """Full acceptance on the uniform batch: the oracle proposes each
    row's own recorded stream, so every step emits k+1 tokens per row
    and the step count collapses toward gen_len / (k+1)."""
    prompt, ref = _lm_ref("fp32")
    lm = build_lm(_lm_plan("fp32", 1))
    lm.attach_draft(OracleDraft(list(ref), prompt_len=prompt.shape[1]), 3)
    toks, stats = lm.generate(prompt, gen_len=8)
    np.testing.assert_array_equal(toks, ref)
    assert stats["spec_accepted"] == stats["spec_proposed"] > 0
    assert stats["spec_steps"] == 2          # ceil(8 / (3+1)) verify passes


def test_lm_int4_weights_spec_parity():
    """INT4 weight streaming and speculation compose in PipelinedLM."""
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 512, (2, 10)).astype(np.int32)
    ref, _ = build_lm(_lm_plan("fp32", 1, quant="int4")).generate(
        prompt, gen_len=6)
    lm = build_lm(_lm_plan("fp32", 1, quant="int4"))
    lm.attach_draft(FakeDraft(512, seed=2), 2)
    toks, stats = lm.generate(prompt, gen_len=6)
    np.testing.assert_array_equal(toks, ref)
    assert stats["spec_steps"] > 0


# ---------------------------------------------------------------------------
# DraftPolicy / EngineSpec resolution seam
# ---------------------------------------------------------------------------


def test_spec_k_requires_draft_arch():
    with pytest.raises(SpecError, match="draft_arch"):
        EngineSpec(offload=True, spec_k=3).validate()
    with pytest.raises(SpecError, match="spec_k"):
        EngineSpec(offload=True, draft_arch="tinyllama-1.1b",
                   spec_k=0).validate()


def test_draft_vocab_must_match_target():
    with pytest.raises(SpecError, match="vocab"):
        EngineSpec(arch=CFG.name, cfg=CFG, offload=True,
                   draft_arch="tinyllama-1.1b").validate()


def test_draft_rejected_on_resident_engine():
    with pytest.raises(SpecError, match="offload"):
        EngineSpec(offload=False, draft_arch="tinyllama-1.1b").validate()


def test_draft_rejected_for_moe_target():
    # draft vocab matches (same arch), so the capability gate is what
    # fires: MoE targets can't verify k+1 tokens without re-routing
    with pytest.raises(SpecError, match="moe_ffn"):
        EngineSpec(arch="mixtral-8x7b", scaled=True, offload=True,
                   draft_arch="mixtral-8x7b").validate()


def test_spec_decode_capability():
    assert spec_decode_capability(CFG) is None
    assert spec_decode_capability(MOE_CFG) == "moe_ffn"
    assert spec_decode_capability(
        scaled_down(get_config("tinyllama-1.1b"))) is None


def test_resolve_spec_k_provenance():
    spec = EngineSpec(arch="tinyllama-1.1b", scaled=True, offload=True,
                      draft_arch="tinyllama-1.1b")
    plan = spec.resolve()
    assert plan.draft_arch == "tinyllama-1.1b" and plan.spec_k == 4
    assert plan.provenance["spec_k"].startswith("auto")
    assert "draft_arch" in plan.provenance
    explicit = EngineSpec(arch="tinyllama-1.1b", scaled=True, offload=True,
                          draft_arch="tinyllama-1.1b", spec_k=2).resolve()
    assert explicit.spec_k == 2
    assert explicit.provenance["spec_k"].startswith("explicit")
    assert "draft" in explicit.summary() and "spec_k=2" in explicit.summary()
    # JSON round-trip carries the speculation fields
    assert type(plan).from_json(plan.to_json()) == plan


def test_resolve_drops_draft_on_resident_fallback():
    """offload=None with an unsupported-for-offload target falls back to
    the resident engine and DROPS the speculation fields (provenance
    says why); draft_policy_for then returns None."""
    plan = EngineSpec(arch="tinyllama-1.1b", scaled=True,
                      placement="device",
                      draft_arch="tinyllama-1.1b").resolve()
    assert plan.engine == "resident"
    assert plan.draft_arch is None and plan.spec_k is None
    assert "dropped" in plan.provenance["draft_arch"]
    assert draft_policy_for(plan) is None


def test_draft_policy_for_plan():
    plan = EngineSpec(arch="tinyllama-1.1b", scaled=True, offload=True,
                      draft_arch="tinyllama-1.1b", spec_k=3).resolve()
    dp = draft_policy_for(plan)
    assert isinstance(dp, DraftPolicy)
    assert dp.k == 3 and dp.arch == "tinyllama-1.1b" and dp.scaled
    with pytest.raises(SpecError, match="spec_k"):
        DraftPolicy("tinyllama-1.1b", True, 0)


def test_cli_flags_round_trip():
    parser = argparse.ArgumentParser()
    add_spec_args(parser)
    args = parser.parse_args(["--offload", "--draft-arch",
                              "tinyllama-1.1b", "--spec-k", "5"])
    spec = spec_from_args(args)
    assert spec.draft_arch == "tinyllama-1.1b" and spec.spec_k == 5
    # absent flags leave speculation off
    off = spec_from_args(parser.parse_args(["--offload"]))
    assert off.draft_arch is None and off.spec_k is None


def test_attach_draft_rejects_moe_engines():
    eng = OffloadedServingEngine(MOE_CFG, b_max=1, max_len=32,
                                 placement="host")
    with pytest.raises(UnsupportedModelError) as ei:
        eng.attach_draft(FakeDraft(MOE_CFG.vocab_size), 2)
    assert ei.value.capability == "moe_ffn"
    eng.shutdown()
    lm = PipelinedLM(MOE_CFG, batch=1, max_len=32, placement="host")
    with pytest.raises(ValueError, match="dense"):
        lm.attach_draft(FakeDraft(MOE_CFG.vocab_size), 2)


# ---------------------------------------------------------------------------
# the shared accept kernel: hypothesis property suite
# ---------------------------------------------------------------------------


def _sequential_greedy(step, cur, n):
    out = []
    for _ in range(n):
        cur = step(cur)
        out.append(cur)
    return out


def _speculative_greedy(step, propose, cur, n, k):
    """Emit >= n tokens via draft-then-verify: the target's greedy map
    ``step`` scores [cur, d1..dk] and the accept kernel emits the
    matching prefix plus the bonus token — the engines' loop, distilled."""
    out = []
    while len(out) < n:
        draft = propose(cur, k)
        target = [step(cur)] + [step(d) for d in draft]
        acc = accepted_tokens(draft, target)
        out.extend(acc)
        cur = acc[-1]
    return out[:n]


if given is not None:
    @given(seed=st.integers(0, 2**32 - 1),
           k=st.integers(min_value=1, max_value=6),
           vocab=st.integers(min_value=2, max_value=32),
           n=st.integers(min_value=1, max_value=24),
           quality=st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=60, deadline=None)
    def test_spec_greedy_equals_sequential_for_any_draft(seed, k, vocab,
                                                         n, quality):
        """For EVERY greedy target map, draft quality, k, and horizon:
        the speculative stream equals the sequential stream exactly.
        ``quality`` sweeps the draft from adversarial to oracle — it
        must move nothing but the step count."""
        rng = np.random.default_rng(seed)
        table = rng.integers(0, vocab, vocab)
        step = lambda t: int(table[t % vocab])

        def propose(cur, k):
            out, c = [], cur
            for _ in range(k):
                c = step(c) if rng.random() < quality \
                    else int(rng.integers(0, vocab))
                out.append(c)
            return out

        want = _sequential_greedy(step, 0, n)
        got = _speculative_greedy(step, propose, 0, n, k)
        assert got == want

    @given(draft=st.lists(st.integers(0, 7), min_size=0, max_size=8),
           target=st.lists(st.integers(0, 7), min_size=9, max_size=9))
    @settings(max_examples=60, deadline=None)
    def test_accept_kernel_invariants(draft, target):
        """accept_length is the longest matching prefix; accepted_tokens
        is target[:a+1] with 1 <= len <= k+1; truncating the draft never
        grows acceptance."""
        a = accept_length(draft, target)
        assert 0 <= a <= len(draft)
        assert all(draft[i] == target[i] for i in range(a))
        assert a == len(draft) or draft[a] != target[a]
        toks = accepted_tokens(draft, target)
        assert toks == [int(t) for t in target[:a + 1]]
        assert 1 <= len(toks) <= len(draft) + 1
        for cut in range(len(draft)):
            assert accept_length(draft[:cut], target) == min(a, cut)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_spec_greedy_equals_sequential_for_any_draft():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_accept_kernel_invariants():
        pass


def test_accept_kernel_examples():
    """Pinned examples (run even without hypothesis): full accept,
    first-token reject, mid reject."""
    assert accept_length([1, 2, 3], [1, 2, 3, 9]) == 3
    assert accepted_tokens([1, 2, 3], [1, 2, 3, 9]) == [1, 2, 3, 9]
    assert accept_length([5, 2], [1, 2, 3]) == 0
    assert accepted_tokens([5, 2], [1, 2, 3]) == [1]
    assert accept_length([1, 9, 3], [1, 2, 3, 4]) == 1
    assert accepted_tokens([1, 9, 3], [1, 2, 3, 4]) == [1, 2]
