"""Coverage for the §Perf-pass code paths: MLA-latent ring attention,
ragged (continuous-batching) decode, INT4-weight variant, KV slot
offload/restore."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, scaled_down
from repro.models import Dist, build_model
from repro.models.attention import (decode_attention, mla_ring_attention,
                                    ref_attention)

KEY = jax.random.PRNGKey(0)


def test_mla_ring_matches_expanded_reference():
    """Latent-rotating ring (axis=None) == expand-then-attend oracle."""
    b, s, h, r, dn, dr, dv = 2, 24, 4, 12, 8, 6, 10
    q_nope = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, dn))
    q_rope = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, h, dr))
    c = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, r))
    kr = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, dr))
    w_uk = jax.random.normal(jax.random.fold_in(KEY, 5), (r, h, dn)) * 0.3
    w_uv = jax.random.normal(jax.random.fold_in(KEY, 6), (r, h, dv)) * 0.3

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = mla_ring_attention(q, c, kr, w_uk, w_uv, axis=None, q_chunk=8)

    k_nope = jnp.einsum("bsr,rhn->bshn", c, w_uk)
    v = jnp.einsum("bsr,rhv->bshv", c, w_uv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], (b, s, h, dr))], -1)
    ref = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_ragged_decode_matches_per_row_scalar_decode():
    """Vector-pos decode == scalar-pos decode applied per row."""
    b, S, h, hkv, dh = 3, 32, 4, 2, 16
    kc = jax.random.normal(jax.random.fold_in(KEY, 1), (b, S, hkv, dh))
    vc = jax.random.normal(jax.random.fold_in(KEY, 2), (b, S, hkv, dh))
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (b, 1, h, dh))
    kn = jax.random.normal(jax.random.fold_in(KEY, 4), (b, 1, hkv, dh))
    vn = jax.random.normal(jax.random.fold_in(KEY, 5), (b, 1, hkv, dh))
    pos = jnp.asarray([5, 17, 29], jnp.int32)

    out_r, kc_r, vc_r = decode_attention(q, kc, vc, kn, vn, pos, axes=())
    for i in range(b):
        o_i, kc_i, vc_i = decode_attention(
            q[i:i + 1], kc[i:i + 1], vc[i:i + 1], kn[i:i + 1], vn[i:i + 1],
            jnp.int32(int(pos[i])), axes=())
        np.testing.assert_allclose(np.asarray(out_r[i:i + 1]),
                                   np.asarray(o_i), atol=2e-5)
        np.testing.assert_allclose(np.asarray(kc_r[i:i + 1]),
                                   np.asarray(kc_i), atol=0)


def test_w4_variant_model_runs():
    """quant_weights=True: packed params exist, forward/decode still work,
    and the packed tree is ~4x smaller on the quantized leaves."""
    cfg = scaled_down(ASSIGNED["granite-8b"], d_model=128, num_heads=4,
                      num_kv_heads=4, d_ff=512, vocab_size=512)
    cfg_q = dataclasses.replace(cfg, quant_weights=True)
    m = build_model(cfg_q)
    params = m.init(KEY, jnp.float32)
    names = set(params["pat"][0])
    # ffn mats clear the >=64K-element packing threshold; tiny attention
    # projections (128x64) stay bf16 — mixed packed/plain must coexist
    assert "w_gate#q" in names and "w_gate#s" in names
    assert "w_gate" not in names and "wq" in names
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    loss = m.train_loss(params, {"tokens": toks, "labels": toks},
                        Dist.local())
    assert np.isfinite(float(loss))
    nt, caches = m.prefill(params, {"tokens": toks}, Dist.local(), 32)
    nt2, _ = m.decode_step(params, {"token": nt[:, None],
                                    "pos": jnp.int32(s)}, caches,
                           Dist.local())
    assert nt2.shape == (b,)
    # byte accounting: packed w_gate holds K*N/2 uint8 = 1/4 of bf16 bytes
    wg_q = params["pat"][0]["w_gate#q"]
    assert wg_q.dtype == jnp.uint8
    n_periods = cfg_q.num_periods
    assert wg_q.nbytes == n_periods * 128 * 512 // 2


def test_serving_offload_restore_roundtrip():
    from repro.serving import Request, ServingEngine
    cfg = scaled_down(get_config("tinyllama-1.1b"))
    eng = ServingEngine(cfg, b_max=2, max_len=48)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=7, prompt=rng.integers(
        0, cfg.vocab_size, (8,)).astype(np.int32), max_new=3))
    done = eng.run()
    assert len(done) == 1
    # the finished slot spilled its rows (epoch-1 namespace since spills
    # are namespaced per run()); wipe slot 0 and restore
    ns = eng._spill_ns(7)
    before = [np.asarray(l) for l in jax.tree_util.tree_leaves(eng.caches)]
    eng.caches = jax.tree.map(jnp.zeros_like, eng.caches)
    eng.restore_slot(0, ns)
    after = [np.asarray(l) for l in jax.tree_util.tree_leaves(eng.caches)]
    diffs = sum(float(np.abs(a).sum()) for a in after)
    assert diffs > 0, "restore_slot wrote nothing"
    # restored rows equal the offloaded rows
    flat, _ = jax.tree_util.tree_flatten_with_path(eng.caches)
    for i, (path, leaf) in enumerate(flat):
        ax = eng._batch_axis(path)
        idx = [slice(None)] * leaf.ndim
        idx[ax] = 0
        np.testing.assert_array_equal(
            np.asarray(leaf[tuple(idx)], np.float32),
            np.asarray(eng.host.get(f"{ns}/{i}"), np.float32))
