"""Data pipeline, checkpointing, fault tolerance, gradient compression,
serving engine."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_config, scaled_down
from repro.data import DataConfig, DataPipeline, MemmapSource, SyntheticSource
from repro.models import Dist, build_model
from repro.optim import AdamW, apply_updates
from repro.runtime import ErrorFeedbackCompressor, StragglerDetector
from repro.runtime.fault_tolerance import (FailureInjector, RunnerConfig,
                                           TrainRunner)
from repro.serving import Request, ServingEngine


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=100,
                     host_count=2, host_index=0)
    p0 = DataPipeline(SyntheticSource(cfg), cfg)
    b0 = p0.batch_at(5)
    b0_again = p0.batch_at(5)
    np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
    cfg1 = DataConfig(seq_len=16, global_batch=8, vocab_size=100,
                      host_count=2, host_index=1)
    b1 = DataPipeline(SyntheticSource(cfg1), cfg1).batch_at(5)
    assert not np.array_equal(b0["tokens"], b1["tokens"])  # disjoint slices
    assert b0["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


def test_data_prefetch_thread():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab_size=50, prefetch=2)
    p = DataPipeline(SyntheticSource(cfg), cfg).start()
    batches = [next(p) for _ in range(4)]
    p.stop()
    assert [b["step"] for b in batches] == [0, 1, 2, 3]


def test_memmap_source(tmp_path):
    toks = np.arange(10_000, dtype=np.int32) % 777
    path = str(tmp_path / "corpus.bin")
    MemmapSource.write_corpus(path, toks)
    cfg = DataConfig(seq_len=32, global_batch=2, vocab_size=777)
    src = MemmapSource(cfg, path)
    a = src.sample(3, 0)
    b = src.sample(3, 0)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (33,)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    save_checkpoint(str(tmp_path), 7, tree, meta={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored, manifest = restore_checkpoint(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert manifest["note"] == "x"


def test_async_checkpointer_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert len(steps) <= 2 and 4 in steps


# ---------------------------------------------------------------------------
# fault tolerance: failure injection + resume reproduces the trajectory
# ---------------------------------------------------------------------------

def _make_training(tmp_path, fail_at=None, max_steps=12):
    cfg = scaled_down(get_config("tinyllama-1.1b"))
    m = build_model(cfg)
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    dist = Dist.local()

    def init_state():
        params = m.init(jax.random.PRNGKey(0), jnp.float32)
        return params, opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: m.train_loss(p, batch, dist))(params)
        upd, opt_state, _ = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, {"loss": loss}

    dcfg = DataConfig(seq_len=24, global_batch=2, vocab_size=cfg.vocab_size)
    data = DataPipeline(SyntheticSource(dcfg), dcfg)
    rcfg = RunnerConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=4,
                        max_steps=max_steps)
    return TrainRunner(rcfg, step, init_state, data, fail_at=fail_at)


@pytest.mark.slow
def test_failure_injection_and_resume(tmp_path):
    # uninterrupted reference run
    ref = _make_training(tmp_path / "ref").run()
    # crashed run: dies at step 6 (after the step-4 checkpoint)
    crashed = _make_training(tmp_path / "crash", fail_at=6)
    with pytest.raises(FailureInjector):
        crashed.run()
    crashed.ckpt.wait()
    assert latest_step(str((tmp_path / "crash") / "ckpt")) == 4
    # restart: resumes from step 4, finishes, final losses must match the
    # uninterrupted run exactly (deterministic data + state-only resume)
    resumed = _make_training(tmp_path / "crash").run()
    assert resumed["final_step"] == ref["final_step"]
    np.testing.assert_allclose(resumed["losses"][-4:], ref["losses"][-4:],
                               rtol=1e-5)


def test_straggler_detection():
    det = StragglerDetector(window=8, factor=2.0)
    for _ in range(8):
        det.observe([0.1, 0.1, 0.5, 0.1])   # host 2 is 5x median
    assert det.stragglers() == [2]
    stats = det.step_stats()
    assert stats["max_s"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_error_feedback_unbiased_over_time():
    ef = ErrorFeedbackCompressor()
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal((64,)) * 1e-3)}
    residual = ef.init(g_true)
    total_applied = jnp.zeros((64,))
    for _ in range(50):
        comp, residual = ef.compress(g_true, residual)
        total_applied = total_applied + ef.decompress(comp)["w"]
    # mean applied -> true gradient (error feedback kills the bias)
    np.testing.assert_allclose(np.asarray(total_applied / 50),
                               np.asarray(g_true["w"]), atol=1e-6)


def test_compression_ratio():
    from repro.runtime.compression import compress_int8, decompress_int8
    x = jnp.asarray(np.random.default_rng(1).standard_normal((1024,)))
    q, s = compress_int8(x)
    assert q.dtype == jnp.int8
    rec = decompress_int8(q, s)
    rel = float(jnp.max(jnp.abs(rec - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01  # 1/127 quantization grid


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_continuous_batching_parity():
    cfg = scaled_down(get_config("tinyllama-1.1b"))
    eng = ServingEngine(cfg, b_max=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, (6 + i,)).astype(np.int32), max_new=5)
        for i in range(4)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 4
    assert eng.stats["decode_steps"] < 4 * 4  # batching actually shared steps
    # parity vs single-request decode for the first request
    import jax.numpy as jnp2
    m, params, dist = eng.model, eng.params, Dist.local()
    r0 = reqs[0]
    nt, caches = m.prefill(params, {"tokens": jnp2.asarray(r0.prompt)[None]},
                           dist, 64)
    outs = [int(nt[0])]
    pos = len(r0.prompt)
    for _ in range(r0.max_new - 1):
        nt, caches = m.decode_step(params, {"token": nt[:, None],
                                            "pos": jnp2.int32(pos)},
                                   caches, dist)
        outs.append(int(nt[0]))
        pos += 1
    assert outs == r0.out
    # offload accounting: finished slots spilled KV to host
    assert eng.host.bytes_used > 0
