"""Serving offload round-trips: OffloadedServingEngine (weights streamed
through the PIPO pipeline) must match the resident ServingEngine token for
token — warm or cold pipeline, FP16 or INT4 streaming, dense or MoE — and
slot offload -> restore -> resume must be lossless."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core.pipeline import ThreadPool
from repro.serving import (EngineSpec, OffloadedServingEngine, Request,
                           ServingEngine, create_engine)
from repro.serving.offload_engine import quant_roundtrip_params


def _cfg():
    return scaled_down(get_config("tinyllama-1.1b"))


def _offload_spec(cfg, **kw):
    """Spec-path construction (the canonical create_engine route); most
    tests below keep the legacy kwarg shim on purpose — both must act on
    identical plans (tests/test_spec.py asserts that)."""
    kw.setdefault("placement", "host")
    return create_engine(EngineSpec(arch=cfg.name, cfg=cfg, offload=True,
                                    **kw))


def _moe_cfg():
    return scaled_down(get_config("llama4-scout-17b-a16e"))


def _prompts(cfg, n=4, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    return [rng.integers(0, cfg.vocab_size, (6 + i,)).astype(np.int32)
            for i in range(n)]


def _serve(eng, prompts, max_new=5):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new=max_new))
    done = eng.run()
    out = {r.rid: r.out for r in done}
    if isinstance(eng, OffloadedServingEngine):
        eng.shutdown()
    return out


@pytest.fixture(scope="module")
def resident_tokens():
    cfg = _cfg()
    return _serve(ServingEngine(cfg, b_max=2, max_len=64), _prompts(cfg))


def test_offload_decode_parity_host(resident_tokens):
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="host", pipeline="performance")
    assert eng.warm                    # warm pipeline is the default
    assert _serve(eng, _prompts(cfg)) == resident_tokens


def test_offload_decode_parity_cold(resident_tokens):
    """warm=False reproduces the PR-1 cold-per-step pipeline; tokens are
    identical either way (warm is a scheduling change only)."""
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="host", pipeline="performance",
                                 warm=False)
    assert _serve(eng, _prompts(cfg)) == resident_tokens


@pytest.mark.parametrize("depth", [2, 3])
def test_offload_decode_parity_depth(resident_tokens, depth):
    """Depth-D windows are a scheduling change only: token parity with
    the resident engine holds at every preload depth.  Built through
    the spec path — a StaticDepth(D) plan must match the pre-redesign
    engine bit for bit (acceptance criterion)."""
    from repro.serving import StaticDepth
    cfg = _cfg()
    eng = _offload_spec(cfg, b_max=2, max_len=64, pipeline="performance",
                        depth=depth)
    assert isinstance(eng.preload_policy, StaticDepth)
    assert eng.sched.depth == min(depth, len(eng.units) - 1)
    assert _serve(eng, _prompts(cfg)) == resident_tokens


def test_offload_default_depth_is_budget_sized():
    """depth=None sizes the window from the memory budget
    (autoconfig.serving_preload_depth) instead of pinning the paper's
    two-resident-layer constant."""
    from repro.core.autoconfig import serving_preload_depth
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="host", pipeline="performance")
    want = serving_preload_depth(cfg, b_max=2, max_len=64, spill_cap=32)
    assert eng.sched.depth == min(want, len(eng.units) - 1) >= 1
    eng.shutdown()


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["memory", "sequential"])
def test_offload_decode_parity_modes(resident_tokens, mode):
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="host", pipeline=mode)
    assert _serve(eng, _prompts(cfg)) == resident_tokens


@pytest.mark.slow
def test_offload_decode_parity_disk(resident_tokens, tmp_path):
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="disk", pipeline="performance",
                                 disk_root=str(tmp_path / "weights"))
    assert _serve(eng, _prompts(cfg)) == resident_tokens


# ---------------------------------------------------------------------------
# INT4 weight streaming
# ---------------------------------------------------------------------------


def test_offload_int4_decode_parity():
    """INT4 streaming decodes token-identical to a resident engine holding
    the same quantize->dequantize roundtripped weights (the 'INT4
    resident path'), and the streamed bytes actually shrink."""
    cfg = _cfg()
    ref = ServingEngine(cfg, b_max=2, max_len=64)
    ref.params = quant_roundtrip_params(cfg, ref.params)
    ref_tokens = _serve(ref, _prompts(cfg))

    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="host", pipeline="performance",
                                 quant="int4")
    int4_bytes = sum(eng.weights.nbytes(u.key) for u in eng.units)
    assert _serve(eng, _prompts(cfg)) == ref_tokens

    fp32 = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                  placement="host")
    fp32_bytes = sum(fp32.weights.nbytes(u.key) for u in fp32.units)
    fp32.shutdown()
    assert int4_bytes < 0.5 * fp32_bytes      # packed nibbles + scales


@pytest.mark.parametrize("depth", [2, 3])
def test_offload_int4_depth_parity(depth):
    """Acceptance criterion: parity holds at every depth/quant combo —
    an INT4 StaticDepth(D) plan still matches the roundtripped resident
    reference token for token."""
    cfg = _cfg()
    ref = ServingEngine(cfg, b_max=2, max_len=64)
    ref.params = quant_roundtrip_params(cfg, ref.params)
    ref_tokens = _serve(ref, _prompts(cfg))
    eng = _offload_spec(cfg, b_max=2, max_len=64, pipeline="performance",
                        quant="int4", depth=depth)
    assert _serve(eng, _prompts(cfg)) == ref_tokens


def test_int4_quant_changes_tokens_vs_fp16():
    """Sanity: the INT4 path really quantizes (its reference differs from
    the plain FP32 params for at least one leaf)."""
    cfg = _cfg()
    eng = ServingEngine(cfg, b_max=1, max_len=32)
    q = quant_roundtrip_params(cfg, eng.params)
    diffs = 0
    for a, b in zip(jax.tree_util.tree_leaves(eng.params),
                    jax.tree_util.tree_leaves(q)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            diffs += 1
    assert diffs > 0


# ---------------------------------------------------------------------------
# Tiered KV: live-row slabs + INT4 KV streaming
# ---------------------------------------------------------------------------


def test_kv_load_ships_live_rows_not_the_slab():
    """Live-row slicing on the real engine: with ONE short request in a
    4-slot engine, every decode KV_LOAD's traced bytes sit strictly
    below the allocated (b_max, max_len) slab, the live extent is
    recorded on the event, and fp32 tokens still match the resident
    engine bit for bit (the padding is value-invisible)."""
    cfg = _cfg()
    prompt = _prompts(cfg, 1)[0]
    ref = ServingEngine(cfg, b_max=4, max_len=64)
    ref.submit(Request(rid=0, prompt=prompt.copy(), max_new=5))
    want = ref.run()[0].out

    eng = OffloadedServingEngine(cfg, b_max=4, max_len=64,
                                 placement="host", pipeline="performance")
    eng.submit(Request(rid=0, prompt=prompt.copy(), max_new=5))
    got = eng.run()[0].out
    assert got == want
    kv_loads = [e for e in eng.trace.events()
                if e.kind == "kv_load" and e.nbytes]
    assert kv_loads
    slab = max(eng.kvstore.slab_nbytes(j) for j in range(len(eng.units)))
    assert all(e.nbytes < slab for e in kv_loads)
    # one active slot, short positions: extents are (1, pos)-shaped
    assert all(e.extent is not None and e.extent[0] == 1
               for e in kv_loads)
    assert max(e.extent[1] for e in kv_loads) < 64
    # and the whole traced KV volume sits far below slab * loads
    rep = eng.pipeline_report()
    assert rep["per_kind"]["kv_load"]["bytes"] < \
        0.5 * slab * rep["per_kind"]["kv_load"]["count"]
    assert rep["per_kind"]["kv_save"]["bytes"] > 0     # saves accounted
    eng.shutdown()


@pytest.fixture(scope="module")
def kv_roundtrip_tokens():
    """Resident reference whose newly-written cache rows roundtrip
    through the store's exact quantize->dequantize (fp32 weights)."""
    from repro.serving import KVRoundtripServingEngine
    cfg = _cfg()
    return _serve(KVRoundtripServingEngine(cfg, b_max=2, max_len=64),
                  _prompts(cfg))


@pytest.fixture(scope="module")
def kv_int4_roundtrip_tokens():
    """Same reference with INT4-roundtripped weights on top — the
    weights-int4 x kv-int4 corner."""
    from repro.serving import KVRoundtripServingEngine
    cfg = _cfg()
    ref = KVRoundtripServingEngine(cfg, b_max=2, max_len=64)
    ref.params = quant_roundtrip_params(cfg, ref.params)
    return _serve(ref, _prompts(cfg))


@pytest.mark.parametrize("depth", [1, 2])
def test_kv_int4_decode_parity(kv_roundtrip_tokens, depth):
    """Acceptance criterion: kv_mode='int4' decodes token-identical to
    the KV-roundtripped resident reference at every preload depth
    (fp32 weights)."""
    cfg = _cfg()
    eng = _offload_spec(cfg, b_max=2, max_len=64, pipeline="performance",
                        kv_mode="int4", depth=depth)
    assert eng.kvstore.kv_mode == "int4"
    assert _serve(eng, _prompts(cfg)) == kv_roundtrip_tokens


@pytest.mark.parametrize("depth", [1, 2])
def test_kv_int4_weights_int4_decode_parity(kv_int4_roundtrip_tokens,
                                            depth):
    """Acceptance criterion: the full INT4 corner — packed weights AND
    packed KV — still matches its roundtripped resident reference at
    depth {1, 2}."""
    cfg = _cfg()
    eng = _offload_spec(cfg, b_max=2, max_len=64, pipeline="performance",
                        quant="int4", kv_mode="int4", depth=depth)
    assert _serve(eng, _prompts(cfg)) == kv_int4_roundtrip_tokens


def test_kv_int4_actually_quantizes(resident_tokens, kv_roundtrip_tokens):
    """Sanity: INT4 KV is a real precision change (the reference differs
    from the plain resident tokens), so the parity above is not
    vacuous; and the traced KV bytes shrink accordingly."""
    assert kv_roundtrip_tokens != resident_tokens
    cfg = _cfg()
    eng4 = _offload_spec(cfg, b_max=2, max_len=64, kv_mode="int4")
    fp = _offload_spec(cfg, b_max=2, max_len=64)
    assert eng4.kvstore.slab_nbytes(0) < 0.5 * fp.kvstore.slab_nbytes(0)
    fp.shutdown()
    eng4.shutdown()


def test_kv_int4_spill_restore_resume_parity():
    """Preempt/resume under INT4 KV: packed rows spill and restore
    losslessly, so the interrupted stream equals the uninterrupted
    one."""
    from repro.serving import KVRoundtripServingEngine
    cfg = _cfg()
    prompt = _prompts(cfg, 1)[0]
    ref = KVRoundtripServingEngine(cfg, b_max=2, max_len=64)
    ref.submit(Request(rid=0, prompt=prompt.copy(), max_new=8))
    uninterrupted = ref.run()[0].out

    eng = _offload_spec(cfg, b_max=2, max_len=64, kv_mode="int4")
    eng.submit(Request(rid=0, prompt=prompt.copy(), max_new=8))
    eng._admit()
    done = []
    for _ in range(3):
        eng._decode_step(done)
    assert not done
    eng.preempt_slot(0)
    done = eng.run()
    eng.shutdown()
    assert done[0].out == uninterrupted
    assert eng.stats["slot_restores"] == 1


def test_kv_mode_moe_decode_parity():
    """INT4 KV composes with MoE routed-union serving (every mixer kind
    the offloaded engine carries streams through the same store)."""
    from repro.serving import KVRoundtripServingEngine
    cfg = _moe_cfg()
    prompts = _prompts(cfg, 3)
    ref = _serve(KVRoundtripServingEngine(cfg, b_max=2, max_len=48),
                 prompts, max_new=4)
    eng = _offload_spec(cfg, b_max=2, max_len=48, pipeline="performance",
                        kv_mode="int4")
    assert _serve(eng, prompts, max_new=4) == ref


def test_moe_quant_resident_parity():
    """moe_quant='int4' — the resident engine's routed expert stacks
    packed once at load, unpacked per step through the fused-int4 path —
    decodes token-identical to a resident engine holding the SAME
    roundtripped stacks, and the resident expert bytes shrink >6x."""
    import jax.numpy as jnp
    from repro.quant.int4 import dequantize_int4_stack
    cfg = _moe_cfg()
    prompts = _prompts(cfg, 3)
    eng = create_engine(EngineSpec(arch=cfg.name, cfg=cfg, offload=False,
                                   b_max=2, max_len=48, moe_quant="int4"))
    assert eng.plan.moe_quant == "int4"
    assert "moe_quant" in eng.plan.provenance
    stacks = ("w_gate", "w_up", "w_down")
    packed_tables = [
        (part, i, t) for part in ("pat", "rem")
        for i, t in enumerate(eng.params.get(part, ()))
        if isinstance(t, dict) and "w_gate#q" in t]
    assert packed_tables                      # every MoE table packed
    for _, _, t in packed_tables:
        assert not any(n in t for n in stacks)     # fp leaves replaced
        assert "wg" in t                           # router stays fp

    # reference: plain resident engine holding the dequantized stacks
    ref = ServingEngine(cfg, b_max=2, max_len=48)
    packed_b = fp_b = 0
    ref_parts = dict(ref.params)
    for part, i, t in packed_tables:
        rt = dict(ref_parts[part][i])
        for n in stacks:
            fp_b += rt[n].nbytes
            packed_b += t[n + "#q"].nbytes + t[n + "#s"].nbytes
            rt[n] = dequantize_int4_stack(t[n + "#q"], t[n + "#s"],
                                          jnp.float32)
        ref_parts[part] = (ref_parts[part][:i] + (rt,)
                           + ref_parts[part][i + 1:])
    ref.params = ref_parts
    assert packed_b * 6 < fp_b                # real resident-memory win
    assert _serve(eng, prompts, max_new=4) == _serve(ref, prompts,
                                                     max_new=4)


def test_moe_quant_dropped_on_offloaded_plan():
    """moe_quant is a resident-engine feature: an offloaded plan drops
    it with provenance (experts stream through the unit quant path)."""
    cfg = _moe_cfg()
    plan = EngineSpec(arch=cfg.name, cfg=cfg, offload=True,
                      moe_quant="int4").resolve()
    assert plan.moe_quant is None
    assert "dropped" in plan.provenance["moe_quant"]


# ---------------------------------------------------------------------------
# MoE routed-union serving
# ---------------------------------------------------------------------------


def test_offload_moe_decode_parity():
    """MoE serving (router resident, per-expert streaming) matches the
    resident engine token for token."""
    cfg = _moe_cfg()
    prompts = _prompts(cfg, 3)
    ref = _serve(ServingEngine(cfg, b_max=2, max_len=48), prompts,
                 max_new=4)
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=48,
                                 placement="host", pipeline="performance")
    assert _serve(eng, prompts, max_new=4) == ref


def test_offload_moe_loads_routed_union_only():
    """Decode loads only the routed-expert union per MoE layer — asserted
    on trace bytes: expert WEIGHT_LOAD volume over the decode steps is
    exactly union-size * per-expert bytes, strictly below the whole
    bank."""
    cfg = _moe_cfg()              # scaled llama4: 4 experts, top_k=1
    m = cfg.moe
    eng = OffloadedServingEngine(cfg, b_max=1, max_len=48,
                                 placement="host", pipeline="performance")
    eng.submit(Request(rid=0, prompt=_prompts(cfg, 1)[0], max_new=4))
    eng._admit()                               # prefill (routes per-token)
    expert_keys = [k for u in eng.units if u.moe for k in u.expert_keys]
    snap = dict(eng.weights.load_counts)
    done = []
    while eng.slots[0] is not None:
        eng._decode_step(done)
    assert len(done) == 1

    n_moe_units = sum(1 for u in eng.units if u.moe)
    steps = eng.stats["decode_steps"]
    decode_loads = sum(eng.weights.load_counts.get(k, 0) - snap.get(k, 0)
                       for k in expert_keys)
    # b=1, top_k=1: the routed union is exactly ONE expert per MoE unit
    # per decode step — 4x below the whole bank
    assert decode_loads == steps * n_moe_units
    assert decode_loads < steps * n_moe_units * m.num_experts
    # and the trace carries the byte accounting: expert WEIGHT_LOAD bytes
    # equal loads * per-expert buffer size (scheduler-named unit loads use
    # 'w[0]'-style names; expert tasks are named by their store key)
    per_expert = {k: eng.weights.nbytes(k) for k in expert_keys}
    traced = eng.trace.bytes_moved("weight_load", "w[u")
    assert traced == sum(eng.weights.load_counts.get(k, 0) * b
                         for k, b in per_expert.items())
    eng.shutdown()


def test_offload_moe_compact_combine_stacks_union_bytes():
    """The combine boundary is |union|-proportional too (the PR-2 gap):
    the compact combine stacks exactly the loaded experts — one fp32
    slot per expert WEIGHT_LOAD — never a zero-padded full bank, so
    total stacked bytes sit strictly below the bank-sized staging the
    padded combine used to do every MoE step."""
    cfg = _moe_cfg()              # scaled llama4: 4 experts, top_k=1
    m = cfg.moe
    eng = OffloadedServingEngine(cfg, b_max=1, max_len=48,
                                 placement="host", pipeline="performance")
    eng.submit(Request(rid=0, prompt=_prompts(cfg, 1)[0], max_new=4))
    done = eng.run()
    assert len(done) == 1
    expert_keys = [k for u in eng.units if u.moe for k in u.expert_keys]
    total_loads = sum(eng.weights.load_counts.get(k, 0)
                      for k in expert_keys)
    d, f = cfg.d_model, m.expert_d_ff
    per_expert_fp32 = 4 * (2 * d * f + f * d)    # w_gate + w_up + w_down
    assert eng.stats["moe_stack_bytes"] == total_loads * per_expert_fp32
    n_moe_units = sum(1 for u in eng.units if u.moe)
    n_combines = (eng.stats["prefills"]
                  + eng.stats["decode_steps"]) * n_moe_units
    assert eng.stats["moe_stack_bytes"] \
        < n_combines * m.num_experts * per_expert_fp32
    eng.shutdown()


# ---------------------------------------------------------------------------
# Warm pipeline on the live engine
# ---------------------------------------------------------------------------


def test_warm_engine_preloads_across_decode_steps():
    """On the live engine the warm scheduler leaves at most one pending
    weight preload between steps, and steady-state decode produces more
    w[0] loads than decode steps would cold-start (the preloads ARE the
    per-step loads)."""
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="host", pipeline="performance")
    _serve(eng, _prompts(cfg, 2), max_new=4)
    # every generate() call left a w[0] preload pending for the next one;
    # totals: one w[0] per call + one dangling => calls + 1
    calls = eng.stats["prefills"] + eng.stats["decode_steps"]
    w0 = [e for e in eng.trace.events()
          if e.kind == "weight_load" and e.name == "w[0]"]
    assert len(w0) == calls + 1


# ---------------------------------------------------------------------------
# Slot spill: epoch namespacing + LRU retention
# ---------------------------------------------------------------------------


def test_slot_offload_restore_resume_parity():
    """Preempt a mid-flight request (KV spilled to host), resume it via
    restore_slot, and the full token stream must equal an uninterrupted
    run — the slot-granularity PIPO KV round-trip."""
    cfg = _cfg()
    prompt = _prompts(cfg, 1)[0]

    ref = ServingEngine(cfg, b_max=2, max_len=64)
    ref.submit(Request(rid=0, prompt=prompt.copy(), max_new=8))
    uninterrupted = ref.run()[0].out

    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64, placement="host")
    eng.submit(Request(rid=0, prompt=prompt.copy(), max_new=8))
    eng._admit()
    done = []
    for _ in range(3):
        eng._decode_step(done)
    assert not done
    eng.preempt_slot(0)
    assert eng.slots[0] is None and eng.queue     # parked, back in queue
    assert eng.queue[0].spill_ns                  # namespace recorded
    done = eng.run()
    eng.shutdown()
    assert done[0].out == uninterrupted
    assert eng.stats["slot_restores"] == 1


def test_resident_async_slot_offload_roundtrip():
    """ServingEngine with a transfer pool spills finished slots as KV_SAVE
    tasks (overlapped), and the spilled rows still restore exactly.

    The two requests finish on different steps, so the first spill is
    followed by further decode steps whose jitted _decode donates the old
    cache buffers — the snapshot must not alias them (read-after-free on
    the pool thread otherwise)."""
    cfg = _cfg()
    pool = ThreadPool(2)
    eng = ServingEngine(cfg, b_max=2, max_len=48, kv_pool=pool)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=7, prompt=rng.integers(
        0, cfg.vocab_size, (8,)).astype(np.int32), max_new=3))
    eng.submit(Request(rid=8, prompt=rng.integers(
        0, cfg.vocab_size, (9,)).astype(np.int32), max_new=12))
    done = eng.run()
    eng.shutdown()                 # drain in-flight slot saves
    pool.shutdown()
    assert len(done) == 2
    ns7, ns8 = eng._spill_ns(7), eng._spill_ns(8)   # epoch 1 namespaces
    assert any(k.startswith(ns7 + "/") for k in eng.host.keys())
    assert any(k.startswith(ns8 + "/") for k in eng.host.keys())
    eng.restore_slot(0, ns7)
    # restored rows equal the rows present when the request finished
    flat, _ = jax.tree_util.tree_flatten_with_path(eng.caches)
    for i, (path, leaf) in enumerate(flat):
        ax = eng._batch_axis(path)
        idx = [slice(None)] * leaf.ndim
        idx[ax] = 0
        np.testing.assert_array_equal(
            np.asarray(leaf[tuple(idx)]), eng.host.get(f"{ns7}/{i}"))


def test_spill_epoch_namespacing_across_runs():
    """Reused rids across run() calls land in distinct namespaces, so a
    later run can never alias (or clobber) an earlier run's spill."""
    cfg = _cfg()
    eng = ServingEngine(cfg, b_max=1, max_len=48)
    p = _prompts(cfg, 1)[0]
    eng.submit(Request(rid=0, prompt=p.copy(), max_new=2))
    eng.run()
    eng.submit(Request(rid=0, prompt=p.copy(), max_new=2))
    eng.run()
    eng.shutdown()
    keys = eng.host.keys()
    assert any(k.startswith("e1/slot0/") for k in keys)
    assert any(k.startswith("e2/slot0/") for k in keys)


def test_spill_lru_eviction_prefers_finished_over_parked():
    """With spill_cap=1: a finished request's spill is evicted when the
    cap is exceeded, but a parked (preempted) request's spill is pinned —
    it must survive to resume losslessly."""
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=1, max_len=64,
                                 placement="host", spill_cap=1)
    prompts = _prompts(cfg, 2)
    # park rid=0 mid-flight: its spill namespace becomes pinned
    eng.submit(Request(rid=0, prompt=prompts[0].copy(), max_new=8))
    eng._admit()
    done = []
    eng._decode_step(done)
    eng.preempt_slot(0)
    parked_ns = eng.queue[0].spill_ns
    assert parked_ns
    # slip rid=1 in FRONT of the parked request so it occupies the single
    # slot; the parked one stays queued (and therefore pinned) meanwhile
    eng.submit(Request(rid=1, prompt=prompts[1].copy(), max_new=2))
    eng.queue.reverse()                # [rid1, parked rid0]
    eng._admit()
    assert eng.slots[0] is not None and eng.slots[0].rid == 1
    while eng.slots[0] is not None:    # finish rid1 -> its slot spills
        eng._decode_step(done)
    # cap=1 with two spills (parked + rid1's): rid1's was evicted, the
    # parked one survived the LRU pass despite being older
    assert eng.stats["spill_evictions"] == 1
    assert any(k.startswith(parked_ns + "/") for k in eng.host.keys()), \
        "parked request's spill was evicted"
    assert not any(k.startswith(eng._spill_ns(1) + "/")
                   for k in eng.host.keys())
    # the parked request still resumes losslessly after the eviction
    resumed = eng.run()
    eng.shutdown()
    ref = OffloadedServingEngine(cfg, b_max=1, max_len=64,
                                 placement="host")
    ref.submit(Request(rid=0, prompt=prompts[0].copy(), max_new=8))
    expect = ref.run()[0].out
    ref.shutdown()
    assert [r.out for r in resumed if r.rid == 0] == [expect]


def test_spill_cap_never_evicts_a_just_preempted_request():
    """Regression: the request being preempted must already count as
    parked when its own spill is recorded — with spill_cap=1 and another
    parked request pinning the LRU, the second preemption's spill used
    to be evicted immediately, and its resume raised KeyError."""
    cfg = _cfg()
    eng = ServingEngine(cfg, b_max=2, max_len=64, spill_cap=1)
    prompts = _prompts(cfg, 2)
    for rid in (0, 1):
        eng.submit(Request(rid=rid, prompt=prompts[rid].copy(), max_new=8))
    eng._admit()
    done = []
    eng._decode_step(done)
    eng.preempt_slot(0)               # parks A (pins its spill)
    eng.preempt_slot(1)               # parks B — must be pinned too
    for r in eng.queue:
        assert any(k.startswith(r.spill_ns + "/")
                   for k in eng.host.keys()), f"rid {r.rid} spill evicted"
    resumed = {r.rid: r.out for r in eng.run()}
    # both resumed losslessly: same tokens as an uninterrupted run
    ref = ServingEngine(cfg, b_max=2, max_len=64)
    for rid in (0, 1):
        ref.submit(Request(rid=rid, prompt=prompts[rid].copy(), max_new=8))
    expect = {r.rid: r.out for r in ref.run()}
    assert resumed == expect


def test_offload_pipeline_report_populated():
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64, placement="host")
    _serve(eng, _prompts(cfg, 2), max_new=3)
    rep = eng.pipeline_report()
    assert rep["span_s"] > 0
    assert rep["per_kind"]["compute"]["count"] > 0
    assert rep["per_kind"]["weight_load"]["count"] > 0
    assert rep["per_kind"]["weight_load"]["bytes"] > 0
    assert rep["per_kind"]["kv_load"]["count"] > 0
    assert rep["per_kind"]["kv_save"]["count"] > 0
    assert 0 < rep["compute_util"] <= 1
    assert abs(rep["compute_util"] + rep["bubble_frac"] - 1.0) < 1e-9


# ---------------------------------------------------------------------------
# Pipeline-parallel staging (--stages): per-stage tiered stores + pools
# ---------------------------------------------------------------------------


def _pp_engine(cfg, **kw):
    kw.setdefault("b_max", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("pipeline", "performance")
    kw.setdefault("stages", 2)
    return _offload_spec(cfg, **kw)


@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("quant,kv_mode",
                         [(None, "fp32"), ("int4", "fp32"),
                          (None, "int4"), ("int4", "int4")])
def test_pp_two_stage_decode_parity(request, quant, kv_mode, depth):
    """Acceptance criterion: a 2-stage engine (each stage its own tiered
    weight/KV store, transfer pool and preload window, activations
    microbatched between them) decodes token-identical to the resident
    reference across the full quant x kv_mode x depth matrix — staging
    is a scheduling change only."""
    cfg = _cfg()
    if kv_mode == "int4":
        from repro.serving import KVRoundtripServingEngine
        ref = KVRoundtripServingEngine(cfg, b_max=2, max_len=64)
    else:
        ref = ServingEngine(cfg, b_max=2, max_len=64)
    if quant == "int4":
        ref.params = quant_roundtrip_params(cfg, ref.params)
    want = _serve(ref, _prompts(cfg))

    kw = dict(depth=depth)
    if quant:
        kw["quant"] = quant
    if kv_mode != "fp32":
        kw["kv_mode"] = kv_mode
    eng = _pp_engine(cfg, **kw)
    assert eng.n_stages == 2
    assert eng.stage_bounds == [(0, 1), (1, 2)]
    assert _serve(eng, _prompts(cfg)) == want


def test_pp_trace_carries_stage_structure():
    """The staged engine's trace is stage-tagged end to end: meta records
    the tiling, events carry both stage ids, the report grows the
    stage_bubbles bucket — and each stage streams over its OWN link
    (aggregate bandwidth is the whole point)."""
    cfg = _cfg()
    eng = _pp_engine(cfg)
    _serve(eng, _prompts(cfg, 2), max_new=3)
    assert eng.trace.meta["stages"] == 2
    assert eng.trace.meta["stage_units"] == [[0, 1], [1, 2]]
    assert {e.stage for e in eng.trace.events()} == {0, 1}
    assert set(eng.pipeline_report()["stage_bubbles"]) == {0, 1}
    s0, s1 = eng.weights.stores
    assert s0.link is not s1.link
    assert eng.kvstore.stores[0].link is s0.link
    assert eng.kvstore.stores[1].link is s1.link


def test_pp_both_stages_preload_weights():
    """Every stage primes its own window: decode steps show stage-tagged
    weight loads from BOTH stages, and the downstream stage's loads are
    issued by its own pool (no cross-stage load serialization)."""
    cfg = _cfg()
    eng = _pp_engine(cfg)
    _serve(eng, _prompts(cfg, 2), max_new=4)
    by_stage = {}
    for e in eng.trace.events():
        if e.kind == "weight_load":
            by_stage.setdefault(e.stage, []).append(e)
    assert set(by_stage) == {0, 1}
    # the fake-free engine names units globally: stage 1 loads w[1]
    assert {e.name for e in by_stage[1]} == {"w[1]"}
    assert len(by_stage[1]) > 1


def test_pp_spill_restore_resume_parity():
    """Preempt/resume under staging: each stage's KV store spills into
    its own namespace (ns/s<stage>), and the interrupted stream still
    equals the uninterrupted one."""
    cfg = _cfg()
    prompt = _prompts(cfg, 1)[0]
    ref = ServingEngine(cfg, b_max=2, max_len=64)
    ref.submit(Request(rid=0, prompt=prompt.copy(), max_new=8))
    uninterrupted = ref.run()[0].out

    eng = _pp_engine(cfg)
    eng.submit(Request(rid=0, prompt=prompt.copy(), max_new=8))
    eng._admit()
    done = []
    for _ in range(3):
        eng._decode_step(done)
    assert not done
    eng.preempt_slot(0)
    done = eng.run()
    eng.shutdown()
    assert done[0].out == uninterrupted
    assert eng.stats["slot_restores"] == 1


def test_pp_stage_count_clamps_to_units():
    """stages > n_units resolves to one unit per stage, not an error —
    the scaled test config has two schedulable units."""
    cfg = _cfg()
    eng = _pp_engine(cfg, stages=8)
    assert eng.n_stages == 2
    assert eng.plan.stages == 2
    assert "clamped" in eng.plan.provenance["stages"]
    _serve(eng, _prompts(cfg, 1), max_new=2)
