"""Serving offload round-trips: OffloadedServingEngine (weights streamed
through the PIPO pipeline) must match the resident ServingEngine token for
token, and slot offload -> restore -> resume must be lossless."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core.pipeline import ThreadPool
from repro.serving import OffloadedServingEngine, Request, ServingEngine


def _cfg():
    return scaled_down(get_config("tinyllama-1.1b"))


def _prompts(cfg, n=4, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    return [rng.integers(0, cfg.vocab_size, (6 + i,)).astype(np.int32)
            for i in range(n)]


def _serve(eng, prompts, max_new=5):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new=max_new))
    done = eng.run()
    out = {r.rid: r.out for r in done}
    if isinstance(eng, OffloadedServingEngine):
        eng.shutdown()
    return out


@pytest.fixture(scope="module")
def resident_tokens():
    cfg = _cfg()
    return _serve(ServingEngine(cfg, b_max=2, max_len=64), _prompts(cfg))


def test_offload_decode_parity_host(resident_tokens):
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="host", pipeline="performance")
    assert _serve(eng, _prompts(cfg)) == resident_tokens


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["memory", "sequential"])
def test_offload_decode_parity_modes(resident_tokens, mode):
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="host", pipeline=mode)
    assert _serve(eng, _prompts(cfg)) == resident_tokens


@pytest.mark.slow
def test_offload_decode_parity_disk(resident_tokens, tmp_path):
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64,
                                 placement="disk", pipeline="performance",
                                 disk_root=str(tmp_path / "weights"))
    assert _serve(eng, _prompts(cfg)) == resident_tokens


def test_slot_offload_restore_resume_parity():
    """Preempt a mid-flight request (KV spilled to host), resume it via
    restore_slot, and the full token stream must equal an uninterrupted
    run — the slot-granularity PIPO KV round-trip."""
    cfg = _cfg()
    prompt = _prompts(cfg, 1)[0]

    ref = ServingEngine(cfg, b_max=2, max_len=64)
    ref.submit(Request(rid=0, prompt=prompt.copy(), max_new=8))
    uninterrupted = ref.run()[0].out

    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64, placement="host")
    eng.submit(Request(rid=0, prompt=prompt.copy(), max_new=8))
    eng._admit()
    done = []
    for _ in range(3):
        eng._decode_step(done)
    assert not done
    eng.preempt_slot(0)
    assert eng.slots[0] is None and eng.queue     # parked, back in queue
    done = eng.run()
    eng.shutdown()
    assert done[0].out == uninterrupted
    assert eng.stats["slot_restores"] == 1


def test_resident_async_slot_offload_roundtrip():
    """ServingEngine with a transfer pool spills finished slots as KV_SAVE
    tasks (overlapped), and the spilled rows still restore exactly.

    The two requests finish on different steps, so the first spill is
    followed by further decode steps whose jitted _decode donates the old
    cache buffers — the snapshot must not alias them (read-after-free on
    the pool thread otherwise)."""
    cfg = _cfg()
    pool = ThreadPool(2)
    eng = ServingEngine(cfg, b_max=2, max_len=48, kv_pool=pool)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=7, prompt=rng.integers(
        0, cfg.vocab_size, (8,)).astype(np.int32), max_new=3))
    eng.submit(Request(rid=8, prompt=rng.integers(
        0, cfg.vocab_size, (9,)).astype(np.int32), max_new=12))
    done = eng.run()
    eng.shutdown()                 # drain in-flight slot saves
    pool.shutdown()
    assert len(done) == 2
    assert any(k.startswith("slot7/") for k in eng.host.keys())
    assert any(k.startswith("slot8/") for k in eng.host.keys())
    before = jax.tree_util.tree_map(np.asarray, eng.caches)
    eng.restore_slot(0, 7)
    # restored rows equal the rows present when the request finished
    flat, _ = jax.tree_util.tree_flatten_with_path(eng.caches)
    for i, (path, leaf) in enumerate(flat):
        ax = eng._batch_axis(path)
        idx = [slice(None)] * leaf.ndim
        idx[ax] = 0
        np.testing.assert_array_equal(
            np.asarray(leaf[tuple(idx)]), eng.host.get(f"slot7/{i}"))


def test_offload_pipeline_report_populated():
    cfg = _cfg()
    eng = OffloadedServingEngine(cfg, b_max=2, max_len=64, placement="host")
    _serve(eng, _prompts(cfg, 2), max_new=3)
    rep = eng.pipeline_report()
    assert rep["span_s"] > 0
    assert rep["per_kind"]["compute"]["count"] > 0
    assert rep["per_kind"]["weight_load"]["count"] > 0
    assert rep["per_kind"]["kv_load"]["count"] > 0
    assert rep["per_kind"]["kv_save"]["count"] > 0
    assert 0 < rep["compute_util"] <= 1
    assert abs(rep["compute_util"] + rep["bubble_frac"] - 1.0) < 1e-9
