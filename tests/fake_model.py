"""Deterministic fake model for PipelineScheduler tests.

Used with ``core.pipeline.VirtualPool``: every task executes synchronously
(single-threaded, deterministic call order) while its start/end times are
assigned on a virtual timeline from the fixed per-type COSTS below —
ordering invariants are asserted on ``Trace`` virtual timestamps, never on
wall-clock, so there are no sleeps and no timing races.
"""
from repro.core.pipeline import PipelineScheduler, VirtualPool
from repro.core.tasks import TaskType

# virtual durations: weight loads dominate (the offloading regime), KV
# transfers cheaper than compute, saves slower than loads (write path)
COSTS = {TaskType.WEIGHT_LOAD: 10.0, TaskType.COMPUTE: 4.0,
         TaskType.KV_LOAD: 2.0, TaskType.KV_SAVE: 3.0}


def cost_fn(task):
    return COSTS[task.kind]


class FakeModel:
    """Layer stack [mha, mlp] * n_layers; records scheduler callbacks in
    call order and validates producer->consumer handles."""

    def __init__(self, n_layers: int = 3):
        self.n = 2 * n_layers
        self.calls = []

    def is_mha(self, j):
        return j % 2 == 0

    def load_weights(self, j):
        self.calls.append(("w", -1, j))
        return f"w{j}"

    def release_weights(self, j, handle):
        self.calls.append(("rel", -1, j))

    def load_kv(self, i, j):
        self.calls.append(("kv_load", i, j))
        return f"kv{i},{j}"

    def save_kv(self, i, j, kv):
        self.calls.append(("kv_save", i, j))

    def compute(self, i, j, x, w, kv):
        assert w == f"w{j}", (w, j)
        if self.is_mha(j):
            assert kv == f"kv{i},{j}", (kv, i, j)
        self.calls.append(("compute", i, j))
        return x + 1, ("new_kv" if self.is_mha(j) else None)

    def finalize(self, i, x):
        return x


def run_virtual(mode: str, n_layers: int = 3, iters: int = 3):
    """Drive the real scheduler over the fake model on a virtual clock;
    returns (model, trace, outputs)."""
    model = FakeModel(n_layers)
    pool = VirtualPool(3, cost_fn=cost_fn)
    sched = PipelineScheduler(model.n, mode, pool=pool, trace=pool.trace)
    outs = sched.generate(model, lambda i: 0, iters)
    sched.shutdown()
    return model, pool.trace, outs
