"""Deterministic fake models for PipelineScheduler tests.

Used with ``core.pipeline.VirtualPool``: every task executes synchronously
(single-threaded, deterministic call order) while its start/end times are
assigned on a virtual timeline from the fixed per-type COSTS below —
ordering invariants are asserted on ``Trace`` virtual timestamps, never on
wall-clock, so there are no sleeps and no timing races.

``FakeModel`` is the plain dense stack.  ``FakeMoEModel`` mirrors the
engines' routed-union MoE path: its MoE units gate first, then submit one
WEIGHT_LOAD per *routed* expert through the pool from inside the compute
callback — exactly how ``OffloadedServingEngine._compute_moe`` overlaps
expert streaming with compute.
"""
import numpy as np

from repro.core.pipeline import (PipelineScheduler, StagedScheduler,
                                 VirtualPool)
from repro.core.tasks import Task, TaskType, Trace, VirtualClock

# virtual durations: weight loads dominate (the offloading regime), KV
# transfers cheaper than compute, saves slower than loads (write path)
COSTS = {TaskType.WEIGHT_LOAD: 10.0, TaskType.COMPUTE: 4.0,
         TaskType.KV_LOAD: 2.0, TaskType.KV_SAVE: 3.0}

# fixed per-task payload sizes the fake model reports through the
# scheduler's byte-accounting hooks — per-kind byte totals on the trace
# are then exactly count * constant, assertable in the virtual tests
NBYTES = {TaskType.WEIGHT_LOAD: 1000, TaskType.KV_LOAD: 40,
          TaskType.KV_SAVE: 8}
KV_EXTENT = (2, 7)                 # fake live (batch, len) on KV loads


def cost_fn(task):
    return COSTS[task.kind]


class FakeModel:
    """Layer stack [mha, mlp] * n_layers; records scheduler callbacks in
    call order and validates producer->consumer handles."""

    def __init__(self, n_layers: int = 3):
        self.n = 2 * n_layers
        self.calls = []

    def is_mha(self, j):
        return j % 2 == 0

    def load_weights(self, j):
        self.calls.append(("w", -1, j))
        return f"w{j}"

    def weight_nbytes(self, j):
        return NBYTES[TaskType.WEIGHT_LOAD]

    def release_weights(self, j, handle):
        self.calls.append(("rel", -1, j))

    def load_kv(self, i, j):
        self.calls.append(("kv_load", i, j))
        return f"kv{i},{j}"

    def kv_nbytes(self, i, j):
        return NBYTES[TaskType.KV_LOAD]

    def kv_extent(self, i, j):
        return KV_EXTENT

    def save_kv(self, i, j, kv):
        self.calls.append(("kv_save", i, j))

    def kv_save_nbytes(self, i, j):
        return NBYTES[TaskType.KV_SAVE]

    def compute(self, i, j, x, w, kv):
        assert w == f"w{j}", (w, j)
        if self.is_mha(j):
            assert kv == f"kv{i},{j}", (kv, i, j)
        self.calls.append(("compute", i, j))
        return x + 1, ("new_kv" if self.is_mha(j) else None)

    def finalize(self, i, x):
        return x


class FakeMoEModel(FakeModel):
    """[mha, moe] * n_layers with ``n_experts`` experts per MoE unit.
    ``routed(i, j)`` gives the per-iteration routed union; the compute
    callback submits one expert WEIGHT_LOAD per routed expert through the
    pool (set by ``run_virtual_moe``) and waits them — the routed-union
    streaming pattern of the engines, visible on the virtual trace."""

    EXPERT_NBYTES = 1000

    def __init__(self, n_layers: int = 2, n_experts: int = 4, top_k: int = 2):
        super().__init__(n_layers)
        self.n_experts = n_experts
        self.top_k = top_k
        self.pool = None               # injected by run_virtual_moe
        self.expert_loads = []         # (i, j, e) in load order

    def is_moe(self, j):
        return j % 2 == 1

    def routed(self, i, j):
        """Deterministic routed union: top_k distinct experts rotating
        with the iteration so successive steps hit different subsets."""
        return sorted({(i + j + k) % self.n_experts
                       for k in range(self.top_k)})

    def compute(self, i, j, x, w, kv):
        assert w == f"w{j}", (w, j)
        if self.is_mha(j):
            assert kv == f"kv{i},{j}", (kv, i, j)
        self.calls.append(("compute", i, j))
        if self.is_moe(j):
            tasks = []
            for e in self.routed(i, j):
                t = Task(TaskType.WEIGHT_LOAD, f"exp[{j}][{e}]",
                         lambda i=i, j=j, e=e: self._load_expert(i, j, e))
                t.nbytes = self.EXPERT_NBYTES
                self.pool.submit(t)
                tasks.append(t)
            for t in tasks:
                t.wait()
        return x + 1, ("new_kv" if self.is_mha(j) else None)

    def _load_expert(self, i, j, e):
        self.expert_loads.append((i, j, e))
        return f"exp{j},{e}"


def run_virtual(mode: str, n_layers: int = 3, iters: int = 3,
                warm: bool = False, calls: int = 1, depth: int = 1):
    """Drive the real scheduler over the fake model on a virtual clock;
    ``calls`` generate() invocations of ``iters`` iterations each (warm
    schedulers keep their pipeline state across calls; ``depth`` is the
    preload window).  Returns (model, trace, outputs-of-last-call)."""
    model = FakeModel(n_layers)
    pool = VirtualPool(3, cost_fn=cost_fn)
    sched = PipelineScheduler(model.n, mode, pool=pool, trace=pool.trace,
                              warm=warm, depth=depth)
    outs = None
    for _ in range(calls):
        outs = sched.generate(model, lambda i: 0, iters)
    sched.shutdown()
    return model, pool.trace, outs


def stage_split(n: int, stages: int):
    """Contiguous near-even unit split, [(lo, hi)] per stage — the same
    balanced tiling the spec resolver uses."""
    bounds = [round(s * n / stages) for s in range(stages + 1)]
    return [(bounds[s], bounds[s + 1]) for s in range(stages)]


def run_virtual_pp(n_layers: int = 3, stages: int = 2, iters: int = 4,
                   warm: bool = False, calls: int = 1, depth: int = 1,
                   mode: str = "performance"):
    """Drive the STAGED scheduler over the fake model: per-stage
    ``VirtualPool``s (one virtual clock + 3 transfer slots each — every
    stage owns its own link) sharing ONE trace, microbatched activation
    handoff between contiguous stage slices.  Returns (model, trace,
    outputs-of-last-call); outputs match ``run_virtual`` bit for bit
    (staging is a scheduling change only)."""
    model = FakeModel(n_layers)
    trace = Trace(clock=VirtualClock())
    pools = [VirtualPool(3, trace=trace, cost_fn=cost_fn,
                         clock=VirtualClock()) for _ in range(stages)]
    sched = StagedScheduler(stage_split(model.n, stages), mode, pools=pools,
                            trace=trace, warm=warm,
                            depths=[depth] * stages)
    outs = None
    for _ in range(calls):
        outs = sched.generate(model, lambda i: 0, iters)
    sched.shutdown()
    return model, trace, outs


def run_virtual_moe(mode: str = "performance", n_layers: int = 2,
                    iters: int = 2, warm: bool = False, calls: int = 1,
                    depth: int = 1):
    """Same as run_virtual but over FakeMoEModel (routed-union expert
    loads submitted from inside compute)."""
    model = FakeMoEModel(n_layers)
    pool = VirtualPool(3, cost_fn=cost_fn)
    sched = PipelineScheduler(model.n, mode, pool=pool, trace=pool.trace,
                              warm=warm, depth=depth)
    model.pool = sched.pool
    outs = None
    for _ in range(calls):
        outs = sched.generate(model, lambda i: 0, iters)
    sched.shutdown()
    return model, pool.trace, outs


class FakeTrafficModel(FakeModel):
    """Composite-x fake mirroring the offloaded engine's MIXED steps
    (chunked prefill riding the decode batch): x = (x_dec, x_chunk),
    both legs advanced by one compute under the SAME weights handle —
    so the trace shows one WEIGHT_LOAD per layer per step whether or
    not a chunk is in flight (the tentpole scheduling invariant)."""

    def compute(self, i, j, x, w, kv):
        assert w == f"w{j}", (w, j)
        if self.is_mha(j):
            assert kv == f"kv{i},{j}", (kv, i, j)
        self.calls.append(("compute", i, j))
        xd, xc = x
        return ((None if xd is None else xd + 1,
                 None if xc is None else xc + 1),
                "new_kv" if self.is_mha(j) else None)

    def finalize(self, i, x):
        return x


def run_virtual_traffic(n_layers: int = 3, steps: int = 4, depth: int = 1,
                        chunk_steps=(1, 2)):
    """Drive the warm scheduler through ``steps`` serving steps on the
    virtual clock, one generate() call each; steps listed in
    ``chunk_steps`` carry a prefill chunk alongside the decode batch
    (composite x).  Returns (model, trace, per-step outputs)."""
    model = FakeTrafficModel(n_layers)
    pool = VirtualPool(3, cost_fn=cost_fn)
    sched = PipelineScheduler(model.n, "performance", pool=pool,
                              trace=pool.trace, warm=True, depth=depth)
    outs = []
    for it in range(steps):
        ck = 0 if it in chunk_steps else None
        outs.append(sched.generate(model, lambda i: (0, ck), 1))
    sched.shutdown()
    return model, pool.trace, outs


# ---------------------------------------------------------------------------
# Speculative decoding fakes: proposal sources for the engines' parity
# tests, and a virtual-clock driver for the draft-then-verify schedule.
# ---------------------------------------------------------------------------


class FakeDraft:
    """Proposal stand-in for the real ``core.draft.ResidentDraft``:
    deterministic seeded pseudo-random tokens (mostly WRONG — exercising
    the rejection/truncate path).  Greedy accept/reject keeps the emitted
    stream bit-identical to non-speculative decode for ANY proposal
    source, so the engines' parity matrix injects this instead of paying
    for a second real model."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = int(vocab)
        self.rng = np.random.default_rng(seed)
        self.prefills = []                 # (slot-or-'batch', n_tokens)

    def prefill_slot(self, slot, prompt):
        self.prefills.append((int(slot), len(prompt)))

    def prefill_batch(self, tokens):
        self.prefills.append(("batch", int(tokens.shape[1])))

    def propose(self, tokens, pos, k):
        b = len(np.asarray(tokens).reshape(-1))
        return self.rng.integers(0, self.vocab, (b, k)).astype(np.int32)


class OracleDraft(FakeDraft):
    """Proposals replayed from the recorded non-speculative stream(s) —
    the target agrees with every one, forcing FULL acceptance each step
    (the truncate-is-a-no-op boundary and the bench's best case).
    ``streams``: per-row emitted token lists; ``prompt_len``: the shared
    prompt length (uniform batch / single slot).  At a step's start the
    cache holds rows ``0..pos-1`` and the LAST emitted token (stream
    index ``pos - prompt_len``, the prefill's token not yet written back)
    is the verify input, so row r's next proposal is stream index
    ``pos[r] - prompt_len + 1``."""

    def __init__(self, streams, prompt_len: int):
        super().__init__(vocab=1)
        self.streams = [list(map(int, s)) for s in streams]
        self.prompt_len = int(prompt_len)

    def propose(self, tokens, pos, k):
        pos = np.asarray(pos).reshape(-1)
        out = np.zeros((len(pos), k), np.int32)
        for r, st in enumerate(self.streams):
            idx = int(pos[r]) - self.prompt_len + 1   # next stream index
            for t in range(k):
                out[r, t] = st[idx + t] if 0 <= idx + t < len(st) else 0
        return out


DRAFT_NAME = "draft"      # virtual draft-compute event (replay skips it)


def run_virtual_spec(iters: int = 3, n_layers: int = 3, depth: int = 1,
                     reject=(), pool_width: int = 3):
    """Drive the engines' speculative step sequence on the virtual clock:
    per decode step, ``prime_weights`` pre-submits the verify pass's
    first weight window, the draft runs as a main-thread COMPUTE while
    those loads stream, the verify runs as one warm ``generate`` call,
    and steps listed in ``reject`` finish with the engines' rejection
    sequence (``drain_saves`` + ``drop_kv_preloads``).  Returns (model,
    trace, steps) where steps[i] = dict(primed, draft=(t0, t1),
    outs)."""
    model = FakeModel(n_layers)
    pool = VirtualPool(pool_width, cost_fn=cost_fn)
    sched = PipelineScheduler(model.n, "performance", pool=pool,
                              trace=pool.trace, warm=True, depth=depth)
    steps = []
    for it in range(iters):
        primed = sched.prime_weights(model)
        d = Task(TaskType.COMPUTE, f"{DRAFT_NAME}[{it}]", lambda: None)
        pool.run_on_main(d)
        outs = sched.generate(model, lambda i: 0, 1)
        if it in reject:
            sched.drain_saves()
            sched.drop_kv_preloads()
        steps.append(dict(primed=primed, draft=(d.t_start, d.t_end),
                          outs=outs))
    sched.shutdown()
    return model, pool.trace, steps
