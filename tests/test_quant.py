"""INT4 quantization: bijection, error bounds, tree quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional test dep
from hypothesis import given, settings, strategies as st

from repro.quant.int4 import (dequantize_int4, pack_int4, quantize_int4,
                              quantize_tree, unpack_int4)

KEY = jax.random.PRNGKey(0)


@given(st.integers(1, 8), st.integers(1, 16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_bijection(kd2, nd2, seed):
    K, N = 2 * kd2, 2 * nd2
    q = jax.random.randint(jax.random.PRNGKey(seed), (K, N), -8, 8)
    assert (unpack_int4(pack_int4(q)) == q).all()


def test_quantize_error_bound():
    w = jax.random.normal(KEY, (512, 64), jnp.float32)
    packed, scale = quantize_int4(w)
    deq = dequantize_int4(packed, scale, jnp.float32)
    # symmetric int4: |err| <= scale/2 per group
    err = jnp.abs(deq - w)
    bound = jnp.repeat(scale, 128, axis=0) * 0.5 + 1e-6
    assert bool((err <= bound).all())


def test_quantize_tree_selects_eligible():
    params = {
        "big": jnp.ones((256, 512)),
        "small": jnp.ones((4, 4)),
        "vec": jnp.ones((256,)),
        "odd": jnp.ones((100, 64)),  # K not divisible by group
    }
    qt, quantized = quantize_tree(params, min_size=1024)
    assert "big" in quantized and len(quantized) == 1
    assert set(qt["big"]) == {"packed", "scale"}
    assert qt["small"].shape == (4, 4)


def test_bytes_saved():
    w = jax.random.normal(KEY, (1024, 256), jnp.float32)
    packed, scale = quantize_int4(w)
    ratio = (packed.size + scale.size * 4) / (w.size * 2)  # vs bf16
    assert ratio < 0.3  # ~4x smaller than bf16
