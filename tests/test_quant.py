"""INT4 quantization: bijection, error bounds, tree quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                  # optional test dep: only the
    from hypothesis import given, settings, strategies as st
except ImportError:                   # property test needs it
    given = None

from repro.quant.int4 import (dequantize_int4, dequantize_int4_stack,
                              pack_int4, quantize_int4, quantize_int4_stack,
                              quantize_tree, stack_eligible, stack_group,
                              unpack_int4)

KEY = jax.random.PRNGKey(0)


if given is not None:
    @given(st.integers(1, 8), st.integers(1, 16), st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_pack_unpack_bijection(kd2, nd2, seed):
        K, N = 2 * kd2, 2 * nd2
        q = jax.random.randint(jax.random.PRNGKey(seed), (K, N), -8, 8)
        assert (unpack_int4(pack_int4(q)) == q).all()
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_pack_unpack_bijection():
        pass


def test_quantize_error_bound():
    w = jax.random.normal(KEY, (512, 64), jnp.float32)
    packed, scale = quantize_int4(w)
    deq = dequantize_int4(packed, scale, jnp.float32)
    # symmetric int4: |err| <= scale/2 per group
    err = jnp.abs(deq - w)
    bound = jnp.repeat(scale, 128, axis=0) * 0.5 + 1e-6
    assert bool((err <= bound).all())


def test_quantize_tree_selects_eligible():
    params = {
        "big": jnp.ones((256, 512)),
        "small": jnp.ones((4, 4)),
        "vec": jnp.ones((256,)),
        "odd": jnp.ones((100, 64)),  # K not divisible by group
    }
    qt, quantized = quantize_tree(params, min_size=1024)
    assert "big" in quantized and len(quantized) == 1
    assert set(qt["big"]) == {"packed", "scale"}
    assert qt["small"].shape == (4, 4)


def test_stack_quantize_matches_per_slice():
    """quantize_int4_stack over (E, K, N) == quantize_int4 per slice —
    one layout, vmapped; the group defaults to gcd(K, 128) so small
    contraction dims (MoE expert stacks) stay eligible."""
    w = jax.random.normal(KEY, (3, 2, 64, 32), jnp.float32)
    g = stack_group(64)
    assert g == 64
    packed, scale = quantize_int4_stack(w)
    assert packed.shape == (3, 2, 64, 16) and packed.dtype == jnp.uint8
    assert scale.shape == (3, 2, 1, 32)
    for i in range(3):
        for j in range(2):
            p2, s2 = quantize_int4(w[i, j], g)
            assert (np.asarray(packed[i, j]) == np.asarray(p2)).all()
            np.testing.assert_array_equal(np.asarray(scale[i, j]),
                                          np.asarray(s2))
    # roundtrip with the group inferred from shapes alone
    deq = dequantize_int4_stack(packed, scale, jnp.float32)
    ref = dequantize_int4(p2, s2, jnp.float32, g)
    np.testing.assert_array_equal(np.asarray(deq[2, 1]), np.asarray(ref))
    err = jnp.abs(deq - w)
    bound = jnp.repeat(scale, g, axis=-2) * 0.5 + 1e-6
    assert bool((err <= bound).all())


def test_stack_eligible():
    assert stack_eligible((4, 64, 32))          # expert stack
    assert stack_eligible((2, 4, 64, 32))       # periods-stacked
    assert not stack_eligible((64, 32))         # 2-D: _maybe_quant's job
    assert not stack_eligible((4, 64, 31))      # odd N
    assert not stack_eligible((4, 9, 32))       # gcd(9,128)=1 < 16


def test_bytes_saved():
    w = jax.random.normal(KEY, (1024, 256), jnp.float32)
    packed, scale = quantize_int4(w)
    ratio = (packed.size + scale.size * 4) / (w.size * 2)  # vs bf16
    assert ratio < 0.3  # ~4x smaller than bf16
