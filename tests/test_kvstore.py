"""TieredKVStore: live-row slab slicing, INT4 KV packing, spill/restore,
scheduler byte accounting on the virtual clock, measured-bandwidth
feedback into AdaptiveDepth, and slot-spill LRU policy driven through
``SlotEngineBase`` with a store-backed fake engine (deterministic via
``VirtualClock`` — the spill tasks execute synchronously on the virtual
transport)."""
import numpy as np
import pytest

from repro.core.kvstore import (TieredKVStore, dequantize_kv_rows,
                                kv_eligible, kv_group, kv_roundtrip_rows,
                                quantize_kv_rows)
from repro.core.offload import HostStore, MemoryBudget
from repro.core.pipeline import PipelineScheduler, VirtualPool
from repro.core.tasks import TaskType

B_MAX, MAX_LEN, FEAT = 4, 32, (2, 16)
F = int(np.prod(FEAT))


def _store(kv_mode="fp32", n_units=2):
    shapes = [{"k": ((B_MAX, MAX_LEN) + FEAT, np.float32),
               "v": ((B_MAX, MAX_LEN) + FEAT, np.float32)}
              for _ in range(n_units)]
    kinds = [{"k": "kv", "v": "kv"} for _ in range(n_units)]
    return TieredKVStore(shapes, kinds, b_max=B_MAX, max_len=MAX_LEN,
                         kv_mode=kv_mode)


def _rows(seed, shape):
    return np.random.default_rng(seed).standard_normal(shape).astype(
        np.float32)


# ---------------------------------------------------------------------------
# live-row slabs
# ---------------------------------------------------------------------------


def test_live_load_bytes_strictly_below_slab():
    """The headline invariant: a half-full slot's KV_LOAD moves strictly
    fewer bytes than the allocated (b_max, max_len) slab."""
    st = _store()
    slab = st.slab_nbytes(0)
    live = st.load_nbytes(0, live_b=1, live_len=MAX_LEN // 2)
    assert live < slab
    assert live == slab // B_MAX // 2
    # monotone in both extents, equal to the slab at the full extent
    assert st.load_nbytes(0, 2, 8) < st.load_nbytes(0, 2, 16) \
        < st.load_nbytes(0, 4, 16) < slab
    assert st.load_nbytes(0, B_MAX, MAX_LEN) == slab


def test_live_load_pads_to_full_slab_shape_with_zeros():
    """Rows inside the live extent are the host rows; rows outside are
    zeros — and the device result always has the full slab shape, so
    jitted consumers never retrace on the live extent."""
    st = _store()
    rows = _rows(1, (MAX_LEN,) + FEAT)
    st.save_prefill(0, 1, {"k": rows, "v": rows})
    dev = st.load(0, live_b=2, live_len=10)
    got = np.asarray(dev["k"])
    assert got.shape == (B_MAX, MAX_LEN) + FEAT
    np.testing.assert_array_equal(got[1, :10], rows[:10])
    assert (got[1, 10:] == 0).all()          # beyond live_len: padded
    assert (got[2:] == 0).all()              # beyond live_b: padded
    # full-extent load is bit-identical to the raw slab (fp32 mode is
    # byte-preserving — the pre-store engines' payload exactly)
    np.testing.assert_array_equal(np.asarray(st.load(0)["k"][1]), rows)


def test_decode_save_scatters_live_rows_only():
    st = _store()
    new = _rows(2, (2, 1) + FEAT)
    pos = np.array([5, 9, 0, 0], np.int32)
    st.save_decode(0, {"k": new, "v": new}, active=[0, 1], pos=pos)
    slab = np.asarray(st.load(0)["k"])
    np.testing.assert_array_equal(slab[0, 5], new[0, 0])
    np.testing.assert_array_equal(slab[1, 9], new[1, 0])
    assert (slab[2:] == 0).all()
    assert st.save_nbytes(0, 2) == 2 * 2 * F * 4        # k+v, f32 rows


# ---------------------------------------------------------------------------
# INT4 KV packing
# ---------------------------------------------------------------------------


def test_int4_rows_quantize_roundtrip_and_zeros():
    g = kv_group(F)
    x = _rows(3, (6, F))
    rt = kv_roundtrip_rows(x, g)
    assert rt.dtype == x.dtype
    assert np.abs(rt - x).max() < np.abs(x).max() / 7 + 1e-6
    # zeros survive exactly (padded rows must stay value-invisible)
    z = kv_roundtrip_rows(np.zeros((3, F), np.float32), g)
    assert (z == 0).all()
    # deterministic: same rows -> same packed bytes
    p1, s1 = quantize_kv_rows(x, g)
    p2, s2 = quantize_kv_rows(x, g)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(s1, s2)


def test_int4_store_load_equals_roundtrip_reference():
    """Streamed rows == quantize->dequantize of the saved rows, the
    exact transformation KVRoundtripServingEngine applies — the store
    and the parity reference can never drift.  The packed layout never
    escapes the store: ``load`` dequantizes on the transfer thread and
    returns plain compute-precision leaves in every mode."""
    st = _store("int4")
    rows = _rows(4, (MAX_LEN,) + FEAT)
    st.save_prefill(0, 0, {"k": rows, "v": rows})
    dev = st.load(0, 1, MAX_LEN)
    assert sorted(dev) == ["k", "v"]
    want = kv_roundtrip_rows(rows.reshape(MAX_LEN, F)).reshape(rows.shape)
    np.testing.assert_array_equal(np.asarray(dev["k"][0], np.float32),
                                  want)


def test_int4_load_bytes_shrink_vs_fp32():
    fp, q4 = _store("fp32"), _store("int4")
    assert q4.slab_nbytes(0) < 0.5 * fp.slab_nbytes(0)
    assert q4.load_nbytes(0, 2, 8) < 0.5 * fp.load_nbytes(0, 2, 8)
    assert q4.host_nbytes() < 0.5 * fp.host_nbytes()


def test_kv_eligibility_predicate():
    assert kv_eligible("kv", (2, 16))
    assert not kv_eligible("rep", (2, 16))      # rewritten every step
    assert not kv_eligible("state", (4, 8, 16))
    assert not kv_eligible("kv", (3,))          # odd feature count
    st = TieredKVStore(
        [{"k": ((2, 8, 4), np.float32), "conv": ((2, 3, 6), np.float32)}],
        [{"k": "kv", "conv": "rep"}], b_max=2, max_len=8, kv_mode="int4")
    meta = st.leaf_meta(0)
    assert meta["k"].quant and not meta["conv"].quant


@pytest.mark.parametrize("kv_mode", ["fp32", "int4"])
def test_spill_restore_lossless(kv_mode):
    st = _store(kv_mode)
    host = HostStore()
    rows = _rows(5, (MAX_LEN,) + FEAT)
    st.save_prefill(0, 2, {"k": rows, "v": rows})
    st.save_prefill(1, 2, {"k": 2 * rows, "v": 2 * rows})
    before = {j: np.asarray(st.load(j)["k"][2]).copy() for j in range(2)}
    st.spill(host, "e1/slot7", 2)
    # clobber the slot, then restore
    st.save_prefill(0, 2, {"k": 0 * rows, "v": 0 * rows})
    st.restore(host, "e1/slot7", 2)
    for j in range(2):
        after = np.asarray(st.load(j)["k"][2])
        np.testing.assert_array_equal(after, before[j])


# ---------------------------------------------------------------------------
# truncate: the speculative rejection path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_mode", ["fp32", "int4"])
def test_truncate_then_append_bit_exact(kv_mode):
    """The speculative invariant the rejection path rests on: a slot
    that admitted k+1 verify rows, truncated back to the accepted
    prefix, and re-appended fresh rows is BIT-IDENTICAL to a store that
    never saw the rejected rows — in fp32 and in packed INT4 (zero
    packed nibbles under zero scales dequantize to exact zeros, so no
    ghost of the rejected rows survives in scales or padding)."""
    KEEP, APPEND = 8, 4
    junk = _rows(7, (MAX_LEN,) + FEAT)
    clean = junk.copy()
    clean[KEEP:] = 0                   # what an untainted slot looks like
    fresh = _rows(8, (APPEND,) + FEAT)
    other = _rows(9, (MAX_LEN,) + FEAT)
    st_t, st_ref = _store(kv_mode), _store(kv_mode)
    for st, rows in ((st_t, junk), (st_ref, clean)):
        for j in range(2):
            st.save_prefill(j, 1, {"k": rows, "v": rows})
            st.save_prefill(j, 0, {"k": other, "v": other})  # bystander
    st_t.truncate(1, KEEP)
    for st in (st_t, st_ref):          # truncate-then-append round-trip
        for t in range(APPEND):
            dec = np.zeros((2, 1) + FEAT, np.float32)
            dec[1, 0] = fresh[t]
            pos = np.full(B_MAX, KEEP + t, np.int32)
            for j in range(2):
                st.save_decode(j, {"k": dec, "v": dec}, active=[1], pos=pos)
    for j in range(2):
        for name in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(st_t.load(j)[name]),
                np.asarray(st_ref.load(j)[name]), err_msg=f"{j}/{name}")
    if kv_mode == "int4":              # live packed bytes match too, not
        live = KEEP + APPEND           # just the dequantized view; the
        for j in range(2):             # truncated tail is EXACT zeros
            for name in ("k", "v"):    # (the ref's prefill encodes zero
                lt = st_t._units[j][name]       # rows as offset-binary
                lr = st_ref._units[j][name]     # zeros under a floor
                np.testing.assert_array_equal(  # scale instead)
                    lt.packed[1, :live], lr.packed[1, :live])
                np.testing.assert_array_equal(
                    lt.scale[1, :live], lr.scale[1, :live])
                assert (lt.packed[1, live:] == 0).all()
                assert (lt.scale[1, live:] == 0).all()


@pytest.mark.parametrize("kv_mode", ["fp32", "int4"])
def test_truncate_clamps_and_zeroes(kv_mode):
    st = _store(kv_mode)
    rows = _rows(11, (MAX_LEN,) + FEAT)
    st.save_prefill(0, 2, {"k": rows, "v": rows})
    before = np.asarray(st.load(0)["k"][2]).copy()
    st.truncate(2, MAX_LEN + 99)       # beyond the slab: no-op
    np.testing.assert_array_equal(np.asarray(st.load(0)["k"][2]), before)
    st.truncate(2, -5)                 # below zero: clamp, full wipe
    assert (np.asarray(st.load(0)["k"][2]) == 0).all()


def test_truncate_leaves_non_sequence_leaves_alone():
    """Rolling-window / state leaves (kind != 'kv') carry no position
    extent — they are rewritten every step, and truncate must not touch
    them."""
    st = TieredKVStore(
        [{"k": ((2, 8, 4), np.float32), "conv": ((2, 3, 6), np.float32)}],
        [{"k": "kv", "conv": "rep"}], b_max=2, max_len=8, kv_mode="int4")
    k_rows = _rows(12, (8, 4))
    conv = _rows(13, (3, 6))
    st.save_prefill(0, 1, {"k": k_rows, "conv": conv})
    st.truncate(1, 2)
    out = st.load(0, 2, 8)
    assert (np.asarray(out["k"][1][2:]) == 0).all()
    np.testing.assert_array_equal(np.asarray(out["conv"][1]), conv)


# ---------------------------------------------------------------------------
# store through the scheduler on the virtual clock
# ---------------------------------------------------------------------------


class _StoreModel:
    """Scheduler-driveable model whose KV side IS a TieredKVStore at a
    fixed live extent — the virtual-clock rendering of the engine's
    live-row KV_LOAD payloads."""

    def __init__(self, n_layers=2, live_b=1, live_len=MAX_LEN // 2,
                 kv_mode="fp32"):
        self.n = 2 * n_layers
        self.store = _store(kv_mode, n_units=self.n)
        self.live = (live_b, live_len)

    def is_mha(self, j):
        return j % 2 == 0

    def load_weights(self, j):
        return f"w{j}"

    def release_weights(self, j, handle):
        pass

    def load_kv(self, i, j):
        return self.store.load(j, *self.live)

    def kv_nbytes(self, i, j):
        return self.store.load_nbytes(j, *self.live)

    def kv_extent(self, i, j):
        return self.live

    def save_kv(self, i, j, kv):
        rows = np.zeros((self.live[0], 1) + FEAT, np.float32)
        self.store.save_decode(j, {"k": rows, "v": rows},
                               active=range(self.live[0]),
                               pos=np.full(B_MAX, i % MAX_LEN, np.int32))

    def kv_save_nbytes(self, i, j):
        return self.store.save_nbytes(j, self.live[0])

    def compute(self, i, j, x, w, kv):
        return x + 1, ("rows" if self.is_mha(j) else None)

    def finalize(self, i, x):
        return x


def test_virtual_trace_kv_load_bytes_below_slab():
    """Acceptance criterion, on the virtual clock: KV_LOAD bytes for a
    half-full slot are strictly less than the (b_max, max_len) slab
    bytes, and the live extent is observable on every trace event."""
    model = _StoreModel(live_b=1, live_len=MAX_LEN // 2)
    pool = VirtualPool(3)
    sched = PipelineScheduler(model.n, "performance", pool=pool,
                              trace=pool.trace)
    sched.generate(model, lambda i: 0, 3)
    sched.shutdown()
    kv_loads = [e for e in pool.trace.events() if e.kind == "kv_load"]
    assert kv_loads
    slab = model.store.slab_nbytes(0)
    live = model.store.load_nbytes(0, 1, MAX_LEN // 2)
    assert all(e.nbytes == live for e in kv_loads)
    assert all(e.nbytes < slab for e in kv_loads)
    assert all(e.extent == (1, MAX_LEN // 2) for e in kv_loads)
    rep = pool.trace.report()
    assert rep["per_kind"]["kv_load"]["bytes"] == len(kv_loads) * live
    # saves are byte-accounted too (the satellite): live rows only
    assert rep["per_kind"]["kv_save"]["bytes"] == \
        rep["per_kind"]["kv_save"]["count"] * model.store.save_nbytes(0, 1)


def test_virtual_trace_int4_kv_bytes_shrink():
    """Same schedule, INT4 KV: the traced KV_LOAD volume shrinks by the
    packing ratio — quantized bytes are what the trace accounts (the
    Trace.bytes_moved satellite)."""
    traces = {}
    for mode in ("fp32", "int4"):
        model = _StoreModel(live_b=2, live_len=16, kv_mode=mode)
        pool = VirtualPool(3)
        sched = PipelineScheduler(model.n, "performance", pool=pool,
                                  trace=pool.trace)
        sched.generate(model, lambda i: 0, 2)
        sched.shutdown()
        traces[mode] = pool.trace.report()["per_kind"]["kv_load"]["bytes"]
    assert 0 < traces["int4"] < 0.5 * traces["fp32"]


# ---------------------------------------------------------------------------
# measured-bandwidth feedback into AdaptiveDepth
# ---------------------------------------------------------------------------


def _adaptive_policy(depth_cap=8):
    from repro.configs import get_config, scaled_down
    from repro.serving.spec import AdaptiveDepth
    cfg = scaled_down(get_config("tinyllama-1.1b"))
    return AdaptiveDepth(cfg, b_max=2, max_len=64,
                         budget=MemoryBudget(device=1 << 40, host=1 << 40),
                         depth_cap=depth_cap)


def test_adaptive_depth_resolves_from_measured_bandwidth():
    """A fast measured link needs no window (depth -> 1); as the
    measured bandwidth collapses, the SAME policy deepens the window up
    to the memory fit — the budget's assumed bw no longer decides."""
    from repro.serving.spec import Pressure
    pol = _adaptive_policy()
    p = Pressure(active=1, max_pos=8, kv_layer_bytes=1 << 10)
    unmeasured = pol.depth(p)          # memory model only (pre-feedback)
    assert unmeasured == 8             # huge budget: cap
    pol.set_link_profile(1 << 20)      # 1 MiB of weights per layer
    # fast link: 1 GB/s, 10 ms of compute per layer -> t_link ~1ms << t_c
    pol.observe(transfer_bytes=1 << 30, transfer_busy_s=1.0,
                compute_busy_s=0.1, layers=10)
    assert pol.depth(p) == 1
    # the link slows 100x mid-run: the window re-opens toward the cap
    for _ in range(8):
        pol.observe(transfer_bytes=1 << 30, transfer_busy_s=100.0,
                    compute_busy_s=0.1, layers=10)
    assert pol.depth(p) == 8
    assert pol.bw_ewma < 0.2 * (1 << 30)


def test_adaptive_depth_window_resizes_when_virtual_link_slows():
    """Acceptance criterion: drive the real scheduler across warm decode
    steps on the virtual clock while feeding the policy each step's
    Trace deltas (exactly what the engine's _observe_trace does); when
    the virtual link's per-byte cost jumps mid-run, the resolved window
    deepens and the scheduler re-sizes."""
    from fake_model import COSTS, NBYTES, FakeModel
    from repro.serving.spec import Pressure
    model = FakeModel(3)
    link_slowdown = [1.0]              # mutable: per-byte cost multiplier

    def cost_fn(task):
        c = COSTS[task.kind]
        if task.kind in (TaskType.WEIGHT_LOAD, TaskType.KV_LOAD):
            c *= link_slowdown[0]
        return c

    pool = VirtualPool(6, cost_fn=cost_fn)
    sched = PipelineScheduler(model.n, "performance", pool=pool,
                              trace=pool.trace, warm=True, depth=1)
    pol = _adaptive_policy(depth_cap=4)
    pol.set_link_profile(NBYTES[TaskType.WEIGHT_LOAD])
    pressure = Pressure(active=1, max_pos=8,
                        kv_layer_bytes=NBYTES[TaskType.KV_LOAD])

    depths, mark = [], 0

    def step():
        nonlocal mark
        sched.generate(model, lambda i: 0, 1)
        evs = pool.trace.events()
        new, mark = evs[mark:], len(evs)
        xfer = [e for e in new if e.kind in ("weight_load", "kv_load")]
        comp = [e for e in new if e.kind == "compute"]
        pol.observe(
            transfer_bytes=sum(e.nbytes for e in xfer),
            transfer_busy_s=sum(e.t_end - e.t_start for e in xfer),
            compute_busy_s=sum(e.t_end - e.t_start for e in comp),
            layers=len(comp))
        depths.append(sched.set_depth(pol.depth(pressure)))

    for _ in range(3):
        step()                          # steady state on the fast link
    fast = depths[-1]
    link_slowdown[0] = 40.0             # the link collapses mid-run
    for _ in range(6):
        step()
    sched.shutdown()
    assert depths[-1] > fast, depths
    assert depths[-1] == 4              # deepened to the cap


# ---------------------------------------------------------------------------
# slot-spill LRU through SlotEngineBase with a store-backed engine
# ---------------------------------------------------------------------------


class _StoreSlotEngine:
    """Deterministic SlotEngineBase subclass whose KV rows live in a
    TieredKVStore and whose spills run as VirtualPool KV_SAVE tasks —
    the LRU/pinning/epoch invariants on a virtual clock, no threads."""

    def __new__(cls, *a, **kw):
        # late import so the module-level class statement stays simple
        from repro.serving.base import SlotEngineBase

        class Impl(SlotEngineBase):
            def __init__(self, b_max=2, max_len=16, spill_cap=2,
                         pool=None, kv_mode="fp32"):
                super().__init__(cfg=None, b_max=b_max, max_len=max_len,
                                 kv_pool=pool, spill_cap=spill_cap)
                self.store = TieredKVStore(
                    [{"k": ((b_max, max_len, 4), np.float32)}],
                    [{"k": "kv"}], b_max=b_max, max_len=max_len,
                    kv_mode=kv_mode)

            def _prefill_into_slot(self, slot, req):
                rows = np.zeros((self.max_len, 4), np.float32)
                rows[:len(req.prompt)] = float(req.rid + 1)
                self.store.save_prefill(0, slot, {"k": rows})
                return 1

            def _decode_active(self, active):
                rows = np.zeros((self.b_max, 1, 4), np.float32)
                for s in active:
                    rows[s] = 100 * (self.slots[s].rid + 1) + self.pos[s]
                self.store.save_decode(0, {"k": rows}, active, self.pos)
                return np.ones(self.b_max, np.int64)

            def _offload_snapshot(self, slot):
                return slot

            def _offload_write(self, ns, slot):
                self.store.spill(self.host, ns, slot)

            def restore_slot(self, slot, ns):
                self.store.restore(self.host, ns, slot)

        return Impl(*a, **kw)


def _req(rid, n=4, max_new=3):
    from repro.serving.base import Request
    return Request(rid=rid, prompt=np.arange(n).astype(np.int32),
                   max_new=max_new)


def test_slot_spill_lru_eviction_order_virtual():
    """LRU order under epoch namespacing with the store-backed spill
    path: least-recently-written namespaces evict first, the retained
    set is exactly the most recent ``spill_cap``."""
    pool = VirtualPool(2)
    eng = _StoreSlotEngine(b_max=1, max_len=16, spill_cap=2, pool=pool)
    for rid in range(4):
        eng.submit(_req(rid))
    eng.run()
    eng.shutdown()
    # rids finish in order; cap=2 keeps the LAST two spill namespaces
    assert eng.stats["spill_evictions"] == 2
    assert list(eng._spill_lru) == [f"e1/slot{r}" for r in (2, 3)]
    keys = eng.host.keys()
    for rid in (0, 1):
        assert not any(k.startswith(f"e1/slot{rid}/") for k in keys)
    for rid in (2, 3):
        assert any(k.startswith(f"e1/slot{rid}/") for k in keys)


def test_slot_spill_parked_pinning_survives_store_refactor():
    """A parked (preempted) request's spill is pinned across later
    evictions and restores its exact store rows on resume — the
    parked-request guarantee, now routed through TieredKVStore."""
    pool = VirtualPool(2)
    eng = _StoreSlotEngine(b_max=1, max_len=16, spill_cap=1, pool=pool)
    eng.submit(_req(0, max_new=6))
    eng._admit()
    done = []
    eng._decode_step(done)
    rows_before = np.asarray(eng.store.load(0)["k"][0]).copy()
    eng.preempt_slot(0)
    parked_ns = eng.queue[0].spill_ns
    # run two more requests through the single slot: each finishing spill
    # would evict the parked one without pinning
    eng.submit(_req(1))
    eng.submit(_req(2))
    eng.queue.append(eng.queue.pop(0))       # park resumes last
    eng.run()
    eng.shutdown()
    assert eng.stats["spill_evictions"] >= 1
    assert eng.stats["slot_restores"] == 1
    # the parked namespace survived until its restore consumed it
    assert not any(k.startswith(parked_ns + "/") for k in eng.host.keys())
    # restored rows were bit-identical at resume: the decode rows the
    # resumed request then wrote extend the original prefix
    rows_after = np.asarray(eng.store.load(0)["k"][0])
    np.testing.assert_array_equal(rows_after[:4], rows_before[:4])


def test_slot_spill_epoch_namespacing_virtual():
    pool = VirtualPool(2)
    eng = _StoreSlotEngine(b_max=1, max_len=16, spill_cap=8, pool=pool)
    eng.submit(_req(0))
    eng.run()
    eng.submit(_req(0))
    eng.run()
    eng.shutdown()
    keys = eng.host.keys()
    assert any(k.startswith("e1/slot0/") for k in keys)
    assert any(k.startswith("e2/slot0/") for k in keys)
