"""Attention variants vs the reference oracle (local, single device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (decode_attention, local_decode_attention,
                                    mla_decode_attention, ref_attention,
                                    ring_attention)

KEY = jax.random.PRNGKey(0)


def _qkv(b, s, h, hkv, dh, dtype=jnp.float32):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, hkv, dh), dtype)
    return q, k, v


@pytest.mark.parametrize("h,hkv", [(8, 8), (8, 4), (8, 1)])
@pytest.mark.parametrize("window", [0, 7])
@pytest.mark.parametrize("q_chunk", [0, 8])
def test_ring_local_matches_ref(h, hkv, window, q_chunk):
    q, k, v = _qkv(2, 32, h, hkv, 16)
    ref = ref_attention(q, k, v, causal=True, window=window)
    out = ring_attention(q, k, v, axis=None, causal=True, window=window,
                         q_chunk=q_chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("pos", [0, 17, 31])
def test_decode_matches_ref(pos):
    b, s, h, hkv, dh, S = 2, 32, 8, 4, 16, 32
    q, k, v = _qkv(b, s, h, hkv, dh)
    kc = jnp.zeros((b, S, hkv, dh)).at[:, :s].set(k)
    vc = jnp.zeros((b, S, hkv, dh)).at[:, :s].set(v)
    qd = q[:, pos:pos + 1]
    out, kc2, vc2 = decode_attention(qd, kc, vc, k[:, pos:pos + 1],
                                     v[:, pos:pos + 1], jnp.int32(pos),
                                     axes=())
    ref = ref_attention(qd, k[:, :pos + 1], v[:, :pos + 1], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    assert bool((kc2[:, pos] == k[:, pos]).all())


@pytest.mark.parametrize("pos", [3, 9, 23])
def test_local_decode_rolling_buffer(pos):
    b, s, h, hkv, dh, W = 2, 32, 4, 2, 16, 8
    q, k, v = _qkv(b, s, h, hkv, dh)
    kcw = jnp.zeros((b, W, hkv, dh))
    vcw = jnp.zeros((b, W, hkv, dh))
    for p in range(pos):
        kcw = kcw.at[:, p % W].set(k[:, p])
        vcw = vcw.at[:, p % W].set(v[:, p])
    qd = q[:, pos:pos + 1]
    out, _, _ = local_decode_attention(qd, kcw, vcw, k[:, pos:pos + 1],
                                       v[:, pos:pos + 1], jnp.int32(pos), W)
    lo = max(0, pos - W + 1)
    ref = ref_attention(qd, k[:, lo:pos + 1], v[:, lo:pos + 1], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_mla_decode_matches_naive():
    b, S, pos, r, dr, H = 2, 32, 17, 12, 6, 4
    ql = jax.random.normal(jax.random.fold_in(KEY, 5), (b, 1, H, r))
    qr = jax.random.normal(jax.random.fold_in(KEY, 6), (b, 1, H, dr))
    cc = jax.random.normal(jax.random.fold_in(KEY, 7), (b, S, r))
    kr = jax.random.normal(jax.random.fold_in(KEY, 8), (b, S, dr))
    cn = jax.random.normal(jax.random.fold_in(KEY, 9), (b, 1, r))
    krn = jax.random.normal(jax.random.fold_in(KEY, 10), (b, 1, dr))
    scale = 1.0 / np.sqrt(r + dr)
    ctx, cc2, kr2 = mla_decode_attention(ql, qr, cc, kr, cn, krn,
                                         jnp.int32(pos), scale=scale, axes=())
    s = (jnp.einsum("bqhr,bsr->bhqs", ql, cc2[:, :pos + 1])
         + jnp.einsum("bqhd,bsd->bhqs", qr, kr2[:, :pos + 1])) * scale
    p = jax.nn.softmax(s, -1)
    ref = jnp.moveaxis(jnp.einsum("bhqs,bsr->bhqr", p, cc2[:, :pos + 1]), 2, 1)
    np.testing.assert_allclose(np.asarray(ctx), np.asarray(ref), atol=2e-5)
    assert bool((cc2[:, pos] == cn[:, 0]).all())
