"""PIPO pipeline scheduler: ordering invariants (Algorithm 1) via a mock
model that logs every event with timestamps."""
import threading
import time

import pytest

from repro.core.pipeline import PipelineScheduler
from repro.core.tasks import Trace


class MockModel:
    """Layer stack [mha, mlp] * n with tunable per-task latencies; records
    (event, i, j, t) tuples."""

    def __init__(self, n_layers=3, t_load=0.02, t_compute=0.01, t_kv=0.005):
        self.n = 2 * n_layers
        self.t_load, self.t_compute, self.t_kv = t_load, t_compute, t_kv
        self.events = []
        self._lock = threading.Lock()

    def _log(self, ev, i, j):
        with self._lock:
            self.events.append((ev, i, j, time.perf_counter()))

    def is_mha(self, j):
        return j % 2 == 0

    def load_weights(self, j):
        time.sleep(self.t_load)
        self._log("w_done", -1, j)
        return f"w{j}"

    def release_weights(self, j, h):
        self._log("w_release", -1, j)

    def load_kv(self, i, j):
        time.sleep(self.t_kv)
        self._log("kv_load_done", i, j)
        return f"kv{i},{j}"

    def save_kv(self, i, j, kv):
        time.sleep(self.t_kv)
        self._log("kv_save_done", i, j)

    def compute(self, i, j, x, w, kv):
        assert w == f"w{j}", (w, j)
        if self.is_mha(j):
            assert kv == f"kv{i},{j}"
        self._log("compute_start", i, j)
        time.sleep(self.t_compute)
        self._log("compute_end", i, j)
        return x + 1, ("new_kv" if self.is_mha(j) else None)

    def finalize(self, i, x):
        return x


@pytest.mark.parametrize("mode", ["performance", "memory", "sequential"])
def test_all_tasks_execute_in_every_mode(mode):
    model = MockModel(n_layers=3)
    sched = PipelineScheduler(model.n, mode)
    outs = sched.generate(model, lambda i: 0, num_iterations=3)
    sched.shutdown()
    assert outs == [model.n, model.n, model.n]  # x incremented per layer
    ev = [(e, i, j) for e, i, j, _ in model.events]
    for i in range(3):
        for j in range(model.n):
            assert ("compute_start", i, j) in ev
            if model.is_mha(j):
                assert ("kv_load_done", i, j) in ev
                assert ("kv_save_done", i, j) in ev


def test_load_completes_before_compute():
    model = MockModel()
    sched = PipelineScheduler(model.n, "performance")
    sched.generate(model, lambda i: 0, num_iterations=2)
    sched.shutdown()
    # ordered scan: a layer's weights must be loaded (and not yet released)
    # when its compute starts.  Events from pool threads may interleave but
    # each (load -> compute -> release) chain is causally ordered.
    events = sorted(model.events, key=lambda e: e[3])
    done_w = set()
    for e, i, j, ts in events:
        if e == "w_done":
            done_w.add(j)
        if e == "compute_start":
            assert j in done_w, f"compute {j} before its weight load"
        if e == "w_release":
            done_w.discard(j)


def test_kv_save_before_next_iteration_load():
    model = MockModel()
    sched = PipelineScheduler(model.n, "performance")
    sched.generate(model, lambda i: 0, num_iterations=3)
    sched.shutdown()
    t = {(e, i, j): ts for e, i, j, ts in model.events}
    for i in range(1, 3):
        for j in range(model.n):
            if model.is_mha(j):
                assert t[("kv_save_done", i - 1, j)] <= \
                    t[("kv_load_done", i, j)], \
                    f"kv load ({i},{j}) before save ({i-1},{j}) finished"


def test_performance_mode_overlaps_load_with_compute():
    """In performance mode, some weight load must complete during another
    layer's compute window (the pipeline's raison d'etre)."""
    model = MockModel(n_layers=4, t_load=0.02, t_compute=0.02)
    sched = PipelineScheduler(model.n, "performance")
    sched.generate(model, lambda i: 0, num_iterations=2)
    sched.shutdown()
    starts = {}
    computes = []
    for e, i, j, ts in model.events:
        if e == "compute_start":
            starts[(i, j)] = ts
        elif e == "compute_end" and (i, j) in starts:
            computes.append((starts[(i, j)], ts))
    loads = [ts for e, i, j, ts in model.events if e == "w_done"]
    overlapped = sum(1 for ts in loads
                     if any(s < ts < t for s, t in computes))
    assert overlapped >= 1, "no load completed inside a compute window"


def test_sequential_mode_never_overlaps():
    model = MockModel(n_layers=3, t_load=0.01, t_compute=0.01)
    sched = PipelineScheduler(model.n, "sequential")
    sched.generate(model, lambda i: 0, num_iterations=2)
    sched.shutdown()
    # sequential: every event interval is disjoint from compute intervals
    spans = []
    start = None
    for e, i, j, ts in model.events:
        if e == "compute_start":
            start = ts
        elif e == "compute_end":
            spans.append((start, ts))
    loads = [ts for e, i, j, ts in model.events if e == "w_done"]
    overlapped = sum(1 for ts in loads if any(s < ts < t for s, t in spans))
    assert overlapped == 0


def test_busy_fraction_higher_with_pipeline():
    def run(mode):
        model = MockModel(n_layers=4, t_load=0.015, t_compute=0.015)
        trace = Trace()
        sched = PipelineScheduler(model.n, mode, trace=trace)
        sched.generate(model, lambda i: 0, num_iterations=3)
        sched.shutdown()
        return trace.busy_fraction("compute")
    busy_seq = run("sequential")
    busy_perf = run("performance")
    assert busy_perf > busy_seq
