"""Real-thread PipelineScheduler integration smoke.

The scheduler's *ordering invariants* (preload overlap, single-layer
residency, save-before-load, full serialization, warm cross-call
preloads, MoE union streaming) are asserted deterministically on the
virtual clock in tests/test_pipeline_virtual.py.  This module keeps one
genuine 3-thread integration check: the real ThreadPool + Events path
completes every task, respects causality (a layer's weights are loaded
and unreleased when its compute starts), and the warm scheduler survives
repeated generate() calls without deadlock — no timing-window
assertions, so no flakes."""
import threading
import time

import pytest

from repro.core.pipeline import PipelineScheduler
from repro.core.tasks import Trace


class MockModel:
    """Layer stack [mha, mlp] * n with small real sleeps; records
    (event, i, j, t) tuples thread-safely."""

    def __init__(self, n_layers=3, t_load=0.005, t_compute=0.002,
                 t_kv=0.002):
        self.n = 2 * n_layers
        self.t_load, self.t_compute, self.t_kv = t_load, t_compute, t_kv
        self.events = []
        self._lock = threading.Lock()

    def _log(self, ev, i, j):
        with self._lock:
            self.events.append((ev, i, j, time.perf_counter()))

    def is_mha(self, j):
        return j % 2 == 0

    def load_weights(self, j):
        time.sleep(self.t_load)
        self._log("w_done", -1, j)
        return f"w{j}"

    def release_weights(self, j, h):
        self._log("w_release", -1, j)

    def load_kv(self, i, j):
        time.sleep(self.t_kv)
        self._log("kv_load_done", i, j)
        return f"kv{i},{j}"

    def save_kv(self, i, j, kv):
        time.sleep(self.t_kv)
        self._log("kv_save_done", i, j)

    def compute(self, i, j, x, w, kv):
        assert w == f"w{j}", (w, j)
        if self.is_mha(j):
            assert kv == f"kv{i},{j}"
        self._log("compute_start", i, j)
        time.sleep(self.t_compute)
        self._log("compute_end", i, j)
        return x + 1, ("new_kv" if self.is_mha(j) else None)

    def finalize(self, i, x):
        return x


@pytest.mark.parametrize("mode", ["performance", "memory", "sequential"])
def test_real_threads_complete_and_causally_ordered(mode):
    """Every task executes; weights are loaded-and-unreleased when their
    compute starts; save(i-1,j) lands before load(i,j).  These are
    causal facts (each chain synchronizes through Events), not timing
    windows, so they hold on loaded CI machines too."""
    model = MockModel(n_layers=3)
    trace = Trace()
    sched = PipelineScheduler(model.n, mode, trace=trace)
    outs = sched.generate(model, lambda i: 0, num_iterations=3)
    sched.shutdown()
    assert outs == [model.n, model.n, model.n]
    ev = [(e, i, j) for e, i, j, _ in model.events]
    for i in range(3):
        for j in range(model.n):
            assert ("compute_start", i, j) in ev
            if model.is_mha(j):
                assert ("kv_load_done", i, j) in ev
                assert ("kv_save_done", i, j) in ev
    # causal scan: weights loaded (not yet released) at compute start
    events = sorted(model.events, key=lambda e: e[3])
    done_w = set()
    for e, i, j, ts in events:
        if e == "w_done":
            done_w.add(j)
        if e == "compute_start":
            assert j in done_w, f"compute {j} before its weight load"
        if e == "w_release":
            done_w.discard(j)
    # save-before-next-load (the §3.2.1 advanced completion check)
    t = {(e, i, j): ts for e, i, j, ts in model.events}
    for i in range(1, 3):
        for j in range(model.n):
            if model.is_mha(j):
                assert t[("kv_save_done", i - 1, j)] <= \
                    t[("kv_load_done", i, j)]


def test_real_threads_warm_scheduler_across_calls():
    """Warm pipeline on real threads: repeated single-iteration calls
    (the serving decode-step pattern) complete with correct outputs and
    the cross-call KV ordering intact; drop_kv_preloads/drain_saves
    don't deadlock mid-stream."""
    model = MockModel(n_layers=2)
    sched = PipelineScheduler(model.n, "performance", warm=True)
    outs = []
    for step in range(4):
        outs += sched.generate(model, lambda i: 0, num_iterations=1)
        if step == 1:
            sched.drain_saves()
            sched.drop_kv_preloads()   # simulates a slot restore
    sched.shutdown()
    assert outs == [model.n] * 4
    t = {(e, i, j): ts for e, i, j, ts in model.events}
    for i in range(1, 4):
        for j in range(model.n):
            if model.is_mha(j) and ("kv_load_done", i, j) in t:
                assert t[("kv_save_done", i - 1, j)] <= \
                    t[("kv_load_done", i, j)]
