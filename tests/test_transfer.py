"""Data-transfer suite: merging, blockwise reads, pipelined staging."""
import numpy as np
import pytest

from repro.core.offload import DiskStore, HostStore
from repro.core.transfer import (blockwise_disk_to_host, merge_tensors,
                                 naive_disk_to_host, pipelined_disk_to_device,
                                 split_views, sweep_block_size)


def test_merge_split_roundtrip():
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.standard_normal((32, 16)).astype(np.float32),
        "b": rng.standard_normal((8,)).astype(np.float32),
        "c": rng.integers(0, 255, (4, 4)).astype(np.uint8),
    }
    buf, man = merge_tensors(tensors)
    views = split_views(buf, man)
    for k, v in tensors.items():
        np.testing.assert_array_equal(views[k], v)
    assert man.total_bytes == sum(v.nbytes for v in tensors.values())


@pytest.mark.parametrize("n_threads", [1, 3])
@pytest.mark.parametrize("block", [1 << 12, 1 << 16, 1 << 22])
def test_blockwise_equals_naive(tmp_path, n_threads, block):
    disk = DiskStore(str(tmp_path))
    rng = np.random.default_rng(1)
    arr = rng.standard_normal((512, 257)).astype(np.float32)  # odd size
    disk.put("x", arr)
    naive = naive_disk_to_host(disk, "x")
    blockwise = blockwise_disk_to_host(disk, "x", block_bytes=block,
                                       n_threads=n_threads)
    np.testing.assert_array_equal(naive, arr)
    np.testing.assert_array_equal(blockwise, arr)


def test_pipelined_to_device(tmp_path):
    disk = DiskStore(str(tmp_path))
    rng = np.random.default_rng(2)
    arr = rng.standard_normal((1024, 128)).astype(np.float32)
    disk.put("w", arr)
    dev = pipelined_disk_to_device(disk, "w", block_bytes=1 << 16)
    np.testing.assert_array_equal(np.asarray(dev), arr)


def test_block_size_sweep_runs(tmp_path):
    disk = DiskStore(str(tmp_path))
    arr = np.zeros((1 << 20,), np.uint8)  # 1MB
    disk.put("s", arr)
    out = sweep_block_size(disk, "s", sizes=[1 << 18, 1 << 20], repeats=1)
    assert len(out) == 2 and all(bw > 0 for _, bw in out)


def test_store_accounting(tmp_path):
    host = HostStore()
    a = np.zeros((1024,), np.float32)
    host.put("a", a)
    assert host.bytes_used == a.nbytes
    host.put("b", a)
    assert host.peak_bytes == 2 * a.nbytes
    host.delete("a")
    assert host.bytes_used == a.nbytes
