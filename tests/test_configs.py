"""Config registry: assigned numbers, parameter counts, cell accounting."""
import pytest

from repro.configs import ASSIGNED, all_cells, get_config, list_archs

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab, ~params B, ~active B)
    "granite-8b": (36, 4096, 32, 8, 14336, 49152, 8.0, 8.0),
    "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000, 1.1, 1.1),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144, 3.9, 3.9),
    "qwen3-8b": (36, 4096, 32, 8, 12288, 151936, 8.2, 8.2),
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064, 72.7, 72.7),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536, 398, 93),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048, 108, 17.2),
    "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280, 704, 37.6),
    "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280, 1.34, 1.34),
    "whisper-base": (6, 512, 8, 8, 2048, 51865, 0.11, 0.11),
}


def test_ten_archs_assigned():
    assert len(list_archs()) == 10
    assert set(list_archs()) == set(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_assigned_numbers(arch):
    L, d, h, kv, ff, V, pb, ab = EXPECTED[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == h and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == V


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_param_counts(arch):
    _, _, _, _, _, _, pb, ab = EXPECTED[arch]
    cfg = get_config(arch)
    total = cfg.param_count() / 1e9
    active = cfg.param_count(active_only=True) / 1e9
    assert abs(total - pb) / pb < 0.12, (arch, total)
    assert abs(active - ab) / ab < 0.12, (arch, active)


def test_cell_accounting():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(runnable) == 33 and len(skipped) == 7
    long_ok = {a for a, s, ok, _ in cells if s == "long_500k" and ok}
    assert long_ok == {"mamba2-1.3b", "jamba-1.5-large-398b", "gemma3-4b"}
    for _, _, ok, why in skipped:
        assert "full-attention" in why


def test_pattern_consistency():
    for arch in list_archs():
        cfg = get_config(arch)
        total = len(cfg.pattern) * cfg.num_periods + len(cfg.remainder)
        assert total == cfg.num_layers, arch
