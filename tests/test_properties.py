"""Property-based tests (hypothesis) for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional test dep
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.autoconfig import configure
from repro.core.memory_model import estimate
from repro.core.offload import MemoryBudget
from repro.models.common import (empty_partials, finalize_partials,
                                 merge_partials)
from repro.models.rope import rope_angles


# ---------------------------------------------------------------------------
# Online-softmax partials: merge is associative + order-independent and
# finalizing merged partials equals full softmax.
# ---------------------------------------------------------------------------

def _partials(key, sk, shape=(2, 3)):
    s = jax.random.normal(key, (*shape, sk))
    v = jax.random.normal(jax.random.fold_in(key, 1), (sk, 4))
    m = jnp.max(s, -1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, -1)
    o = p @ v
    return (m, l, o), s, v


@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(2, 5))
@settings(max_examples=20, deadline=None)
def test_merge_partials_equals_full_softmax(seed, n1, n2):
    key = jax.random.PRNGKey(seed)
    (pa, sa, va) = _partials(jax.random.fold_in(key, 1), n1)
    (pb, sb, vb) = _partials(jax.random.fold_in(key, 2), n2)
    merged = merge_partials(pa, pb)
    out = finalize_partials(*merged)
    s = jnp.concatenate([sa, sb], -1)
    v = jnp.concatenate([va, vb], 0)
    ref = jax.nn.softmax(s, -1) @ v
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # commutativity
    out2 = finalize_partials(*merge_partials(pb, pa))
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_merge_partials_associative(seed):
    key = jax.random.PRNGKey(seed)
    ps = [_partials(jax.random.fold_in(key, i), 3)[0] for i in range(3)]
    left = merge_partials(merge_partials(ps[0], ps[1]), ps[2])
    right = merge_partials(ps[0], merge_partials(ps[1], ps[2]))
    for a, b in zip(left, right):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_merge_with_empty_is_identity():
    key = jax.random.PRNGKey(0)
    (p, s, v) = _partials(key, 4)
    e = empty_partials((2, 3), 4)
    merged = merge_partials(e, p)
    np.testing.assert_allclose(np.asarray(finalize_partials(*merged)),
                               np.asarray(finalize_partials(*p)), atol=1e-6)


# ---------------------------------------------------------------------------
# Memory model (Appendix B): monotonicity + placement decisions.
# ---------------------------------------------------------------------------

@given(st.integers(1, 32), st.integers(128, 4096))
@settings(max_examples=20, deadline=None)
def test_memory_monotonic_in_batch_and_seq(b, s):
    cfg = get_config("llama3.1-8b")
    e1 = estimate(cfg, batch=b, seq=s)
    e2 = estimate(cfg, batch=b + 1, seq=s)
    e3 = estimate(cfg, batch=b, seq=s + 128)
    assert e2.kv_cache > e1.kv_cache and e3.kv_cache > e1.kv_cache
    assert e2.peak_prefill >= e1.peak_prefill
    assert e3.peak_prefill >= e1.peak_prefill


def test_autoconfig_placements():
    small = get_config("llama3.2-1b")
    big = get_config("llama3.1-70b")
    laptop = MemoryBudget()
    ac_small = configure(small, batch=1, prompt_len=512, gen_len=32,
                         budget=laptop)
    ac_big = configure(big, batch=1, prompt_len=512, gen_len=32,
                       budget=laptop)
    assert ac_small.weight_placement == "device"
    assert ac_big.weight_placement == "disk"   # 140GB > 16GB host
    ac_8b = configure(get_config("llama3.1-8b"), batch=4, prompt_len=512,
                      gen_len=32, budget=laptop)
    assert ac_8b.weight_placement in ("host", "disk")
    # int4 kernel rule: batch < 16
    a = configure(small, batch=4, prompt_len=64, gen_len=8, quant="int4")
    b_ = configure(small, batch=32, prompt_len=64, gen_len=8, quant="int4")
    assert a.use_int4_kernel and not b_.use_int4_kernel


@given(st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_preload_needs_more_memory(b):
    cfg = get_config("llama3.1-8b")
    pre = estimate(cfg, batch=b, seq=1024, preload=True)
    nopre = estimate(cfg, batch=b, seq=1024, preload=False)
    assert pre.peak_prefill >= nopre.peak_prefill
    assert pre.peak_decode >= nopre.peak_decode


# ---------------------------------------------------------------------------
# M-RoPE with equal (t,h,w) positions coincides with 1-D RoPE.
# ---------------------------------------------------------------------------

@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_mrope_degenerates_to_rope(seed):
    pos = jnp.arange(16)
    a1 = rope_angles(pos, 32, 10000.0)
    pos3 = jnp.broadcast_to(pos, (3, 16))
    a2 = rope_angles(pos3, 32, 10000.0, mrope_sections=(6, 5, 5))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-6)
