"""Per-arch smoke: reduced configs, one forward/train step + prefill/decode
consistency, output shapes, no NaNs.  (Full configs are exercised only via
the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, list_archs, scaled_down
from repro.models import Dist, build_model
from repro.models import layers as L
from repro.models import transformer as T

KEY = jax.random.PRNGKey(42)
DIST = Dist.local()

# fast default: one dense-GQA arch + one SSM arch; the rest of the matrix
# runs with --runslow (CI full job / weekly)
FAST_ARCHS = ("tinyllama-1.1b", "mamba2-1.3b")
ARCHS = [a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
         for a in list_archs()]


def _batch(cfg, b, s, key):
    batch = {"labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "embeds" and not cfg.enc_dec:
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model)) * 0.05
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model)) * 0.05
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg = scaled_down(ASSIGNED[arch])
    m = build_model(cfg)
    params = m.init(KEY, jnp.float32)
    batch = _batch(cfg, 2, 32, KEY)
    loss = m.train_loss(params, batch, DIST)
    assert np.isfinite(float(loss)), arch
    assert 2.0 < float(loss) < 12.0, (arch, float(loss))  # ~ln(V) at init


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Hidden state after [prefill(s) + decode(token s)] must match the
    full-(s+1) prefill — validates every cache type's semantics."""
    cfg = scaled_down(ASSIGNED[arch])
    m = build_model(cfg)
    params = m.init(KEY, jnp.float32)
    b, s = 2, 33
    full = s + 1
    batch = _batch(cfg, b, full, KEY)
    batch.pop("labels")
    if "tokens" in batch:
        toks = batch["tokens"]
        bs = {"tokens": toks[:, :s]}
        bf = {"tokens": toks}
        dec_in = {"token": toks[:, s:s + 1]}
    else:
        emb = batch["embeds"]
        bs = {"embeds": emb[:, :s]}
        bf = {"embeds": emb}
        dec_in = {"embeds": emb[:, s:s + 1]}
    if cfg.enc_dec:
        bs["enc_embeds"] = bf["enc_embeds"] = batch["enc_embeds"]

    # hidden state via full prefill
    ctx_f = L.Ctx(cfg=cfg, dist=DIST, mode="prefill",
                  angles=T._angles(cfg, jnp.arange(full)),
                  cache_len=full + 1, batch_size=b,
                  memory=(T._encode(params, cfg, DIST, batch["enc_embeds"],
                                    "prefill") if cfg.enc_dec else None))
    xf = T._inputs_to_x(params, cfg, ctx_f, bf)
    hf, _, _ = T._run_stack(params, xf, ctx_f, None, cfg, cfg.pattern,
                            cfg.remainder, remat=False)

    # prefill(s) then decode token s
    _, caches = m.prefill(params, bs, DIST, cache_len=full + 1)
    ctx_d = L.Ctx(cfg=cfg, dist=DIST, mode="decode",
                  angles=(T._angles(cfg, jnp.int32(s)[None])
                          if cfg.rope_theta else None),
                  pos=jnp.int32(s), batch_size=b)
    xd = T._inputs_to_x(params, cfg, ctx_d, dec_in)
    hd, _, _ = T._run_stack(params, xd, ctx_d, caches, cfg, cfg.pattern,
                            cfg.remainder, remat=False)

    a = np.asarray(hf[:, -1])
    b_ = np.asarray(hd[:, 0])
    rel = np.abs(a - b_).max() / (np.abs(a).max() + 1e-9)
    assert rel < 5e-4, (arch, rel)


@pytest.mark.parametrize("arch", ARCHS)
def test_output_shapes(arch):
    cfg = scaled_down(ASSIGNED[arch])
    m = build_model(cfg)
    params = m.init(KEY, jnp.float32)
    b, s = 2, 16
    batch = _batch(cfg, b, s, KEY)
    batch.pop("labels")
    nt, caches = m.prefill(params, batch, DIST, cache_len=32)
    assert nt.shape == (b,) and nt.dtype == jnp.int32
    assert int(nt.max()) < cfg.vocab_size  # vocab padding masked
    nt2, caches2 = m.decode_step(
        params, {"token": nt[:, None], "pos": jnp.int32(s)}
        if "tokens" in batch or cfg.enc_dec else
        {"embeds": jax.random.normal(KEY, (b, 1, cfg.d_model)) * 0.05,
         "pos": jnp.int32(s)},
        caches, DIST)
    assert nt2.shape == (b,)
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)
