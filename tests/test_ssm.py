"""Mamba2/SSD: chunked and decode paths vs the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (ssd_chunked, ssd_decode_step, ssd_sequential)

KEY = jax.random.PRNGKey(0)


def _inputs(b=2, l=64, H=4, hd=8, G=2, N=16):
    xh = jax.random.normal(jax.random.fold_in(KEY, 1), (b, l, H, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 2),
                                           (b, l, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 3), (H,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(KEY, 4), (b, l, G, N)) * 0.3
    C = jax.random.normal(jax.random.fold_in(KEY, 5), (b, l, G, N)) * 0.3
    return xh, dt, A, B, C


@pytest.mark.parametrize("chunk", [4, 8, 16, 64])
def test_chunked_matches_sequential(chunk):
    xh, dt, A, B, C = _inputs()
    y_ref, h_ref = ssd_sequential(xh, dt, A, B, C)
    y, h, _ = ssd_chunked(xh, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-5)


def test_chunked_with_initial_state():
    xh, dt, A, B, C = _inputs()
    h0 = jax.random.normal(jax.random.fold_in(KEY, 6), (2, 4, 8, 16)) * 0.3
    y_ref, h_ref = ssd_sequential(xh, dt, A, B, C, h_init=h0)
    y, h, _ = ssd_chunked(xh, dt, A, B, C, 8, h_init=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-5)


def test_decode_steps_match_sequential():
    xh, dt, A, B, C = _inputs(l=8)
    y_ref, h_ref = ssd_sequential(xh, dt, A, B, C)
    h = jnp.zeros((2, 4, 8, 16))
    for t in range(8):
        y, h = ssd_decode_step(xh[:, t], dt[:, t], A, B[:, t], C[:, t], h)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref[:, t]),
                                   atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-5)
