"""The CI docs job, runnable locally: dead intra-repo links/paths in
README + docs/*.md fail, and the documented quickstart commands must
still parse (--help / --list dry form).  tools/check_docs.py is the
single implementation; this wrapper keeps it in the tier-1 loop."""
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_docs_health():
    r = subprocess.run([sys.executable, str(ROOT / "tools/check_docs.py")],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"\n{r.stdout}\n{r.stderr}"


def test_docs_exist_and_linked():
    """Cheap tier-1 subset: the docs tree exists and README links it."""
    assert (ROOT / "docs/ARCHITECTURE.md").exists()
    assert (ROOT / "docs/BENCHMARKS.md").exists()
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/BENCHMARKS.md" in readme
