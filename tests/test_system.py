"""End-to-end behaviour tests for the system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig
from repro.core import MemoryBudget, configure
from repro.core.engine import PipelinedLM
from repro.models import Dist, build_model
from repro.optim import AdamW, apply_updates
from repro.roofline.analysis import analyze_hlo, roofline_report


def test_tiny_training_loss_decreases():
    cfg = scaled_down(get_config("tinyllama-1.1b"))
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key, jnp.float32)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    state = opt.init(params)
    dist = Dist.local()
    # a memorizable batch
    toks = jax.random.randint(key, (4, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(
            lambda p: m.train_loss(p, batch, dist))(params)
        upd, state, _ = opt.update(grads, state, params)
        return apply_updates(params, upd), state, loss

    losses = []
    for _ in range(30):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, losses[::10]
    assert np.isfinite(losses[-1])


def test_autoconfig_drives_engine(tmp_path):
    cfg = ModelConfig(name="e2e", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
                      pattern=(LayerSpec(ATTN, DENSE),))
    # tiny budget: force host placement
    budget = MemoryBudget(device=1 << 14, host=1 << 30, disk=1 << 40)
    ac = configure(cfg, batch=2, prompt_len=8, gen_len=4, budget=budget)
    assert ac.weight_placement in ("host", "disk")
    lm = PipelinedLM(cfg, batch=2, max_len=16, placement=ac.weight_placement,
                     pipeline=(ac.pipeline if ac.pipeline != "memory"
                               else "memory"),
                     disk_root=str(tmp_path / "d"))
    prompt = np.random.default_rng(0).integers(0, 256, (2, 8)).astype(np.int32)
    toks, stats = lm.generate(prompt, gen_len=4)
    assert toks.shape == (2, 4)


def test_roofline_counts_loop_trips():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out
    x = jnp.zeros((128, 128))
    w = jnp.zeros((128, 128))
    c = jax.jit(f).lower(x, w).compile()
    acc = analyze_hlo(c.as_text(), total_devices=1)
    assert acc["flops"] == 2 * 128 ** 3 * 10
    rep = roofline_report(acc)
    assert rep["bottleneck"] in ("compute", "memory")
    assert rep["t_memory_s"] > 0


def test_generation_determinism_across_pipelines(tmp_path):
    cfg = ModelConfig(name="det", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
                      pattern=(LayerSpec(ATTN, DENSE),))
    prompt = np.random.default_rng(1).integers(0, 128, (1, 8)).astype(np.int32)
    outs = []
    for mode in ("sequential", "memory", "performance"):
        lm = PipelinedLM(cfg, batch=1, max_len=16, placement="disk",
                         pipeline=mode, disk_root=str(tmp_path / mode))
        toks, _ = lm.generate(prompt, gen_len=5)
        outs.append(toks)
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_generation_determinism_across_depths(tmp_path):
    cfg = ModelConfig(name="det-d", num_layers=3, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
                      pattern=(LayerSpec(ATTN, DENSE),))
    prompt = np.random.default_rng(2).integers(0, 128, (1, 8)).astype(np.int32)
    outs = []
    for depth in (1, 2, 4):
        lm = PipelinedLM(cfg, batch=1, max_len=16, placement="host",
                         pipeline="performance", depth=depth,
                         disk_root=str(tmp_path / f"d{depth}"))
        toks, _ = lm.generate(prompt, gen_len=5)
        outs.append(toks)
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_depth_capacity_scales_with_budget_and_quant():
    """Depth sizing is monotone in the device budget, at least 1 even
    when the budget is blown, and INT4 streaming (fewer in-flight bytes
    per layer) never shrinks the window."""
    from repro.core.autoconfig import serving_preload_depth
    from repro.core.memory_model import depth_capacity, estimate
    cfg = get_config("llama3.1-8b")
    kw = dict(batch=4, seq=544, p=2)
    est = estimate(cfg, **kw, preload=1)
    tiny, mid, big = 1 << 20, est.peak_decode * 2, est.peak_decode * 8
    d_tiny = depth_capacity(cfg, **kw, budget_bytes=tiny)
    d_mid = depth_capacity(cfg, **kw, budget_bytes=mid)
    d_big = depth_capacity(cfg, **kw, budget_bytes=big)
    assert d_tiny == 1
    assert 1 <= d_mid <= d_big <= 8      # default depth_cap
    d_int4 = depth_capacity(cfg, **kw, budget_bytes=mid, quant="int4")
    assert d_int4 >= d_mid
    # estimate() accepts integer preload depths and grows monotonically
    e1 = estimate(cfg, **kw, preload=1)
    e3 = estimate(cfg, **kw, preload=3)
    assert e3.peak_decode > e1.peak_decode
    assert e3.peak_prefill > e1.peak_prefill
    # serving entry point: host pressure from retained spills forces the
    # conservative window
    budget = MemoryBudget(host=est.weights + est.kv_cache)
    assert serving_preload_depth(cfg, b_max=4, max_len=544,
                                 precision_bytes=2, spill_cap=64,
                                 budget=budget) == 1
