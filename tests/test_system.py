"""End-to-end behaviour tests for the system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig
from repro.core import MemoryBudget, configure
from repro.core.engine import PipelinedLM
from repro.models import Dist, build_model
from repro.optim import AdamW, apply_updates
from repro.roofline.analysis import analyze_hlo, roofline_report


def test_tiny_training_loss_decreases():
    cfg = scaled_down(get_config("tinyllama-1.1b"))
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key, jnp.float32)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    state = opt.init(params)
    dist = Dist.local()
    # a memorizable batch
    toks = jax.random.randint(key, (4, 33), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(
            lambda p: m.train_loss(p, batch, dist))(params)
        upd, state, _ = opt.update(grads, state, params)
        return apply_updates(params, upd), state, loss

    losses = []
    for _ in range(30):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, losses[::10]
    assert np.isfinite(losses[-1])


def test_autoconfig_drives_engine(tmp_path):
    cfg = ModelConfig(name="e2e", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
                      pattern=(LayerSpec(ATTN, DENSE),))
    # tiny budget: force host placement
    budget = MemoryBudget(device=1 << 14, host=1 << 30, disk=1 << 40)
    ac = configure(cfg, batch=2, prompt_len=8, gen_len=4, budget=budget)
    assert ac.weight_placement in ("host", "disk")
    lm = PipelinedLM(cfg, batch=2, max_len=16, placement=ac.weight_placement,
                     pipeline=(ac.pipeline if ac.pipeline != "memory"
                               else "memory"),
                     disk_root=str(tmp_path / "d"))
    prompt = np.random.default_rng(0).integers(0, 256, (2, 8)).astype(np.int32)
    toks, stats = lm.generate(prompt, gen_len=4)
    assert toks.shape == (2, 4)


def test_roofline_counts_loop_trips():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out
    x = jnp.zeros((128, 128))
    w = jnp.zeros((128, 128))
    c = jax.jit(f).lower(x, w).compile()
    acc = analyze_hlo(c.as_text(), total_devices=1)
    assert acc["flops"] == 2 * 128 ** 3 * 10
    rep = roofline_report(acc)
    assert rep["bottleneck"] in ("compute", "memory")
    assert rep["t_memory_s"] > 0


def test_generation_determinism_across_pipelines(tmp_path):
    cfg = ModelConfig(name="det", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
                      pattern=(LayerSpec(ATTN, DENSE),))
    prompt = np.random.default_rng(1).integers(0, 128, (1, 8)).astype(np.int32)
    outs = []
    for mode in ("sequential", "memory", "performance"):
        lm = PipelinedLM(cfg, batch=1, max_len=16, placement="disk",
                         pipeline=mode, disk_root=str(tmp_path / mode))
        toks, _ = lm.generate(prompt, gen_len=5)
        outs.append(toks)
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
