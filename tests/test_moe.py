"""MoE dispatch/combine vs the dense per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models.moe import (_dispatch_indices, moe_ffn,
                              moe_ffn_dense_oracle, moe_ffn_replicated)

KEY = jax.random.PRNGKey(0)


def _params(d, f, E):
    return dict(
        wg=jax.random.normal(jax.random.fold_in(KEY, 1), (d, E)) * 0.5,
        w_gate=jax.random.normal(jax.random.fold_in(KEY, 2), (E, d, f)) * 0.1,
        w_up=jax.random.normal(jax.random.fold_in(KEY, 3), (E, d, f)) * 0.1,
        w_down=jax.random.normal(jax.random.fold_in(KEY, 4), (E, f, d)) * 0.1,
    )


@pytest.mark.parametrize("E,k", [(4, 1), (8, 2), (8, 4)])
def test_moe_matches_oracle_no_drops(E, k):
    T, d, f = 64, 16, 32
    cfg = MoEConfig(num_experts=E, top_k=k, expert_d_ff=f,
                    capacity_factor=float(E))  # capacity >= all tokens
    params = _params(d, f, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (T, d))
    oracle = moe_ffn_dense_oracle(x, params, cfg)
    out, aux = moe_ffn(x, params, cfg, axis=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), atol=1e-5)
    assert float(aux) > 0


def test_moe_replicated_matches_oracle():
    T, d, f, E, k = 32, 16, 32, 8, 2
    cfg = MoEConfig(num_experts=E, top_k=k, expert_d_ff=f)
    params = _params(d, f, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (T, d))
    oracle = moe_ffn_dense_oracle(x, params, cfg)
    out, _ = moe_ffn_replicated(x, params, cfg, axis=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), atol=1e-5)


def test_dispatch_capacity_drops():
    # 8 tokens all routed to expert 0, capacity 4 -> 4 dropped
    ids = jnp.zeros((8, 1), jnp.int32)
    e, slot, valid = _dispatch_indices(ids, num_experts=2, capacity=4)
    assert int(valid.sum()) == 4
    assert int(slot.max()) == 7  # ranks keep counting; validity gates


def test_capacity_drop_reduces_output():
    T, d, f, E, k = 64, 16, 32, 4, 2
    params = _params(d, f, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (T, d))
    big = MoEConfig(num_experts=E, top_k=k, expert_d_ff=f,
                    capacity_factor=8.0)
    tiny = MoEConfig(num_experts=E, top_k=k, expert_d_ff=f,
                     capacity_factor=0.25)
    out_big, _ = moe_ffn(x, params, big, axis=None)
    out_tiny, _ = moe_ffn(x, params, tiny, axis=None)
    # dropped tokens -> strictly less output mass
    assert float(jnp.abs(out_tiny).sum()) < float(jnp.abs(out_big).sum())
