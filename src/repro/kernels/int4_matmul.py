"""Pallas TPU kernel: fused INT4-dequant matmul (paper §3.4, TPU-native).

The paper's GPU kernel computes matvec directly on 4-bit weights to skip
the dequantization pass.  The TPU adaptation: only INT4 bytes cross
HBM->VMEM (the expensive hop — the PCIe analogue); nibbles are unpacked
and scaled in VREGs and fed straight to the MXU with fp32 accumulation.
The packed layout (quant/int4.py) is column-pair packing so the
contraction dim K stays unpacked (free K-blocking) and the unpack is a
minor-dim interleave.

Block sizes default to MXU-aligned (128) tiles; K blocks are multiples of
the quantization group (128) so each K block sees whole scale rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

GROUP = 128


def _kernel(x_ref, p_ref, s_ref, o_ref, acc_ref, *, n_k: int, group: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, bk)
    packed = p_ref[...]                             # (bk, bn//2) uint8
    scale = s_ref[...]                              # (bk//G, bn) f32
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = ((packed >> 4) & 0xF).astype(jnp.int32) - 8
    bk, bn2 = packed.shape
    q = jnp.stack([lo, hi], axis=-1).reshape(bk, bn2 * 2)   # minor interleave
    # groupwise scaling in VREGs: (bk//G, G, bn) * (bk//G, 1, bn)
    w = (q.reshape(bk // group, group, bn2 * 2).astype(jnp.float32)
         * scale[:, None, :]).reshape(bk, bn2 * 2)
    acc_ref[...] += jax.lax.dot(
        x.astype(jnp.float32), w, precision=jax.lax.Precision.DEFAULT,
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _out():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def int4_matmul(x, packed, scale, *, group: int = GROUP, block_m: int = 128,
                block_n: int = 128, block_k: int = 256,
                out_dtype=jnp.float32, interpret: bool = True):
    """x (M, K) bf16/f32 @ int4-packed W -> (M, N) out_dtype.

    packed: (K, N//2) uint8, scale: (K//group, N) f32 (see quant/int4.py).
    """
    M, K = x.shape
    Kp, N2 = packed.shape
    N = N2 * 2
    assert Kp == K and K % group == 0, (K, Kp, group)
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    # block_k: largest multiple of `group` that divides K and is <= request
    kk = group
    for c in range(min(block_k, K), group - 1, -group):
        if K % c == 0 and c % group == 0:
            kk = c
            break
    block_k = kk
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, block_m, block_n, block_k)
    n_k = K // block_k

    grid = (M // block_m, N // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n // 2), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_k // group, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if not interpret else None,
    )(x, packed, scale)
