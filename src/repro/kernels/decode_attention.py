"""Pallas TPU kernel: single-token GQA decode attention over a (possibly
huge) KV cache.

The decode roofline is pure memory: reading the KV cache once is the
floor.  This kernel streams the cache through VMEM in (block_s) chunks
with online-softmax state in scratch — HBM traffic = cache + q + o, the
paper's "KV-cache loading" rendered as HBM->VMEM streaming.  Emits
normalized output; a partials-emitting variant backs the cross-shard
(sequence-sharded) merge of models/attention.decode_attention.

``decode_attention_int4_kernel`` is the INT4-KV variant backing
``core.kvstore``'s ``kv_mode="int4"`` on TPU: the cache arrives as the
store's packed row layout — per-(batch, position) rows of ``F = hkv*dh``
features as nibble pairs (``(b, S, F//2)`` uint8) + groupwise f32 scales
(``(b, S, F//g)``) — and the dequant happens IN-KERNEL, in VREGs, after
the packed bytes crossed HBM->VMEM.  Only INT4 bytes pay the memory
floor; no f32 cache is ever materialized (the cache rendering of the
paper's §3.4 "no dequantization pass").  On the CPU container the same
dequant runs on the transfer thread over live rows only
(``kvstore.load``, post-link) — numerics are identical (asserted in
tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_s: int, n_s: int, g: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    q = q_ref[0]                                        # (h, dh)
    k = k_ref[0]                                        # (bs, hkv, dh)
    v = v_ref[0]
    h, dh = q.shape
    hkv = k.shape[1]
    qg = q.reshape(hkv, g, dh)
    s = jnp.einsum("kgd,skd->kgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / (dh ** 0.5)   # (hkv, g, bs)
    kv_pos = si * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (hkv, g, block_s), 2)
    s = jnp.where(kv_pos <= pos, s, NEG_INF)

    m_prev = m_ref[...]                                  # (h, 1)
    m_cur = jnp.max(s, axis=2).reshape(h, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new.reshape(hkv, g, 1))
    p = jnp.where(kv_pos <= pos, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    alpha = jnp.where(m_prev > NEG_INF / 2, alpha, 0.0)
    pv = jnp.einsum("kgs,skd->kgd", p, v.astype(jnp.float32))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=2).reshape(h, 1)
    acc_ref[...] = acc_ref[...] * alpha + pv.reshape(h, dh)
    m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _out():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, pos, *, block_s: int = 512,
                            interpret: bool = True):
    """q (b, h, dh); caches (b, S, hkv, dh); pos scalar -> (b, h, dh)."""
    b, h, dh = q.shape
    _, S, hkv, _ = k_cache.shape
    g = h // hkv
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    n_s = S // block_s
    grid = (b, n_s)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    kernel = functools.partial(_kernel, block_s=block_s, n_s=n_s, g=g)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM)
            if not interpret else pl.BlockSpec((1,), lambda bi, si: (0,)),
            pl.BlockSpec((1, h, dh), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, dh), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, dh), lambda bi, si: (bi, si, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda bi, si: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
        if not interpret else None,
    )(pos_arr, q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# INT4-KV variant: packed cache rows dequantized in VREGs
# ---------------------------------------------------------------------------


def _unpack_rows(pk_ref, sc_ref, *, group: int, hkv: int, dh: int):
    """One VMEM block of packed cache rows -> (bs, hkv, dh) f32 via the
    STORE's own dequant (``core.kvstore._dequant_impl`` — plain
    traceable jnp, so it lowers inside the kernel body): the packing
    layout lives in exactly one place and the kernel can't drift from
    it.  Runs on the VPU; the nibble unpack is a minor-dim interleave
    that lowers to vector ops, the per-group scale a broadcast
    multiply."""
    from repro.core.kvstore import _dequant_impl
    pk = pk_ref[0]                                      # (bs, F//2) uint8
    sc = sc_ref[0]                                      # (bs, F//group)
    bs = pk.shape[0]
    return _dequant_impl(pk, sc, group).reshape(bs, hkv, dh)


def _kernel_int4(pos_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
                 m_ref, l_ref, acc_ref, *, block_s: int, n_s: int, g: int,
                 group: int, hkv: int, dh: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    q = q_ref[0]                                        # (h, dh)
    # dequant HERE, after the packed bytes crossed HBM->VMEM — INT4
    # bytes are the only cache traffic the roofline sees
    k = _unpack_rows(kq_ref, ks_ref, group=group, hkv=hkv, dh=dh)
    v = _unpack_rows(vq_ref, vs_ref, group=group, hkv=hkv, dh=dh)
    h, _ = q.shape
    qg = q.reshape(hkv, g, dh)
    s = jnp.einsum("kgd,skd->kgs", qg.astype(jnp.float32), k) / (dh ** 0.5)
    kv_pos = si * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (hkv, g, block_s), 2)
    s = jnp.where(kv_pos <= pos, s, NEG_INF)

    m_prev = m_ref[...]                                  # (h, 1)
    m_cur = jnp.max(s, axis=2).reshape(h, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new.reshape(hkv, g, 1))
    p = jnp.where(kv_pos <= pos, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    alpha = jnp.where(m_prev > NEG_INF / 2, alpha, 0.0)
    pv = jnp.einsum("kgs,skd->kgd", p, v)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=2).reshape(h, 1)
    acc_ref[...] = acc_ref[...] * alpha + pv.reshape(h, dh)
    m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _out():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention_int4_kernel(q, k_packed, k_scale, v_packed, v_scale,
                                 pos, *, hkv: int, group: int,
                                 block_s: int = 512,
                                 interpret: bool = True):
    """q (b, h, dh); packed caches (b, S, hkv*dh//2) uint8 with scales
    (b, S, hkv*dh//group) f32 (``core.kvstore`` row layout) ->
    (b, h, dh).  Numerically identical to ``decode_attention_kernel``
    over the dequantized cache (same per-element dequant, same online
    softmax) while only packed bytes stream HBM->VMEM."""
    b, h, dh = q.shape
    _, S, F2 = k_packed.shape
    assert F2 * 2 == hkv * dh, (F2, hkv, dh)
    g = h // hkv
    Fg = k_scale.shape[-1]
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    n_s = S // block_s
    grid = (b, n_s)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    kernel = functools.partial(_kernel_int4, block_s=block_s, n_s=n_s, g=g,
                               group=group, hkv=hkv, dh=dh)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM)
            if not interpret else pl.BlockSpec((1,), lambda bi, si: (0,)),
            pl.BlockSpec((1, h, dh), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, block_s, F2), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, block_s, Fg), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, block_s, F2), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, block_s, Fg), lambda bi, si: (bi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda bi, si: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
        if not interpret else None,
    )(pos_arr, q, k_packed, k_scale, v_packed, v_scale)
