"""jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode for validation;
on TPU they compile natively.  ``use_kernels(False)`` forces the pure-jnp
reference path (used by the dry-run, whose compiled artifact must consist
of ops the roofline analyzer models).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int4_matmul import int4_matmul

_STATE = {"enabled": True}


def use_kernels(flag: bool):
    _STATE["enabled"] = flag


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def int4_matmul_op(x, packed, scale, **kw):
    if not _STATE["enabled"]:
        return R.int4_matmul_ref(x, packed, scale)
    return int4_matmul(x, packed, scale, interpret=_interpret(), **kw)


def flash_attention_op(q, k, v, *, causal=True, window=0, q_offset=0, **kw):
    if not _STATE["enabled"]:
        return R.flash_attention_ref(q, k, v, causal=causal, window=window,
                                     q_offset=q_offset)
    return flash_attention(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, interpret=_interpret(), **kw)


def decode_attention_op(q, k_cache, v_cache, pos, **kw):
    if not _STATE["enabled"]:
        return R.decode_attention_ref(q[:, None], k_cache, v_cache,
                                      pos)[:, 0]
    return decode_attention_kernel(q, k_cache, v_cache, pos,
                                   interpret=_interpret(), **kw)
