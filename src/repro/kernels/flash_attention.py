"""Pallas TPU kernel: blocked causal flash attention with GQA + sliding
window.

This is the HBM-traffic fix the roofline analysis demands: the jnp
attention path writes (sq x sk) score tensors to HBM every layer (the
dominant memory term in the baseline dry-runs); here scores live in VMEM
scratch only — HBM traffic is q, k, v, o.  Grid: (batch, q_heads,
q_blocks, kv_blocks) with the kv dim 'arbitrary' (sequential) so the
(m, l, acc) online-softmax state lives in VMEM scratch across kv steps.
GQA maps each q head to its kv head in the k/v index_maps (no kv
duplication in HBM or VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, n_k: int, causal: bool, window: int,
            q_offset: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :]                               # (bq, dh)
    k = k_ref[0, :, 0, :]                               # (bk, dh)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(m_new > NEG_INF / 2, p, 0.0)          # fully-masked rows
    alpha = jnp.exp(m_prev - m_new)
    alpha = jnp.where(m_prev > NEG_INF / 2, alpha, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _out():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True):
    """q (b, sq, h, dh); k/v (b, sk, hkv, dh) -> (b, sq, h, dh)."""
    b, sq, h, dh = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    n_k = sk // block_k
    grid = (b, h, sq // block_q, n_k)
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               n_k=n_k, causal=causal, window=window,
                               q_offset=q_offset, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dh),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dh),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")) if not interpret else None,
    )(q, k, v)
