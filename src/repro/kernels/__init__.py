from repro.kernels.ops import (decode_attention_op, flash_attention_op,
                               int4_matmul_op, use_kernels)

__all__ = ["decode_attention_op", "flash_attention_op", "int4_matmul_op",
           "use_kernels"]
