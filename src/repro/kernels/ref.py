"""Pure-jnp oracles for every Pallas kernel (the source of truth in
kernel tests: sweeps assert_allclose kernel-vs-ref across shapes/dtypes)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import ref_attention
from repro.quant.int4 import dequantize_int4


def int4_matmul_ref(x, packed, scale, group: int = 128,
                    out_dtype=jnp.float32):
    """x (M, K) @ dequant(packed (K, N//2), scale (K//G, N)) -> (M, N)."""
    w = dequantize_int4(packed, scale, jnp.float32, group)
    return (x.astype(jnp.float32) @ w).astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """q (b, sq, h, dh), k/v (b, sk, hkv, dh) -> (b, sq, h, dh)."""
    return ref_attention(q, k, v, causal=causal, window=window,
                         q_offset=q_offset)


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q (b, 1, h, dh); caches (b, S, hkv, dh); attends positions <= pos."""
    return ref_attention(q, k_cache, v_cache, causal=False,
                         kv_valid_len=pos + 1)
