"""Groupwise symmetric INT4 quantization (paper §3.4 / W4 weights).

Layout for a weight W (K, N):
  * groups of G=128 along the contraction dim K;
  * scales: (K//G, N) float32 with s = max|w_group| / 7;
  * values: q = clip(round(w / s), -8, 7), two nibbles packed per uint8
    along *column pairs* -> packed (K, N//2): column 2j in the low nibble,
    column 2j+1 in the high nibble.

Column-pair packing keeps the contraction dim unpacked so the Pallas
kernel can K-block freely, and the in-register unpack is a minor-dim
interleave (stack + reshape) that lowers cleanly to TPU vector ops.  The
kernel (kernels/int4_matmul.py) consumes exactly this layout and fuses
dequantization into the MXU matmul — INT4 bytes are what cross HBM->VMEM,
the TPU rendering of the paper's "no dequantization pass".
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

GROUP = 128


def quantize_int4(w, group: int = GROUP):
    """w (K, N) -> (packed (K, N//2) uint8, scales (K//group, N) f32)."""
    K, N = w.shape
    assert K % group == 0 and N % 2 == 0, (K, N, group)
    wg = w.astype(jnp.float32).reshape(K // group, group, N)
    scale = jnp.max(jnp.abs(wg), axis=1) / 7.0            # (K//group, N)
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(wg / scale[:, None, :]).astype(jnp.int32)
    q = jnp.clip(q, -8, 7).reshape(K, N)
    return pack_int4(q), scale


def pack_int4(q):
    """int values in [-8, 7], shape (K, N) -> uint8 (K, N//2)."""
    qu = (q + 8).astype(jnp.uint8)                        # [0, 15]
    lo = qu[:, 0::2]
    hi = qu[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed):
    """uint8 (K, N//2) -> int32 (K, N) in [-8, 7]."""
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = ((packed >> 4) & 0xF).astype(jnp.int32) - 8
    K, N2 = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(K, N2 * 2)


def dequantize_int4(packed, scale, dtype=jnp.bfloat16, group: int = GROUP):
    """Inverse of quantize_int4 -> (K, N) dtype."""
    q = unpack_int4(packed)                               # (K, N)
    K, N = q.shape
    w = q.reshape(K // group, group, N).astype(jnp.float32) \
        * scale[:, None, :]
    return w.reshape(K, N).astype(dtype)


def stack_group(K: int) -> int:
    """Group size for a stacked matrix with contraction dim ``K``:
    ``gcd(K, 128)`` always divides K, so expert matrices whose
    contraction dim is smaller than (or not a multiple of) the 2-D
    GROUP stay quantizable with the same groupwise layout."""
    return math.gcd(int(K), GROUP)


def stack_eligible(shape) -> bool:
    """Whether a stacked weight (..., K, N) packs as INT4: at least one
    stack axis, an even N (nibble pairs), and a group of >= 16 along K
    (smaller groups spend more scale bytes than they save)."""
    return (len(shape) >= 3 and shape[-1] % 2 == 0
            and stack_group(shape[-2]) >= 16)


def quantize_int4_stack(w, group: int = 0):
    """w (..., K, N) -> (packed (..., K, N//2) uint8, scale
    (..., K//g, N) f32): ``quantize_int4`` vmapped over every leading
    (stack) axis — each (K, N) slice carries exactly the 2-D layout, so
    the fused kernels and ``dequantize_int4`` apply per slice.  ``group``
    defaults to ``stack_group(K)``."""
    g = group or stack_group(w.shape[-2])
    fn = functools.partial(quantize_int4, group=g)
    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    return fn(w)


def dequantize_int4_stack(packed, scale, dtype=jnp.bfloat16,
                          group: int = 0):
    """Inverse of ``quantize_int4_stack`` -> (..., K, N) dtype.  The
    group is inferable from the shapes (``K // scale.shape[-2]``)."""
    g = group or packed.shape[-2] // scale.shape[-2]
    fn = functools.partial(dequantize_int4, dtype=dtype, group=g)
    for _ in range(packed.ndim - 2):
        fn = jax.vmap(fn)
    return fn(packed, scale)


def quantize_tree(params, min_size: int = 1 << 16, group: int = GROUP):
    """Quantize every 2-D leaf with K divisible by group and >= min_size
    elements; returns (qtree with {packed, scale} dicts, set of paths)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    quantized = set()

    def path_str(path):
        return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)

    leaves = []
    for path, leaf in flat:
        ps = path_str(path)
        if (hasattr(leaf, "ndim") and leaf.ndim == 2 and
                leaf.shape[0] % group == 0 and leaf.shape[1] % 2 == 0 and
                leaf.size >= min_size):
            packed, scale = quantize_int4(leaf, group)
            leaves.append({"packed": packed, "scale": scale})
            quantized.add(ps)
        else:
            leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves), quantized
