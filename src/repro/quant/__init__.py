from repro.quant.int4 import (dequantize_int4, pack_int4, quantize_int4,
                              quantize_tree, unpack_int4)

__all__ = ["dequantize_int4", "pack_int4", "quantize_int4", "quantize_tree",
           "unpack_int4"]
