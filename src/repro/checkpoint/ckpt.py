"""Sharded checkpointing: per-leaf .npy files + JSON manifest.

Fault-tolerance posture:
  * atomic: writes land in ``step_K.tmp`` and are renamed only after the
    manifest is fsync'd — a crash mid-save never corrupts the latest
    checkpoint;
  * elastic: restore targets *any* mesh — leaves are loaded logically and
    re-device_put under the new sharding (shrink/grow = new NamedSharding);
  * async: ``AsyncCheckpointer`` snapshots to host (np.asarray) on the
    caller thread (cheap) and writes on a background thread so the train
    loop never blocks on disk;
  * self-describing: the manifest stores step, config name and the leaf
    paths, so restore validates compatibility before touching weights.

On a real multi-host pod each host writes only its addressable shards;
here (single host) the full logical array is written — the layout and
protocol are identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    meta: Optional[dict] = None) -> str:
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f"step_{step}.tmp"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten(tree)
    names = []
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_str = str(arr.dtype)
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.int8, np.uint8, np.int16, np.uint16,
                             np.uint32, np.uint64, np.bool_, np.float16):
            # ml_dtypes (bfloat16, fp8, ...): np.save would drop the
            # descriptor ("|V2") — store raw bytes + the dtype name.
            np.save(tmp / f"leaf_{i}.npy", arr.view(np.uint8))
        else:
            np.save(tmp / f"leaf_{i}.npy", arr)
        names.append({"path": key, "file": f"leaf_{i}.npy",
                      "shape": list(arr.shape), "dtype": dtype_str})
    manifest = {"step": step, "leaves": names, "time": time.time(),
                **(meta or {})}
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target_tree: Any, *,
                       shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``target_tree``; if ``shardings`` is a
    matching tree of NamedShardings the leaves are placed under them (the
    elastic-remesh path — the saved mesh is irrelevant)."""
    d = Path(ckpt_dir) / f"step_{step}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(target_tree)
    saved = {e["path"]: e for e in manifest["leaves"]}
    assert len(saved) == len(leaves), (len(saved), len(leaves))
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _flatten(shardings)[0]]
    out = []
    for i, (key, leaf) in enumerate(leaves):
        e = saved.get(key)
        assert e is not None, f"missing leaf {key} in checkpoint"
        arr = np.load(d / e["file"])
        if arr.dtype == np.uint8 and e["dtype"] not in ("uint8",):
            import ml_dtypes
            try:
                dt = np.dtype(e["dtype"])
            except TypeError:
                dt = np.dtype(getattr(ml_dtypes, e["dtype"]))
            arr = arr.view(dt).reshape(e["shape"])
        want = tuple(getattr(leaf, "shape", arr.shape))
        assert tuple(arr.shape) == want, (key, arr.shape, want)
        if shard_leaves is not None and shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest


class AsyncCheckpointer:
    """Non-blocking saves: snapshot on caller thread, write on background
    thread; at most one write in flight (a newer request supersedes)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree: Any, meta: Optional[dict] = None):
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now
        self.wait()

        def work():
            save_checkpoint(str(self.ckpt_dir), step, host_tree, meta=meta)
            with self._lock:
                self.last_saved = step
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.ckpt_dir.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.ckpt_dir / f"step_{s}", ignore_errors=True)
