"""PIPO memory model (paper §3.5 + Appendix B), generalized to every
ModelConfig in the registry.

Notation follows the paper: l layers, d model dim, V vocab, p precision
bytes, b batch, s input length (prompt + generated), h heads, h_kv KV
heads, d_h MLP hidden dim.

  W = 2*W_embed + l*(W_mha + W_mlp)
  C = 2*p*b*s*l*d*(h_kv/h)                (total KV cache)
  peak M = max(M_mha, M_mlp, M_embed) with/without preloading

``preload`` generalizes the paper's boolean to an integer *depth*: the
number of extra resident layers the pipeline keeps in flight beyond the
computing one (``PipelineScheduler(depth=D)`` holds D+1 layers).  The
paper's performance pipeline is depth 1, the memory pipeline depth 0.
``depth_capacity`` inverts the model: the largest depth whose resident
window still fits a device budget.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class MemoryEstimate:
    weights: int          # total weight bytes W
    kv_cache: int         # total KV bytes C
    peak_prefill: int     # peak device bytes, prefill stage
    peak_decode: int      # peak device bytes, decode stage
    w_mha: int
    w_mlp: int
    w_embed: int


def weight_sizes(cfg: ModelConfig, p: int):
    """(W_embed, W_mha, W_mlp) for one layer, paper Appendix B shapes."""
    d = cfg.d_model
    w_embed = p * d * cfg.vocab_size
    if cfg.num_heads:
        hkv_ratio = cfg.num_kv_heads / cfg.num_heads
        w_mha = p * d * (cfg.num_heads * cfg.head_dim
                         + 2 * cfg.num_kv_heads * cfg.head_dim
                         + cfg.num_heads * cfg.head_dim) \
            + p * d  # norm
    else:  # SSM mixer
        w_mha = p * cfg.mixer_params(cfg.pattern[0])
    if cfg.moe is not None and any(sp.ffn == "moe" for sp in cfg.pattern):
        w_mlp = p * cfg.ffn_params(cfg.pattern[-1])
    else:
        w_mlp = p * 3 * d * cfg.d_ff
    return w_embed, w_mha, w_mlp


def estimate(cfg: ModelConfig, *, batch: int, seq: int, p: int = 2,
             preload: "bool | int" = True) -> MemoryEstimate:
    d, V, l = cfg.d_model, cfg.vocab_size, cfg.num_layers
    b, s = batch, seq
    h = max(1, cfg.num_heads)
    d_h = max(1, cfg.d_ff)
    hkv_ratio = (cfg.num_kv_heads / h) if cfg.num_heads else 0.0

    w_embed, w_mha, w_mlp = weight_sizes(cfg, p)
    W = 2 * w_embed + l * (w_mha + w_mlp)
    C = int(2 * p * b * s * l * d * hkv_ratio)
    C_layer = C // max(1, l)

    pre_n = int(preload)              # extra resident layers (preload depth)

    # ---- prefill stage (Appendix B.1) ----
    m_mha_pre = (p * b * s * (5 * d + h * s)
                 + w_mha + pre_n * w_mlp + (1 + pre_n) * C_layer)
    m_mlp_pre = (p * b * s * (3 * d_h + 2 * d)
                 + w_mlp + pre_n * w_mha + pre_n * C_layer)
    m_embed_pre = p * b * s * (d + V) + (1 + pre_n) * w_embed
    peak_prefill = max(m_mha_pre, m_mlp_pre, m_embed_pre)

    # ---- decode stage (Appendix B.2): input length 1 ----
    m_mha_dec = (p * b * (5 * d + h)
                 + w_mha + pre_n * w_mlp + (1 + pre_n) * 2 * p * b * s * d
                 * hkv_ratio)
    m_mlp_dec = (p * b * (3 * d_h + 2 * d)
                 + w_mlp + pre_n * w_mha + pre_n * 2 * p * b * s * d
                 * hkv_ratio)
    m_embed_dec = p * b * (d + V) + (1 + pre_n) * w_embed
    peak_decode = max(m_mha_dec, m_mlp_dec, m_embed_dec)

    return MemoryEstimate(int(W), int(C), int(peak_prefill),
                          int(peak_decode), int(w_mha), int(w_mlp),
                          int(w_embed))


def quant_weight_ratio(p: int, quant: "str | None") -> float:
    """Streamed-weight byte ratio under quantization: INT4 packs two
    nibbles per byte (+ scales), so weights cost ~0.5 bytes each against
    a p-byte baseline.  The single source for the convention shared by
    ``configure``, ``depth_capacity``, and ``serving_preload_depth``."""
    return (0.5 / p) if quant == "int4" else 1.0


def quant_kv_ratio(p: int, kv_mode: "str | None") -> float:
    """Streamed/pinned KV byte ratio under ``kv_mode``: INT4 cache rows
    are stored and cross the link packed (two nibbles per byte + group
    scales), the same 0.5-byte convention as ``quant_weight_ratio`` —
    in-flight preloads and host-pinned cache both sit packed; the f32
    expansion only exists inside the consuming compute."""
    return (0.5 / p) if kv_mode == "int4" else 1.0


def depth_capacity(cfg: ModelConfig, *, batch: int, seq: int, p: int = 2,
                   budget_bytes: int, quant: "str | None" = None,
                   kv_mode: "str | None" = None,
                   kv_layer_bytes: "int | None" = None,
                   depth_cap: int = 8) -> int:
    """Largest preload depth whose resident window fits ``budget_bytes``
    of device memory.

    Depth D keeps D+1 schedulable layers resident: the computing layer
    plus D in-flight preloads, each pinning its weights and its decode KV
    working copy.  Activations are depth-independent, so the marginal
    cost of one more depth step is one layer's weights (quant-scaled:
    INT4 units cross the link and sit in flight packed, the same
    convention ``autoconfig.configure`` uses for placement) plus one
    layer's KV payload; the base cost is the depth-0 peak.  The KV term
    is the modeled live slab (``kv_mode``-scaled) unless the caller
    passes ``kv_layer_bytes`` — the EXACT per-layer live KV_LOAD size a
    ``TieredKVStore`` measures, which replaces the model entirely (the
    adaptive window's pricing is then exact, not modeled).  Always
    returns at least 1 — the pipeline's minimum useful window — even
    when the budget is already blown (placement, not depth, is the knob
    there)."""
    est0 = estimate(cfg, batch=batch, seq=seq, p=p, preload=0)
    base = max(est0.peak_prefill, est0.peak_decode)
    w_layer = int(max(est0.w_mha, est0.w_mlp)
                  * quant_weight_ratio(p, quant))
    if kv_layer_bytes is not None:
        kv_layer = int(kv_layer_bytes)
    else:
        kv_layer = int(est0.kv_cache // max(1, cfg.num_layers)
                       * quant_kv_ratio(p, kv_mode))
    per_extra = max(1, w_layer + kv_layer)
    headroom = budget_bytes - base
    if headroom < per_extra:
        return 1
    return int(max(1, min(depth_cap, headroom // per_extra)))


def host_pinned_bytes(cfg: ModelConfig, *, b_max: int, max_len: int,
                      p: int = 4, quant: "str | None" = None,
                      kv_mode: "str | None" = None,
                      placement: str = "host") -> "tuple[int, int]":
    """(fixed_bytes, per_spill_bytes) the serving host tier pins: the
    full decode KV cache (packed under ``kv_mode="int4"`` — the tiered
    KV store keeps cache rows AND their spills as nibbles) plus — for
    host placement — the weights themselves (packed under quant, the
    same byte convention as ``quant_weight_ratio``; disk placement keeps
    only in-flight buffers in host RAM), and the marginal cost of one
    retained slot spill (one request's KV rows).  The single
    implementation behind BOTH the resolve-time host guard
    (``autoconfig.serving_depth_decision``) and the live one
    (``live_depth``) — the two must never drift."""
    est = estimate(cfg, batch=b_max, seq=max_len, p=p, preload=1)
    w_host = int(est.weights * quant_weight_ratio(p, quant)) \
        if placement == "host" else 0
    kv = int(est.kv_cache * quant_kv_ratio(p, kv_mode))
    return w_host + kv, kv // max(1, b_max)


def live_depth(cfg: ModelConfig, *, active: int, pos_used: int,
               b_max: int, max_len: int, p: int = 4,
               quant: "str | None" = None,
               kv_mode: "str | None" = None, spills: int = 0,
               placement: str = "host", device_budget: int,
               host_budget: int, depth_cap: int = 8,
               host_fixed: "int | None" = None,
               per_spill: "int | None" = None,
               kv_layer_bytes: "int | None" = None) -> int:
    """Preload depth under LIVE serving pressure (the ``AdaptiveDepth``
    policy's model): the static sizing prices the window at worst case —
    ``b_max`` slots, every one at ``max_len`` — but between decode steps
    the engine knows how many requests are actually in flight
    (``active``), the longest position actually written (``pos_used``),
    and how many slot spills the host currently retains (``spills``).
    Feeding those into the same §3.5 capacity model yields a window that
    deepens under light load and shrinks as KV/spill pressure ramps:

      * device side: ``depth_capacity`` at (batch=active, seq=pos_used+1)
        — the KV payload each in-flight layer pins is priced at its live
        occupancy, not the allocation bound; when the engine measures the
        exact live KV_LOAD size (``TieredKVStore.load_nbytes``) it passes
        ``kv_layer_bytes`` and the modeled term drops out entirely;
      * host side: the ``serving_preload_depth`` guard with the *live*
        retained-spill count instead of the worst-case ``spill_cap`` —
        a host saturated by spills forces depth 1 exactly as at resolve
        time.

    ``host_fixed``/``per_spill`` accept the load-invariant
    ``host_pinned_bytes`` terms precomputed once (the per-step caller's
    fast path — AdaptiveDepth sits on the decode hot path).
    """
    b = max(1, min(int(active), b_max))
    s = max(8, min(int(pos_used) + 1, max_len))
    if host_fixed is None or per_spill is None:
        host_fixed, per_spill = host_pinned_bytes(
            cfg, b_max=b_max, max_len=max_len, p=p, quant=quant,
            kv_mode=kv_mode, placement=placement)
    if host_fixed + spills * per_spill > host_budget:
        return 1
    return depth_capacity(cfg, batch=b, seq=s, p=p,
                          budget_bytes=device_budget, quant=quant,
                          kv_mode=kv_mode, kv_layer_bytes=kv_layer_bytes,
                          depth_cap=depth_cap)
