"""PIPO memory model (paper §3.5 + Appendix B), generalized to every
ModelConfig in the registry.

Notation follows the paper: l layers, d model dim, V vocab, p precision
bytes, b batch, s input length (prompt + generated), h heads, h_kv KV
heads, d_h MLP hidden dim.

  W = 2*W_embed + l*(W_mha + W_mlp)
  C = 2*p*b*s*l*d*(h_kv/h)                (total KV cache)
  peak M = max(M_mha, M_mlp, M_embed) with/without preloading

``preload`` generalizes the paper's boolean to an integer *depth*: the
number of extra resident layers the pipeline keeps in flight beyond the
computing one (``PipelineScheduler(depth=D)`` holds D+1 layers).  The
paper's performance pipeline is depth 1, the memory pipeline depth 0.
``depth_capacity`` inverts the model: the largest depth whose resident
window still fits a device budget.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class MemoryEstimate:
    weights: int          # total weight bytes W
    kv_cache: int         # total KV bytes C
    peak_prefill: int     # peak device bytes, prefill stage
    peak_decode: int      # peak device bytes, decode stage
    w_mha: int
    w_mlp: int
    w_embed: int


def weight_sizes(cfg: ModelConfig, p: int):
    """(W_embed, W_mha, W_mlp) for one layer, paper Appendix B shapes."""
    d = cfg.d_model
    w_embed = p * d * cfg.vocab_size
    if cfg.num_heads:
        hkv_ratio = cfg.num_kv_heads / cfg.num_heads
        w_mha = p * d * (cfg.num_heads * cfg.head_dim
                         + 2 * cfg.num_kv_heads * cfg.head_dim
                         + cfg.num_heads * cfg.head_dim) \
            + p * d  # norm
    else:  # SSM mixer
        w_mha = p * cfg.mixer_params(cfg.pattern[0])
    if cfg.moe is not None and any(sp.ffn == "moe" for sp in cfg.pattern):
        w_mlp = p * cfg.ffn_params(cfg.pattern[-1])
    else:
        w_mlp = p * 3 * d * cfg.d_ff
    return w_embed, w_mha, w_mlp


def estimate(cfg: ModelConfig, *, batch: int, seq: int, p: int = 2,
             preload: "bool | int" = True) -> MemoryEstimate:
    d, V, l = cfg.d_model, cfg.vocab_size, cfg.num_layers
    b, s = batch, seq
    h = max(1, cfg.num_heads)
    d_h = max(1, cfg.d_ff)
    hkv_ratio = (cfg.num_kv_heads / h) if cfg.num_heads else 0.0

    w_embed, w_mha, w_mlp = weight_sizes(cfg, p)
    W = 2 * w_embed + l * (w_mha + w_mlp)
    C = int(2 * p * b * s * l * d * hkv_ratio)
    C_layer = C // max(1, l)

    pre_n = int(preload)              # extra resident layers (preload depth)

    # ---- prefill stage (Appendix B.1) ----
    m_mha_pre = (p * b * s * (5 * d + h * s)
                 + w_mha + pre_n * w_mlp + (1 + pre_n) * C_layer)
    m_mlp_pre = (p * b * s * (3 * d_h + 2 * d)
                 + w_mlp + pre_n * w_mha + pre_n * C_layer)
    m_embed_pre = p * b * s * (d + V) + (1 + pre_n) * w_embed
    peak_prefill = max(m_mha_pre, m_mlp_pre, m_embed_pre)

    # ---- decode stage (Appendix B.2): input length 1 ----
    m_mha_dec = (p * b * (5 * d + h)
                 + w_mha + pre_n * w_mlp + (1 + pre_n) * 2 * p * b * s * d
                 * hkv_ratio)
    m_mlp_dec = (p * b * (3 * d_h + 2 * d)
                 + w_mlp + pre_n * w_mha + pre_n * 2 * p * b * s * d
                 * hkv_ratio)
    m_embed_dec = p * b * (d + V) + (1 + pre_n) * w_embed
    peak_decode = max(m_mha_dec, m_mlp_dec, m_embed_dec)

    return MemoryEstimate(int(W), int(C), int(peak_prefill),
                          int(peak_decode), int(w_mha), int(w_mlp),
                          int(w_embed))


def quant_weight_ratio(p: int, quant: "str | None") -> float:
    """Streamed-weight byte ratio under quantization: INT4 packs two
    nibbles per byte (+ scales), so weights cost ~0.5 bytes each against
    a p-byte baseline.  The single source for the convention shared by
    ``configure``, ``depth_capacity``, and ``serving_preload_depth``."""
    return (0.5 / p) if quant == "int4" else 1.0


def depth_capacity(cfg: ModelConfig, *, batch: int, seq: int, p: int = 2,
                   budget_bytes: int, quant: "str | None" = None,
                   depth_cap: int = 8) -> int:
    """Largest preload depth whose resident window fits ``budget_bytes``
    of device memory.

    Depth D keeps D+1 schedulable layers resident: the computing layer
    plus D in-flight preloads, each pinning its weights and its decode KV
    working copy.  Activations are depth-independent, so the marginal
    cost of one more depth step is one layer's weights (quant-scaled:
    INT4 units cross the link and sit in flight packed, the same
    convention ``autoconfig.configure`` uses for placement) plus one
    layer's KV slab; the base cost is the depth-0 peak.  Always returns
    at least 1 — the pipeline's minimum useful window — even when the
    budget is already blown (placement, not depth, is the knob there)."""
    est0 = estimate(cfg, batch=batch, seq=seq, p=p, preload=0)
    base = max(est0.peak_prefill, est0.peak_decode)
    w_layer = int(max(est0.w_mha, est0.w_mlp)
                  * quant_weight_ratio(p, quant))
    kv_layer = est0.kv_cache // max(1, cfg.num_layers)
    per_extra = max(1, w_layer + kv_layer)
    headroom = budget_bytes - base
    if headroom < per_extra:
        return 1
    return int(max(1, min(depth_cap, headroom // per_extra)))
