"""PipelinedLM: a generation engine whose weights/KV live in memory tiers
and move through the PIPO pipeline (the paper's system, end to end).

Layer granularity follows the paper ("treating MHA and MLP as separate
layers"): the schedulable unit list is [mha_0, mlp_0, mha_1, mlp_1, ...].
Per unit, weights are *merged* into one contiguous buffer (transfer suite
§3.3) living on the placement tier; the KV cache lives in the SAME
``core.kvstore.TieredKVStore`` the serving engines use (``cache_on=
"host"``): every KV_LOAD ships only the live ``(batch, positions)``
rows, ``kv_mode="int4"`` streams them packed (dequantized post-link on
the transfer thread), and both are byte-accounted on the trace.  With
``cache_on="device"`` the cache is device-resident — KV_SAVE refreshes
the device store and nothing crosses the link.

Compute units are jitted once per (kind, phase) and run on the main
thread; weight-load / kv-load / kv-save run on the 3-thread pool per
Algorithm 1.  INT4 weights halve..quarter transfer bytes and the fused
dequant-matmul path is the paper's compute-kernel optimization (§3.4).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MOE, ModelConfig
from repro.core.draft import accept_length
from repro.core.kvstore import (PhasedKVExtents, TieredKVStore,
                                kv_roundtrip_traceable)
from repro.core.offload import DeviceStore, DiskStore, HostStore
from repro.core.pipeline import PipelineScheduler, ThreadPool
from repro.core.tasks import Trace
from repro.core.transfer import Manifest, TieredWeightStore
from repro.models.attention import (decode_attention, ref_attention,
                                    spec_decode_attention)
from repro.models.common import rms_norm, silu
from repro.models.rope import apply_rope, rope_angles
from repro.quant.int4 import quantize_int4

# pre-spec constructor defaults: the deprecation shim overlays provided
# kwargs on these so a legacy call resolves to the exact plan the old
# constructor acted on (note depth defaulted to 1 here, NOT auto)
_LEGACY_DEFAULTS = dict(
    batch=4, max_len=256, placement="host", cache_on="host",
    pipeline="performance", quant=None, kv_mode=None, fused_int4=True,
    disk_root="/tmp/pipo_disk", block_bytes=None, n_io_threads=3,
    cold_reads=False, seed=0, depth=1)


# ---------------------------------------------------------------------------
# Per-unit compute (jitted)
# ---------------------------------------------------------------------------


def _qkv(x, w, pos, cfg: ModelConfig):
    b, s, d = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xn = rms_norm(x, w["norm"], cfg.norm_eps)
    q = (xn @ w["wq"]).reshape(b, s, h, dh)
    k = (xn @ w["wk"]).reshape(b, s, hkv, dh)
    v = (xn @ w["wv"]).reshape(b, s, hkv, dh)
    angles = rope_angles(pos + jnp.arange(s), dh, cfg.rope_theta)
    return apply_rope(q, angles), apply_rope(k, angles), v


def _attn_prefill_unit(x, w, *, cfg: ModelConfig):
    """Prefill attends within the prompt only — no cache is consumed.
    Returns (x', k_new, v_new) with k/v (b, s, hkv, dh)."""
    b, s, d = x.shape
    q, k, v = _qkv(x, w, jnp.int32(0), cfg)
    out = ref_attention(q, k, v, causal=True)
    return x + out.reshape(b, s, -1) @ w["wo"], k, v


def _attn_decode_unit(x, w, kc, vc, pos, *, cfg: ModelConfig,
                      kv_roundtrip=None):
    """x (b, s, d) — s == 1 for plain decode, k+1 for a speculative
    verify pass (the current token plus the draft's proposals, scored in
    one ragged step); kc/vc (b, L, hkv, dh) device copies of the tiered
    cache.  ``kv_roundtrip`` (host cache tier + kv_mode='int4') lets the
    verify pass attend its own earlier rows at the precision sequential
    decode would reload them at.  Returns (x', k_new, v_new, kc', vc')
    — the functionally updated caches back the ``cache_on="device"``
    store refresh; host mode persists through the KV store instead and
    drops them."""
    b, s, d = x.shape
    q, k, v = _qkv(x, w, pos, cfg)
    if s > 1:
        out, kc, vc = spec_decode_attention(q, kc, vc, k, v, pos,
                                            kv_roundtrip=kv_roundtrip)
    else:
        out, kc, vc = decode_attention(q, kc, vc, k, v, pos, axes=())
    return x + out.reshape(b, s, -1) @ w["wo"], k, v, kc, vc


def _mlp_unit(x, w, *, cfg: ModelConfig):
    xn = rms_norm(x, w["norm"], cfg.norm_eps)
    hdn = silu(xn @ w["w_gate"]) * (xn @ w["w_up"])
    return x + hdn @ w["w_down"]


def _gate_unit(x, wg, *, top_k: int):
    """Router: returns (weights (b*s, k), ids (b*s, k)) for the flat batch."""
    b, s, d = x.shape
    logits = x.reshape(b * s, d) @ wg
    vals, ids = jax.lax.top_k(logits, top_k)
    w = jax.nn.softmax(vals.astype(jnp.float32), axis=-1)
    return w, ids


def _expert_unit(x, w, *, cfg: ModelConfig):
    """One expert's FFN on the full batch (combined with router weights
    outside)."""
    xn = rms_norm(x, w["norm"], cfg.norm_eps)
    hdn = silu(xn @ w["w_gate"]) * (xn @ w["w_up"])
    return hdn @ w["w_down"]


def _embed_unit(tokens, emb):
    return jnp.take(emb, tokens, axis=0)


def _head_unit(x, emb):
    return jnp.argmax(x[:, -1].astype(jnp.float32) @ emb.T, axis=-1)


def _spec_head_unit(x, emb):
    """Per-POSITION greedy argmax for the verify pass: each of the b*s
    rows goes through exactly ``_head_unit``'s row arithmetic, so the
    per-position tokens match what s sequential single-token heads
    would emit.  x (b, s, d) -> (b, s) int32."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d).astype(jnp.float32) @ emb.T
    return jnp.argmax(flat, axis=-1).reshape(b, s)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclass
class UnitSpec:
    kind: str           # "mha" | "mlp"
    layer: int
    key: str            # store key


class PipelinedLM(PhasedKVExtents):
    """Offloaded generation per PIPO.

    placement: "device" | "host" | "disk" — where the merged unit weights
    live (paper's Weight-on GPU/CPU/Disk).  cache_on: "host" | "device".
    pipeline: "performance" | "memory" | "sequential".
    quant: None | "int4".
    depth: performance-pipeline preload window (layers in flight beyond
    the computing one; 1 = the paper's two-resident-layer invariant).
    """

    def __init__(self, plan=None, **legacy_kwargs):
        """Canonical construction takes ONE argument: a ``ResolvedPlan``
        (``serving.spec.build_lm(plan)``; the plan's ``b_max`` is the
        generation batch).  Passing a ``ModelConfig`` plus the pre-spec
        keyword arguments still works through a deprecation shim — the
        kwargs are converted to an ``EngineSpec`` and resolved, so both
        paths act on an identical plan."""
        from repro.serving.spec import (EngineSpec, ResolvedPlan,
                                        draft_policy_for,
                                        warn_deprecated_once)
        if isinstance(plan, ModelConfig):
            warn_deprecated_once(
                "PipelinedLM.legacy_kwargs",
                "PipelinedLM(cfg, **kwargs) is deprecated; build an "
                "EngineSpec and pass its resolved plan "
                "(serving.spec.build_lm) instead")
            unknown = set(legacy_kwargs) - set(_LEGACY_DEFAULTS)
            if unknown:
                raise TypeError(f"unknown kwargs {sorted(unknown)}")
            kw = {**_LEGACY_DEFAULTS, **legacy_kwargs}
            spec = EngineSpec(
                arch=plan.name, cfg=plan, offload=True,
                placement=kw["placement"],
                b_max=kw["batch"], max_len=kw["max_len"],
                pipeline=kw["pipeline"], quant=kw["quant"],
                kv_mode=kw["kv_mode"],
                fused_int4=kw["fused_int4"], depth=kw["depth"],
                cache_on=kw["cache_on"], disk_root=kw["disk_root"],
                block_bytes=kw["block_bytes"],
                n_io_threads=kw["n_io_threads"],
                cold_reads=kw["cold_reads"], seed=kw["seed"])
            plan = spec.resolve()
        elif not isinstance(plan, ResolvedPlan):
            raise TypeError(f"PipelinedLM takes a ResolvedPlan or a "
                            f"ModelConfig, got {type(plan).__name__}")
        elif legacy_kwargs:
            raise TypeError("plan construction takes no kwargs; set the "
                            "fields on the EngineSpec instead")
        cfg = plan.model_config()
        self.plan = plan
        self.cfg = cfg
        self.batch = plan.b_max
        self.max_len = plan.max_len
        self.placement = plan.placement
        self.cache_on = plan.cache_on
        self.quant = plan.quant
        self.kv_mode = plan.kv_mode or "fp32"
        self.depth = max(1, plan.depth)
        self.trace = Trace()
        self.host = HostStore()
        self.device = DeviceStore()
        self.disk = DiskStore(plan.disk_root)
        self.weights = TieredWeightStore(
            placement=plan.placement, host=self.host, device=self.device,
            disk=self.disk, quant=plan.quant, fused_int4=plan.fused_int4,
            block_bytes=plan.block_bytes, n_io_threads=plan.n_io_threads,
            cold_reads=plan.cold_reads, sim_bw=plan.sim_bw)
        self.pipeline_mode = plan.pipeline
        self.units: list[UnitSpec] = []
        self._build(plan.seed)
        self._kv_init()
        self._jit_units()
        # speculative decoding (core.draft): device-resident draft
        # proposes, the streamed target verifies k+1 positions per trip
        self.draft = None
        self._spec_k = 0
        self._spec_s = 1                 # rows the current step writes
        self._spec_mode = False
        self._iter_pos: Dict[int, int] = {}   # global iter -> start pos
        dp = draft_policy_for(plan)
        if dp is not None:
            self.attach_draft(
                dp.build(b_max=plan.b_max, max_len=plan.max_len), dp.k)

    def attach_draft(self, draft, k: int):
        """Enable speculative decoding with ``draft`` — anything with
        ``prefill_batch(tokens)`` and ``propose(tokens, pos, k) ->
        (batch, k)`` (``core.draft.ResidentDraft``, or a test fake).
        The uniform-batch engine advances all rows in lockstep, so a
        step accepts min-over-rows proposals; rows that accepted more
        re-derive their surplus next step (greedy decode is
        deterministic, so the stream stays bit-identical).  Main
        thread, before ``generate``."""
        if self.cfg.moe is not None:
            raise ValueError(
                "speculative decoding needs a dense stack: routing k+1 "
                "tokens jointly would change MoE capacity assignment "
                "versus sequential decode, breaking token parity")
        self.draft = draft
        self._spec_k = max(1, int(k))

    # -- weights -------------------------------------------------------------
    def _unit_tensors(self, kind: str, rng: np.random.Generator):
        cfg = self.cfg
        d, h, hkv, dh = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                         cfg.head_dim)
        s = 1.0 / math.sqrt(d)
        mk = lambda *shape: (rng.standard_normal(shape) * s).astype(np.float32)
        if kind == "mha":
            t = {"wq": mk(d, h * dh), "wk": mk(d, hkv * dh),
                 "wv": mk(d, hkv * dh), "wo": mk(h * dh, d),
                 "norm": np.zeros((d,), np.float32)}
        else:
            t = {"w_gate": mk(d, cfg.d_ff), "w_up": mk(d, cfg.d_ff),
                 "w_down": mk(cfg.d_ff, d) * (1.0 / math.sqrt(cfg.d_ff / d)),
                 "norm": np.zeros((d,), np.float32)}
        if self.quant == "int4":
            qt = {}
            for name, arr in t.items():
                if arr.ndim == 2 and arr.shape[0] % 128 == 0:
                    packed, scale = quantize_int4(jnp.asarray(arr))
                    qt[name + "#q"] = np.asarray(packed)
                    qt[name + "#s"] = np.asarray(scale)
                else:
                    qt[name] = arr
            t = qt
        return t

    def _put_tier(self, key: str, tensors: dict):
        self.weights.put(key, tensors)

    @property
    def manifests(self) -> Dict[str, Manifest]:
        return self.weights.manifests

    def _build(self, seed: int):
        cfg = self.cfg
        rng = np.random.default_rng(seed)
        emb = (rng.standard_normal((cfg.vocab_size, cfg.d_model))
               * (1.0 / math.sqrt(cfg.d_model))).astype(np.float32)
        self.device.put("emb", emb)      # embeddings stay on device (small)
        moe = cfg.moe
        for l in range(cfg.num_layers):
            key = f"mha[{l}]"
            self._put_tier(key, self._unit_tensors("mha", rng))
            self.units.append(UnitSpec("mha", l, key))
            if moe is not None:
                # router stays on device (tiny; needed before any prefetch)
                d = cfg.d_model
                self.device.put(f"wg[{l}]",
                                (rng.standard_normal((d, moe.num_experts))
                                 / math.sqrt(d)).astype(np.float32))
                for e in range(moe.num_experts):
                    self._put_tier(f"exp[{l}][{e}]",
                                   self._unit_tensors("mlp", rng))
                if moe.num_shared:
                    self._put_tier(f"shx[{l}]",
                                   self._unit_tensors("mlp", rng))
                self.units.append(UnitSpec("moe", l, f"shx[{l}]"))
            else:
                key = f"mlp[{l}]"
                self._put_tier(key, self._unit_tensors("mlp", rng))
                self.units.append(UnitSpec("mlp", l, key))

    # -- KV cache --------------------------------------------------------------
    def _kv_init(self):
        """One KV path for both engines: the host cache is a
        ``TieredKVStore`` indexed by schedulable unit (mha units carry
        ``k``/``v`` slabs, mlp/moe units are empty), sharing the weight
        store's link so live-row/INT4 byte reductions pay the same
        simulated interconnect serving pays.  ``cache_on="device"``
        keeps plain device arrays (nothing ever crosses the link)."""
        cfg = self.cfg
        shape = (self.batch, self.max_len, cfg.num_kv_heads, cfg.head_dim)
        if self.cache_on == "host":
            shapes = [({"k": (shape, np.float32), "v": (shape, np.float32)}
                       if u.kind == "mha" else {}) for u in self.units]
            kinds = [({"k": "kv", "v": "kv"} if u.kind == "mha" else {})
                     for u in self.units]
            self.kvstore = TieredKVStore(
                shapes, kinds, b_max=self.batch, max_len=self.max_len,
                kv_mode=self.kv_mode, link=self.weights.link)
        else:
            self.kvstore = None
            for l in range(cfg.num_layers):
                self.device.put(f"kc[{l}]", np.zeros(shape, np.float32))
                self.device.put(f"vc[{l}]", np.zeros(shape, np.float32))

    # -- jitted units ------------------------------------------------------------
    def _jit_units(self):
        cfg = self.cfg
        self._attn_prefill = jax.jit(partial(_attn_prefill_unit, cfg=cfg))
        rt = (kv_roundtrip_traceable
              if self.cache_on == "host" and self.kv_mode == "int4" else None)
        self._attn_decode = jax.jit(partial(_attn_decode_unit, cfg=cfg,
                                            kv_roundtrip=rt))
        self._mlp = jax.jit(partial(_mlp_unit, cfg=cfg))
        self._embed = jax.jit(_embed_unit)
        self._head = jax.jit(_head_unit)
        self._spec_head = jax.jit(_spec_head_unit)
        if cfg.moe is not None:
            self._gate = jax.jit(partial(_gate_unit, top_k=cfg.moe.top_k))
            self._expert = jax.jit(partial(_expert_unit, cfg=cfg))
        self._pool = None  # set by generate()

    # -- scheduler callbacks ------------------------------------------------------
    def is_mha(self, j: int) -> bool:
        return self.units[j].kind == "mha"

    def _load_key(self, key: str):
        return self.weights.load(key)

    def load_weights(self, j: int):
        u = self.units[j]
        if u.kind == "moe" and self.cfg.moe.num_shared == 0:
            return {}
        return self._load_key(u.key)

    def weight_nbytes(self, j: int) -> int:
        """Bytes unit j's base WEIGHT_LOAD moves (trace byte accounting)."""
        u = self.units[j]
        if u.kind == "moe" and self.cfg.moe.num_shared == 0:
            return 0
        return self.weights.nbytes(u.key)

    def release_weights(self, j: int, handle):
        del handle  # device arrays freed by GC; stores unaffected

    def _live_len(self, i: int) -> int:
        """Sequence rows iteration ``i``'s decode attention actually
        reads: the prompt plus the decode rows already saved (rows
        ``0..pos-1``; the rows at ``pos..`` arrive with the step's own
        k/v).  Iteration 0 is the prefill — no cache is consumed.
        Non-speculative decode is a pure function of ``i`` (one row per
        iteration) so warm cross-call preloads price exactly what they
        later ship; speculative steps advance by a variable 1..k+1 rows,
        so the per-iteration start positions are PLANNED on the main
        thread before submission (``_iter_pos``; the next iteration is
        planned at full acceptance — a superset when rows are rejected,
        and superset rows are zeros the attention mask ignores)."""
        if self._spec_mode:
            return min(self._iter_pos.get(i, self.max_len), self.max_len)
        return min(self._prompt_len + i - 1, self.max_len)

    # ``kv_nbytes``/``kv_extent``/``kv_save_nbytes``/``load_kv`` come
    # from ``PhasedKVExtents`` (the phase-aware logic shared with the
    # serving engines); the host hooks below feed it.
    def _kv_phase(self, i: int) -> str:
        """Iteration 0 is the batch prefill.  Phase is a pure function
        of the GLOBAL iteration index — never the ``_phase`` mode flag —
        so warm cross-call preloads price exactly what they later
        ship."""
        return "prefill" if i == 0 else "decode"

    def _kv_live(self, i: int):
        return (self.batch, self._live_len(i))

    def _kv_streams(self, j: int) -> bool:
        return self.cache_on == "host" and self.is_mha(j)

    def _kv_prefill_save_nbytes(self, j: int) -> int:
        return self.kvstore.prefill_save_nbytes(j, self.batch,
                                                self._prompt_len)

    def load_kv(self, i: int, j: int):
        if self.cache_on == "device":
            l = self.units[j].layer
            return {"k": self.device.get(f"kc[{l}]"),
                    "v": self.device.get(f"vc[{l}]")}
        return super().load_kv(i, j)

    def save_kv(self, i: int, j: int, new_kv):
        phase, k_new, v_new, pos, length = new_kv
        if self.cache_on == "device":
            # device-resident cache: refresh the store with the updated
            # arrays; the scheduler's save-before-load ordering makes
            # them visible to the next iteration's load (no bytes cross
            # the link).  Decode ships the functionally-updated caches
            # whole; the prefill ships the prompt's rows, scattered here.
            l = self.units[j].layer
            if phase == "prefill":
                k_new = self.device.get(f"kc[{l}]").at[:, :length].set(k_new)
                v_new = self.device.get(f"vc[{l}]").at[:, :length].set(v_new)
            self.device.put(f"kc[{l}]", k_new)
            self.device.put(f"vc[{l}]", v_new)
            return
        rows = {"k": k_new, "v": v_new}
        if phase == "prefill":
            self.kvstore.save_prefill_batch(j, rows, length)
        else:
            self.kvstore.save_decode(j, rows, active=range(self.batch),
                                     pos=np.full(self.batch, pos, np.int32))

    def compute(self, i: int, j: int, x, weights, kv):
        u = self.units[j]
        if u.kind == "mlp":
            return self._mlp(x, weights), None
        if u.kind == "moe":
            return self._compute_moe(u, x, weights), None
        pos = self._pos
        if self._phase == "prefill":
            x, k, v = self._attn_prefill(x, weights)
            return x, ("prefill", k, v, 0, x.shape[1])
        x, k, v, kc, vc = self._attn_decode(x, weights, kv["k"], kv["v"],
                                            jnp.int32(pos))
        if self.cache_on == "device":
            # ship the whole updated caches to the save task (device
            # puts, no link crossing); host mode ships only the new
            # rows (1 plain, k+1 for a speculative verify pass)
            return x, ("decode", kc, vc, int(pos), x.shape[1])
        return x, ("decode", k, v, int(pos), x.shape[1])

    def _compute_moe(self, u, x, shared_w):
        """Paper Appendix C.4: the gate forces a sync (experts unknown until
        it runs); then the union of routed experts is loaded through the
        pool while the shared expert (and earlier-arrived experts) compute —
        one expert's compute overlaps the next one's weight load."""
        from repro.core.tasks import Task, TaskType
        cfg = self.cfg
        moe = cfg.moe
        b, s, d = x.shape
        wts, ids = self._gate(x, self.device.get(f"wg[{u.layer}]"))
        ids_np = np.asarray(ids)                    # sync point (paper)
        union = sorted(set(ids_np.reshape(-1).tolist()))
        tasks = []
        for e in union:
            t = Task(TaskType.WEIGHT_LOAD, f"exp[{u.layer}][{e}]",
                     lambda e=e: self._load_key(f"exp[{u.layer}][{e}]"))
            t.nbytes = self.weights.nbytes(f"exp[{u.layer}][{e}]")
            self._pool.submit(t)
            tasks.append((e, t))
        out = jnp.zeros_like(x)
        if moe.num_shared and shared_w:
            out = out + self._expert(x, shared_w)   # overlaps expert loads
        wts_np = wts
        for e, t in tasks:
            we = t.wait()
            ye = self._expert(x, we)                # (b, s, d) all tokens
            w_e = jnp.sum(jnp.where(ids == e, wts_np, 0.0),
                          axis=-1).reshape(b, s, 1)
            out = out + ye * w_e.astype(ye.dtype)
        return x + out

    def finalize(self, i: int, x):
        if self._phase == "decode" and x.shape[1] > 1:
            # speculative verify: per-position argmax, (b, k+1)
            tok = self._spec_head(x, self.device.get("emb"))
        else:
            tok = self._head(x, self.device.get("emb"))
        self._last_tokens = np.asarray(tok)
        return self._last_tokens

    # -- public API -----------------------------------------------------------
    def generate(self, prompt: np.ndarray, gen_len: int, pool=None):
        """prompt (b, s) int32.  Greedy-generates gen_len tokens.  Returns
        (tokens (b, gen_len), stats dict).  ``pool`` injects a transfer
        pool (e.g. ``VirtualPool`` for virtual-clock byte/cost tests);
        its trace becomes the engine's."""
        b, s = prompt.shape
        assert b == self.batch and s + gen_len <= self.max_len
        cfg = self.cfg
        self._prompt_len = s        # KV hooks derive live extents from this
        if pool is not None and getattr(pool, "trace", None) is not None:
            self.trace = pool.trace
        # warm: the scheduler persists across the per-token generate()
        # calls below, pre-submitting token t+1's first weight/KV loads
        # during token t's tail compute (performance mode only).  load_kv
        # depends only on the (global, deterministic) iteration index —
        # never on the phase flag — so warm cross-call preloads stay
        # valid; saves drain at shutdown().
        sched = PipelineScheduler(len(self.units), self.pipeline_mode,
                                  pool=pool, trace=self.trace,
                                  warm=self.pipeline_mode == "performance",
                                  depth=self.depth)
        self._pool = sched.pool
        # link/precision stamps: a dumped trace replays without the model
        self.trace.meta.update(
            arch=cfg.name, b_max=self.batch, max_len=self.max_len,
            sim_bw=self.plan.sim_bw, quant=self.quant,
            kv_mode=self.kv_mode)
        t0 = time.perf_counter()
        outs = []

        emb = self.device.get("emb")

        # ---- prefill (iteration 0 processes the whole prompt) ----
        self._phase, self._pos = "prefill", 0
        x_prompt = self._embed(jnp.asarray(prompt), emb)
        first = sched.generate(self._model_view(), lambda i: x_prompt, 1)
        outs.append(first[-1])
        t_first = time.perf_counter() - t0

        # ---- decode ----
        self._phase = "decode"
        spec = {"spec_steps": 0, "spec_proposed": 0, "spec_accepted": 0}
        if self.draft is None:
            for t in range(1, gen_len):
                self._pos = s + t - 1
                x_tok = self._embed(jnp.asarray(outs[-1][:, None]), emb)
                nxt = sched.generate(self._model_view(), lambda i: x_tok, 1)
                outs.append(nxt[-1])
        else:
            self._decode_spec(sched, prompt, gen_len, outs, emb, spec)
        sched.shutdown()
        dt = time.perf_counter() - t0
        toks = np.stack(outs, axis=1)
        stats = {
            "ttft_s": t_first,
            "total_s": dt,
            "decode_tok_s": b * (gen_len - 1) / max(1e-9, dt - t_first),
            "throughput_tok_s": b * gen_len / dt,
            "compute_busy": self.trace.busy_fraction("compute"),
            "host_peak_gb": self.host.peak_bytes / 2**30,
            "device_peak_gb": self.device.peak_bytes / 2**30,
            "pipeline": self.trace.report(),
            **spec,
        }
        return toks, stats

    def _decode_spec(self, sched, prompt, gen_len, outs, emb, spec):
        """Draft-then-verify decode loop (main thread).  Each step: the
        draft proposes ``k`` tokens while ``prime_weights`` streams the
        verify pass's first weight loads over the idle link; the target
        scores all ``k+1`` positions in one trip through the layer
        stack; the batch advances by the MINIMUM accepted run over rows
        (uniform-batch lockstep — surplus accepted tokens re-derive
        next step, so the stream is bit-identical to plain greedy).
        Rejection truncates the tiered store's rows and drops the
        now-stale warm KV preloads; full acceptance keeps them (their
        planned extent was exact)."""
        s = prompt.shape[1]
        self._iter_pos.clear()
        # seed the first decode iteration's plan BEFORE flipping the
        # mode flag: the prefill's warm tail preload may still be in
        # flight and must resolve the same extent it was priced at
        self._iter_pos[sched._iter0] = s
        self._spec_mode = True
        self.draft.prefill_batch(prompt)
        try:
            while len(outs) < gen_len:
                pos = s + len(outs) - 1
                self._pos = pos
                remaining = gen_len - len(outs)
                k = min(self._spec_k, remaining - 1, self.max_len - 1 - pos)
                gi = sched._iter0
                if k < 1:
                    self._spec_s = 1
                    self._iter_pos[gi] = pos
                    self._iter_pos[gi + 1] = pos + 1
                    x_tok = self._embed(jnp.asarray(outs[-1][:, None]), emb)
                    nxt = sched.generate(self._model_view(),
                                         lambda i: x_tok, 1)
                    outs.append(nxt[-1])
                    continue
                self._spec_s = k + 1
                self._iter_pos[gi] = pos
                self._iter_pos[gi + 1] = pos + k + 1   # full-accept plan
                t0 = time.perf_counter()
                primed = sched.prime_weights(self._model_view())
                props = np.asarray(self.draft.propose(
                    outs[-1], np.full(self.batch, pos, np.int32), k),
                    np.int32)                          # (b, k)
                draft_s = time.perf_counter() - t0
                seq = np.concatenate(
                    [np.asarray(outs[-1], np.int32)[:, None], props], axis=1)
                x_tok = self._embed(jnp.asarray(seq), emb)
                nxt = sched.generate(self._model_view(), lambda i: x_tok, 1)
                tgt = np.asarray(nxt[-1])              # (b, k+1)
                a_min = min(accept_length(props[r], tgt[r])
                            for r in range(self.batch))
                emitted = min(a_min + 1, remaining)
                for t in range(emitted):
                    outs.append(tgt[:, t])
                if emitted < k + 1:
                    # rejected (or generation-capped) rows: the saves in
                    # flight would re-write them after the truncate, and
                    # the warm KV preloads priced the full-accept extent
                    # — drain, invalidate, drop (weight preloads stay)
                    sched.drain_saves()
                    sched.drop_kv_preloads()
                    if self.kvstore is not None:
                        for r in range(self.batch):
                            self.kvstore.truncate(r, pos + emitted)
                spec["spec_steps"] += 1
                spec["spec_proposed"] += k * self.batch
                spec["spec_accepted"] += int(a_min) * self.batch
                self.trace.meta.setdefault("spec_steps", []).append(dict(
                    k=int(k), primed=int(primed), draft_s=float(draft_s),
                    accepts=[int(a_min)] * self.batch))
        finally:
            self._spec_mode = False

    def _model_view(self):
        return self
