"""Trace-replay cost model: deterministic what-if analysis on recorded
pipelines (the ROADMAP "plan autotuner" — FlexInfer/PipeMax-style plan
selection by estimation, no hardware in the loop).

A recorded ``Trace`` already carries everything a cost model needs: the
per-task durations, payload bytes, and extents of every weight load, KV
transfer, and layer compute, plus the scheduling context the scheduler
stamps in ``trace.meta`` (mode, warm, depth, pool size, per-call
iteration counts, sim link, quant modes).  ``replay()`` re-runs that
recording through the REAL ``PipelineScheduler`` on a fresh
``VirtualPool`` — same Algorithm-1 code path, virtual timeline — with a
cost function derived from the recording, so "what would this run look
like at depth 3 / INT4 KV / half the link?" is answered in milliseconds:

  * unchanged knobs reproduce the recorded step times bit-for-bit
    (regression-tested against the committed golden fixtures);
  * ``sim_bw`` re-prices every transfer as
    ``overhead + bytes / bw`` (overhead = recorded time above the
    recorded link's byte cost); the virtual makespan is monotone in
    per-task durations, so a slower hypothetical link can never predict
    a faster step;
  * ``quant`` / ``kv_mode`` scale payload bytes by the §3.5 memory
    model's packing ratios (``quant_weight_ratio`` / ``quant_kv_ratio``)
    before pricing them;
  * ``depth`` / ``pool_size`` / ``mode`` / ``warm`` re-schedule the same
    recorded work under a different window.

``best_depth()`` sweeps the window and returns the simulated-argmin
depth — ``serving.spec.EngineSpec.resolve(budget, trace=...)`` uses it
(via ``core.autoconfig.replay_depth_decision``) to pick the measured
best configuration instead of the closed-form heuristic, recording
``replay`` as the depth's provenance source.

Known limits: expert loads submitted from inside MoE compute callbacks
carry engine-specific names the replayer cannot re-schedule — their time
stays inside the recorded compute durations, so dense stacks replay
exactly while MoE stacks replay with expert streaming folded into
compute.  Adaptive-depth recordings replay at the window's initial
depth (resizes are not in the schema).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.memory_model import quant_kv_ratio, quant_weight_ratio
from repro.core.pipeline import (PipelineScheduler, StagedScheduler,
                                 VirtualPool)
from repro.core.tasks import TaskType, Trace, VirtualClock

__all__ = ["ReplayError", "ReplayKnobs", "TraceProfile", "ReplayResult",
           "replay", "best_depth", "best_stage_depth", "step_boundaries",
           "step_times", "steady_step_s", "replay_traffic"]

_W_RE = re.compile(r"^w\[(\d+)\]$")
_PAIR_RE = re.compile(r"^(kv|sv|c)\[(\d+),(\d+)\]$")


class ReplayError(ValueError):
    """The trace cannot be replayed (no parseable scheduler events, or
    the requested iteration window is empty)."""


def _parse(name: str) -> Optional[Tuple[str, Optional[int], int]]:
    """(kind, iteration, layer) from a scheduler task name; None for
    names the scheduler didn't mint (e.g. MoE expert loads submitted
    from inside compute callbacks)."""
    m = _W_RE.match(name)
    if m:
        return "w", None, int(m.group(1))
    m = _PAIR_RE.match(name)
    if m:
        return m.group(1), int(m.group(2)), int(m.group(3))
    return None


# ---------------------------------------------------------------------------
# step timing helpers (shared by recorded and replayed traces)
# ---------------------------------------------------------------------------


def step_boundaries(trace: Trace) -> List[float]:
    """End-of-iteration timestamps: the t_end of each iteration's tail
    compute ``c[i, n-1]``, in iteration order.  A step's duration is the
    gap between consecutive boundaries."""
    tails: Dict[int, float] = {}
    n = 0
    for e in trace.events():
        p = _parse(e.name)
        if p is not None and p[0] == "c":
            n = max(n, p[2] + 1)
    if n == 0:
        return []
    for e in trace.events():
        p = _parse(e.name)
        if p is not None and p[0] == "c" and p[2] == n - 1:
            tails[p[1]] = e.t_end
    return [tails[i] for i in sorted(tails)]


def step_times(trace: Trace) -> List[float]:
    """Per-iteration step durations; the first is measured from the
    earliest event start (pipeline fill included)."""
    b = step_boundaries(trace)
    if not b:
        return []
    evs = trace.events()
    t0 = min(e.t_start for e in evs) if evs else 0.0
    return [b[0] - t0] + [b[k] - b[k - 1] for k in range(1, len(b))]


def steady_step_s(trace: Trace) -> float:
    """Steady-state seconds per iteration: boundary-to-boundary mean with
    the first (fill-dominated) step dropped; single-step traces fall back
    to that step."""
    b = step_boundaries(trace)
    if not b:
        return 0.0
    if len(b) == 1:
        return step_times(trace)[0]
    return (b[-1] - b[0]) / (len(b) - 1)


# ---------------------------------------------------------------------------
# TraceProfile — what the recording says about the workload
# ---------------------------------------------------------------------------


def _mean(vals) -> float:
    vals = list(vals)
    return sum(vals) / len(vals) if vals else 0.0


@dataclass
class TraceProfile:
    """Per-task durations/bytes recovered from a recording, iteration
    indices renumbered to 0..len(iters)-1 (``start_iter``/``stop_iter``
    slice a steady-state window out of a longer serving trace)."""

    n_units: int
    iters: List[int]                       # renumbered iteration ids
    calls: List[int]                       # generate() iteration counts
    mode: str
    warm: bool
    depth: int
    pool_size: int
    stages: int                            # pipeline-parallel stage count
    stage_units: Optional[List[tuple]]     # [(lo, hi)] when stages > 1
    stage_depths: Optional[List[int]]      # per-stage window when recorded
    sim_bw: Optional[float]
    quant: Optional[str]
    kv_mode: Optional[str]
    mha_layers: frozenset
    compute_s: Dict[Tuple[int, int], float]
    compute_mean: Dict[int, float]
    weight_s: Dict[int, float]             # mean duration per layer
    weight_b: Dict[int, float]             # mean bytes per layer
    kv_s: Dict[Tuple[int, int], float]
    kv_b: Dict[Tuple[int, int], float]
    kv_ext: Dict[Tuple[int, int], Optional[tuple]]
    kv_mean_s: Dict[int, float]
    kv_mean_b: Dict[int, float]
    sv_s: Dict[Tuple[int, int], float]
    sv_b: Dict[Tuple[int, int], float]
    sv_mean_s: Dict[int, float]
    sv_mean_b: Dict[int, float]

    @classmethod
    def from_trace(cls, trace: Trace, start_iter: Optional[int] = None,
                   stop_iter: Optional[int] = None) -> "TraceProfile":
        meta = trace.meta
        parsed = []
        n_units = int(meta.get("n_units") or 0)
        for e in trace.events():
            p = _parse(e.name)
            if p is None:
                continue
            parsed.append((p, e))
            n_units = max(n_units, p[2] + 1)
        if not any(p[0] == "c" for p, _ in parsed):
            raise ReplayError("trace has no scheduler compute events "
                              "(c[i,j]) to replay")

        def in_window(i):
            return ((start_iter is None or i >= start_iter)
                    and (stop_iter is None or i < stop_iter))

        iters = sorted({p[1] for p, _ in parsed
                        if p[0] == "c" and in_window(p[1])})
        if not iters:
            raise ReplayError(f"no compute events in iteration window "
                              f"[{start_iter}, {stop_iter})")
        base = iters[0]

        compute_s: Dict[Tuple[int, int], float] = {}
        w_s: Dict[int, list] = {}
        w_b: Dict[int, list] = {}
        kv_s: Dict[Tuple[int, int], float] = {}
        kv_b: Dict[Tuple[int, int], float] = {}
        kv_ext: Dict[Tuple[int, int], Optional[tuple]] = {}
        sv_s: Dict[Tuple[int, int], float] = {}
        sv_b: Dict[Tuple[int, int], float] = {}
        for (kind, i, j), e in parsed:
            dur = e.t_end - e.t_start
            if kind == "w":
                # weight loads carry no iteration index; layer cost is
                # steady (same bytes every pass), so pool all of them
                w_s.setdefault(j, []).append(dur)
                w_b.setdefault(j, []).append(e.nbytes)
            elif i is None or not in_window(i):
                continue
            elif kind == "c":
                compute_s[(i - base, j)] = dur
            elif kind == "kv":
                kv_s[(i - base, j)] = dur
                kv_b[(i - base, j)] = e.nbytes
                kv_ext[(i - base, j)] = e.extent
            else:  # sv
                sv_s[(i - base, j)] = dur
                sv_b[(i - base, j)] = e.nbytes

        by_layer = lambda d: {
            j: _mean(v for (ii, jj), v in d.items() if jj == j)
            for j in {jj for _, jj in d}}
        # slice the recorded call partition to the window: each call's
        # overlap with [base, base+len(iters)) becomes a replay call
        rec_calls = list(meta.get("calls") or [])
        calls, c0 = [], 0
        for c in rec_calls:
            lo, hi = max(c0, base), min(c0 + c, base + len(iters))
            if hi > lo:
                calls.append(hi - lo)
            c0 += c
        if sum(calls) != len(iters):
            calls = [len(iters)]           # untagged trace: one call

        su = meta.get("stage_units")
        return cls(
            n_units=n_units, iters=list(range(len(iters))), calls=calls,
            mode=meta.get("mode") or "performance",
            warm=bool(meta.get("warm", False)),
            depth=int(meta.get("depth") or 1),
            pool_size=int(meta.get("pool_size") or 3),
            stages=int(meta.get("stages") or 1),
            stage_units=None if su is None else [tuple(u) for u in su],
            stage_depths=(None if meta.get("stage_depths") is None
                          else [int(d) for d in meta["stage_depths"]]),
            sim_bw=meta.get("sim_bw"), quant=meta.get("quant"),
            kv_mode=meta.get("kv_mode"),
            mha_layers=frozenset({j for _, j in kv_s}
                                 | {j for _, j in sv_s}),
            compute_s=compute_s, compute_mean=by_layer(compute_s),
            weight_s={j: _mean(v) for j, v in w_s.items()},
            weight_b={j: _mean(v) for j, v in w_b.items()},
            kv_s=kv_s, kv_b=kv_b, kv_ext=kv_ext,
            kv_mean_s=by_layer(kv_s), kv_mean_b=by_layer(kv_b),
            sv_s=sv_s, sv_b=sv_b,
            sv_mean_s=by_layer(sv_s), sv_mean_b=by_layer(sv_b))


# ---------------------------------------------------------------------------
# ReplayKnobs — the hypothetical configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ReplayKnobs:
    """What-if overrides; every ``None`` field keeps the recorded value.
    ``quant``/``kv_mode`` accept ``"fp32"`` to explicitly mean
    unquantized (distinct from None = as recorded)."""

    depth: Optional[int] = None
    mode: Optional[str] = None
    warm: Optional[bool] = None
    pool_size: Optional[int] = None
    sim_bw: Optional[float] = None
    quant: Optional[str] = None
    kv_mode: Optional[str] = None
    stages: Optional[int] = None           # pipeline-parallel re-staging


def _pack_ratio(ratio_fn, new: Optional[str], rec: Optional[str]) -> float:
    """Byte multiplier recorded -> hypothetical precision (p cancels in
    the ratio of §3.5 packing ratios)."""
    if new is None or new == rec:
        return 1.0
    return ratio_fn(4, new) / ratio_fn(4, rec)


def _transfer_s(t_rec: float, b_rec: float, b_new: float,
                bw_rec: Optional[float], bw_new: Optional[float]) -> float:
    """Hypothetical transfer duration.  With a link model (recorded or
    requested bandwidth) the cost is fixed overhead + bytes/bw, the
    overhead being whatever the recorded duration spent above the
    recorded link's byte cost; without one, the recorded duration scales
    by the byte ratio.  Monotone: slower bw / more bytes never shrinks
    the result."""
    if bw_new is None:
        bw_new = bw_rec
    if not bw_new or b_new <= 0 or b_rec <= 0:
        if b_rec > 0:
            return t_rec * (b_new / b_rec)
        return t_rec
    overhead = max(0.0, t_rec - b_rec / bw_rec) if bw_rec else 0.0
    return overhead + b_new / bw_new


class _ReplayModel:
    """Scheduler callbacks with no side effects: bytes come from the
    profile scaled to the hypothetical precisions; durations are priced
    by the pool's cost_fn (same lookup tables)."""

    def __init__(self, prof: TraceProfile, rw: float, rkv: float):
        self.prof = prof
        self.rw = rw
        self.rkv = rkv

    def is_mha(self, j):
        return j in self.prof.mha_layers

    def load_weights(self, j):
        return ("w", j)

    def release_weights(self, j, handle):
        pass

    def load_kv(self, i, j):
        return ("kv", i, j)

    def save_kv(self, i, j, kv):
        pass

    def compute(self, i, j, x, w, kv):
        return x, ("kv" if self.is_mha(j) else None)

    def finalize(self, i, x):
        return x

    # byte-accounting hooks (scaled to the hypothetical precision)
    def weight_nbytes(self, j):
        return int(round(self.prof.weight_b.get(j, 0.0) * self.rw))

    def kv_nbytes(self, i, j):
        p = self.prof
        return int(round(p.kv_b.get((i, j), p.kv_mean_b.get(j, 0.0))
                         * self.rkv))

    def kv_extent(self, i, j):
        return self.prof.kv_ext.get((i, j))

    def kv_save_nbytes(self, i, j):
        p = self.prof
        return int(round(p.sv_b.get((i, j), p.sv_mean_b.get(j, 0.0))
                         * self.rkv))


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------


@dataclass
class ReplayResult:
    """One simulated run: the predicted trace plus the derived step/byte
    figures (``trace.meta`` carries the knobs it was simulated under, so
    a result is itself replayable)."""

    trace: Trace
    profile: TraceProfile
    step_times_s: List[float]
    steady_step_s: float
    span_s: float
    bytes_by_kind: Dict[str, int]
    report: Dict[str, Any] = field(default_factory=dict)


def replay(trace: Trace, knobs: Optional[ReplayKnobs] = None, *,
           start_iter: Optional[int] = None,
           stop_iter: Optional[int] = None) -> ReplayResult:
    """Re-run a recorded trace through the real scheduler on a virtual
    pool under hypothetical knobs; deterministic, model-free, O(events).
    ``start_iter``/``stop_iter`` slice a steady window out of a longer
    recording (e.g. the timed decode steps of a serving run) before
    replaying it."""
    k = knobs or ReplayKnobs()
    prof = TraceProfile.from_trace(trace, start_iter, stop_iter)
    mode = k.mode or prof.mode
    warm = prof.warm if k.warm is None else bool(k.warm)
    depth = prof.depth if k.depth is None else int(k.depth)
    depth = PipelineScheduler.clamp_depth(mode, prof.n_units, depth)
    if k.pool_size is not None:
        pool_size = int(k.pool_size)
    elif k.depth is None:
        pool_size = prof.pool_size
    else:
        # a hypothetical window gets the pool an engine would build for it
        pool_size = PipelineScheduler.pool_size(depth)
    sim_bw = prof.sim_bw if k.sim_bw is None else float(k.sim_bw)
    quant = prof.quant if k.quant is None else k.quant
    kv_mode = prof.kv_mode if k.kv_mode is None else k.kv_mode
    stages = prof.stages if k.stages is None else int(k.stages)
    stages = max(1, min(stages, prof.n_units))
    rw = _pack_ratio(quant_weight_ratio, k.quant, prof.quant)
    rkv = _pack_ratio(quant_kv_ratio, k.kv_mode, prof.kv_mode)

    model = _ReplayModel(prof, rw, rkv)

    def cost(task) -> float:
        p = _parse(task.name)
        if p is None:
            return 0.0
        kind, i, j = p
        if kind == "c":
            return prof.compute_s.get((i, j), prof.compute_mean.get(j, 0.0))
        if kind == "w":
            return _transfer_s(prof.weight_s.get(j, 0.0),
                               prof.weight_b.get(j, 0.0),
                               model.weight_nbytes(j), prof.sim_bw, sim_bw)
        if kind == "kv":
            t_rec = prof.kv_s.get((i, j), prof.kv_mean_s.get(j, 0.0))
            b_rec = prof.kv_b.get((i, j), prof.kv_mean_b.get(j, 0.0))
            return _transfer_s(t_rec, b_rec, model.kv_nbytes(i, j),
                               prof.sim_bw, sim_bw)
        t_rec = prof.sv_s.get((i, j), prof.sv_mean_s.get(j, 0.0))
        b_rec = prof.sv_b.get((i, j), prof.sv_mean_b.get(j, 0.0))
        return _transfer_s(t_rec, b_rec, model.kv_save_nbytes(i, j),
                           prof.sim_bw, sim_bw)

    if stages > 1:
        # stage-aware re-scheduling: rebuild the staged run — per-stage
        # virtual pools (own clock + transfer slots each, the per-stage
        # link) over ONE shared trace, exactly the topology the recorder
        # used, so unchanged knobs reproduce the recording bit-for-bit
        # and a single-stage recording can be re-staged hypothetically.
        if stages == prof.stages and prof.stage_units:
            units = [tuple(u) for u in prof.stage_units]
        else:
            bounds = [round(s * prof.n_units / stages)
                      for s in range(stages + 1)]
            units = [(bounds[s], bounds[s + 1]) for s in range(stages)]
        if (k.depth is None and stages == prof.stages
                and prof.stage_depths):
            depths = list(prof.stage_depths)
        else:
            depths = [depth] * stages
        out_trace = Trace(clock=VirtualClock())
        pools = [VirtualPool(max(1, pool_size), trace=out_trace,
                             cost_fn=cost, clock=VirtualClock())
                 for _ in range(stages)]
        sched = StagedScheduler(units, mode, pools=pools, trace=out_trace,
                                warm=warm, depths=depths)
        for iters in prof.calls:
            sched.generate(model, lambda i: 0, iters)
        sched.shutdown()
        out = out_trace
    else:
        pool = VirtualPool(max(1, pool_size), cost_fn=cost)
        sched = PipelineScheduler(prof.n_units, mode, pool=pool,
                                  trace=pool.trace, warm=warm, depth=depth)
        for iters in prof.calls:
            sched.generate(model, lambda i: 0, iters)
        sched.shutdown()
        out = pool.trace
    out.meta.update(sim_bw=sim_bw, quant=quant, kv_mode=kv_mode,
                    replayed=True)
    return ReplayResult(
        trace=out, profile=prof, step_times_s=step_times(out),
        steady_step_s=steady_step_s(out), span_s=out.span(),
        bytes_by_kind={t.value: out.bytes_moved(t.value)
                       for t in TaskType},
        report=out.report())


def replay_traffic(trace: Trace, *, sched: Optional[str] = None,
                   chunk: Optional[int] = None,
                   b_max: Optional[int] = None,
                   costs: Optional[dict] = None):
    """What-if re-run of a recorded traffic simulation: a
    ``serving.workload.TrafficSim`` trace carries its arrival schedule
    and knobs in ``meta["traffic"]``, so the same traffic replays under
    a different scheduling policy / chunk cap / slot count / cost model
    in milliseconds — "would OnlineSLO at chunk 16 have met the p99 SLO
    on yesterday's traffic?" without the engine.  Every ``None`` keeps
    the recorded value; ``costs`` keys override individual
    ``SimCosts`` fields.  Returns a ``workload.SimResult`` (itself
    replayable).  Deferred import: ``core.replay`` loads at ``core``
    package init, before the serving package exists."""
    from repro.serving.workload import ArrivalTrace, SimCosts, TrafficSim
    rec = trace.meta.get("traffic")
    if not rec:
        raise ReplayError("trace has no meta['traffic'] block "
                          "(not a TrafficSim recording)")
    c = dict(rec.get("costs") or {})
    c.update(costs or {})
    sim = TrafficSim(
        ArrivalTrace.from_json(rec["arrivals"]),
        b_max=int(rec["b_max"] if b_max is None else b_max),
        sched=str(rec["sched"] if sched is None else sched),
        chunk=int(rec["chunk"] if chunk is None else chunk),
        costs=SimCosts(**c))
    return sim.run()


def best_depth(trace: Trace, *, depth_cap: int = 8,
               knobs: Optional[ReplayKnobs] = None,
               start_iter: Optional[int] = None,
               stop_iter: Optional[int] = None
               ) -> Tuple[int, Dict[int, float]]:
    """Simulated-argmin preload depth: replay the recording at every
    depth in 1..depth_cap (each with the pool an engine would build for
    that window) and return (best depth, {depth: predicted steady s per
    step}).  Ties break toward the shallower window — less residency for
    the same predicted step."""
    import dataclasses
    base = knobs or ReplayKnobs()
    preds: Dict[int, float] = {}
    for d in range(1, max(1, int(depth_cap)) + 1):
        res = replay(trace, dataclasses.replace(base, depth=d),
                     start_iter=start_iter, stop_iter=stop_iter)
        preds[d] = res.steady_step_s
    best = min(preds, key=lambda d: (preds[d], d))
    return best, preds


def best_stage_depth(trace: Trace, *, stage_cap: int = 4,
                     depth_cap: int = 8,
                     knobs: Optional[ReplayKnobs] = None,
                     start_iter: Optional[int] = None,
                     stop_iter: Optional[int] = None
                     ) -> Tuple[Tuple[int, int], Dict[Tuple[int, int],
                                                      float]]:
    """Joint simulated argmin over ``(stages, depth)``: replay the
    recording at every staging x window combination (each stage with the
    pool an engine would build for that window) and return
    ``((stages, depth), {(stages, depth): predicted steady s/step})``.
    Ties break toward fewer stages, then the shallower window — less
    hardware and less residency for the same predicted step.  Stage
    counts beyond the unit count are skipped (a stage must own at least
    one unit)."""
    import dataclasses
    base = knobs or ReplayKnobs()
    prof = TraceProfile.from_trace(trace, start_iter, stop_iter)
    preds: Dict[Tuple[int, int], float] = {}
    for s in range(1, max(1, int(stage_cap)) + 1):
        if s > prof.n_units:
            break
        for d in range(1, max(1, int(depth_cap)) + 1):
            res = replay(trace, dataclasses.replace(base, stages=s,
                                                    depth=d),
                         start_iter=start_iter, stop_iter=stop_iter)
            preds[(s, d)] = res.steady_step_s
    best = min(preds, key=lambda sd: (preds[sd], sd))
    return best, preds
