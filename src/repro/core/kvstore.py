"""Tiered KV store: first-class residency for the decode cache.

The PIPO engines used to keep the KV cache as ad-hoc numpy dicts inside
each engine and ship the entire allocated ``(b_max, max_len)`` slab on
every ``KV_LOAD``.  Post the INT4 weight work, decode is KV-dominated
(see docs/BENCHMARKS.md) — the cache bytes, not the weight bytes, bound
the step.  ``TieredKVStore`` extracts KV ownership into one subsystem
(mirroring ``core.transfer.TieredWeightStore`` for weights) and attacks
the KV bytes two ways:

* **live-row slabs** — ``load(j, live_b, live_len)`` moves only the
  actually-occupied rows over the link: slots ``0..live_b-1`` and, for
  sequence-extent (kind ``"kv"``) leaves, positions ``0..live_len-1``.
  The device-side result is still the full-slab shape (zero-padded after
  the link) so jitted consumers never retrace; rows outside the live
  extent are masked by decode attention (``kv_pos <= pos``) and written
  before they are read, so the padding is value-invisible — ``kv_mode=
  "fp32"`` stays bit-exact with the old whole-slab path.
  ``load_nbytes`` prices exactly the bytes that crossed, which is what
  ``Task.nbytes``/``Trace`` record and what ``AdaptiveDepth`` prices the
  window with (exact, not modeled).

* **INT4 KV streaming** (``kv_mode="int4"``, the ``QuantPolicy.kv_mode``
  seam) — sequence-extent cache rows are stored *packed*: each
  ``(slot, position)`` row is group-quantized over its flattened feature
  dim (symmetric, groups of ``gcd(F, 32)``, two nibbles per byte +
  f32 group scales — the KV rendering of ``quant/int4.py``).  Rows are
  quantized once, when saved (write-once per position), so the
  quantize→dequantize roundtrip is applied exactly once per row and a
  resident reference that roundtrips newly-written rows reproduces the
  streamed tokens exactly (``serving.engine.KVRoundtripServingEngine``).
  Loads ship packed bytes (+scales) over the link; the dequant runs on
  the *transfer thread* right after the link, bounded by the live
  ``(slots, positions)`` extent — never the allocated slab — exactly
  like the weights path (``transfer._maybe_dequant``), so it overlaps
  main-thread compute instead of competing with it inside the decode
  jit (on TPU the in-kernel rendering is
  ``kernels/decode_attention.py::decode_attention_int4_kernel``).
  Consumers receive plain compute-precision leaves in every mode — the
  packed layout never escapes the store.  ``dequant_nbytes`` /
  ``dequant_bytes_total`` account the unpacked bytes so the live-extent
  bound is assertable on traces.
  Non-sequence leaves (rolling windows, SSM conv/state) are rewritten
  every step — requantizing them would compound error and break the
  roundtrip-once reference — so they stream at full precision.

Thread affinity: construction and ``alloc`` run on the main thread at
engine build; ``load``/``save_*``/``spill``/``restore`` run on transfer
pool threads (numpy + jax ops only, no engine state).  The ``link``
(``transfer.SimLink``) floors each load at ``bytes / bw`` like every
other transfer, so the live-row/INT4 byte reductions show up as wall
time under the deterministic benchmark link.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TieredKVStore", "PhasedKVExtents", "KV_GROUP", "kv_group",
    "kv_eligible", "quantize_kv_rows", "dequantize_kv_rows",
    "kv_roundtrip_rows",
]

# canonical KV quantization group: rows are short (hkv*dh features), so
# the group is the gcd with 32 — full-size heads get 32, scaled-down
# test configs a smaller power of two (same spirit as transfer.int4_group
# for weights, which uses 128 against the much longer contraction dims)
KV_GROUP = 32


def kv_group(n_features: int) -> int:
    """Group size for one cache row of ``n_features`` values."""
    return math.gcd(int(n_features), KV_GROUP)


def kv_eligible(kind: str, feat_shape: Sequence[int]) -> bool:
    """Whether a cache leaf quantizes under ``kv_mode='int4'``: only
    sequence-extent (kind ``'kv'``) rows — written once per position, so
    the quantize-once invariant holds — with an even flattened feature
    count (nibble pairs).  Rolling-window/conv/state leaves are rewritten
    every step and stream at full precision."""
    f = int(np.prod(feat_shape)) if len(feat_shape) else 1
    return kind == "kv" and f % 2 == 0 and f >= 2


@partial(jax.jit, static_argnums=(1,))
def _quantize_rows(x, group: int):
    """x (..., F) f32 -> (packed (..., F//2) uint8, scale (..., F//g) f32).
    Symmetric groupwise over the trailing feature dim; nibble pairs packed
    along adjacent feature columns."""
    *lead, F = x.shape
    xg = x.reshape(*lead, F // group, group)
    scale = jnp.max(jnp.abs(xg), axis=-1) / 7.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(xg / scale[..., None]).astype(jnp.int32)
    q = jnp.clip(q, -8, 7).reshape(*lead, F)
    qu = (q + 8).astype(jnp.uint8)
    lo = qu[..., 0::2]
    hi = qu[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8), scale


def _dequant_impl(packed, scale, group: int):
    """Traceable inverse of ``_quantize_rows`` -> (..., F) f32.  Plain
    function so consumers can inline it inside their own jit (the fused
    path: XLA folds the unpack+scale into the attention compute)."""
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = ((packed >> 4) & 0xF).astype(jnp.int32) - 8
    *lead, F2 = packed.shape
    q = jnp.stack([lo, hi], axis=-1).reshape(*lead, F2 * 2)
    w = (q.reshape(*lead, (F2 * 2) // group, group).astype(jnp.float32)
         * scale[..., None])
    return w.reshape(*lead, F2 * 2)


_dequantize_rows = jax.jit(_dequant_impl, static_argnums=(2,))


@partial(jax.jit, static_argnums=(2, 3, 4))
def _dequant_pad_rows(packed, scale, group: int, full: Tuple[int, ...],
                      dtype):
    """One-dispatch load body for INT4 leaves: dequantize the bucketed
    live rows, cast to compute precision, and scatter them into a zeroed
    full-slab array — fused so the f32 intermediate never materializes
    (the eager chain costs real transfer-thread CPU per load)."""
    rows = _dequant_impl(packed, scale, group)
    rows = rows.reshape(rows.shape[:-1] + full[2:]).astype(dtype)
    dev = jnp.zeros(full, dtype)
    return dev.at[:rows.shape[0], :rows.shape[1]].set(rows)


# live_len bucket for on-load shapes: the dequant/pad ops are shape-
# specialized (jit / dispatch caches) and decode presents a FRESH
# live_len every step — unbucketed that is a recompile per step, which
# on real clocks dwarfs the dead-byte win this store exists to claim.
# Rounding the sliced extent up to 32 positions caps the distinct
# shapes at max_len/32.  The bucket's tail rows are zero-filled on the
# host side (zero packed bytes under zero scales dequantize to exact
# zeros), so padded rows stay value-invisible and the link still
# prices only the true live bytes.
KV_LEN_BUCKET = 32


def quantize_kv_rows(x, group: Optional[int] = None):
    """Quantize cache rows (..., F) -> (packed, scale) numpy arrays.  The
    single quantization the store, the spill path, and the parity
    reference all share — any drift breaks the roundtrip-once parity.
    Accepts host or device arrays directly (no forced host bounce)."""
    x = jnp.asarray(x, jnp.float32)
    g = group or kv_group(x.shape[-1])
    packed, scale = _quantize_rows(x, g)
    return np.asarray(packed), np.asarray(scale)


def dequantize_kv_rows(packed, scale, group: int, dtype=jnp.bfloat16):
    """Inverse of ``quantize_kv_rows`` -> (..., F) numpy array of
    ``dtype`` (the cache's compute precision).  Accepts host or device
    arrays directly (no forced host bounce)."""
    out = _dequantize_rows(jnp.asarray(packed), jnp.asarray(scale), group)
    return np.asarray(out.astype(dtype))


def kv_roundtrip_rows(x, group: Optional[int] = None):
    """quantize -> dequantize rows through the exact jitted ops the INT4
    streaming path uses, cast back to the input dtype — the reference
    transformation ``KVRoundtripServingEngine`` applies to newly-written
    cache rows so its tokens match the streamed engine's exactly."""
    g = group or kv_group(x.shape[-1])
    packed, scale = quantize_kv_rows(x, g)
    return dequantize_kv_rows(packed, scale, g, jnp.dtype(x.dtype))


def kv_roundtrip_traceable(x):
    """Traceable in-graph form of ``kv_roundtrip_rows`` for cache rows
    shaped ``(b, s, *feat)`` — the SAME quantize/dequantize ops
    ``save_decode``/``load`` run, so the result is bitwise what the host
    tier will serve back for these rows.  The speculative verify pass
    uses it so query ``t`` attends rows ``pos..pos+t-1`` at exactly the
    precision sequential decode would have read them at (they went
    through the store between sequential steps; in the fused verify pass
    they never left the device).  Ineligible leaves (odd flattened
    feature count) stream at full precision in the store, so they pass
    through unchanged here too.  Shape/group resolve at trace time."""
    feat = x.shape[2:]
    if not kv_eligible("kv", feat):
        return x
    F = int(np.prod(feat))
    g = kv_group(F)
    flat = x.reshape(x.shape[0], x.shape[1], F).astype(jnp.float32)
    packed, scale = _quantize_rows(flat, g)
    return _dequant_impl(packed, scale, g).reshape(x.shape).astype(x.dtype)


@dataclass
class _LeafMeta:
    """Per-leaf layout (kept public via ``leaf_meta`` for tests and
    byte-accounting consumers; the packed layout itself never leaves the
    store — ``load`` returns compute-precision leaves in every mode)."""
    kind: str                 # transformer cache kind ("kv"/"rep"/...)
    feat: Tuple[int, ...]     # trailing feature shape after (b[, L])
    dtype: Any                # compute-precision dtype of the leaf
    quant: bool = False       # stored packed INT4 (dequant on load)
    group: int = 0            # quant group over the flattened features


@dataclass
class _RawLeaf:
    arr: np.ndarray           # (b, ...) full precision


@dataclass
class _QuantLeaf:
    packed: np.ndarray        # (b, L, F//2) uint8
    scale: np.ndarray         # (b, L, F//g) f32
    group: int
    feat: Tuple[int, ...]     # original trailing feature shape
    dtype: Any                # original compute dtype


class TieredKVStore:
    """Host-resident decode cache with live-row loads and optional INT4
    row packing (see module docstring).

    ``unit_shapes``/``unit_kinds``: one dict per schedulable unit, name ->
    ((b_max, [max_len,] *feat) shape, dtype) / name -> cache kind, as
    produced by ``models.transformer.cache_struct`` (the engine strips
    the period-stack dim).  ``link`` is a ``transfer.SimLink`` (or any
    object with ``floor(nbytes, t0)``) shared with the weight store so KV
    pays the same simulated link."""

    def __init__(self, unit_shapes: List[Dict[str, tuple]],
                 unit_kinds: List[Dict[str, str]], *, b_max: int,
                 max_len: int, kv_mode: str = "fp32", link=None):
        assert kv_mode in ("fp32", "int4"), kv_mode
        self.b_max = b_max
        self.max_len = max_len
        self.kv_mode = kv_mode
        self.link = link
        self.kinds: List[Dict[str, str]] = [dict(k) for k in unit_kinds]
        # running total of compute-precision bytes the load-side dequant
        # materialized — bounded by live extents, never the slab
        # (asserted in tests/test_kvstore.py); 0 forever under fp32
        self.dequant_bytes_total = 0
        self._units: List[Dict[str, Any]] = []
        self._meta: List[Dict[str, _LeafMeta]] = []
        for shapes, kinds in zip(unit_shapes, unit_kinds):
            leaves: Dict[str, Any] = {}
            meta: Dict[str, _LeafMeta] = {}
            for name, (shape, dtype) in shapes.items():
                kind = kinds[name]
                feat = tuple(shape[2:]) if kind == "kv" else tuple(shape[1:])
                m = _LeafMeta(kind, feat, np.dtype(dtype))
                if kv_mode == "int4" and kv_eligible(kind, feat):
                    F = int(np.prod(feat))
                    g = kv_group(F)
                    m.quant, m.group = True, g
                    leaves[name] = _QuantLeaf(
                        np.zeros((shape[0], shape[1], F // 2), np.uint8),
                        np.zeros((shape[0], shape[1], F // g), np.float32),
                        g, feat, np.dtype(dtype))
                else:
                    leaves[name] = _RawLeaf(np.zeros(shape, dtype))
                meta[name] = m
            self._units.append(leaves)
            self._meta.append(meta)

    # ---- layout introspection (main thread, build time) --------------------
    def __len__(self):
        return len(self._units)

    def leaf_meta(self, j: int) -> Dict[str, _LeafMeta]:
        """Per-leaf layout for unit ``j`` (introspection / tests)."""
        return self._meta[j]

    def has_kv(self, j: int) -> bool:
        return bool(self.kinds[j])

    # ---- byte accounting (any thread; non-blocking) ------------------------
    def _leaf_arrays(self, j: int, name: str):
        leaf = self._units[j][name]
        if isinstance(leaf, _QuantLeaf):
            return (leaf.packed, leaf.scale)
        return (leaf.arr,)

    def load_nbytes(self, j: int, live_b: Optional[int] = None,
                    live_len: Optional[int] = None) -> int:
        """Bytes one ``load(j, live_b, live_len)`` moves over the link —
        exactly the sliced rows (packed bytes for INT4 leaves).  This is
        what ``Task.nbytes`` records on KV_LOAD trace events and what
        ``AdaptiveDepth`` prices the window's KV term with."""
        lb = self.b_max if live_b is None else min(int(live_b), self.b_max)
        ll = self.max_len if live_len is None else min(int(live_len),
                                                      self.max_len)
        total = 0
        for name, m in self._meta[j].items():
            for a in self._leaf_arrays(j, name):
                shape = list(a.shape)
                shape[0] = lb
                if m.kind == "kv":
                    shape[1] = ll
                total += int(np.prod(shape)) * a.itemsize
        return total

    def slab_nbytes(self, j: int) -> int:
        """Bytes the full allocated ``(b_max, max_len)`` slab would move
        — the pre-live-row KV_LOAD payload, kept for tests/pricing."""
        return self.load_nbytes(j, self.b_max, self.max_len)

    def save_nbytes(self, j: int, live_b: Optional[int] = None,
                    rows: int = 1) -> int:
        """Bytes one decode ``save_decode`` payload moves device->host:
        the freshly-written rows of ``live_b`` slots at compute precision
        (quantization happens at the host tier, after the transfer).
        ``rows`` is the per-slot row count — 1 for plain decode, ``k+1``
        for a speculative verify pass (non-kv kinds ship full per-slot
        state either way)."""
        lb = self.b_max if live_b is None else min(int(live_b), self.b_max)
        total = 0
        for name, m in self._meta[j].items():
            row = int(np.prod(m.feat)) * np.dtype(m.dtype).itemsize
            if m.kind == "kv":
                row *= max(1, int(rows))
            total += lb * row
        return total

    def prefill_save_nbytes(self, j: int, live_b: int = 1,
                            length: Optional[int] = None) -> int:
        """Bytes a prefill save moves: ``live_b`` slots' rows at compute
        precision, ``length`` positions each for kv kinds (default the
        full per-slot extent — one slot's whole rows, the serving
        engine's per-slot admission payload)."""
        ll = self.max_len if length is None else min(int(length),
                                                     self.max_len)
        total = 0
        for name, m in self._meta[j].items():
            n = int(np.prod(m.feat)) * np.dtype(m.dtype).itemsize
            if m.kind == "kv":
                n *= ll
            total += n
        return total * max(1, int(live_b))

    def dequant_nbytes(self, j: int, live_b: Optional[int] = None,
                       live_len: Optional[int] = None) -> int:
        """Compute-precision bytes one ``load(j, live_b, live_len)``
        materializes on the transfer thread when unpacking INT4 leaves —
        the dequant cost, bounded by the live extent (0 in fp32 mode)."""
        lb = self.b_max if live_b is None else min(int(live_b), self.b_max)
        ll = self.max_len if live_len is None else min(int(live_len),
                                                      self.max_len)
        total = 0
        for name, m in self._meta[j].items():
            if m.quant:
                total += lb * ll * int(np.prod(m.feat)) \
                    * np.dtype(m.dtype).itemsize
        return total

    def max_live_load_nbytes(self, live_b: int, live_len: int) -> int:
        """Largest per-unit live KV_LOAD payload at the given extents —
        the exact per-layer KV price ``AdaptiveDepth`` feeds the memory
        model instead of the modeled slab."""
        return max(self.load_nbytes(j, live_b, live_len)
                   for j in range(len(self._units))) if self._units else 0

    def host_nbytes(self) -> int:
        """Total host bytes the store pins (packed bytes under INT4)."""
        return sum(a.nbytes for j in range(len(self._units))
                   for name in self._units[j]
                   for a in self._leaf_arrays(j, name))

    # ---- loads (transfer-pool thread) --------------------------------------
    def _bucket_len(self, ll: int) -> int:
        """``live_len`` rounded up to the shape bucket (see
        ``KV_LEN_BUCKET``), clamped to the slab extent."""
        return min(self.max_len,
                   -(-int(ll) // KV_LEN_BUCKET) * KV_LEN_BUCKET)

    @staticmethod
    def _bucketed(arr: np.ndarray, lb: int, ll: int, ll_b: int):
        """Host-side ``(lb, ll_b, ...)`` slice of a ``(b, L, ...)`` slab
        with the ``ll..ll_b`` tail zero-filled — the fixed-shape payload
        the shape-specialized device ops consume."""
        if ll_b == ll:
            return np.ascontiguousarray(arr[:lb, :ll])
        out = np.zeros((lb, ll_b) + arr.shape[2:], arr.dtype)
        out[:, :ll] = arr[:lb, :ll]
        return out

    def _put_padded(self, arr: np.ndarray, lb: int, ll: int, seq: bool):
        sl = arr[:lb, :ll] if seq else arr[:lb]
        if sl.shape == arr.shape:
            return jnp.asarray(arr)
        if seq:
            ll_b = self._bucket_len(ll)
            rows = jnp.asarray(self._bucketed(arr, lb, ll, ll_b))
            dev = jnp.zeros(arr.shape, rows.dtype)
            return dev.at[:lb, :ll_b].set(rows)
        rows = jnp.asarray(np.ascontiguousarray(sl))
        dev = jnp.zeros(arr.shape, rows.dtype)
        return dev.at[tuple(slice(0, s) for s in sl.shape)].set(rows)

    def load(self, j: int, live_b: Optional[int] = None,
             live_len: Optional[int] = None) -> Dict[str, Any]:
        """KV_LOAD body: host rows -> device, sliced to the live extent
        and zero-padded back to the full slab shape (device side, after
        the link) so jitted consumers keep one signature.  INT4 leaves
        cross the link packed, then dequantize HERE — on the transfer
        thread, over only the live rows rounded up to the shape bucket
        (never the slab), the same post-link discipline as
        ``transfer._maybe_dequant`` for weights — so consumers receive
        plain compute-precision leaves in every mode.  Pays the link
        floor on exactly the (packed) live bytes; ``dequant_bytes_total``
        likewise prices the live extent (bucket padding is a
        compile-amortization detail, not modeled cost)."""
        t0 = time.perf_counter()
        lb = self.b_max if live_b is None else \
            max(1, min(int(live_b), self.b_max))
        ll = self.max_len if live_len is None else \
            max(1, min(int(live_len), self.max_len))
        out: Dict[str, Any] = {}
        for name, m in self._meta[j].items():
            leaf = self._units[j][name]
            if isinstance(leaf, _QuantLeaf):
                ll_b = self._bucket_len(ll)
                packed = jnp.asarray(self._bucketed(leaf.packed,
                                                    lb, ll, ll_b))
                scale = jnp.asarray(self._bucketed(leaf.scale,
                                                   lb, ll, ll_b))
                full = (self.b_max, self.max_len) + m.feat
                out[name] = _dequant_pad_rows(packed, scale, leaf.group,
                                              full, m.dtype)
                self.dequant_bytes_total += lb * ll \
                    * int(np.prod(m.feat)) * np.dtype(m.dtype).itemsize
            else:
                out[name] = self._put_padded(leaf.arr, lb, ll,
                                             seq=m.kind == "kv")
        for a in out.values():
            a.block_until_ready()
        if self.link is not None:
            self.link.floor(self.load_nbytes(j, lb, ll), t0)
        return out

    # ---- saves (transfer-pool thread) --------------------------------------
    def save_prefill(self, j: int, slot: int,
                     rows: Dict[str, np.ndarray]) -> None:
        """Scatter one slot's freshly-prefilled rows (name -> the slot's
        full per-slot extent, e.g. ``(max_len, *feat)`` for kv kinds).
        INT4 leaves quantize here — once per row; positions beyond the
        prompt are zeros and roundtrip to zeros exactly."""
        for name, m in self._meta[j].items():
            leaf = self._units[j][name]
            row = np.asarray(rows[name])
            if isinstance(leaf, _QuantLeaf):
                # cast to the cache's compute precision FIRST: the fp32
                # store path downcasts on assignment into the bf16 host
                # array, and the parity reference roundtrips bf16 cache
                # rows — quantizing the pre-cast f32 activations would
                # pick (slightly) different scales and break parity
                row = row.astype(m.dtype)
                F = int(np.prod(m.feat))
                packed, scale = quantize_kv_rows(
                    row.reshape(row.shape[0], F), leaf.group)
                leaf.packed[slot] = packed
                leaf.scale[slot] = scale
            else:
                leaf.arr[slot] = row

    def save_prefill_batch(self, j: int, rows: Dict[str, np.ndarray],
                           length: Optional[int] = None) -> None:
        """Scatter ALL slots' freshly-prefilled rows at once (name ->
        ``(b, length, *feat)`` live rows for kv kinds, ``(b, *feat)``
        for per-slot state) — the batch-generation admission path
        (``PipelinedLM``), where every slot prefills together.  Positions
        beyond ``length`` reset to zeros (and zeros roundtrip to zeros
        under INT4, so the tail stays value-invisible)."""
        for name, m in self._meta[j].items():
            leaf = self._units[j][name]
            row = np.asarray(rows[name])
            if isinstance(leaf, _QuantLeaf):
                row = row.astype(m.dtype)     # compute precision first
                ll = row.shape[1] if length is None else int(length)
                F = int(np.prod(m.feat))
                b = row.shape[0]
                packed, scale = quantize_kv_rows(
                    row[:, :ll].reshape(b, ll, F), leaf.group)
                leaf.packed[:b, :ll] = packed
                leaf.packed[:b, ll:] = 0
                leaf.scale[:b, :ll] = scale
                leaf.scale[:b, ll:] = 0
            elif m.kind == "kv":
                ll = row.shape[1] if length is None else int(length)
                b = row.shape[0]
                leaf.arr[:b, :ll] = row[:, :ll]
                leaf.arr[:b, ll:] = 0
            else:
                leaf.arr[:row.shape[0]] = row

    def save_decode(self, j: int, rows: Dict[str, np.ndarray],
                    active: Sequence[int], pos: np.ndarray) -> None:
        """Scatter a decode step's new rows: for kv kinds ``rows[name]``
        is ``(live_b, n, *feat)`` (slot s's ``n`` new rows at positions
        ``pos[s]..pos[s]+n-1`` — ``n == 1`` for plain decode, ``k+1``
        for a speculative verify pass), other kinds carry the full
        per-slot state.  INT4 leaves quantize the new rows — the only
        time they are ever quantized."""
        for name, m in self._meta[j].items():
            leaf = self._units[j][name]
            row = np.asarray(rows[name])
            if isinstance(leaf, _QuantLeaf):
                row = row.astype(m.dtype)     # compute precision first
                F = int(np.prod(m.feat))
                n = row.shape[1]
                packed, scale = quantize_kv_rows(
                    row.reshape(row.shape[0], n, F), leaf.group)
                for s in active:
                    p = int(pos[s])
                    leaf.packed[s, p:p + n] = packed[s]
                    leaf.scale[s, p:p + n] = scale[s]
            elif m.kind == "kv":
                n = row.shape[1]
                for s in active:
                    p = int(pos[s])
                    leaf.arr[s, p:p + n] = row[s]
            else:
                for s in active:
                    leaf.arr[s] = row[s]

    def truncate(self, slot: int, new_len: int) -> None:
        """Shrink one slot's live position extent to ``new_len`` rows:
        positions ``new_len..max_len-1`` reset to zeros across every
        unit's sequence-extent (kind ``'kv'``) leaves.  Packed-INT4-safe:
        zero packed bytes under zero scales dequantize to exact zeros
        (the same invariant ``save_prefill_batch`` tail-zeroing relies
        on), so a truncate-then-append round-trip is bit-exact in both
        modes.  This is the rejection path of speculative decoding — a
        verify pass appends ``k+1`` rows, then the engine truncates back
        to the accepted prefix.  Non-sequence leaves (rolling windows,
        SSM state) are rewritten every step and carry no position
        extent, so they are left untouched."""
        nl = max(0, min(int(new_len), self.max_len))
        for j in range(len(self._units)):
            for name, m in self._meta[j].items():
                leaf = self._units[j][name]
                if isinstance(leaf, _QuantLeaf):
                    leaf.packed[slot, nl:] = 0
                    leaf.scale[slot, nl:] = 0
                elif m.kind == "kv":
                    leaf.arr[slot, nl:] = 0

    # ---- slot spill/restore (transfer-pool / main thread) ------------------
    def spill(self, host, ns: str, slot: int) -> None:
        """Copy one slot's rows into ``host`` under ``{ns}/{unit}/{name}``
        keys.  INT4 rows spill packed (lossless; ~0.625 B/value against
        the 2 B bf16 cache, ~3x) under ``...{name}#q`` /
        ``...{name}#s``."""
        for j in range(len(self._units)):
            for name in self._units[j]:
                leaf = self._units[j][name]
                if isinstance(leaf, _QuantLeaf):
                    host.put(f"{ns}/{j}/{name}#q", leaf.packed[slot].copy())
                    host.put(f"{ns}/{j}/{name}#s", leaf.scale[slot].copy())
                else:
                    host.put(f"{ns}/{j}/{name}", leaf.arr[slot].copy())

    def restore(self, host, ns: str, slot: int) -> None:
        """Inverse of ``spill``: bring a parked request's rows back into
        ``slot``.  Bit-lossless in both modes (packed rows round-trip
        untouched)."""
        for j in range(len(self._units)):
            for name in self._units[j]:
                leaf = self._units[j][name]
                if isinstance(leaf, _QuantLeaf):
                    leaf.packed[slot] = host.get(f"{ns}/{j}/{name}#q")
                    leaf.scale[slot] = host.get(f"{ns}/{j}/{name}#s")
                else:
                    leaf.arr[slot] = host.get(f"{ns}/{j}/{name}")


class PhasedKVExtents:
    """Phase-aware KV hooks for the ``PipelineScheduler`` — one home for
    the prefill special-cases and live-extent pricing that used to be
    duplicated (asymmetrically) between ``OffloadedServingEngine`` and
    ``PipelinedLM``.

    The host engine answers what an iteration is doing and what is live;
    the mixin derives the scheduler-facing ``kv_nbytes`` / ``kv_extent``
    / ``kv_save_nbytes`` / ``load_kv`` from the answers, so both engines
    share one statement of the invariants:

      * a **prefill** iteration builds fresh caches in-pass — no KV
        loads cross the link (``load_kv`` returns None; a warm tail
        preload issued during a prefill is thereby *poisoned* and must
        be dropped by the engine before the next decode consumes it),
        and the save ships the whole prompt's rows;
      * a **decode** iteration loads the live ``(slots, positions)``
        extent and saves one (or ``k+1`` speculative) fresh row(s) per
        live slot;
      * a **chunk** iteration (chunked-prefill-only engine step) loads
        nothing — the chunk attends the engine-held fp32 prefix, not
        the store — and only the chunk's append crosses on the save.

    Pricing (``kv_nbytes``/``kv_save_nbytes``) and shipping (``load_kv``)
    share the same ``_kv_live`` extents, so trace bytes never overstate
    what crossed.  Host hooks::

        _kv_phase(i)   -> "prefill" | "decode" | "chunk"
        _kv_live(i)    -> (live_batch, live_len) of iteration i's load
        _kv_streams(j) -> does unit j's cache cross the link at all?
        _kv_prefill_save_nbytes(j)   whole-prompt save payload bytes
        _kv_chunk_save_nbytes(j)     in-flight chunk append bytes (0
                                     unless a chunked engine overrides)

    plus ``self.kvstore`` (a ``TieredKVStore``).  Engines with a
    device-resident tier override ``load_kv`` and fall through to
    ``super()`` for the streamed path."""

    kvstore: "TieredKVStore"

    # ---- host hooks ---------------------------------------------------------
    def _kv_phase(self, i: int) -> str:
        raise NotImplementedError

    def _kv_live(self, i: int) -> Tuple[int, int]:
        raise NotImplementedError

    def _kv_streams(self, j: int) -> bool:
        raise NotImplementedError

    def _kv_prefill_save_nbytes(self, j: int) -> int:
        raise NotImplementedError

    def _kv_chunk_save_nbytes(self, j: int) -> int:
        return 0

    def _kv_save_rows(self) -> int:
        """Rows per live slot a decode save ships (k+1 for a speculative
        verify pass)."""
        return getattr(self, "_spec_s", 1)

    # ---- derived PipelineScheduler callbacks (any thread) -------------------
    def kv_nbytes(self, i: int, j: int) -> int:
        """Bytes iteration i's KV_LOAD of unit j moves over the link —
        the LIVE rows only (packed bytes under ``kv_mode='int4'``), 0
        outside decode.  Recorded on trace events so transfer volume
        (and the live-row saving) is assertable from ``Trace.report()``."""
        if not self._kv_streams(j) or self._kv_phase(i) != "decode":
            return 0
        lb, ll = self._kv_live(i)
        return self.kvstore.load_nbytes(j, lb, ll)

    def kv_extent(self, i: int, j: int):
        """Live (batch, len) of iteration i's KV_LOAD payload — recorded
        on the trace event (None outside decode)."""
        if not self._kv_streams(j) or self._kv_phase(i) != "decode":
            return None
        return self._kv_live(i)

    def kv_save_nbytes(self, i: int, j: int) -> int:
        """Bytes iteration i's KV_SAVE payload moves device->host:
        prefill ships whole prompt rows, decode the live slots' fresh
        rows, and an in-flight prefill chunk adds its append on top."""
        if not self._kv_streams(j):
            return 0
        phase = self._kv_phase(i)
        if phase == "prefill":
            return self._kv_prefill_save_nbytes(j)
        n = self._kv_chunk_save_nbytes(j)
        if phase == "decode":
            lb, _ = self._kv_live(i)
            n += self.kvstore.save_nbytes(j, lb, rows=self._kv_save_rows())
        return n

    def load_kv(self, i: int, j: int):
        """KV_LOAD body (transfer-pool thread): live host rows -> device
        slab via the tiered store.  None outside decode — prefill/chunk
        iterations build or extend caches in-pass."""
        if not self._kv_streams(j) or self._kv_phase(i) != "decode":
            return None
        lb, ll = self._kv_live(i)
        return self.kvstore.load(j, lb, ll)
