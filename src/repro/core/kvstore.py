"""Tiered KV store: first-class residency for the decode cache.

The PIPO engines used to keep the KV cache as ad-hoc numpy dicts inside
each engine and ship the entire allocated ``(b_max, max_len)`` slab on
every ``KV_LOAD``.  Post the INT4 weight work, decode is KV-dominated
(see docs/BENCHMARKS.md) — the cache bytes, not the weight bytes, bound
the step.  ``TieredKVStore`` extracts KV ownership into one subsystem
(mirroring ``core.transfer.TieredWeightStore`` for weights) and attacks
the KV bytes two ways:

* **live-row slabs** — ``load(j, live_b, live_len)`` moves only the
  actually-occupied rows over the link: slots ``0..live_b-1`` and, for
  sequence-extent (kind ``"kv"``) leaves, positions ``0..live_len-1``.
  The device-side result is still the full-slab shape (zero-padded after
  the link) so jitted consumers never retrace; rows outside the live
  extent are masked by decode attention (``kv_pos <= pos``) and written
  before they are read, so the padding is value-invisible — ``kv_mode=
  "fp32"`` stays bit-exact with the old whole-slab path.
  ``load_nbytes`` prices exactly the bytes that crossed, which is what
  ``Task.nbytes``/``Trace`` record and what ``AdaptiveDepth`` prices the
  window with (exact, not modeled).

* **INT4 KV streaming** (``kv_mode="int4"``, the ``QuantPolicy.kv_mode``
  seam) — sequence-extent cache rows are stored *packed*: each
  ``(slot, position)`` row is group-quantized over its flattened feature
  dim (symmetric, groups of ``gcd(F, 32)``, two nibbles per byte +
  f32 group scales — the KV rendering of ``quant/int4.py``).  Rows are
  quantized once, when saved (write-once per position), so the
  quantize→dequantize roundtrip is applied exactly once per row and a
  resident reference that roundtrips newly-written rows reproduces the
  streamed tokens exactly (``serving.engine.KVRoundtripServingEngine``).
  Loads ship packed bytes (+scales) over the link; the dequant runs
  inside the consumer's jit (``device_cache``; XLA fuses it into the
  attention compute — on TPU the Pallas rendering is
  ``kernels/decode_attention.py::decode_attention_int4_kernel``).
  Non-sequence leaves (rolling windows, SSM conv/state) are rewritten
  every step — requantizing them would compound error and break the
  roundtrip-once reference — so they stream at full precision.

Thread affinity: construction and ``alloc`` run on the main thread at
engine build; ``load``/``save_*``/``spill``/``restore`` run on transfer
pool threads (numpy + jax ops only, no engine state).  The ``link``
(``transfer.SimLink``) floors each load at ``bytes / bw`` like every
other transfer, so the live-row/INT4 byte reductions show up as wall
time under the deterministic benchmark link.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TieredKVStore", "KV_GROUP", "kv_group", "kv_eligible",
    "quantize_kv_rows", "dequantize_kv_rows", "kv_roundtrip_rows",
    "device_cache",
]

# canonical KV quantization group: rows are short (hkv*dh features), so
# the group is the gcd with 32 — full-size heads get 32, scaled-down
# test configs a smaller power of two (same spirit as transfer.int4_group
# for weights, which uses 128 against the much longer contraction dims)
KV_GROUP = 32


def kv_group(n_features: int) -> int:
    """Group size for one cache row of ``n_features`` values."""
    return math.gcd(int(n_features), KV_GROUP)


def kv_eligible(kind: str, feat_shape: Sequence[int]) -> bool:
    """Whether a cache leaf quantizes under ``kv_mode='int4'``: only
    sequence-extent (kind ``'kv'``) rows — written once per position, so
    the quantize-once invariant holds — with an even flattened feature
    count (nibble pairs).  Rolling-window/conv/state leaves are rewritten
    every step and stream at full precision."""
    f = int(np.prod(feat_shape)) if len(feat_shape) else 1
    return kind == "kv" and f % 2 == 0 and f >= 2


@partial(jax.jit, static_argnums=(1,))
def _quantize_rows(x, group: int):
    """x (..., F) f32 -> (packed (..., F//2) uint8, scale (..., F//g) f32).
    Symmetric groupwise over the trailing feature dim; nibble pairs packed
    along adjacent feature columns."""
    *lead, F = x.shape
    xg = x.reshape(*lead, F // group, group)
    scale = jnp.max(jnp.abs(xg), axis=-1) / 7.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(xg / scale[..., None]).astype(jnp.int32)
    q = jnp.clip(q, -8, 7).reshape(*lead, F)
    qu = (q + 8).astype(jnp.uint8)
    lo = qu[..., 0::2]
    hi = qu[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8), scale


def _dequant_impl(packed, scale, group: int):
    """Traceable inverse of ``_quantize_rows`` -> (..., F) f32.  Plain
    function so consumers can inline it inside their own jit (the fused
    path: XLA folds the unpack+scale into the attention compute)."""
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = ((packed >> 4) & 0xF).astype(jnp.int32) - 8
    *lead, F2 = packed.shape
    q = jnp.stack([lo, hi], axis=-1).reshape(*lead, F2 * 2)
    w = (q.reshape(*lead, (F2 * 2) // group, group).astype(jnp.float32)
         * scale[..., None])
    return w.reshape(*lead, F2 * 2)


_dequantize_rows = jax.jit(_dequant_impl, static_argnums=(2,))


def quantize_kv_rows(x, group: Optional[int] = None):
    """Quantize cache rows (..., F) -> (packed, scale) numpy arrays.  The
    single quantization the store, the spill path, and the parity
    reference all share — any drift breaks the roundtrip-once parity."""
    x = jnp.asarray(np.asarray(x), jnp.float32)
    g = group or kv_group(x.shape[-1])
    packed, scale = _quantize_rows(x, g)
    return np.asarray(packed), np.asarray(scale)


def dequantize_kv_rows(packed, scale, group: int, dtype=jnp.bfloat16):
    """Inverse of ``quantize_kv_rows`` -> (..., F) numpy array of
    ``dtype`` (the cache's compute precision)."""
    out = _dequantize_rows(jnp.asarray(np.asarray(packed)),
                           jnp.asarray(np.asarray(scale)), group)
    return np.asarray(out.astype(dtype))


def kv_roundtrip_rows(x, group: Optional[int] = None):
    """quantize -> dequantize rows through the exact jitted ops the INT4
    streaming path uses, cast back to the input dtype — the reference
    transformation ``KVRoundtripServingEngine`` applies to newly-written
    cache rows so its tokens match the streamed engine's exactly."""
    x = np.asarray(x)
    g = group or kv_group(x.shape[-1])
    packed, scale = quantize_kv_rows(x, g)
    return dequantize_kv_rows(packed, scale, g, jnp.dtype(x.dtype))


@dataclass
class _LeafMeta:
    """Per-leaf layout the store shares with its jitted consumers."""
    kind: str                 # transformer cache kind ("kv"/"rep"/...)
    feat: Tuple[int, ...]     # trailing feature shape after (b[, L])
    dtype: Any                # compute-precision dtype of the leaf
    quant: bool = False       # stored/streamed packed INT4
    group: int = 0            # quant group over the flattened features


def device_cache(cache: Dict[str, Any], meta: Dict[str, "_LeafMeta"]):
    """Rebuild the compute-precision cache dict from a ``load()`` result
    inside a consumer's jit: packed ``name#q``/``name#s`` pairs are
    dequantized here (traceable; XLA fuses the unpack into the attention
    that consumes it), full-precision leaves pass through untouched.
    fp32 mode is the identity — bit-exact with the pre-store engines."""
    out = {}
    for name, m in meta.items():
        if not m.quant:
            out[name] = cache[name]
            continue
        packed, scale = cache[name + "#q"], cache[name + "#s"]
        rows = _dequant_impl(packed, scale, m.group)
        out[name] = rows.reshape(rows.shape[:-1] + m.feat).astype(m.dtype)
    return out


@dataclass
class _RawLeaf:
    arr: np.ndarray           # (b, ...) full precision


@dataclass
class _QuantLeaf:
    packed: np.ndarray        # (b, L, F//2) uint8
    scale: np.ndarray         # (b, L, F//g) f32
    group: int
    feat: Tuple[int, ...]     # original trailing feature shape
    dtype: Any                # original compute dtype


class TieredKVStore:
    """Host-resident decode cache with live-row loads and optional INT4
    row packing (see module docstring).

    ``unit_shapes``/``unit_kinds``: one dict per schedulable unit, name ->
    ((b_max, [max_len,] *feat) shape, dtype) / name -> cache kind, as
    produced by ``models.transformer.cache_struct`` (the engine strips
    the period-stack dim).  ``link`` is a ``transfer.SimLink`` (or any
    object with ``floor(nbytes, t0)``) shared with the weight store so KV
    pays the same simulated link."""

    def __init__(self, unit_shapes: List[Dict[str, tuple]],
                 unit_kinds: List[Dict[str, str]], *, b_max: int,
                 max_len: int, kv_mode: str = "fp32", link=None):
        assert kv_mode in ("fp32", "int4"), kv_mode
        self.b_max = b_max
        self.max_len = max_len
        self.kv_mode = kv_mode
        self.link = link
        self.kinds: List[Dict[str, str]] = [dict(k) for k in unit_kinds]
        self._units: List[Dict[str, Any]] = []
        self._meta: List[Dict[str, _LeafMeta]] = []
        for shapes, kinds in zip(unit_shapes, unit_kinds):
            leaves: Dict[str, Any] = {}
            meta: Dict[str, _LeafMeta] = {}
            for name, (shape, dtype) in shapes.items():
                kind = kinds[name]
                feat = tuple(shape[2:]) if kind == "kv" else tuple(shape[1:])
                m = _LeafMeta(kind, feat, np.dtype(dtype))
                if kv_mode == "int4" and kv_eligible(kind, feat):
                    F = int(np.prod(feat))
                    g = kv_group(F)
                    m.quant, m.group = True, g
                    leaves[name] = _QuantLeaf(
                        np.zeros((shape[0], shape[1], F // 2), np.uint8),
                        np.zeros((shape[0], shape[1], F // g), np.float32),
                        g, feat, np.dtype(dtype))
                else:
                    leaves[name] = _RawLeaf(np.zeros(shape, dtype))
                meta[name] = m
            self._units.append(leaves)
            self._meta.append(meta)

    # ---- layout introspection (main thread, build time) --------------------
    def __len__(self):
        return len(self._units)

    def leaf_meta(self, j: int) -> Dict[str, _LeafMeta]:
        """Per-leaf layout for unit ``j`` — closed over by the engine's
        jitted decode fns (``device_cache`` consumes it)."""
        return self._meta[j]

    def has_kv(self, j: int) -> bool:
        return bool(self.kinds[j])

    # ---- byte accounting (any thread; non-blocking) ------------------------
    def _leaf_arrays(self, j: int, name: str):
        leaf = self._units[j][name]
        if isinstance(leaf, _QuantLeaf):
            return (leaf.packed, leaf.scale)
        return (leaf.arr,)

    def load_nbytes(self, j: int, live_b: Optional[int] = None,
                    live_len: Optional[int] = None) -> int:
        """Bytes one ``load(j, live_b, live_len)`` moves over the link —
        exactly the sliced rows (packed bytes for INT4 leaves).  This is
        what ``Task.nbytes`` records on KV_LOAD trace events and what
        ``AdaptiveDepth`` prices the window's KV term with."""
        lb = self.b_max if live_b is None else min(int(live_b), self.b_max)
        ll = self.max_len if live_len is None else min(int(live_len),
                                                      self.max_len)
        total = 0
        for name, m in self._meta[j].items():
            for a in self._leaf_arrays(j, name):
                shape = list(a.shape)
                shape[0] = lb
                if m.kind == "kv":
                    shape[1] = ll
                total += int(np.prod(shape)) * a.itemsize
        return total

    def slab_nbytes(self, j: int) -> int:
        """Bytes the full allocated ``(b_max, max_len)`` slab would move
        — the pre-live-row KV_LOAD payload, kept for tests/pricing."""
        return self.load_nbytes(j, self.b_max, self.max_len)

    def save_nbytes(self, j: int, live_b: Optional[int] = None) -> int:
        """Bytes one decode ``save_decode`` payload moves device->host:
        the freshly-written rows of ``live_b`` slots at compute precision
        (quantization happens at the host tier, after the transfer)."""
        lb = self.b_max if live_b is None else min(int(live_b), self.b_max)
        total = 0
        for name, m in self._meta[j].items():
            row = int(np.prod(m.feat)) * np.dtype(m.dtype).itemsize
            total += lb * row
        return total

    def prefill_save_nbytes(self, j: int) -> int:
        """Bytes a prefill save moves: one slot's full rows."""
        total = 0
        for name, m in self._meta[j].items():
            n = int(np.prod(m.feat)) * np.dtype(m.dtype).itemsize
            if m.kind == "kv":
                n *= self.max_len
            total += n
        return total

    def max_live_load_nbytes(self, live_b: int, live_len: int) -> int:
        """Largest per-unit live KV_LOAD payload at the given extents —
        the exact per-layer KV price ``AdaptiveDepth`` feeds the memory
        model instead of the modeled slab."""
        return max(self.load_nbytes(j, live_b, live_len)
                   for j in range(len(self._units))) if self._units else 0

    def host_nbytes(self) -> int:
        """Total host bytes the store pins (packed bytes under INT4)."""
        return sum(a.nbytes for j in range(len(self._units))
                   for name in self._units[j]
                   for a in self._leaf_arrays(j, name))

    # ---- loads (transfer-pool thread) --------------------------------------
    def _put_padded(self, arr: np.ndarray, lb: int, ll: int, seq: bool):
        sl = arr[:lb, :ll] if seq else arr[:lb]
        if sl.shape == arr.shape:
            dev = jnp.asarray(arr)
        else:
            rows = jnp.asarray(np.ascontiguousarray(sl))
            dev = jnp.zeros(arr.shape, rows.dtype)
            dev = dev.at[tuple(slice(0, s) for s in sl.shape)].set(rows)
        return dev

    def load(self, j: int, live_b: Optional[int] = None,
             live_len: Optional[int] = None) -> Dict[str, Any]:
        """KV_LOAD body: host rows -> device, sliced to the live extent
        and zero-padded back to the full slab shape (device side, after
        the link) so jitted consumers keep one signature.  INT4 leaves
        arrive packed under ``name#q``/``name#s`` — run the result
        through ``device_cache(cache, leaf_meta(j))`` inside the
        consumer's jit.  Transfer-pool thread; pays the link floor on
        exactly the live bytes."""
        t0 = time.perf_counter()
        lb = self.b_max if live_b is None else \
            max(1, min(int(live_b), self.b_max))
        ll = self.max_len if live_len is None else \
            max(1, min(int(live_len), self.max_len))
        out: Dict[str, Any] = {}
        for name, m in self._meta[j].items():
            leaf = self._units[j][name]
            if isinstance(leaf, _QuantLeaf):
                out[name + "#q"] = self._put_padded(leaf.packed, lb, ll, True)
                out[name + "#s"] = self._put_padded(leaf.scale, lb, ll, True)
            else:
                out[name] = self._put_padded(leaf.arr, lb, ll,
                                             seq=m.kind == "kv")
        for a in out.values():
            a.block_until_ready()
        if self.link is not None:
            self.link.floor(self.load_nbytes(j, lb, ll), t0)
        return out

    # ---- saves (transfer-pool thread) --------------------------------------
    def save_prefill(self, j: int, slot: int,
                     rows: Dict[str, np.ndarray]) -> None:
        """Scatter one slot's freshly-prefilled rows (name -> the slot's
        full per-slot extent, e.g. ``(max_len, *feat)`` for kv kinds).
        INT4 leaves quantize here — once per row; positions beyond the
        prompt are zeros and roundtrip to zeros exactly."""
        for name, m in self._meta[j].items():
            leaf = self._units[j][name]
            row = np.asarray(rows[name])
            if isinstance(leaf, _QuantLeaf):
                # cast to the cache's compute precision FIRST: the fp32
                # store path downcasts on assignment into the bf16 host
                # array, and the parity reference roundtrips bf16 cache
                # rows — quantizing the pre-cast f32 activations would
                # pick (slightly) different scales and break parity
                row = row.astype(m.dtype)
                F = int(np.prod(m.feat))
                packed, scale = quantize_kv_rows(
                    row.reshape(row.shape[0], F), leaf.group)
                leaf.packed[slot] = packed
                leaf.scale[slot] = scale
            else:
                leaf.arr[slot] = row

    def save_decode(self, j: int, rows: Dict[str, np.ndarray],
                    active: Sequence[int], pos: np.ndarray) -> None:
        """Scatter a decode step's new rows: for kv kinds ``rows[name]``
        is ``(live_b, 1, *feat)`` (slot s's new row at position
        ``pos[s]``), other kinds carry the full per-slot state.  INT4
        leaves quantize the new row — the only time it is ever
        quantized."""
        for name, m in self._meta[j].items():
            leaf = self._units[j][name]
            row = np.asarray(rows[name])
            if isinstance(leaf, _QuantLeaf):
                row = row.astype(m.dtype)     # compute precision first
                F = int(np.prod(m.feat))
                packed, scale = quantize_kv_rows(
                    row.reshape(row.shape[0], 1, F), leaf.group)
                for s in active:
                    leaf.packed[s, pos[s]] = packed[s, 0]
                    leaf.scale[s, pos[s]] = scale[s, 0]
            elif m.kind == "kv":
                for s in active:
                    leaf.arr[s, pos[s]] = row[s, 0]
            else:
                for s in active:
                    leaf.arr[s] = row[s]

    # ---- slot spill/restore (transfer-pool / main thread) ------------------
    def spill(self, host, ns: str, slot: int) -> None:
        """Copy one slot's rows into ``host`` under ``{ns}/{unit}/{name}``
        keys.  INT4 rows spill packed (lossless; ~0.625 B/value against
        the 2 B bf16 cache, ~3x) under ``...{name}#q`` /
        ``...{name}#s``."""
        for j in range(len(self._units)):
            for name in self._units[j]:
                leaf = self._units[j][name]
                if isinstance(leaf, _QuantLeaf):
                    host.put(f"{ns}/{j}/{name}#q", leaf.packed[slot].copy())
                    host.put(f"{ns}/{j}/{name}#s", leaf.scale[slot].copy())
                else:
                    host.put(f"{ns}/{j}/{name}", leaf.arr[slot].copy())

    def restore(self, host, ns: str, slot: int) -> None:
        """Inverse of ``spill``: bring a parked request's rows back into
        ``slot``.  Bit-lossless in both modes (packed rows round-trip
        untouched)."""
        for j in range(len(self._units)):
            for name in self._units[j]:
                leaf = self._units[j][name]
                if isinstance(leaf, _QuantLeaf):
                    leaf.packed[slot] = host.get(f"{ns}/{j}/{name}#q")
                    leaf.scale[slot] = host.get(f"{ns}/{j}/{name}#s")
                else:
                    leaf.arr[slot] = host.get(f"{ns}/{j}/{name}")
