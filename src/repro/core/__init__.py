from repro.core.autoconfig import AutoConfig, configure
from repro.core.engine import PipelinedLM
from repro.core.memory_model import estimate
from repro.core.offload import (DeviceStore, DiskStore, HostStore,
                                MemoryBudget)
from repro.core.pipeline import PipelineScheduler, ThreadPool
from repro.core.tasks import Task, TaskType, Trace

__all__ = ["AutoConfig", "configure", "PipelinedLM", "estimate",
           "DeviceStore", "DiskStore", "HostStore", "MemoryBudget",
           "PipelineScheduler", "ThreadPool", "Task", "TaskType", "Trace"]
