from repro.core.autoconfig import AutoConfig, configure
from repro.core.engine import PipelinedLM
from repro.core.memory_model import estimate
from repro.core.offload import (DeviceStore, DiskStore, HostStore,
                                MemoryBudget)
from repro.core.pipeline import PipelineScheduler, ThreadPool, VirtualPool
from repro.core.tasks import (Clock, Task, TaskType, Trace, VirtualClock,
                              WallClock)
from repro.core.transfer import TieredWeightStore

__all__ = ["AutoConfig", "configure", "PipelinedLM", "estimate",
           "DeviceStore", "DiskStore", "HostStore", "MemoryBudget",
           "PipelineScheduler", "ThreadPool", "VirtualPool",
           "Clock", "WallClock", "VirtualClock", "Task", "TaskType", "Trace",
           "TieredWeightStore"]
