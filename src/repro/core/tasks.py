"""PIPO task model (paper §3.1.2).

Inference work is decomposed into four task types:
  * COMPUTE        — MHA/MLP/embedding layer compute (main thread only)
  * WEIGHT_LOAD    — weights: disk/host tier -> device tier
  * KV_LOAD        — KV-cache: host tier -> device tier
  * KV_SAVE        — new KV-pairs: device tier -> host tier

Each task carries a threading.Event for *task-level* synchronization —
the paper's central deviation from FlexGen's device-level sync ('S' boxes
in Fig. 2): a consumer waits on exactly the producer it needs, nothing
else.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Optional


class TaskType(Enum):
    COMPUTE = "compute"
    WEIGHT_LOAD = "weight_load"
    KV_LOAD = "kv_load"
    KV_SAVE = "kv_save"


@dataclass
class Task:
    kind: TaskType
    name: str                      # e.g. "w[3]", "kv_load[i=2,j=5]"
    fn: Callable[[], Any]
    done: threading.Event = field(default_factory=threading.Event)
    result: Any = None
    error: Optional[BaseException] = None
    # timing for the utilization/trace benchmarks
    t_submit: float = 0.0
    t_start: float = 0.0
    t_end: float = 0.0

    def run(self):
        self.t_start = time.perf_counter()
        try:
            self.result = self.fn()
        except BaseException as e:  # propagate to waiter
            self.error = e
        finally:
            self.t_end = time.perf_counter()
            self.done.set()

    def wait(self):
        self.done.wait()
        if self.error is not None:
            raise self.error
        return self.result


@dataclass
class TraceEvent:
    kind: str
    name: str
    t_start: float
    t_end: float
    thread: str


class Trace:
    """Execution trace for the GPU-utilization analogue (Fig. 8) and the
    pipeline-overlap benchmarks."""

    def __init__(self):
        self._events: list[TraceEvent] = []
        self._lock = threading.Lock()
        self.t0 = time.perf_counter()

    def add(self, task: Task, thread: str):
        with self._lock:
            self._events.append(TraceEvent(task.kind.value, task.name,
                                           task.t_start - self.t0,
                                           task.t_end - self.t0, thread))

    def events(self):
        with self._lock:
            return list(self._events)

    def busy_fraction(self, kind: str = "compute") -> float:
        """Fraction of the makespan the given task kind was executing —
        the paper's 'GPU utilization' proxy."""
        evs = self.events()
        if not evs:
            return 0.0
        end = max(e.t_end for e in evs)
        start = min(e.t_start for e in evs)
        span = max(1e-9, end - start)
        ivals = sorted((e.t_start, e.t_end) for e in evs if e.kind == kind)
        busy, cur_s, cur_e = 0.0, None, None
        for s, t in ivals:
            if cur_s is None:
                cur_s, cur_e = s, t
            elif s <= cur_e:
                cur_e = max(cur_e, t)
            else:
                busy += cur_e - cur_s
                cur_s, cur_e = s, t
        if cur_s is not None:
            busy += cur_e - cur_s
        return busy / span
