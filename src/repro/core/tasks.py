"""PIPO task model (paper §3.1.2).

Inference work is decomposed into four task types:
  * COMPUTE        — MHA/MLP/embedding layer compute (main thread only)
  * WEIGHT_LOAD    — weights: disk/host tier -> device tier
  * KV_LOAD        — KV-cache: host tier -> device tier
  * KV_SAVE        — new KV-pairs: device tier -> host tier

Each task carries a threading.Event for *task-level* synchronization —
the paper's central deviation from FlexGen's device-level sync ('S' boxes
in Fig. 2): a consumer waits on exactly the producer it needs, nothing
else.

Clock seam: all timestamps flow through a ``Clock`` so the scheduler can
run against a ``VirtualClock`` (deterministic discrete-event timeline, no
sleeps) in tests and the wall clock in production.  See
``core.pipeline.VirtualPool`` for the fake transport built on top.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, Optional


class TaskType(Enum):
    COMPUTE = "compute"
    WEIGHT_LOAD = "weight_load"
    KV_LOAD = "kv_load"
    KV_SAVE = "kv_save"


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class Clock:
    """Timestamp source for tasks/traces."""

    def now(self) -> float:
        raise NotImplementedError


class WallClock(Clock):
    def now(self) -> float:
        return time.perf_counter()


class VirtualClock(Clock):
    """Deterministic logical time: advanced explicitly by the virtual
    transport (``VirtualPool``), never by sleeping.  Starts at 0 so traces
    are reproducible run to run."""

    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def advance_to(self, t: float):
        if t > self.t:
            self.t = t


WALL_CLOCK = WallClock()


@dataclass
class Task:
    kind: TaskType
    name: str                      # e.g. "w[3]", "kv_load[i=2,j=5]"
    fn: Callable[[], Any]
    done: threading.Event = field(default_factory=threading.Event)
    result: Any = None
    error: Optional[BaseException] = None
    # timing for the utilization/trace benchmarks
    t_submit: float = 0.0
    t_start: float = 0.0
    t_end: float = 0.0
    # payload size (bytes moved); 0 when unknown.  Set by the submitter
    # BEFORE the task is handed to a pool (a VirtualPool traces the task
    # synchronously inside submit), and copied onto the TraceEvent so
    # per-task-type transfer volumes are assertable on traces (e.g. the
    # MoE routed-union invariant: union bytes < whole-bank bytes).  The
    # scheduler fills it for WEIGHT_LOADs (model.weight_nbytes) and
    # KV_LOADs (model.kv_nbytes) when the model exposes those hooks, so
    # report() splits link volume by task kind.
    nbytes: int = 0
    # live extent of a KV payload, (live_batch, live_len); None when the
    # payload is not extent-sliced (weight loads, whole-slab KV).  Set by
    # the submitter alongside nbytes and copied onto the TraceEvent so
    # live-row slicing is observable on traces (the tiered-KV-store
    # invariant: a half-full slot's KV_LOAD bytes < the allocated slab).
    extent: Optional[tuple] = None
    # pipeline-parallel stage this task belongs to (0 for the single-stage
    # pipeline).  Stamped by the submitting scheduler and copied onto the
    # TraceEvent so per-stage residency/bubble accounting is assertable on
    # traces (``report()['stage_bubbles']``).
    stage: int = 0
    # virtual-transport hook: called by wait() once the task is done, so a
    # VirtualPool can advance its clock to the waiter's sync point.
    on_wait: Optional[Callable[["Task"], None]] = None

    def run(self, clock: Clock = WALL_CLOCK):
        self.t_start = clock.now()
        try:
            self.result = self.fn()
        except BaseException as e:  # propagate to waiter
            self.error = e
        finally:
            self.t_end = clock.now()
            self.done.set()

    def wait(self):
        self.done.wait()
        if self.on_wait is not None:
            self.on_wait(self)
        if self.error is not None:
            raise self.error
        return self.result


@dataclass
class TraceEvent:
    kind: str
    name: str
    t_start: float
    t_end: float
    thread: str
    nbytes: int = 0
    extent: Optional[tuple] = None     # live (batch, len) of a KV payload
    stage: int = 0                     # pipeline-parallel stage (0 = single)


def percentile(xs, q: float) -> float:
    """Linear-interpolation percentile (numpy's default method), stdlib
    only so trace tooling stays importable without the array stack.
    ``q`` in [0, 100]; empty input returns 0.0."""
    xs = sorted(xs)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return float(xs[0])
    rank = (len(xs) - 1) * q / 100.0
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


def latency_summary(samples) -> Dict[str, float]:
    """p50/p95/p99 + mean/count for one latency series (seconds)."""
    xs = [float(x) for x in samples]
    return {
        "count": len(xs),
        "mean_s": sum(xs) / len(xs) if xs else 0.0,
        "p50_s": percentile(xs, 50),
        "p95_s": percentile(xs, 95),
        "p99_s": percentile(xs, 99),
    }


def _merged_busy(intervals) -> float:
    """Total length of the union of (start, end) intervals."""
    ivals = sorted(intervals)
    busy, cur_s, cur_e = 0.0, None, None
    for s, t in ivals:
        if cur_s is None:
            cur_s, cur_e = s, t
        elif s <= cur_e:
            cur_e = max(cur_e, t)
        else:
            busy += cur_e - cur_s
            cur_s, cur_e = s, t
    if cur_s is not None:
        busy += cur_e - cur_s
    return busy


class Trace:
    """Execution trace for the GPU-utilization analogue (Fig. 8) and the
    pipeline-overlap benchmarks.  Timestamps are relative to the clock's
    value at construction (0 for a fresh VirtualClock)."""

    def __init__(self, clock: Clock = WALL_CLOCK):
        self._events: list[TraceEvent] = []
        self._lock = threading.Lock()
        self.clock = clock
        self.t0 = clock.now()
        # replayable context: schedulers/pools/engines stamp the knobs the
        # trace was recorded under (mode, warm, depth, pool_size, per-call
        # iteration counts, sim_bw, quant, kv_mode ...) so ``core.replay``
        # can rebuild the run without the model.  Serialized by to_json.
        self.meta: Dict[str, Any] = {}

    def add(self, task: Task, thread: str):
        with self._lock:
            self._events.append(TraceEvent(task.kind.value, task.name,
                                           task.t_start - self.t0,
                                           task.t_end - self.t0, thread,
                                           task.nbytes, task.extent,
                                           task.stage))

    def events(self):
        with self._lock:
            return list(self._events)

    # -- (de)serialization --------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        """JSON-serializable snapshot: ``meta`` + every event, timestamps
        already relative to the trace origin.  Committable as a golden
        fixture; ``from_json`` rebuilds an equivalent trace for
        ``core.replay`` (extent tuples survive the list round-trip)."""
        events = []
        for e in self.events():
            ev = {"kind": e.kind, "name": e.name, "t_start": e.t_start,
                  "t_end": e.t_end, "thread": e.thread, "nbytes": e.nbytes,
                  "extent": None if e.extent is None else list(e.extent)}
            # the stage tag is emitted only when set, so single-stage
            # fixtures recorded before pipeline parallelism stay byte-stable
            if e.stage:
                ev["stage"] = e.stage
            events.append(ev)
        return {"meta": dict(self.meta), "events": events}

    @classmethod
    def from_json(cls, d: "Dict[str, Any] | str") -> "Trace":
        """Rebuild a trace from ``to_json`` output (dict or JSON string).
        The result reads back identically (events/meta/report); its clock
        is a fresh ``VirtualClock`` so t0 is 0, matching the already-
        relative recorded timestamps."""
        if isinstance(d, str):
            d = json.loads(d)
        unknown = set(d) - {"meta", "events"}
        if unknown:
            raise ValueError(f"unknown Trace JSON key(s) {sorted(unknown)}")
        tr = cls(clock=VirtualClock())
        tr.meta = dict(d.get("meta", {}))
        for ev in d.get("events", []):
            ext = ev.get("extent")
            tr._events.append(TraceEvent(
                ev["kind"], ev["name"], ev["t_start"], ev["t_end"],
                ev.get("thread", ""), ev.get("nbytes", 0),
                None if ext is None else tuple(ext),
                ev.get("stage", 0)))
        return tr

    def span(self) -> float:
        evs = self.events()
        if not evs:
            return 0.0
        return max(e.t_end for e in evs) - min(e.t_start for e in evs)

    def busy_time(self, kind: str) -> float:
        """Merged-interval busy seconds for one task kind."""
        return _merged_busy((e.t_start, e.t_end) for e in self.events()
                            if e.kind == kind)

    def thread_busy(self, thread: str = "main") -> float:
        """Merged-interval busy seconds on one executor thread."""
        return _merged_busy((e.t_start, e.t_end) for e in self.events()
                            if e.thread == thread)

    def busy_fraction(self, kind: str = "compute") -> float:
        """Fraction of the makespan the given task kind was executing —
        the paper's 'GPU utilization' proxy."""
        span = self.span()
        if span <= 0:
            return 0.0
        return self.busy_time(kind) / max(1e-9, span)

    def bytes_moved(self, kind: str, name_prefix: str = "") -> int:
        """Sum of per-event payload sizes for one task kind (0-byte events
        are tasks whose submitter didn't know the size).  ``name_prefix``
        filters events, e.g. 'w[u[0][0]/exp' for one MoE layer's expert
        loads — the routed-union invariant is asserted on this."""
        return sum(e.nbytes for e in self.events()
                   if e.kind == kind and e.name.startswith(name_prefix))

    def report(self) -> Dict[str, Any]:
        """Pipeline instrumentation (Fig. 8/9 analogue): per-task-type busy
        time + counts, compute-thread utilization, and bubble accounting
        (compute-thread idle time = pipeline stalls waiting on transfers)."""
        evs = self.events()
        span = self.span()
        per_kind = {}
        # the four task types always get a bucket (zeroed when absent);
        # kinds the schema doesn't know (hand-built or future traces) get
        # their own bucket instead of silently vanishing from the report
        kinds = [t.value for t in TaskType]
        kinds += sorted({e.kind for e in evs} - set(kinds))
        for kind in kinds:
            sub = [e for e in evs if e.kind == kind]
            ivals = [(e.t_start, e.t_end) for e in sub]
            busy = _merged_busy(ivals)
            nbytes = sum(e.nbytes for e in sub)
            per_kind[kind] = {
                "busy_s": busy,
                "count": len(ivals),
                "busy_frac": busy / span if span > 0 else 0.0,
                "bytes": nbytes,
                # measured link bandwidth for this task kind (0 when no
                # byte-accounted events) — the observable AdaptiveDepth's
                # bandwidth feedback EWMAs per step
                "bw_Bps": nbytes / busy if busy > 0 else 0.0,
            }
        compute_busy = self.thread_busy("main")
        out = {
            "span_s": span,
            "per_kind": per_kind,
            "compute_util": compute_busy / span if span > 0 else 0.0,
            "bubble_s": max(0.0, span - compute_busy),
            "bubble_frac": (max(0.0, span - compute_busy) / span
                            if span > 0 else 0.0),
        }
        # pipeline-parallel fill/drain accounting: when any event carries a
        # stage tag, each stage gets a bucket measuring how long it idles
        # before its first compute (fill — upstream stages haven't produced
        # an activation yet) and after its last (drain — downstream stages
        # are still flushing).  Single-stage traces skip the bucket.
        if any(e.stage for e in evs):
            t_lo = min(e.t_start for e in evs)
            t_hi = max(e.t_end for e in evs)
            stage_bubbles = {}
            for s in sorted({e.stage for e in evs}):
                sub = [e for e in evs if e.stage == s]
                comp = [e for e in sub if e.kind == TaskType.COMPUTE.value]
                busy = _merged_busy((e.t_start, e.t_end) for e in comp)
                if comp:
                    fill = min(e.t_start for e in comp) - t_lo
                    drain = t_hi - max(e.t_end for e in comp)
                else:
                    fill, drain = t_hi - t_lo, 0.0
                stage_bubbles[s] = {
                    "fill_s": max(0.0, fill),
                    "drain_s": max(0.0, drain),
                    "busy_s": busy,
                    "idle_s": max(0.0, (t_hi - t_lo) - busy),
                    "span_s": t_hi - t_lo,
                }
            out["stage_bubbles"] = stage_bubbles
        # request-latency percentiles: workload drivers
        # (serving.workload.run_trace / TrafficSim) stamp per-request
        # series into meta["latency"] = {"ttft": [...], "tbt": [...],
        # "e2e": [...]} (seconds); the report summarizes each so p99
        # TTFT is a first-class trace observable next to busy fractions
        lat = self.meta.get("latency")
        if lat:
            out["latency"] = {name: latency_summary(xs)
                              for name, xs in sorted(lat.items())}
        return out
