"""PIPO automatic configuration (paper §3.5, Eq. 1 + Algorithm 2).

Inputs: model, batch, lengths, precision, tier capacities/bandwidths.
Outputs: weight placement (device/host/disk), pipeline mode
(performance-optimized vs memory-efficient), preload depth (how many
layers the performance pipeline keeps in flight — sized from the device
headroom left after the KV cache, per ``memory_model.depth_capacity``),
block size, and whether the INT4 fused kernel is enabled (batch < 16,
per §3.5).  ``serving_preload_depth`` is the serving-engine entry point:
same sizing, plus a host-side sanity check that the weight tier, KV
cache, and retained slot spills (``spill_cap``) actually coexist in host
RAM — when they can't, deep windows only amplify thrash, so it falls
back to depth 1.  docs/TUNING.md walks a worked example.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.memory_model import (MemoryEstimate, depth_capacity,
                                     estimate, host_pinned_bytes,
                                     quant_kv_ratio, quant_weight_ratio)
from repro.core.offload import MemoryBudget


@dataclass(frozen=True)
class AutoConfig:
    weight_placement: str       # "device" | "host" | "disk"
    pipeline: str               # "performance" | "memory"
    block_bytes: int
    use_int4_kernel: bool
    est: MemoryEstimate
    reason: str
    preload_depth: int = 1      # performance-pipeline resident window - 1


def choose_placement(cfg: ModelConfig, *, batch: int, seq: int,
                     precision_bytes: int = 2,
                     budget: Optional[MemoryBudget] = None,
                     quant: Optional[str] = None) -> tuple:
    """Eq. (1) weight placement as a (placement, why) decision — the
    single implementation shared by ``configure()`` and
    ``serving.spec.EngineSpec.resolve()`` (the plan records the why
    string as the field's provenance)."""
    budget = budget or MemoryBudget()
    est_pre = estimate(cfg, batch=batch, seq=seq, p=precision_bytes,
                       preload=True)
    ratio = quant_weight_ratio(precision_bytes, quant)
    W = int(est_pre.weights * ratio)
    C = est_pre.kv_cache
    # quantization shrinks only the *weight* component of peak M; the
    # activation part stays at compute precision (paper: W4 + fp16 act)
    resident_w = est_pre.w_mha + est_pre.w_mlp
    M = int(max(est_pre.peak_prefill, est_pre.peak_decode)
            - resident_w * (1.0 - ratio))
    if W + M < budget.device:
        return "device", f"W+M={(W+M)/2**30:.1f}GiB fits device"
    if W + C < budget.host and budget.disk_bw < budget.device_bw:
        return "host", f"W+C={(W+C)/2**30:.1f}GiB fits host"
    return "disk", "exceeds host; stream from disk"


def configure(cfg: ModelConfig, *, batch: int, prompt_len: int,
              gen_len: int, precision_bytes: int = 2,
              budget: Optional[MemoryBudget] = None,
              quant: Optional[str] = None,
              block_bytes: int = 32 << 20) -> AutoConfig:
    budget = budget or MemoryBudget()
    s = prompt_len + gen_len

    est_pre = estimate(cfg, batch=batch, seq=s, p=precision_bytes,
                       preload=True)
    ratio = quant_weight_ratio(precision_bytes, quant)
    # quantization shrinks only the *weight* component of peak M; the
    # activation part stays at compute precision (paper: W4 + fp16 act)
    resident_w = est_pre.w_mha + est_pre.w_mlp
    M = int(max(est_pre.peak_prefill, est_pre.peak_decode)
            - resident_w * (1.0 - ratio))

    # ---- Eq. (1): weight placement ----
    placement, why = choose_placement(cfg, batch=batch, seq=s,
                                      precision_bytes=precision_bytes,
                                      budget=budget, quant=quant)

    # ---- Eq. (1): pipeline mode ----
    if M < budget.device:
        pipeline = "performance"
    else:
        pipeline = "memory"
        est_min = estimate(cfg, batch=batch, seq=s, p=precision_bytes,
                           preload=False)
        M = int(max(est_min.peak_prefill, est_min.peak_decode)
                - (est_min.w_mha + est_min.w_mlp) * (1.0 - ratio))

    use_int4 = (quant == "int4") and batch < 16   # §3.5
    if pipeline == "performance":
        depth = depth_capacity(cfg, batch=batch, seq=s, p=precision_bytes,
                               budget_bytes=budget.device, quant=quant)
    else:
        depth = 1           # memory mode: single-layer residency, no window
    return AutoConfig(placement, pipeline, block_bytes, use_int4, est_pre,
                      why, depth)


def serving_depth_decision(cfg: ModelConfig, *, b_max: int, max_len: int,
                           precision_bytes: int = 4,
                           quant: Optional[str] = None,
                           kv_mode: Optional[str] = None,
                           spill_cap: int = 0,
                           placement: str = "host",
                           budget: Optional[MemoryBudget] = None,
                           depth_cap: int = 8) -> tuple:
    """``serving_preload_depth`` as a (depth, why) decision, the why
    string carrying the memory-model numbers — ``EngineSpec.resolve()``
    records it as the ``depth`` field's provenance.  ``kv_mode='int4'``
    prices every KV term (host pin, spills, in-flight slabs) at packed
    bytes, so the affordable window deepens just as it does for packed
    weights."""
    budget = budget or MemoryBudget()
    fixed, per_spill = host_pinned_bytes(
        cfg, b_max=b_max, max_len=max_len, p=precision_bytes, quant=quant,
        kv_mode=kv_mode, placement=placement)
    host_need = fixed + spill_cap * per_spill
    if host_need > budget.host:
        return 1, (f"host tier over budget "
                   f"(weights+KV+{spill_cap} spills = "
                   f"{host_need / 2**30:.2f}GiB > "
                   f"{budget.host / 2**30:.0f}GiB): depth 1, deeper "
                   f"windows only thrash a saturated host")
    d = depth_capacity(cfg, batch=b_max, seq=max_len, p=precision_bytes,
                       budget_bytes=budget.device, quant=quant,
                       kv_mode=kv_mode, depth_cap=depth_cap)
    est0 = estimate(cfg, batch=b_max, seq=max_len, p=precision_bytes,
                    preload=0)
    base = max(est0.peak_prefill, est0.peak_decode)
    per = (int(max(est0.w_mha, est0.w_mlp)
               * quant_weight_ratio(precision_bytes, quant))
           + int(est0.kv_cache // max(1, cfg.num_layers)
                 * quant_kv_ratio(precision_bytes, kv_mode)))
    return d, (f"device headroom after depth-0 peak "
               f"({base / 2**20:.0f}MiB) affords {d} in-flight "
               f"layer(s) at {per / 2**20:.1f}MiB each "
               f"(quant={quant or 'fp32'}, kv={kv_mode or 'fp32'}, "
               f"cap {depth_cap})")


def replay_depth_decision(trace, *, depth_cap: int = 8,
                          quant: Optional[str] = None,
                          kv_mode: Optional[str] = None,
                          sim_bw: Optional[float] = None,
                          start_iter: Optional[int] = None,
                          stop_iter: Optional[int] = None) -> tuple:
    """Preload depth as a (depth, why) decision from a recorded trace:
    ``core.replay.best_depth`` sweeps the window 1..depth_cap through
    the simulator and the argmin wins — measured argmin instead of the
    closed-form heuristic.  ``depth_cap`` stays the memory model's job
    (the simulator knows time, not residency), so callers pass the
    capacity-fit cap in.  The why string records the per-depth
    predictions and names ``replay`` as the source —
    ``EngineSpec.resolve(budget, trace=...)`` stores it as the depth
    field's provenance."""
    from repro.core.replay import ReplayKnobs, best_depth
    knobs = ReplayKnobs(quant="fp32" if quant is None else quant,
                        kv_mode="fp32" if kv_mode is None else kv_mode,
                        sim_bw=sim_bw)
    d, preds = best_depth(trace, depth_cap=depth_cap, knobs=knobs,
                          start_iter=start_iter, stop_iter=stop_iter)
    table = ", ".join(f"d{k}={v * 1e3:.2f}ms" for k, v in
                      sorted(preds.items()))
    return d, (f"simulated argmin over depths 1..{depth_cap}: depth {d} "
               f"predicts the fastest steady step ({table}) "
               f"(source=replay)")


def serving_preload_depth(cfg: ModelConfig, *, b_max: int, max_len: int,
                          precision_bytes: int = 4,
                          quant: Optional[str] = None,
                          kv_mode: Optional[str] = None, spill_cap: int = 0,
                          placement: str = "host",
                          budget: Optional[MemoryBudget] = None,
                          depth_cap: int = 8) -> int:
    """Preload depth for an offloaded serving engine (the ``depth=None``
    default of ``OffloadedServingEngine``): ``depth_capacity`` against the
    device budget, with one serving-specific guard — the host tier must
    hold the full decode KV cache, up to ``spill_cap`` retained slot
    spills (each one request's KV rows), and — for host placement — the
    weights themselves (packed under quant; disk placement keeps only
    in-flight buffers in host RAM, so weights don't count there).  When
    the host can't, it is already the bottleneck and a deeper window
    just queues more transfers behind a thrashing tier: fall back to
    depth 1."""
    return serving_depth_decision(
        cfg, b_max=b_max, max_len=max_len, precision_bytes=precision_bytes,
        quant=quant, kv_mode=kv_mode, spill_cap=spill_cap,
        placement=placement, budget=budget, depth_cap=depth_cap)[0]
