"""PIPO automatic configuration (paper §3.5, Eq. 1 + Algorithm 2).

Inputs: model, batch, lengths, precision, tier capacities/bandwidths.
Outputs: weight placement (device/host/disk), pipeline mode
(performance-optimized vs memory-efficient), block size, and whether the
INT4 fused kernel is enabled (batch < 16, per §3.5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.memory_model import MemoryEstimate, estimate
from repro.core.offload import MemoryBudget


@dataclass(frozen=True)
class AutoConfig:
    weight_placement: str       # "device" | "host" | "disk"
    pipeline: str               # "performance" | "memory"
    block_bytes: int
    use_int4_kernel: bool
    est: MemoryEstimate
    reason: str


def configure(cfg: ModelConfig, *, batch: int, prompt_len: int,
              gen_len: int, precision_bytes: int = 2,
              budget: Optional[MemoryBudget] = None,
              quant: Optional[str] = None,
              block_bytes: int = 32 << 20) -> AutoConfig:
    budget = budget or MemoryBudget()
    s = prompt_len + gen_len
    p = precision_bytes if quant is None else 0.5
    p_eff = max(1, int(p * 2)) / 2  # keep fractional int4 byte-costs honest

    est_pre = estimate(cfg, batch=batch, seq=s, p=precision_bytes,
                       preload=True)
    ratio = p / precision_bytes
    W = int(est_pre.weights * ratio)
    C = est_pre.kv_cache
    # quantization shrinks only the *weight* component of peak M; the
    # activation part stays at compute precision (paper: W4 + fp16 act)
    resident_w = est_pre.w_mha + est_pre.w_mlp
    M = int(max(est_pre.peak_prefill, est_pre.peak_decode)
            - resident_w * (1.0 - ratio))

    # ---- Eq. (1): weight placement ----
    if W + M < budget.device:
        placement, why = "device", f"W+M={(W+M)/2**30:.1f}GiB fits device"
    elif W + C < budget.host and budget.disk_bw < budget.device_bw:
        placement, why = "host", f"W+C={(W+C)/2**30:.1f}GiB fits host"
    else:
        placement, why = "disk", "exceeds host; stream from disk"

    # ---- Eq. (1): pipeline mode ----
    if M < budget.device:
        pipeline = "performance"
    else:
        pipeline = "memory"
        est_min = estimate(cfg, batch=batch, seq=s, p=precision_bytes,
                           preload=False)
        M = int(max(est_min.peak_prefill, est_min.peak_decode)
                - (est_min.w_mha + est_min.w_mlp) * (1.0 - ratio))

    use_int4 = (quant == "int4") and batch < 16   # §3.5
    return AutoConfig(placement, pipeline, block_bytes, use_int4, est_pre,
                      why)
