"""PIPO automatic configuration (paper §3.5, Eq. 1 + Algorithm 2).

Inputs: model, batch, lengths, precision, tier capacities/bandwidths.
Outputs: weight placement (device/host/disk), pipeline mode
(performance-optimized vs memory-efficient), preload depth (how many
layers the performance pipeline keeps in flight — sized from the device
headroom left after the KV cache, per ``memory_model.depth_capacity``),
block size, and whether the INT4 fused kernel is enabled (batch < 16,
per §3.5).  ``serving_preload_depth`` is the serving-engine entry point:
same sizing, plus a host-side sanity check that the weight tier, KV
cache, and retained slot spills (``spill_cap``) actually coexist in host
RAM — when they can't, deep windows only amplify thrash, so it falls
back to depth 1.  docs/TUNING.md walks a worked example.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.memory_model import (MemoryEstimate, depth_capacity,
                                     estimate, quant_weight_ratio)
from repro.core.offload import MemoryBudget


@dataclass(frozen=True)
class AutoConfig:
    weight_placement: str       # "device" | "host" | "disk"
    pipeline: str               # "performance" | "memory"
    block_bytes: int
    use_int4_kernel: bool
    est: MemoryEstimate
    reason: str
    preload_depth: int = 1      # performance-pipeline resident window - 1


def configure(cfg: ModelConfig, *, batch: int, prompt_len: int,
              gen_len: int, precision_bytes: int = 2,
              budget: Optional[MemoryBudget] = None,
              quant: Optional[str] = None,
              block_bytes: int = 32 << 20) -> AutoConfig:
    budget = budget or MemoryBudget()
    s = prompt_len + gen_len

    est_pre = estimate(cfg, batch=batch, seq=s, p=precision_bytes,
                       preload=True)
    ratio = quant_weight_ratio(precision_bytes, quant)
    W = int(est_pre.weights * ratio)
    C = est_pre.kv_cache
    # quantization shrinks only the *weight* component of peak M; the
    # activation part stays at compute precision (paper: W4 + fp16 act)
    resident_w = est_pre.w_mha + est_pre.w_mlp
    M = int(max(est_pre.peak_prefill, est_pre.peak_decode)
            - resident_w * (1.0 - ratio))

    # ---- Eq. (1): weight placement ----
    if W + M < budget.device:
        placement, why = "device", f"W+M={(W+M)/2**30:.1f}GiB fits device"
    elif W + C < budget.host and budget.disk_bw < budget.device_bw:
        placement, why = "host", f"W+C={(W+C)/2**30:.1f}GiB fits host"
    else:
        placement, why = "disk", "exceeds host; stream from disk"

    # ---- Eq. (1): pipeline mode ----
    if M < budget.device:
        pipeline = "performance"
    else:
        pipeline = "memory"
        est_min = estimate(cfg, batch=batch, seq=s, p=precision_bytes,
                           preload=False)
        M = int(max(est_min.peak_prefill, est_min.peak_decode)
                - (est_min.w_mha + est_min.w_mlp) * (1.0 - ratio))

    use_int4 = (quant == "int4") and batch < 16   # §3.5
    if pipeline == "performance":
        depth = depth_capacity(cfg, batch=batch, seq=s, p=precision_bytes,
                               budget_bytes=budget.device, quant=quant)
    else:
        depth = 1           # memory mode: single-layer residency, no window
    return AutoConfig(placement, pipeline, block_bytes, use_int4, est_pre,
                      why, depth)


def serving_preload_depth(cfg: ModelConfig, *, b_max: int, max_len: int,
                          precision_bytes: int = 4,
                          quant: Optional[str] = None, spill_cap: int = 0,
                          placement: str = "host",
                          budget: Optional[MemoryBudget] = None,
                          depth_cap: int = 8) -> int:
    """Preload depth for an offloaded serving engine (the ``depth=None``
    default of ``OffloadedServingEngine``): ``depth_capacity`` against the
    device budget, with one serving-specific guard — the host tier must
    hold the full decode KV cache, up to ``spill_cap`` retained slot
    spills (each one request's KV rows), and — for host placement — the
    weights themselves (packed under quant; disk placement keeps only
    in-flight buffers in host RAM, so weights don't count there).  When
    the host can't, it is already the bottleneck and a deeper window
    just queues more transfers behind a thrashing tier: fall back to
    depth 1."""
    budget = budget or MemoryBudget()
    est = estimate(cfg, batch=b_max, seq=max_len, p=precision_bytes,
                   preload=1)
    spill_bytes = spill_cap * (est.kv_cache // max(1, b_max))
    # host weights sit packed under quant (the engine quantizes at put());
    # same byte convention as configure()/depth_capacity
    w_host = int(est.weights * quant_weight_ratio(precision_bytes, quant)) \
        if placement == "host" else 0
    if w_host + est.kv_cache + spill_bytes > budget.host:
        return 1
    return depth_capacity(cfg, batch=b_max, seq=max_len, p=precision_bytes,
                          budget_bytes=budget.device, quant=quant,
                          depth_cap=depth_cap)
