"""Device-resident draft models for speculative decoding.

The dominant cost of offloaded decode is streaming the whole layer
stack over the link once per generated token.  Speculative decoding
(SpecOffload's framing rendered on this codebase) amortizes that: a
small draft model whose weights live ENTIRELY on the device proposes
``k`` cheap tokens, then the streamed target scores all ``k+1``
positions in one ragged decode step — one trip through the layer stack
buys up to ``k+1`` emitted tokens.  Greedy accept/reject makes the
output stream *bit-identical* to non-speculative greedy decode for any
proposal stream, good or bad; the draft's quality only moves the
acceptance length (and therefore the speedup), never the tokens.

``ResidentDraft`` is the real draft: a registry architecture built
through the same ``models`` facade the resident serving engine uses,
with its own device KV cache slaved to the target's slot positions.
It never truncates its cache on rejection — rejected rows sit beyond
the live position, masked by decode attention (``kv_pos <= pos``) and
overwritten by the next proposal pass, the same value-invisibility
argument the tiered KV store's padding relies on.

``accept_length``/``accepted_tokens`` are the pure accept/reject
kernel both engines (and the hypothesis property suite) share — any
drift between engines would otherwise silently fork the semantics.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Dist, build_model

__all__ = ["ResidentDraft", "accept_length", "accepted_tokens"]


def accept_length(draft: Sequence[int], target: Sequence[int]) -> int:
    """Greedy accept rule: the number of leading draft proposals that
    match the target's per-position greedy choices.  ``draft`` carries
    the k proposals; ``target[i]`` is the target's argmax at the
    position whose input was ``draft[i-1]`` (``target[0]``'s input is
    the current token), so proposal ``i`` is sound iff every earlier
    proposal matched AND ``draft[i] == target[i]``."""
    a = 0
    k = len(draft)
    while a < k and int(draft[a]) == int(target[a]):
        a += 1
    return a


def accepted_tokens(draft: Sequence[int], target: Sequence[int]):
    """The tokens one verify pass emits: the ``a`` accepted proposals
    plus the target's bonus token at the first divergence (or after the
    last proposal) — ``target[:a+1]``.  Token-for-token equal to what
    ``a+1`` sequential non-speculative greedy steps would emit."""
    a = accept_length(draft, target)
    return [int(t) for t in target[:a + 1]]


class ResidentDraft:
    """A fully device-resident greedy draft model.

    The draft holds its own parameters and KV cache on the device and
    is *slaved* to the engine's slot state: ``prefill_slot``/
    ``prefill_batch`` admit prompts, ``propose(tokens, pos, k)`` runs
    ``k`` ragged decode steps from the engine's per-slot positions and
    returns the proposals.  The engine never feeds accepted tokens back
    separately — proposal rows double as the draft's cache rows, and
    rejected rows are overwritten by the next pass (masked until then).
    """

    def __init__(self, cfg: ModelConfig, *, b_max: int, max_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.b_max = b_max
        self.max_len = max_len
        self.dist = Dist.local()
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed), jnp.float32)
        self.caches = self.model.init_cache(b_max, max_len)
        m, dist = self.model, self.dist

        def decode(params, tok, pos, caches):
            return m.decode_step(params, {"token": tok, "pos": pos},
                                 caches, dist)
        self._decode = jax.jit(decode, donate_argnums=(3,))

        def prefill1(params, toks):
            return m.prefill(params, {"tokens": toks}, dist, max_len)
        self._prefill = jax.jit(prefill1)

    # ---- cache plumbing (same pat/rem layout as the resident engine) -----
    @staticmethod
    def _batch_axis(path) -> int:
        head = str(getattr(path[0], "key", getattr(path[0], "idx", path[0])))
        return 1 if head == "pat" else 0

    def _scatter_slot(self, slot: int, cache1):
        flat_big, treedef = jax.tree_util.tree_flatten_with_path(self.caches)
        flat_one = treedef.flatten_up_to(cache1)
        out = []
        for (path, big), one in zip(flat_big, flat_one):
            ax = self._batch_axis(path)
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(slot, slot + 1)
            out.append(big.at[tuple(idx)].set(one.astype(big.dtype)))
        self.caches = jax.tree_util.tree_unflatten(treedef, out)

    # ---- admission -------------------------------------------------------
    def prefill_slot(self, slot: int, prompt: np.ndarray) -> None:
        """Admit one prompt into ``slot`` (the serving path)."""
        _, cache1 = self._prefill(self.params,
                                  jnp.asarray(prompt, jnp.int32)[None])
        self._scatter_slot(slot, cache1)

    def prefill_batch(self, tokens: np.ndarray) -> None:
        """Admit a full uniform batch (the ``PipelinedLM`` path);
        ``tokens`` is ``(b_max, s)``."""
        assert tokens.shape[0] == self.b_max, tokens.shape
        _, caches = self._prefill(self.params,
                                  jnp.asarray(tokens, jnp.int32))
        self.caches = jax.tree_util.tree_map(
            lambda one, big: one.astype(big.dtype), caches, self.caches)

    # ---- proposal --------------------------------------------------------
    def propose(self, tokens, pos, k: int) -> np.ndarray:
        """Run ``k`` greedy draft steps from the engine's state:
        ``tokens`` (b_max,) are the last emitted tokens (not yet in any
        cache), ``pos`` (b_max,) the target's per-slot positions.  Step
        ``t`` feeds the previous token at position ``pos + t``.
        Returns the proposals, ``(b_max, k)`` int32."""
        cur = jnp.asarray(np.asarray(tokens, np.int32))[:, None]
        base = np.asarray(pos, np.int32)
        out = np.zeros((self.b_max, int(k)), np.int32)
        for t in range(int(k)):
            nt, self.caches = self._decode(
                self.params, cur, jnp.asarray(base + t), self.caches)
            out[:, t] = np.asarray(nt)
            cur = nt[:, None]
        return out
