"""PIPO data-transfer suite (paper §3.3 + Appendix A).

Three techniques, replacing single-call I/O:
  * blockwise transfer   — tensors move in fixed-size blocks so the
    disk->host and host->device stages overlap (Fig. 3);
  * multi-thread parallel transfer — multiple reader threads each own a
    chunk of the block stream, keeping the NVMe queue full;
  * data merging         — all weight tensors of a layer are stored as ONE
    contiguous buffer + manifest, so a layer is one I/O request.

Block size is picked empirically per device by ``sweep_block_size``
(Appendix A reproduces Fig. 6 with it).
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.offload import DiskStore

DEFAULT_BLOCK = 8 * 2**20          # 8MB disk blocks (paper Appendix A)
DEVICE_BLOCK = 32 * 2**20          # 32MB host->device blocks


# ---------------------------------------------------------------------------
# Data merging
# ---------------------------------------------------------------------------

@dataclass
class Manifest:
    """Layout of tensors merged into one flat uint8 buffer."""
    entries: Dict[str, tuple]       # name -> (offset, shape, dtype)
    total_bytes: int


def merge_tensors(tensors: Dict[str, np.ndarray]) -> tuple[np.ndarray, Manifest]:
    entries, off = {}, 0
    for name, a in sorted(tensors.items()):
        a = np.ascontiguousarray(a)
        entries[name] = (off, a.shape, a.dtype)
        off += a.nbytes
    buf = np.empty(off, np.uint8)
    for name, a in sorted(tensors.items()):
        o, shape, dtype = entries[name]
        buf[o:o + a.nbytes] = np.ascontiguousarray(a).view(np.uint8).reshape(-1)
    return buf, Manifest(entries, off)


def split_views(buf: np.ndarray, manifest: Manifest) -> Dict[str, np.ndarray]:
    out = {}
    for name, (off, shape, dtype) in manifest.entries.items():
        n = int(np.prod(shape)) * np.dtype(dtype).itemsize
        out[name] = buf[off:off + n].view(dtype).reshape(shape)
    return out


# ---------------------------------------------------------------------------
# Transfers
# ---------------------------------------------------------------------------


def naive_disk_to_host(disk: DiskStore, key: str) -> np.ndarray:
    """Baseline: one fromfile() call (the PyTorch-load analogue)."""
    return disk.get(key)


def blockwise_disk_to_host(disk: DiskStore, key: str,
                           block_bytes: int = DEFAULT_BLOCK,
                           n_threads: int = 3,
                           out: Optional[np.ndarray] = None) -> np.ndarray:
    """Parallel blockwise read into a preallocated host buffer."""
    shape, dtype = disk.meta(key)
    total = int(np.prod(shape)) * np.dtype(dtype).itemsize
    if out is None:
        out = np.empty(total, np.uint8)
    blocks = [(o, min(block_bytes, total - o))
              for o in range(0, total, block_bytes)]
    if len(blocks) <= 1 or n_threads <= 1:
        disk.read_range(key, 0, total, out)
        return out.view(dtype).reshape(shape)
    with ThreadPoolExecutor(max_workers=n_threads) as ex:
        list(ex.map(lambda b: disk.read_range(key, b[0], b[1], out), blocks))
    return out.view(dtype).reshape(shape)


def host_to_device(arr: np.ndarray):
    out = jax.device_put(arr)
    out.block_until_ready()
    return out


def pipelined_disk_to_device(disk: DiskStore, key: str,
                             block_bytes: int = DEFAULT_BLOCK,
                             n_threads: int = 3):
    """Full suite: blockwise parallel disk reads overlapped with staged
    host->device copies (Fig. 3 timeline).  The device-side buffer is
    assembled blockwise in a staging array while later disk blocks are
    still in flight, then materialized as one device array."""
    shape, dtype = disk.meta(key)
    total = int(np.prod(shape)) * np.dtype(dtype).itemsize
    host = np.empty(total, np.uint8)
    staging = np.empty(total, np.uint8)   # "pinned" staging = PCIe analogue
    blocks = [(o, min(block_bytes, total - o))
              for o in range(0, total, block_bytes)]
    done_q: queue.Queue = queue.Queue()

    def read_block(b):
        disk.read_range(key, b[0], b[1], host)
        done_q.put(b)

    with ThreadPoolExecutor(max_workers=n_threads) as ex:
        for b in blocks:
            ex.submit(read_block, b)
        copied = 0
        while copied < len(blocks):
            o, n = done_q.get()          # overlap: copy while reads continue
            staging[o:o + n] = host[o:o + n]
            copied += 1
    return host_to_device(staging.view(dtype).reshape(shape))


def sweep_block_size(disk: DiskStore, key: str, sizes=None,
                     n_threads: int = 3, repeats: int = 2):
    """Appendix-A experiment: measured bandwidth per block size."""
    import time
    sizes = sizes or [1 * 2**20, 2 * 2**20, 4 * 2**20, 8 * 2**20,
                      16 * 2**20, 32 * 2**20, 64 * 2**20]
    shape, dtype = disk.meta(key)
    total = int(np.prod(shape)) * np.dtype(dtype).itemsize
    out = []
    for bs in sizes:
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            blockwise_disk_to_host(disk, key, block_bytes=bs,
                                   n_threads=n_threads)
            ts.append(time.perf_counter() - t0)
        bw = total / min(ts)
        out.append((bs, bw))
    return out
