"""PIPO data-transfer suite (paper §3.3 + Appendix A).

Three techniques, replacing single-call I/O:
  * blockwise transfer   — tensors move in fixed-size blocks so the
    disk->host and host->device stages overlap (Fig. 3);
  * multi-thread parallel transfer — multiple reader threads each own a
    chunk of the block stream, keeping the NVMe queue full;
  * data merging         — all weight tensors of a layer are stored as ONE
    contiguous buffer + manifest, so a layer is one I/O request.

Block size is picked empirically per device by ``sweep_block_size``
(Appendix A reproduces Fig. 6 with it).
"""
from __future__ import annotations

import math
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import DiskStore

DEFAULT_BLOCK = 8 * 2**20          # 8MB disk blocks (paper Appendix A)
DEVICE_BLOCK = 32 * 2**20          # 32MB host->device blocks


# ---------------------------------------------------------------------------
# Data merging
# ---------------------------------------------------------------------------

@dataclass
class Manifest:
    """Layout of tensors merged into one flat uint8 buffer."""
    entries: Dict[str, tuple]       # name -> (offset, shape, dtype)
    total_bytes: int


def merge_tensors(tensors: Dict[str, np.ndarray]) -> tuple[np.ndarray, Manifest]:
    """Flatten a unit's tensors (sorted by name) into one contiguous
    uint8 buffer + manifest, so one layer is ONE I/O request (§3.3)."""
    entries, off = {}, 0
    for name, a in sorted(tensors.items()):
        a = np.ascontiguousarray(a)
        entries[name] = (off, a.shape, a.dtype)
        off += a.nbytes
    buf = np.empty(off, np.uint8)
    for name, a in sorted(tensors.items()):
        o, shape, dtype = entries[name]
        buf[o:o + a.nbytes] = np.ascontiguousarray(a).view(np.uint8).reshape(-1)
    return buf, Manifest(entries, off)


def split_views(buf: np.ndarray, manifest: Manifest) -> Dict[str, np.ndarray]:
    """Zero-copy views back out of a merged buffer (inverse of
    merge_tensors)."""
    out = {}
    for name, (off, shape, dtype) in manifest.entries.items():
        n = int(np.prod(shape)) * np.dtype(dtype).itemsize
        out[name] = buf[off:off + n].view(dtype).reshape(shape)
    return out


# ---------------------------------------------------------------------------
# INT4 streaming (paper §3.4: W4 weights quarter the transfer bytes)
# ---------------------------------------------------------------------------

QUANT_MIN_GROUP = 16


def int4_group(arr) -> Optional[int]:
    """The groupwise-quantization group size for one tensor, or None if
    the tensor streams unquantized.  Eligible: 2-D, an even number of
    columns, and a contraction dim divisible by a reasonable group (the
    gcd with the canonical 128 — full-size layers get 128, scaled-down
    test configs a smaller power of two).  This predicate is THE single
    source of truth shared by the engines' streaming path and the
    resident INT4 reference used in parity tests."""
    from repro.quant.int4 import GROUP
    shape = getattr(arr, "shape", ())
    if len(shape) != 2 or shape[1] % 2 != 0:
        return None
    g = math.gcd(int(shape[0]), GROUP)
    return g if g >= QUANT_MIN_GROUP else None


def quantize_unit(tensors: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Quantize a unit's eligible tensors to packed INT4: each eligible
    ``name`` is replaced by ``name#q`` (packed uint8, half the columns)
    and ``name#s`` (groupwise f32 scales); ineligible tensors (norm
    vectors, small/odd projections) pass through.  Runs once at engine
    build time (main thread)."""
    from repro.quant.int4 import quantize_int4
    out = {}
    for name, arr in tensors.items():
        g = int4_group(arr)
        if g is None:
            out[name] = np.asarray(arr)
            continue
        packed, scale = quantize_int4(jnp.asarray(arr, jnp.float32), g)
        out[name + "#q"] = np.asarray(packed)
        out[name + "#s"] = np.asarray(scale)
    return out


def int4_roundtrip(arr):
    """quantize -> dequantize one tensor through the exact jitted dequant
    the streaming path uses — builds the resident INT4 reference whose
    decode tokens the INT4 offloaded engine must match bit-for-bit.
    Ineligible tensors return unchanged."""
    from repro.quant.int4 import quantize_int4
    g = int4_group(arr)
    if g is None:
        return arr
    packed, scale = quantize_int4(jnp.asarray(arr, jnp.float32), g)
    # packed/scale are already device arrays — feed them straight to the
    # jitted dequant, no host bounce
    return np.asarray(_fused_dequant(packed, scale, g))


# ---------------------------------------------------------------------------
# Transfers
# ---------------------------------------------------------------------------


@dataclass
class SimLink:
    """Fixed-bandwidth interconnect model shared by EVERY transfer that
    crosses the offload boundary — weight loads (``TieredWeightStore``)
    and KV loads (``core.kvstore.TieredKVStore``) hold the same instance,
    so both pay the same link.  ``floor(nbytes, t0)`` sleeps out the
    remainder of ``nbytes / bw`` seconds since ``t0`` (GIL released, like
    a DMA engine); ``bw=None`` disables the floor."""

    bw: Optional[float] = None

    def floor(self, nbytes: int, t0: float):
        if self.bw:
            remain = nbytes / self.bw - (time.perf_counter() - t0)
            if remain > 0:
                time.sleep(remain)


class TieredWeightStore:
    """Merged-buffer weight tiering shared by the generation engine
    (core.engine.PipelinedLM) and the offloaded serving engine
    (serving.offload_engine.OffloadedServingEngine).

    ``put`` merges a unit's tensors into ONE contiguous buffer + manifest on
    the placement tier (device/host/disk); ``load`` moves it to the device
    and splits views, transparently dequantizing INT4 pairs (fused inside
    jit when ``fused_int4``, else materialized — the Fig. 9 ablation knob).

    ``sim_bw`` (bytes/s) floors each load's wall time at
    ``total_bytes / sim_bw``, emulating a fixed-bandwidth interconnect
    (PCIe/NVMe per ``offload.MemoryBudget``).  On this CPU-only container
    host->"device" copies are memcpys whose speed varies with CPU
    contention and page-cache state; the floor makes pipeline-overlap
    benchmarks deterministic, and it sleeps (GIL released) so transfer
    threads overlap compute exactly like a DMA engine would.
    """

    def __init__(self, *, placement: str, host, device, disk,
                 quant: Optional[str] = None, fused_int4: bool = True,
                 block_bytes: int = DEFAULT_BLOCK, n_io_threads: int = 3,
                 cold_reads: bool = False, sim_bw: Optional[float] = None):
        assert placement in ("device", "host", "disk"), placement
        self.placement = placement
        self.host, self.device, self.disk = host, device, disk
        self.quant = quant
        self.fused_int4 = fused_int4
        self.block_bytes = block_bytes
        self.n_io_threads = n_io_threads
        self.cold_reads = cold_reads
        self.link = SimLink(sim_bw)
        self.manifests: Dict[str, Manifest] = {}
        # per-key load counters (thread-safe enough for CPython dict ops):
        # benchmarks/tests read these to assert transfer volumes, e.g. the
        # MoE routed-union invariant (union bytes < whole-bank bytes).
        self.load_counts: Dict[str, int] = {}

    def put(self, key: str, tensors: Dict[str, np.ndarray]):
        """Merge + place a unit's tensors on the placement tier (main
        thread, done once at engine build)."""
        buf, man = merge_tensors(tensors)
        self.manifests[key] = man
        if self.placement == "disk":
            self.disk.put(key, buf)
        elif self.placement == "host":
            self.host.put(key, buf)
        else:
            self.device.put(key, buf)

    def nbytes(self, key: str) -> int:
        """Bytes one load() of ``key`` moves over the link (packed bytes
        for INT4 units).  Any thread; non-blocking."""
        return self.manifests[key].total_bytes

    @property
    def sim_bw(self) -> Optional[float]:
        return self.link.bw

    def sim_floor(self, nbytes: int, t0: float):
        """Sleep out the remainder of ``nbytes / sim_bw`` seconds since t0
        (delegates to the shared ``SimLink``)."""
        self.link.floor(nbytes, t0)

    def load(self, key: str) -> Dict[str, np.ndarray]:
        """Placement tier -> device tensors (one I/O request per unit).
        Blocking; runs on whatever thread calls it — in the pipeline that
        is a transfer-pool worker, never the compute (main) thread."""
        t0 = time.perf_counter()
        man = self.manifests[key]
        self.load_counts[key] = self.load_counts.get(key, 0) + 1
        if self.placement == "device":
            buf = self.device.get(key)
            views = split_views(np.asarray(buf), man)
        elif self.placement == "host":
            views = split_views(self.host.get(key), man)
        else:
            if self.cold_reads:
                # evict page cache: measure real NVMe reads (paper regime)
                self.disk.drop_cache(key)
            host_buf = blockwise_disk_to_host(
                self.disk, key, block_bytes=self.block_bytes,
                n_threads=self.n_io_threads)
            views = split_views(host_buf.view(np.uint8), man)
        dev = {}
        for name, arr in views.items():
            dev[name] = jax.device_put(arr)
        for a in dev.values():
            a.block_until_ready()
        self.sim_floor(man.total_bytes, t0)
        return self._maybe_dequant(dev)

    def _maybe_dequant(self, dev):
        """Dequantize INT4 ``#q``/``#s`` pairs after the (cheap, packed)
        bytes crossed the link.  Called from ``load`` on a transfer-pool
        thread: the fused path dispatches one jitted dequant whose cost
        overlaps the main thread's compute on earlier layers — only INT4
        bytes pay the link floor, the f32 expansion never crosses it."""
        if self.quant != "int4":
            return dev
        from repro.quant.int4 import dequantize_int4
        out = {}
        for name, arr in dev.items():
            if name.endswith("#q"):
                base = name[:-2]
                scale = dev[base + "#s"]
                # group size is implied by the shapes: K split into
                # K//group scale rows (scaled-down configs use smaller
                # groups than the canonical 128 — see int4_group).
                g = arr.shape[0] // scale.shape[0]
                if self.fused_int4:
                    # fused path: dequant happens inside jit on-device —
                    # XLA fuses it with the matmul (paper §3.4 kernel).
                    out[base] = _fused_dequant(arr, scale, g)
                else:
                    # unfused baseline: materialize fp32 weights first
                    out[base] = np.asarray(dequantize_int4(
                        arr, scale, jnp.float32, g))
                    out[base] = jax.device_put(out[base])
            elif name.endswith("#s"):
                continue
            else:
                out[name] = arr
        return out


@partial(jax.jit, static_argnums=(2,))
def _fused_dequant(packed, scale, group: int = 128):
    """INT4 weights decoded on-device inside jit; XLA fuses the dequant into
    the consuming matmul — the CPU emulation of the paper's fused kernel
    (on TPU the Pallas kernel in kernels/int4_matmul.py does this in VREGs)."""
    from repro.quant.int4 import dequantize_int4
    return dequantize_int4(packed, scale, jnp.float32, group)


def naive_disk_to_host(disk: DiskStore, key: str) -> np.ndarray:
    """Baseline: one fromfile() call (the PyTorch-load analogue)."""
    return disk.get(key)


def blockwise_disk_to_host(disk: DiskStore, key: str,
                           block_bytes: int = DEFAULT_BLOCK,
                           n_threads: int = 3,
                           out: Optional[np.ndarray] = None) -> np.ndarray:
    """Parallel blockwise read into a preallocated host buffer."""
    shape, dtype = disk.meta(key)
    total = int(np.prod(shape)) * np.dtype(dtype).itemsize
    if out is None:
        out = np.empty(total, np.uint8)
    blocks = [(o, min(block_bytes, total - o))
              for o in range(0, total, block_bytes)]
    if len(blocks) <= 1 or n_threads <= 1:
        disk.read_range(key, 0, total, out)
        return out.view(dtype).reshape(shape)
    with ThreadPoolExecutor(max_workers=n_threads) as ex:
        list(ex.map(lambda b: disk.read_range(key, b[0], b[1], out), blocks))
    return out.view(dtype).reshape(shape)


def host_to_device(arr: np.ndarray):
    """Synchronous host->device copy (blocks the calling thread until
    the device buffer is materialized)."""
    out = jax.device_put(arr)
    out.block_until_ready()
    return out


def pipelined_disk_to_device(disk: DiskStore, key: str,
                             block_bytes: int = DEFAULT_BLOCK,
                             n_threads: int = 3):
    """Full suite: blockwise parallel disk reads overlapped with staged
    host->device copies (Fig. 3 timeline).  The device-side buffer is
    assembled blockwise in a staging array while later disk blocks are
    still in flight, then materialized as one device array."""
    shape, dtype = disk.meta(key)
    total = int(np.prod(shape)) * np.dtype(dtype).itemsize
    host = np.empty(total, np.uint8)
    staging = np.empty(total, np.uint8)   # "pinned" staging = PCIe analogue
    blocks = [(o, min(block_bytes, total - o))
              for o in range(0, total, block_bytes)]
    done_q: queue.Queue = queue.Queue()

    def read_block(b):
        disk.read_range(key, b[0], b[1], host)
        done_q.put(b)

    with ThreadPoolExecutor(max_workers=n_threads) as ex:
        for b in blocks:
            ex.submit(read_block, b)
        copied = 0
        while copied < len(blocks):
            o, n = done_q.get()          # overlap: copy while reads continue
            staging[o:o + n] = host[o:o + n]
            copied += 1
    return host_to_device(staging.view(dtype).reshape(shape))


def sweep_block_size(disk: DiskStore, key: str, sizes=None,
                     n_threads: int = 3, repeats: int = 2):
    """Appendix-A experiment: measured bandwidth per block size."""
    import time
    sizes = sizes or [1 * 2**20, 2 * 2**20, 4 * 2**20, 8 * 2**20,
                      16 * 2**20, 32 * 2**20, 64 * 2**20]
    shape, dtype = disk.meta(key)
    total = int(np.prod(shape)) * np.dtype(dtype).itemsize
    out = []
    for bs in sizes:
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            blockwise_disk_to_host(disk, key, block_bytes=bs,
                                   n_threads=n_threads)
            ts.append(time.perf_counter() - t0)
        bw = total / min(ts)
        out.append((bs, bw))
    return out
