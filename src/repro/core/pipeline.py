"""PIPO pipeline: thread pool + Algorithm-1 scheduler (paper §3.2).

Thread-pool principles (paper §3.2.1):
  * pool size 3 — one slot per transfer type (weight-load, KV-load,
    KV-save); threads are NOT statically bound to task types: they pull
    whatever is next in the queue ("flexible scheduling ... minimizes
    idle time");
  * compute runs on the MAIN thread, outside the pool;
  * KV-save is lower priority (queued behind loads) and may have several
    requests in flight; its completion is only *checked* one layer before
    the same layer's KV-load in the next token loop.

Scheduling modes:
  * "performance"  — preload the next ``depth`` layers' weights during
    layer j's compute (``depth + 1`` layers resident; ``depth=1`` is the
    paper's two-resident-layer performance pipeline);
  * "memory"       — single layer resident; loads start only after the
    previous layer's memory is released; KV-save synchronized before the
    next save launches (paper's memory-efficient pipeline);
  * "sequential"   — FlexGen-like device-level sync baseline: every task
    completes before the next starts (ablation baseline, Fig. 9).

Warm pipeline (``PipelineScheduler(warm=True)``, performance mode): the
scheduler keeps its pending-task state alive *across* ``generate()``
calls and pre-submits the next call's first ``depth`` weight loads (and
the window's KV loads) while the current call's tail layers compute —
serving engines that drain the scheduler once per decode step get zero
cold-start bubble per token (see docs/ARCHITECTURE.md and
docs/TUNING.md for sizing ``depth``).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.core.tasks import Task, TaskType, Trace, VirtualClock

PIPELINE_MODES = ("performance", "memory", "sequential")


class ThreadPool:
    """3 transfer workers pulling from a two-level (priority) queue.

    Thread affinity: ``submit`` is called from the submitter (main)
    thread and returns immediately — the task's ``fn`` executes later on
    one of the pool's worker threads.  ``run_on_main`` executes the task
    synchronously on the *caller's* thread (compute never enters the
    pool).  ``shutdown`` blocks the caller until the workers exit."""

    def __init__(self, n_threads: int = 3, trace: Optional[Trace] = None):
        self.trace = trace or Trace()
        self.n_workers = n_threads
        self._q: "queue.PriorityQueue" = queue.PriorityQueue()
        self._seq = 0
        self._stop = False
        self._lock = threading.Lock()
        self._threads = [threading.Thread(target=self._worker,
                                          args=(f"pool-{i}",), daemon=True)
                         for i in range(n_threads)]
        for t in self._threads:
            t.start()

    def submit(self, task: Task, priority: int = 0) -> Task:
        """Enqueue a task (submitter thread; non-blocking).  Lower
        priority values run first; KV-saves use priority 1 so loads win
        ties (paper §3.2.1)."""
        import time
        task.t_submit = time.perf_counter()
        with self._lock:
            self._seq += 1
            self._q.put((priority, self._seq, task))
        return task

    def _worker(self, name: str):
        while True:
            prio, _, task = self._q.get()
            if task is None:
                return
            task.run()
            self.trace.add(task, name)
            self._q.task_done()

    def run_on_main(self, task: Task) -> Task:
        """Compute tasks execute synchronously on the caller (main)
        thread — blocking until the task body returns."""
        task.run()
        self.trace.add(task, "main")
        if task.error is not None:
            raise task.error
        return task

    def shutdown(self):
        """Drain queued tasks and join the workers (caller thread;
        blocking — sentinel priority 99 runs after all real work)."""
        for _ in self._threads:
            self._q.put((99, 1 << 30, None))
        for t in self._threads:
            t.join(timeout=5)


class VirtualPool:
    """Deterministic fake transport: same interface as ThreadPool, but every
    task executes synchronously on the caller thread while its start/end
    timestamps are assigned on a *virtual* discrete-event timeline with
    ``n_threads`` parallel transfer slots.

    The timeline models exactly what the scheduler enforces: a submitted
    task starts at max(submission time, earliest-free worker); a wait()
    advances the virtual clock to the task's end (the caller blocked until
    then).  Per-task durations come from ``cost_fn(task)`` — tests supply
    fixed costs per TaskType, so scheduler ordering invariants (overlap,
    serialization, save-before-load) are asserted on virtual timestamps
    with zero sleeps and zero timing races.
    """

    def __init__(self, n_threads: int = 3, trace: Optional[Trace] = None,
                 cost_fn: Optional[Callable[[Task], float]] = None,
                 clock: Optional[VirtualClock] = None):
        self.clock = clock or VirtualClock()
        self.trace = trace if trace is not None else Trace(clock=self.clock)
        self.cost_fn = cost_fn or (lambda task: 1.0)
        self.n_workers = n_threads
        self._free = [0.0] * n_threads

    def submit(self, task: Task, priority: int = 0) -> Task:
        """Run the task NOW on the caller thread (side effects are
        immediate, single-threaded) while assigning its trace interval
        on the virtual timeline's earliest-free worker."""
        task.t_submit = self.clock.now()
        task.run(self.clock)               # side effects happen now
        w = min(range(len(self._free)), key=lambda k: self._free[k])
        start = max(self.clock.now(), self._free[w])
        end = start + float(self.cost_fn(task))
        task.t_start, task.t_end = start, end
        self._free[w] = end
        task.on_wait = self._advance       # waiters block until virtual end
        self.trace.add(task, f"vpool-{w}")
        return task

    def _advance(self, task: Task):
        self.clock.advance_to(task.t_end)

    def run_on_main(self, task: Task) -> Task:
        start = self.clock.now()
        task.run(self.clock)
        end = start + float(self.cost_fn(task))
        task.t_start, task.t_end = start, end
        self.clock.advance_to(end)
        self.trace.add(task, "main")
        if task.error is not None:
            raise task.error
        return task

    def shutdown(self):
        pass


@dataclass
class LayerTasks:
    """Per-(iteration, layer) task handles used by the scheduler."""
    weight: Optional[Task] = None
    kv_load: Optional[Task] = None
    kv_save: Optional[Task] = None


class PipelineScheduler:
    """Algorithm 1.  The model supplies callbacks; the scheduler owns all
    ordering/synchronization decisions so they can be tested in isolation
    (tests assert the event-order invariants on Trace timestamps).

    Thread affinity: ``generate``/``drop_kv_preloads``/``drain_saves``/
    ``shutdown`` run on the submitter (main) thread and may block on task
    completion; the model's ``load_weights``/``load_kv``/``save_kv``
    callbacks execute on transfer-pool threads and must be thread-safe;
    ``compute``/``finalize``/``release_weights`` run on the main thread.

    Callbacks (all pure-ish, thread-safe):
      load_weights(j) -> device weights      (WEIGHT_LOAD)
      release_weights(j, handle)             (called on main after compute)
      load_kv(i, j) -> device kv             (KV_LOAD; None for non-MHA)
      save_kv(i, j, new_kv)                  (KV_SAVE)
      compute(i, j, x, weights, kv) -> (x, new_kv)   (COMPUTE, main thread)
      is_mha(j) -> bool
      weight_nbytes(j) -> int                (optional; trace byte account)

    Preload depth (``depth``, performance pipeline only): the scheduler
    keeps the weight loads of the next ``depth`` schedulable positions in
    flight while the current layer computes — ``depth + 1`` layers
    resident, ``depth=1`` reproduces the paper's two-resident-layer
    invariant.  On weight-dominated links a deeper window hides more
    transfer time behind the same compute (up to the pool's parallelism);
    ``core.autoconfig`` sizes it from the memory budget.  ``depth`` is
    clamped to ``num_layers - 1`` so no layer can ever have two loads
    pending under the same key.

    Warm mode (``warm=True``, performance pipeline only): pending task
    state persists *across* ``generate()`` calls.  At the tail of a call,
    the first ``depth`` weight loads (and the window's KV loads) of the
    NEXT call are pre-submitted so they overlap the tail layers' compute
    — a serving engine that drains the scheduler once per decode step
    then starts every step with its first layers' transfers already
    resident instead of paying a cold-start bubble per token.  Iteration
    indices become global (monotonic across calls) so the KV
    save(i-1,j)-before-load(i,j) check keeps working across call
    boundaries.
    """

    def __init__(self, num_layers: int, mode: str = "performance",
                 pool: Optional[ThreadPool] = None,
                 trace: Optional[Trace] = None, warm: bool = False,
                 depth: int = 1, stage: int = 0, unit_base: int = 0):
        assert mode in PIPELINE_MODES, mode
        self.n = num_layers
        self.mode = mode
        # pipeline-parallel placement: ``stage`` tags every task this
        # scheduler submits (Trace stage_bubbles / residency-per-stage
        # accounting); ``unit_base`` offsets task NAMES to the global unit
        # index so a shared multi-stage trace stays replayable — callbacks
        # still receive stage-local indices (a StagedScheduler's per-stage
        # model view translates).
        self.stage = int(stage)
        self.unit_base = int(unit_base)
        self.trace = trace or Trace()
        # cross-call ("warm pipeline") state: preloading across generate()
        # calls only makes sense in performance mode — memory mode's
        # single-layer-resident invariant forbids a second in-flight load,
        # and sequential is a full-serialization baseline by definition.
        self.warm = bool(warm) and mode == "performance"
        self.depth = self.clamp_depth(mode, num_layers, depth)
        self.pool = pool or ThreadPool(self.pool_size(self.depth),
                                       self.trace)
        self._owns_pool = pool is None
        self._w_tasks: Dict[int, Task] = {}          # j -> pending load
        self._kv_tasks: Dict[tuple, Task] = {}       # (i, j) -> pending load
        self._save_tasks: Dict[tuple, Task] = {}     # (i, j) -> pending save
        self._iter0 = 0                              # global iteration base
        # stamp the replayable scheduling context on the trace: with the
        # per-call iteration counts generate() appends, core.replay can
        # re-run the recorded schedule under hypothetical knobs
        self.trace.meta.update(
            mode=self.mode, warm=self.warm, depth=self.depth,
            n_units=self.n,
            pool_size=getattr(self.pool, "n_workers", None)
            or self.pool_size(self.depth))
        self.trace.meta.setdefault("calls", [])

    # -- helpers ------------------------------------------------------------
    @staticmethod
    def clamp_depth(mode: str, num_layers: int, depth: int) -> int:
        """Effective preload depth: > 1 only exists in performance mode,
        and the clamp to n-1 keeps every pending weight load's layer key
        unique (window positions p+1..p+depth are distinct mod n iff
        depth <= n-1).  Engines that pre-build the transfer pool must use
        this + ``pool_size`` so their pool matches the scheduler's
        window."""
        if mode != "performance":
            return 1
        return max(1, min(int(depth), max(1, num_layers - 1)))

    def set_depth(self, depth: int) -> int:
        """Re-size the preload window between ``generate()`` calls (main
        thread) — the ``AdaptiveDepth`` policy's hook.  Takes effect for
        every *subsequent* preload decision: when shrinking at a warm
        tail, loads already in flight beyond the new window are simply
        consumed by the next call's first computes (weights are
        immutable, so nothing is stale), after which residency settles
        to the new ``depth + 1`` bound.  Clamped exactly like the
        constructor; returns the effective depth."""
        self.depth = self.clamp_depth(self.mode, self.n, depth)
        return self.depth

    @staticmethod
    def pool_size(depth: int) -> int:
        """Transfer workers for a depth-D window: depth workers for the
        window's weight loads plus 2 of KV headroom (depth=1 -> the
        paper's one-worker-per-transfer-type pool of 3).  The window can
        also hold up to depth KV *pre*loads, but those are short-lived
        relative to weight loads (cache rows vs merged layer buffers)
        and share the headroom; what the sizing must prevent is weight
        loads monopolizing every worker — with a fixed 3-worker pool,
        depth>=2 queued far-future weight preloads in front of the
        imminent KV traffic and measurably REGRESSED KV-heavy links
        (see docs/BENCHMARKS.md)."""
        return depth + 2

    def _submit(self, kind: TaskType, name: str, fn, priority=0,
                nbytes: int = 0, extent=None) -> Task:
        t = Task(kind, name, fn)
        t.nbytes = nbytes            # before submit: VirtualPool traces here
        t.extent = extent
        t.stage = self.stage
        self.pool.submit(t, priority)
        if self.mode == "sequential":
            t.wait()
        return t

    # -- warm-pipeline maintenance (main thread) ----------------------------
    def drop_kv_preloads(self):
        """Discard ALL pending cross-call KV preloads — with ``depth > 1``
        a warm call's tail leaves up to ``depth`` of them in flight (one
        per MHA position in the window), not just the next layer's.  Main
        thread; blocks until every in-flight load finishes so its
        host-side reads can't race the caller's mutation.  Call before
        mutating KV state outside the pipeline (e.g. a serving slot
        restore writes host KV directly) — every preloaded device copy
        would be stale.  Weight preloads are untouched (weights are
        immutable)."""
        for t in self._kv_tasks.values():
            try:
                t.wait()
            except Exception:
                pass                  # discarded anyway
        self._kv_tasks.clear()

    def drain_saves(self):
        """Block (main thread) until every outstanding KV save has landed.
        In warm mode saves are NOT drained per generate() call (that sync
        is itself a bubble); callers that read or write KV storage outside
        the pipeline must drain first."""
        for t in self._save_tasks.values():
            t.wait()
        self._save_tasks.clear()

    def prime_weights(self, model, count: Optional[int] = None) -> int:
        """Pre-submit the NEXT ``generate()`` call's first ``count``
        weight loads (default: the preload depth) — the warm-window
        generalization of the cross-step preload for speculative
        decoding: while the device-resident DRAFT computes its
        proposals, the link is idle, so the verify pass's first layers
        stream during draft compute instead of cold-starting after it.
        Main thread; non-blocking; a no-op for layers already in flight
        (a warm tail may have submitted them) and outside performance
        mode (the single-layer-resident/sequential invariants forbid a
        second pending load).  Never primes beyond the window — the
        ``depth + 1`` residency bound holds exactly as in steady state.
        Returns the number of loads actually submitted."""
        if self.mode != "performance":
            return 0
        nbytes_of = getattr(model, "weight_nbytes", None)
        c = self.depth if count is None else \
            max(0, min(int(count), self.depth))
        submitted = 0
        for j in range(min(c, self.n)):
            if j in self._w_tasks:
                continue
            self._w_tasks[j] = self._submit(
                TaskType.WEIGHT_LOAD, f"w[{self.unit_base + j}]",
                lambda j=j: model.load_weights(j),
                nbytes=nbytes_of(j) if nbytes_of else 0)
            submitted += 1
        return submitted

    # -- Algorithm 1 ----------------------------------------------------------
    def generate(self, model, x0, num_iterations: int):
        """Run ``num_iterations`` full passes over the layer stack (one per
        generated token); x0 is the initial activation provider:
        callable i -> x input for iteration i (call-local index).  Blocks
        the calling (main) thread; compute runs here, transfers on the
        pool.  Task/trace names use *global* iteration indices so events
        from successive warm calls stay distinct."""
        n = self.n
        ub = self.unit_base                    # global-name offset
        w_tasks, kv_tasks, save_tasks = (self._w_tasks, self._kv_tasks,
                                         self._save_tasks)
        base = self._iter0
        self.trace.meta.setdefault("calls", []).append(num_iterations)
        total = n * num_iterations             # call-local position count
        outputs = []
        nbytes_of = getattr(model, "weight_nbytes", None)
        kv_nbytes_of = getattr(model, "kv_nbytes", None)
        # optional byte-accounting hooks a tiered-KV model exposes: the
        # live (batch, len) extent of a KV_LOAD payload (recorded on the
        # trace event so live-row slicing is assertable) and the size of
        # a KV_SAVE payload (so report() splits ALL link volume by kind,
        # not just the load directions)
        kv_extent_of = getattr(model, "kv_extent", None)
        kv_save_nbytes_of = getattr(model, "kv_save_nbytes", None)

        def submit_weight(j):
            if j is not None and j < n and j not in w_tasks:
                w_tasks[j] = self._submit(
                    TaskType.WEIGHT_LOAD, f"w[{ub + j}]",
                    lambda j=j: model.load_weights(j),
                    nbytes=nbytes_of(j) if nbytes_of else 0)

        def submit_kv(i, j, blocking=True):
            if j is None or not model.is_mha(j):
                return
            if (i, j) in kv_tasks:
                return
            # KV-save completion check, advanced ahead of the load (paper):
            # the save from iteration i-1, layer j must be done before we
            # load layer j's cache in iteration i.  A *pre*load must not
            # stall the main thread on an unfinished save — skip it; a
            # later window pass (or the blocking just-in-time submit)
            # retries once the save has landed.
            prev_save = save_tasks.get((i - 1, j))
            if prev_save is not None:
                if not blocking and not prev_save.done.is_set():
                    return
                save_tasks.pop((i - 1, j))
                prev_save.wait()
            kv_tasks[(i, j)] = self._submit(
                TaskType.KV_LOAD, f"kv[{i},{ub + j}]",
                lambda i=i, j=j: model.load_kv(i, j),
                nbytes=kv_nbytes_of(i, j) if kv_nbytes_of else 0,
                extent=kv_extent_of(i, j) if kv_extent_of else None)

        def preload_window(pc):
            """Keep the next ``depth`` positions' weight loads — and the
            window's KV loads, plus the paper's advance-one-MHA rule — in
            flight while position ``pc`` computes.  Positions past the
            call's tail belong to the NEXT call (warm pipelines only)."""
            for d in range(1, self.depth + 1):
                p = pc + d
                if p >= total and not self.warm:
                    break
                submit_weight(p % n)
            # KV preload of (i, j) is legal only once compute(i-1, j) has
            # been issued — before that, the save it must trail is not
            # even in save_tasks, so the save-before-load check couldn't
            # see it.  Structurally that bounds the lookahead to n-1
            # positions (the distance to the same layer one iteration
            # earlier).
            seen_mha = False
            for d in range(1, n):
                p = pc + d
                if p >= total and not self.warm:
                    break
                jp = p % n
                if not model.is_mha(jp):
                    continue
                if d > self.depth and seen_mha:
                    break              # beyond the window AND advanced one
                submit_kv(base + p // n, jp, blocking=False)
                seen_mha = True
                if d >= self.depth:
                    break

        for it in range(num_iterations):
            gi = base + it                         # global iteration index
            x = x0(it)
            for j in range(n):
                # --- CallLoadData(i, j): ensure current loads in flight ----
                submit_weight(j)                       # no-op if preloaded
                submit_kv(gi, j)                       # no-op if advanced

                # --- SynchronizeLoadTask(i, j) -----------------------------
                weights = w_tasks.pop(j).wait()
                kv = None
                if model.is_mha(j):
                    kv = kv_tasks.pop((gi, j)).wait()

                if self.mode == "performance":
                    # Preload: each window load starts only after the one
                    # ``depth`` positions back completed (= now),
                    # overlapping with this layer's compute (paper §3.1.2;
                    # depth=1 is the paper's next-layer preload).  At the
                    # stack tail a warm scheduler preloads for the NEXT
                    # generate() call.
                    preload_window(it * n + j)

                # --- Compute(i, j) on the main thread ----------------------
                ct = Task(TaskType.COMPUTE, f"c[{gi},{ub + j}]",
                          lambda: model.compute(gi, j, x, weights, kv))
                ct.stage = self.stage
                self.pool.run_on_main(ct)
                x, new_kv = ct.result

                # --- CallStoreCache(i, j) ----------------------------------
                if model.is_mha(j) and new_kv is not None:
                    st = self._submit(TaskType.KV_SAVE, f"sv[{gi},{ub + j}]",
                                      lambda gi=gi, j=j, kv=new_kv:
                                      model.save_kv(gi, j, kv),
                                      priority=1,  # lower priority
                                      nbytes=(kv_save_nbytes_of(gi, j)
                                              if kv_save_nbytes_of else 0))
                    save_tasks[(gi, j)] = st
                    if self.mode in ("memory", "sequential"):
                        st.wait()

                model.release_weights(j, weights)
            outputs.append(model.finalize(it, x))
        self._iter0 = base + num_iterations
        if not self.warm:
            # cold pipeline: drain outstanding saves before returning (the
            # caller may read host KV directly).  Warm pipelines keep saves
            # in flight across calls; drain_saves()/shutdown() syncs.
            self.drain_saves()
        return outputs

    def shutdown(self):
        """Drain outstanding saves and stop the pool if owned (main
        thread; blocking)."""
        self.drain_saves()
        if self._owns_pool:
            self.pool.shutdown()


class _StageView:
    """One stage's view of a global model: the child scheduler hands it
    stage-local unit indices, the wrapped model speaks global ones.
    Non-final stages return ``(activation, t_ready)`` from ``finalize``
    so the downstream stage's activation provider can advance its own
    virtual clock to the handoff point (real pools carry no virtual
    clock; the timestamp is then unused)."""

    def __init__(self, model, base: int, final: bool, clock=None):
        self._m = model
        self._b = base
        self._final = final
        self._clock = clock
        b = base
        # byte-accounting hooks are optional on models; mirror exactly the
        # ones present so generate()'s getattr probes see the same surface
        if hasattr(model, "weight_nbytes"):
            self.weight_nbytes = lambda j: model.weight_nbytes(b + j)
        if hasattr(model, "kv_nbytes"):
            self.kv_nbytes = lambda i, j: model.kv_nbytes(i, b + j)
        if hasattr(model, "kv_extent"):
            self.kv_extent = lambda i, j: model.kv_extent(i, b + j)
        if hasattr(model, "kv_save_nbytes"):
            self.kv_save_nbytes = \
                lambda i, j: model.kv_save_nbytes(i, b + j)

    def is_mha(self, j):
        return self._m.is_mha(self._b + j)

    def load_weights(self, j):
        return self._m.load_weights(self._b + j)

    def release_weights(self, j, handle):
        return self._m.release_weights(self._b + j, handle)

    def load_kv(self, i, j):
        return self._m.load_kv(i, self._b + j)

    def save_kv(self, i, j, new_kv):
        return self._m.save_kv(i, self._b + j, new_kv)

    def compute(self, i, j, x, weights, kv):
        return self._m.compute(i, self._b + j, x, weights, kv)

    def finalize(self, it, x):
        if self._final:
            return self._m.finalize(it, x)
        t = self._clock.now() if self._clock is not None else 0.0
        return (x, t)


class StagedScheduler:
    """Pipeline-parallel composition of per-stage Algorithm-1 schedulers.

    The layer stack is split into contiguous stages; each stage owns its
    OWN scheduler, transfer pool, and (on the engines) tiered stores —
    so every stage streams only its slice and aggregate link bandwidth
    scales with stage count.  Microbatched activations hand stage to
    stage: stage ``s+1`` computes microbatch ``m`` while stage ``s``
    computes ``m+1`` and both overlap their own WEIGHT/KV loads.

    On the virtual harness each stage's pool carries its own
    ``VirtualClock`` over ONE shared ``Trace`` (all clocks start at the
    trace origin): stages execute sequentially in wall order, but the
    downstream provider advances its stage clock to
    ``max(own time, upstream handoff time)`` — exactly the pipeline
    recurrence — so overlap, fill/drain bubbles, and per-stage residency
    are all assertable on virtual timestamps.  Task names use GLOBAL
    unit indices (``unit_base``), every task carries its ``stage`` tag,
    and ``meta`` records ``stages``/``stage_units``/``stage_depths`` so
    ``core.replay`` can rebuild the staged run.

    ``handoff(stage, it, x)`` is the activation-transport seam: identity
    here (queue handoff); the staged serving engine overrides it with a
    device-to-device ``device_put`` on a real mesh.
    """

    def __init__(self, stage_units, mode: str = "performance", pools=None,
                 trace: Optional[Trace] = None, warm: bool = False,
                 depths=None):
        units = [(int(lo), int(hi)) for lo, hi in stage_units]
        assert units and all(lo < hi for lo, hi in units), units
        assert units[0][0] == 0 and all(
            units[s][1] == units[s + 1][0] for s in range(len(units) - 1)), \
            f"stages must tile the stack contiguously: {units}"
        self.stage_units = units
        self.n = units[-1][1]
        self.mode = mode
        if depths is None:
            depths = [1] * len(units)
        if pools is None:
            pools = [None] * len(units)
        self.trace = trace or Trace()
        self.scheds = [
            PipelineScheduler(hi - lo, mode, pool=pools[s], trace=self.trace,
                              warm=warm, depth=depths[s], stage=s,
                              unit_base=lo)
            for s, (lo, hi) in enumerate(units)]
        self.warm = self.scheds[0].warm
        self.depths = [sc.depth for sc in self.scheds]
        self.depth = max(self.depths)
        # each child stamped the shared meta with its own local view (last
        # writer won); restamp the staged run as a whole
        self.trace.meta.update(
            mode=self.mode, warm=self.warm, depth=self.depth,
            n_units=self.n,
            pool_size=max(getattr(sc.pool, "n_workers", 0)
                          or PipelineScheduler.pool_size(sc.depth)
                          for sc in self.scheds),
            stages=len(self.scheds),
            stage_units=[list(u) for u in units],
            stage_depths=list(self.depths))
        self.trace.meta.setdefault("calls", [])

    # -- activation transport (override on real meshes) ---------------------
    def handoff(self, stage: int, it: int, x):
        """Move microbatch ``it``'s activation onto stage ``stage``:
        identity queue-handoff here; the staged engine device_puts."""
        return x

    @property
    def _iter0(self) -> int:
        """Global iteration base (all stages advance in lockstep — the
        serving engines read this to anchor their live decode view)."""
        return self.scheds[0]._iter0

    def prime_weights(self, model, count: Optional[int] = None) -> int:
        """Fan ``prime_weights`` out to every stage (each primes its own
        window through its stage view); returns total loads submitted."""
        last = len(self.scheds) - 1
        return sum(
            sc.prime_weights(
                _StageView(model, sc.unit_base, s == last,
                           getattr(sc.pool, "clock", None)), count)
            for s, sc in enumerate(self.scheds))

    # -- staged Algorithm 1 --------------------------------------------------
    def generate(self, model, x0, num_iterations: int):
        """Run ``num_iterations`` microbatches through every stage.  The
        model's callbacks use GLOBAL unit indices (each stage sees its
        slice through a ``_StageView``).  Blocks the calling thread;
        returns the final stage's outputs."""
        calls = self.trace.meta.setdefault("calls", [])
        mark = len(calls)                    # children append; collapse below
        outs = None
        for s, sched in enumerate(self.scheds):
            final = s == len(self.scheds) - 1
            clock = getattr(sched.pool, "clock", None)
            view = _StageView(model, sched.unit_base, final, clock)
            # all stages start streaming their first window at the current
            # stage-local time — never gated on upstream activations
            sched.prime_weights(view)
            if s == 0:
                prov = x0
            else:
                handed = outs

                def prov(it, _h=handed, _c=clock, _s=s):
                    x, t_ready = _h[it]
                    if isinstance(_c, VirtualClock):
                        _c.advance_to(t_ready)
                    return self.handoff(_s, it, x)
            outs = sched.generate(view, prov, num_iterations)
        # each child recorded the call; the staged run is ONE call
        del calls[mark:]
        calls.append(num_iterations)
        return outs

    # -- maintenance fan-out (main thread) -----------------------------------
    def set_depth(self, depth: int) -> int:
        """Uniform window re-size across stages (per-stage caps apply);
        returns the largest effective depth."""
        self.depths = [sc.set_depth(depth) for sc in self.scheds]
        self.depth = max(self.depths)
        self.trace.meta.update(depth=self.depth,
                               stage_depths=list(self.depths))
        return self.depth

    def drop_kv_preloads(self):
        for sc in self.scheds:
            sc.drop_kv_preloads()

    def drain_saves(self):
        for sc in self.scheds:
            sc.drain_saves()

    def shutdown(self):
        for sc in self.scheds:
            sc.shutdown()
