"""Memory-tier stores for offloading: Disk (np.memmap files), Host (RAM
arrays), Device (jax arrays).

On this container the "device" is the CPU jax backend, but the tier
*structure* and data movement are real: DiskStore does real file I/O,
HostStore holds pinned numpy buffers, DeviceStore jax Arrays.  On TPU the
same interfaces map to (remote store / host DRAM / HBM).  Every store
tracks bytes for the Table-6 memory-footprint benchmark.
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Optional

import jax
import numpy as np


class Store:
    name = "base"

    def __init__(self):
        self._items: Dict[str, object] = {}
        self._bytes = 0
        self._peak = 0
        self._lock = threading.Lock()

    def _account(self, delta: int):
        with self._lock:
            self._bytes += delta
            self._peak = max(self._peak, self._bytes)

    @property
    def bytes_used(self) -> int:
        return self._bytes

    @property
    def peak_bytes(self) -> int:
        return self._peak

    def keys(self):
        return list(self._items)

    def __contains__(self, key):
        return key in self._items

    def delete(self, key: str):
        item = self._items.pop(key, None)
        if item is not None:
            self._account(-self._nbytes(item))

    @staticmethod
    def _nbytes(x) -> int:
        return int(getattr(x, "nbytes", 0))


class HostStore(Store):
    """CPU-memory tier: numpy arrays."""

    name = "host"

    def put(self, key: str, arr: np.ndarray):
        arr = np.asarray(arr)
        if key in self._items:
            self.delete(key)
        self._items[key] = arr
        self._account(arr.nbytes)
        return arr

    def get(self, key: str) -> np.ndarray:
        return self._items[key]


class DeviceStore(Store):
    """Device (HBM analogue) tier: jax Arrays."""

    name = "device"

    def put(self, key: str, arr):
        arr = jax.device_put(arr)
        if key in self._items:
            self.delete(key)
        arr.block_until_ready()
        self._items[key] = arr
        self._account(arr.nbytes)
        return arr

    def get(self, key: str):
        return self._items[key]


class DiskStore(Store):
    """NVMe tier: one file per tensor under ``root``; reads go through
    np.fromfile on a preopened path (real disk I/O on this container)."""

    name = "disk"

    def __init__(self, root: str):
        super().__init__()
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._meta: Dict[str, tuple] = {}

    def _path(self, key: str) -> Path:
        return self.root / (key.replace("/", "_") + ".bin")

    def put(self, key: str, arr: np.ndarray):
        arr = np.ascontiguousarray(arr)
        path = self._path(key)
        arr.tofile(path)
        self._meta[key] = (arr.shape, arr.dtype)
        self._items[key] = path
        self._account(arr.nbytes)
        return path

    def meta(self, key: str):
        return self._meta[key]

    def get(self, key: str) -> np.ndarray:
        shape, dtype = self._meta[key]
        out = np.fromfile(self._path(key), dtype=dtype)
        return out.reshape(shape)

    def read_range(self, key: str, offset_bytes: int, size_bytes: int,
                   out: np.ndarray):
        """Read a byte range into a preallocated buffer (blockwise path)."""
        with open(self._path(key), "rb", buffering=0) as f:
            f.seek(offset_bytes)
            data = f.read(size_bytes)
        flat = out.reshape(-1).view(np.uint8)
        flat[offset_bytes:offset_bytes + len(data)] = np.frombuffer(
            data, np.uint8)
        return len(data)

    def drop_cache(self, key: str):
        """Evict the file from the OS page cache (POSIX_FADV_DONTNEED) so
        benchmarks measure real disk reads, not memcpy — the paper's NVMe
        regime."""
        try:
            with open(self._path(key), "rb") as f:
                os.fsync(f.fileno())
                os.posix_fadvise(f.fileno(), 0, 0, os.POSIX_FADV_DONTNEED)
            return True
        except (OSError, AttributeError):
            return False


@dataclass
class MemoryBudget:
    """Tier capacities for autoconfig (bytes)."""
    device: int = 6 * 2**30        # paper laptop: RTX3060 6GB
    host: int = 16 * 2**30         # 16GB DRAM
    disk: int = 1 * 2**40          # 1TB SSD
    device_bw: float = 12e9        # PCIe x8-ish GPU link (B/s)
    disk_bw: float = 3.5e9         # NVMe read bw (B/s)
