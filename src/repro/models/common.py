"""Shared model utilities: distribution context, norms, online-softmax math.

The ``Dist`` context makes every model function runnable in two worlds:
  * ``Dist.local()`` — no mesh; all collectives degenerate to identity.
    Used by CPU smoke tests and as the numerical oracle.
  * a real mesh — the same code routes through ``shard_map`` islands
    (ring attention, flash-decode, EP all-to-all, sharded CE).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Dist:
    """Distribution context threaded through every model function."""

    mesh: Optional[Mesh] = None
    data_axes: tuple[str, ...] = ()      # batch axes, e.g. ("pod", "data")
    model_axis: Optional[str] = None     # TP/SP/EP axis ("model")
    # axes the decode KV cache's sequence dim is sharded over; defaults to
    # (model_axis,) — long_500k (batch 1) uses ("data", "model").
    kv_axes: tuple[str, ...] = ()

    @staticmethod
    def local() -> "Dist":
        return Dist()

    @property
    def is_dist(self) -> bool:
        return self.mesh is not None

    @property
    def model_size(self) -> int:
        if not self.is_dist or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def kv_shard_axes(self) -> tuple[str, ...]:
        if self.kv_axes:
            return self.kv_axes
        return (self.model_axis,) if self.model_axis else ()

    def kv_shards(self) -> int:
        n = 1
        for a in self.kv_shard_axes:
            n *= self.mesh.shape[a]
        return n

    def constrain(self, x, *spec):
        """``with_sharding_constraint`` that no-ops locally."""
        if not self.is_dist:
            return x
        return lax.with_sharding_constraint(x, NamedSharding(self.mesh, P(*spec)))

    def sharding(self, *spec) -> Optional[NamedSharding]:
        if not self.is_dist:
            return None
        return NamedSharding(self.mesh, P(*spec))


# ---------------------------------------------------------------------------
# Axis-optional collectives (identity when axis is None) — lets shard_map
# bodies double as single-device reference implementations.
# ---------------------------------------------------------------------------

def psum(x, axis):
    return lax.psum(x, axis) if axis else x


def pmax(x, axis):
    """pmax for softmax/logsumexp stabilization.  jax has no differentiation
    rule for lax.pmax, but every use here stabilizes an exp() whose final
    value is exactly invariant to the max — so stop_gradient is exact."""
    return lax.pmax(lax.stop_gradient(x), axis) if axis else x


def pmean(x, axis):
    return lax.pmean(x, axis) if axis else x


def _one_axis_size(a) -> int:
    """``lax.axis_size`` with a jax<0.6 fallback: psum of the constant 1
    constant-folds to the (static) axis size under shard_map."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(a)
    return lax.psum(1, a)


def axis_index(axis):
    if not axis:
        return jnp.int32(0)
    if isinstance(axis, (tuple, list)):
        idx = jnp.int32(0)
        for a in axis:
            idx = idx * _one_axis_size(a) + lax.axis_index(a)
        return idx
    return lax.axis_index(axis)


def axis_size(axis):
    if not axis:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= _one_axis_size(a)
        return n
    return _one_axis_size(axis)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def silu(x):
    return x * jax.nn.sigmoid(x)


def softplus(x):
    return jax.nn.softplus(x)


def swiglu(x, w_gate, w_up, w_down):
    g = x @ w_gate
    u = x @ w_up
    return (silu(g) * u) @ w_down


# ---------------------------------------------------------------------------
# Online-softmax partials: the algebra shared by flash attention, ring
# attention, and the cross-shard decode merge.  A partial is (m, l, o):
#   m = running max of scores, l = sum exp(score - m), o = sum exp(..) * v
# (o unnormalized).  ``merge_partials`` is associative & commutative —
# property-tested in tests/test_properties.py.
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def merge_partials(a, b):
    m_a, l_a, o_a = a
    m_b, l_b, o_b = b
    m = jnp.maximum(m_a, m_b)
    ca = jnp.exp(m_a - m)
    cb = jnp.exp(m_b - m)
    l = l_a * ca + l_b * cb
    o = o_a * ca[..., None] + o_b * cb[..., None]
    return m, l, o


def finalize_partials(m, l, o):
    return o / jnp.maximum(l, 1e-30)[..., None]


def empty_partials(shape_ml, d, dtype=jnp.float32):
    m = jnp.full(shape_ml, NEG_INF, dtype)
    l = jnp.zeros(shape_ml, dtype)
    o = jnp.zeros((*shape_ml, d), dtype)
    return m, l, o


def _abstract_type(x):
    """``jax.typeof`` with a fallback for jax < 0.6 (no ``typeof``; avals
    there carry no ``vma`` either, so callers degrade to a no-op)."""
    typeof = getattr(jax, "typeof", None)
    if typeof is not None:
        return typeof(x)
    return jax.core.get_aval(x)


def match_vma(x, like):
    """Promote x's varying-manual-axes to match ``like`` (shard_map carries).

    Under shard_map, loop carries initialized with jnp.zeros are 'unvarying'
    while computed outputs vary over the mapped axes; lax.fori_loop/scan then
    reject the carry.  No-op outside shard_map (and on jax versions without
    the vma machinery).
    """
    vma = getattr(_abstract_type(like), "vma", None)
    if not vma:
        return x
    def fix(t):
        cur = getattr(_abstract_type(t), "vma", frozenset())
        missing = tuple(sorted(vma - cur))
        if not missing:
            return t
        try:
            return lax.pcast(t, missing, to="varying")
        except (AttributeError, TypeError):
            return lax.pvary(t, missing)
    return jax.tree.map(fix, x)


def init_leaf(key, shape, scale: float, dtype):
    if scale == 0.0:
        return jnp.zeros(shape, dtype)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
