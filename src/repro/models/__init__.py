from repro.models.common import Dist
from repro.models.model import Model, build_model

__all__ = ["Dist", "Model", "build_model"]
