"""Attention: reference oracle, blocked partials, ring attention (shard_map),
distributed flash-decode over a sequence-sharded KV cache, MLA variants,
and rolling-window decode.

Layout convention: activations are BSHD — q: (b, sq, h, dh), k/v:
(b, sk, hkv, dh).  GQA is handled by grouping q heads over kv heads.

Distribution story (the PIPO mapping): the KV cache is sharded along
*sequence* across the `model` (or `data`+`model`) mesh axes — the TPU
analogue of PIPO keeping the KV cache "elsewhere" (CPU DRAM) and moving
only what compute needs.  Instead of shipping the cache to the compute
(PIPO's KV-load task), each shard computes *partial* attention locally and
ships only (m, l, o) softmax partials — a few KB — through one psum
(decode) or rotates KV blocks through the ICI ring overlapped with compute
(prefill), which is the paper's pipeline discipline rendered in collectives.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import (NEG_INF, axis_index, axis_size,
                                 empty_partials, finalize_partials,
                                 match_vma, merge_partials, pmax, psum)

# ---------------------------------------------------------------------------
# Reference oracle (pure jnp, materializes the full score matrix).
# ---------------------------------------------------------------------------


def _mask(q_pos, kv_pos, causal: bool, window: int):
    """(sq, sk) boolean mask; True = attend."""
    dq = q_pos[:, None]
    dk = kv_pos[None, :]
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= dk <= dq
    if window:
        m &= dq - dk < window
    return m


def ref_attention(q, k, v, *, causal=True, window=0, q_offset=0, kv_offset=0,
                  kv_valid_len=None, softcap: float = 0.0):
    """Oracle attention.  q: (b,sq,h,dh); k,v: (b,sk,hkv,dv)."""
    b, sq, h, dh = q.shape
    _, sk, hkv, dv = v.shape
    g = h // hkv
    qr = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qr, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = q_offset + jnp.arange(sq)
    kv_pos = kv_offset + jnp.arange(sk)
    m = _mask(q_pos, kv_pos, causal, window)
    if kv_valid_len is not None:
        m = m & (kv_pos < kv_valid_len)[None, :]
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, dv)


# ---------------------------------------------------------------------------
# Blocked partials: one (q-block x kv-block) tile -> online-softmax partials.
# ---------------------------------------------------------------------------


def attn_partials(q, k, v, mask, *, softcap: float = 0.0, q_chunk: int = 0):
    """Partials (m, l, o) in fp32.  mask: (sq, sk) or (b, sq, sk) bool or
    None — the batched form supports ragged decode positions.

    q_chunk > 0 bounds the transient score matrix to (..., q_chunk, sk)
    via lax.map over query chunks.
    """
    b, sq, h, dh = q.shape
    _, sk, hkv, dv = v.shape
    g = h // hkv

    def block(args):
        qc, mc = args           # (b, c, h, dh), ([b,] c, sk)
        c = qc.shape[1]
        qr = qc.reshape(b, c, hkv, g, dh)
        s = jnp.einsum("bqhgd,bshd->bhgqs", qr, k,
                       preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(jnp.float32(dh))
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        if mc is not None:
            mb = mc[None, None, None] if mc.ndim == 2 \
                else mc[:, None, None]
            s = jnp.where(mb, s, NEG_INF)
        m = jnp.max(s, axis=-1)                       # (b,hkv,g,c)
        p = jnp.exp(s - m[..., None])
        # rows that are fully masked: keep l = 0, o = 0
        dead = m <= NEG_INF / 2
        p = jnp.where(dead[..., None], 0.0, p)
        m = jnp.where(dead, NEG_INF, m)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(v.dtype), v)
        mm = m.reshape(b, h, c)
        ll = l.reshape(b, h, c)
        oo = o.astype(jnp.float32).reshape(b, h, c, dv)
        return mm, ll, oo

    if q_chunk and sq > q_chunk and sq % q_chunk == 0:
        n = sq // q_chunk
        qs = jnp.moveaxis(q.reshape(b, n, q_chunk, h, dh), 1, 0)
        ms = None if mask is None else mask.reshape(n, q_chunk, sk)
        if ms is None:
            mm, ll, oo = lax.map(lambda qc: block((qc, None)), qs)
        else:
            mm, ll, oo = lax.map(block, (qs, ms))
        # (n, b, h, c[, d]) -> (b, h, sq[, d])
        m = jnp.moveaxis(mm, 0, 2).reshape(b, h, sq)
        l = jnp.moveaxis(ll, 0, 2).reshape(b, h, sq)
        o = jnp.moveaxis(oo, 0, 2).reshape(b, h, sq, dv)
        return m, l, o
    return block((q, mask))


# ---------------------------------------------------------------------------
# Ring attention (train/prefill) — call inside shard_map over `axis` with the
# sequence dim sharded.  axis=None degenerates to single-block flash == oracle.
# ---------------------------------------------------------------------------


def ring_attention(q, k, v, *, axis: Optional[str], causal=True, window=0,
                   softcap: float = 0.0, q_chunk: int = 512):
    b, sq, h, dh = q.shape
    _, sk, hkv, dv = v.shape
    P = axis_size(axis)
    i = axis_index(axis)
    q_pos = i * sq + jnp.arange(sq)

    # Number of ring steps actually needed: a windowed causal layer only
    # sees ceil(window/sk)+1 blocks back; full attention needs all P.
    if window:
        steps = min(P, -(-window // sk) + 1)
    else:
        steps = P

    def one_step(t, carry):
        (m, l, o), kc, vc = carry
        j = (i - t) % P
        kv_pos = j * sk + jnp.arange(sk)
        msk = _mask(q_pos, kv_pos, causal, window)
        pm, pl, po = attn_partials(q, kc, vc, msk, softcap=softcap,
                                   q_chunk=q_chunk)
        m, l, o = merge_partials((m, l, o), (pm, pl, po))
        if axis is not None and steps > 1:
            perm = [(s, (s + 1) % P) for s in range(P)]
            kc = lax.ppermute(kc, axis, perm)
            vc = lax.ppermute(vc, axis, perm)
        return (m, l, o), kc, vc

    carry = (match_vma(empty_partials((b, h, sq), dv), q), k, v)
    if steps <= 1:
        carry = one_step(0, carry)
    else:
        carry = lax.fori_loop(0, steps, one_step, carry, unroll=False)
    (m, l, o), _, _ = carry
    out = finalize_partials(m, l, o)                  # (b, h, sq, dv)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)    # -> (b, sq, h, dv)


def chunk_prefill_attention(q, k, v, *, q_offset: int, softcap: float = 0.0,
                            q_chunk: int = 512):
    """Prefill-chunk attention: the chunk's fresh queries (global
    positions ``q_offset .. q_offset+sq-1``) attend causally over the
    full running prefix ``k``/``v`` (``sk = q_offset + sq`` rows: the
    engine-held fresh K/V of earlier chunks plus this chunk's own).

    Single-device mirror of ``ring_attention(axis=None)`` with the query
    positions offset: with ``q_offset=0`` (and ``sk == sq``) it IS the
    monolithic prefill path, bit for bit — and for a later chunk each
    query row sees exactly the columns the monolithic pass left unmasked
    for it, so per-row partials (m, l, o) match the monolithic pass
    exactly (masked tail columns contribute exact zeros).  That row
    identity is what makes chunked prefill token parity a theorem rather
    than a tolerance."""
    b, sq, h, dh = q.shape
    _, sk, hkv, dv = v.shape
    q_pos = q_offset + jnp.arange(sq)
    kv_pos = jnp.arange(sk)
    msk = _mask(q_pos, kv_pos, True, 0)
    pm, pl, po = attn_partials(q, k, v, msk, softcap=softcap,
                               q_chunk=q_chunk)
    m, l, o = merge_partials(
        match_vma(empty_partials((b, h, sq), dv), q), (pm, pl, po))
    out = finalize_partials(m, l, o)                  # (b, h, sq, dv)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)    # -> (b, sq, h, dv)


def mla_ring_attention(q, c, kr, w_uk, w_uv, *, axis: Optional[str],
                       q_chunk: int = 256):
    """MLA-aware ring attention (beyond-paper, §Perf C1).

    The generic ring rotates the *expanded* per-head K/V
    (h*(d_nope+d_rope+d_v) = 40960 dims/token for deepseek-v3); MLA's whole
    point is that tokens compress to a 576-dim latent.  Rotating (c, k_rope)
    and expanding through W_uk/W_uv locally per ring step cuts ppermute
    bytes ~71x for ~1.6x attention-region FLOPs (expansion einsums), which
    the napkin math and the §Perf log show is a large net win at pod scale.

    q: (b, sq, h, dn+dr) — nope||rope; c: (b, sk, r); kr: (b, sk, dr);
    w_uk: (r, h, dn); w_uv: (r, h, dv).
    """
    b, sq, h, dq = q.shape
    _, sk, r = c.shape
    dr = kr.shape[-1]
    dn = dq - dr
    dv = w_uv.shape[-1]
    P = axis_size(axis)
    i = axis_index(axis)
    q_pos = i * sq + jnp.arange(sq)

    def expand(c_blk, kr_blk):
        k_nope = jnp.einsum("bsr,rhn->bshn", c_blk, w_uk)
        v = jnp.einsum("bsr,rhv->bshv", c_blk, w_uv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_blk[:, :, None, :],
                                      (b, sk, h, dr))], axis=-1)
        return k, v

    def one_step(t, carry):
        (m, l, o), c_cur, kr_cur = carry
        j = (i - t) % P
        kv_pos = j * sk + jnp.arange(sk)
        msk = _mask(q_pos, kv_pos, True, 0)
        k, v = expand(c_cur, kr_cur)
        pm, pl, po = attn_partials(q, k, v, msk, q_chunk=q_chunk)
        m, l, o = merge_partials((m, l, o), (pm, pl, po))
        if axis is not None and P > 1:
            perm = [(s, (s + 1) % P) for s in range(P)]
            c_cur = lax.ppermute(c_cur, axis, perm)
            kr_cur = lax.ppermute(kr_cur, axis, perm)
        return (m, l, o), c_cur, kr_cur

    carry = (match_vma(empty_partials((b, h, sq), dv), q), c, kr)
    if P <= 1:
        carry = one_step(0, carry)
    else:
        carry = lax.fori_loop(0, P, one_step, carry, unroll=False)
    (m, l, o), _, _ = carry
    return jnp.moveaxis(finalize_partials(m, l, o), 1, 2).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode over a sequence-sharded KV cache (distributed flash-decode).
# Call inside shard_map; axes=() degenerates to the local single-shard case.
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, k_new, v_new, pos, *, axes=(),
                     softcap: float = 0.0):
    """q: (b, sq=1, h, dh); caches (b, S_loc, hkv, dh); k_new/v_new
    (b, 1, hkv, dh); pos: scalar int32 OR (b,) ragged positions (each
    sequence writes/attends its own position — continuous batching).
    Returns (out (b,1,h,dv), k_cache', v_cache')."""
    b, S_loc, hkv, dh = k_cache.shape
    i = axis_index(axes)
    ragged = jnp.ndim(pos) == 1
    owner = pos // S_loc
    loc = pos - owner * S_loc
    is_owner = (i == owner)

    if ragged:
        rows = jnp.arange(b)

        def write(cache, new):
            upd = cache.at[rows, loc].set(
                jnp.where(is_owner[:, None, None], new[:, 0],
                          cache[rows, loc]).astype(cache.dtype))
            return upd
    else:
        def write(cache, new):
            # O(1) ownership select: read back the 1-token slice and choose
            # between it and the new KV — NOT a full-cache where() (which
            # costs a cache-sized copy per layer; found via the §Perf
            # profile: 2 x 5.5 GB/layer on qwen2-vl decode).
            old = lax.dynamic_slice(cache, (0, loc, 0, 0),
                                    (cache.shape[0], 1, *cache.shape[2:]))
            val = jnp.where(is_owner, new.astype(cache.dtype), old)
            return lax.dynamic_update_slice(cache, val, (0, loc, 0, 0))

    k_cache = write(k_cache, k_new)
    v_cache = write(v_cache, v_new)

    kv_pos = i * S_loc + jnp.arange(S_loc)
    if ragged:
        valid = (kv_pos[None, :] <= pos[:, None])[:, None, :]  # (b,1,S)
    else:
        valid = (kv_pos <= pos)[None, :]             # (1=sq, S_loc)
    m, l, o = attn_partials(q, k_cache, v_cache, valid, softcap=softcap)
    # merge across shards: tiny psum of partials, not the cache
    if axes:
        M = pmax(m, axes)
        scale = jnp.exp(m - M)
        l = psum(l * scale, axes)
        o = psum(o * scale[..., None], axes)
        m = M
    out = jnp.moveaxis(finalize_partials(m, l, o), 1, 2).astype(q.dtype)
    return out, k_cache, v_cache


def spec_decode_attention(q, k_cache, v_cache, k_new, v_new, pos, *,
                          kv_roundtrip=None, softcap: float = 0.0):
    """Multi-position decode for the speculative verify pass: one step
    appends ``s`` new rows per sequence (the current token plus the
    draft's proposals) and attends each query position through its own
    causal prefix.  q: (b, s, h, dh); caches (b, S, hkv, dh); k_new/v_new
    (b, s, hkv, dh); pos: scalar int32 or (b,) ragged — the position of
    the FIRST new token (query t writes/attends position ``pos + t``).

    Each query runs as its own (b, 1, S) ``attn_partials`` call — the
    exact shape and reduction structure of the sequential ragged
    ``decode_attention`` path — against exactly the rows sequential
    decode would see: the loaded prefix, the pass's earlier new rows,
    and its OWN row fresh.  Under a lossy KV tier the distinction
    matters: between sequential steps rows pos..pos+t-1 round-trip the
    host store, so ``kv_roundtrip`` (e.g. ``kvstore.
    kv_roundtrip_traceable`` for kv_mode='int4') is applied to the new
    rows every LATER query attends, while each query's own row stays
    fresh — precisely the sequential write-then-attend semantics.  The
    returned caches hold the fresh rows: the save path quantizes them
    once, exactly as sequential decode would.  Returns (out (b,s,h,dv),
    k_cache', v_cache')."""
    b, s, hkv, dh = k_new.shape
    S = k_cache.shape[1]
    p0 = pos if jnp.ndim(pos) == 1 else jnp.broadcast_to(pos, (b,))
    rowsb = jnp.arange(b)
    rows = rowsb[:, None]                             # (b, 1)
    locs = p0[:, None] + jnp.arange(s)[None, :]       # (b, s)
    kn = k_new.astype(k_cache.dtype)
    vn = v_new.astype(v_cache.dtype)
    k_out = k_cache.at[rows, locs].set(kn)
    v_out = v_cache.at[rows, locs].set(vn)
    lossy = kv_roundtrip is not None and s > 1
    if lossy:
        k_att = k_cache.at[rows, locs].set(kv_roundtrip(kn))
        v_att = v_cache.at[rows, locs].set(kv_roundtrip(vn))
    else:
        k_att, v_att = k_out, v_out
    kv_pos = jnp.arange(S)
    outs = []
    for t in range(s):
        loc_t = locs[:, t]
        if lossy:
            kc = k_att.at[rowsb, loc_t].set(kn[:, t])
            vc = v_att.at[rowsb, loc_t].set(vn[:, t])
        else:
            kc, vc = k_att, v_att
        valid = (kv_pos[None, :] <= loc_t[:, None])[:, None, :]  # (b,1,S)
        m, l, o = attn_partials(q[:, t:t + 1], kc, vc, valid,
                                softcap=softcap)
        outs.append(jnp.moveaxis(finalize_partials(m, l, o), 1, 2))
    out = jnp.concatenate(outs, axis=1).astype(q.dtype)
    return out, k_out, v_out


def local_decode_attention(q, k_cache, v_cache, k_new, v_new, pos, window):
    """Rolling-buffer decode for sliding-window layers; cache (b, W, hkv, dh)
    replicated (W is small).  Slot j holds position pos - ((pos - j) mod W).
    pos: scalar or (b,) ragged."""
    b, W, hkv, dh = k_cache.shape
    slot = pos % W
    j = jnp.arange(W)
    if jnp.ndim(pos) == 1:
        rows = jnp.arange(b)
        k_cache = k_cache.at[rows, slot].set(k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, slot].set(v_new[:, 0].astype(v_cache.dtype))
        p_j = pos[:, None] - ((pos[:, None] - j[None]) % W)
        valid = (p_j >= 0)[:, None, :]               # (b, 1, W)
    else:
        k_cache = lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
        p_j = pos - ((pos - j) % W)
        valid = (p_j >= 0)[None, :]
    m, l, o = attn_partials(q, k_cache, v_cache, valid)
    out = jnp.moveaxis(finalize_partials(m, l, o), 1, 2).astype(q.dtype)
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek): decode over the sequence-sharded *latent* cache.
# ---------------------------------------------------------------------------


def mla_decode_attention(q_eff, q_rope, c_cache, kr_cache, c_new, kr_new,
                         pos, *, scale, axes=()):
    """q_eff: (b, 1, h, r) — q_nope absorbed through W_uk;
    q_rope: (b, 1, h, dr); c_cache: (b, S_loc, r); kr_cache: (b, S_loc, dr);
    c_new: (b, 1, r); kr_new: (b, 1, dr).
    Returns (ctx_latent (b,1,h,r), c_cache', kr_cache')."""
    b, S_loc, r = c_cache.shape
    i = axis_index(axes)
    ragged = jnp.ndim(pos) == 1
    owner = pos // S_loc
    loc = pos - owner * S_loc
    is_owner = (i == owner)

    if ragged:
        rows = jnp.arange(b)

        def write(cache, new):
            return cache.at[rows, loc].set(
                jnp.where(is_owner[:, None], new[:, 0],
                          cache[rows, loc]).astype(cache.dtype))
    else:
        def write(cache, new):
            # O(1) ownership select (see decode_attention.write)
            old = lax.dynamic_slice(cache, (0, loc, 0),
                                    (cache.shape[0], 1, cache.shape[2]))
            val = jnp.where(is_owner, new.astype(cache.dtype), old)
            return lax.dynamic_update_slice(cache, val, (0, loc, 0))

    c_cache = write(c_cache, c_new)
    kr_cache = write(kr_cache, kr_new)

    kv_pos = i * S_loc + jnp.arange(S_loc)
    s = (jnp.einsum("bqhr,bsr->bhqs", q_eff, c_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhd,bsd->bhqs", q_rope, kr_cache,
                      preferred_element_type=jnp.float32)) * scale
    if ragged:
        valid = kv_pos[None, :] <= pos[:, None]       # (b, S_loc)
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        valid = valid[:, None, None]
    else:
        valid = kv_pos <= pos                         # (S_loc,)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    dead = m <= NEG_INF / 2
    p = jnp.where(dead[..., None], 0.0, p)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqs,bsr->bhqr", p.astype(c_cache.dtype),
                   c_cache).astype(jnp.float32)
    if axes:
        M = pmax(m, axes)
        sc = jnp.exp(m - M)
        l = psum(l * sc, axes)
        o = psum(o * sc[..., None], axes)
    ctx = (o / jnp.maximum(l, 1e-30)[..., None])      # (b,h,1,r)
    return jnp.moveaxis(ctx, 2, 1), c_cache, kr_cache  # (b,1,h,r)
