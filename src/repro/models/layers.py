"""Layer parameter tables + apply functions.

Every parameter is declared once in a *table*: ``name -> ParamDef(shape,
axes, scale)`` where ``axes`` are logical axis names ("vocab", "ff",
"experts", "heads", "embed", ...).  The same table drives init (shapes),
sharding (logical->mesh rules in launch/sharding.py), and checkpointing
(leaf paths are stable).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, ATTN_LOCAL, CROSS, DENSE, ENC, MLA, MOE,
                                SSM, LayerSpec, ModelConfig)
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (NEG_INF, Dist, axis_index, psum, pmax,
                                 rms_norm, silu)
from repro.models.rope import apply_rope, rope_angles


class ParamDef(NamedTuple):
    shape: tuple
    axes: tuple          # logical axis names, len == len(shape)
    scale: float = -1.0  # -1 -> fan-in default; 0 -> zeros


def _fan_in(shape):
    return 1.0 / math.sqrt(max(1, shape[0]))


# ===========================================================================
# Parameter tables
# ===========================================================================


QUANT_GROUP = 128


def _maybe_quant(cfg: ModelConfig, table: dict) -> dict:
    """Replace eligible 2-D ParamDefs with INT4 packed + scale pairs
    (paper's W4; dequant is VREG-fused, see kernels/int4_matmul.py)."""
    if not cfg.quant_weights:
        return table
    out = {}
    for name, pd in table.items():
        K = pd.shape[0] if pd.shape else 0
        if (len(pd.shape) == 2 and K % QUANT_GROUP == 0
                and pd.shape[1] % 2 == 0 and K * pd.shape[1] >= 1 << 16):
            out[name + "#q"] = ParamDef((K, pd.shape[1] // 2),
                                        (pd.axes[0], pd.axes[1]), -2.0)
            out[name + "#s"] = ParamDef((K // QUANT_GROUP, pd.shape[1]),
                                        (None, pd.axes[1]), -3.0)
        else:
            out[name] = pd
    return out


def _mm(xn, p, name):
    """x @ W with transparent INT4-packed weights: the dequant runs under a
    ``vreg_fused_int4`` scope — the roofline analyzer maps it to the Pallas
    kernel's traffic model (packed bytes cross HBM; fp weights live in
    VREGs only).  Validated against the kernel in tests/test_kernels.py."""
    if name + "#q" in p:
        with jax.named_scope("vreg_fused_int4"):
            from repro.quant.int4 import dequantize_int4
            w = dequantize_int4(p[name + "#q"], p[name + "#s"], xn.dtype,
                                QUANT_GROUP)
        return xn @ w
    return xn @ p[name]


def attn_table(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pre = "c" if cross else ""
    t = {
        pre + "wq": ParamDef((d, h * dh), ("embed", "heads_ff")),
        pre + "wk": ParamDef((d, hkv * dh), ("embed", "kv_ff")),
        pre + "wv": ParamDef((d, hkv * dh), ("embed", "kv_ff")),
        pre + "wo": ParamDef((h * dh, d), ("heads_ff", "embed")),
    }
    t = _maybe_quant(cfg, t)
    if cfg.qk_norm and not cross:
        t["q_norm"] = ParamDef((dh,), (None,), 0.0)
        t["k_norm"] = ParamDef((dh,), (None,), 0.0)
    return t


def mla_table(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dq = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ParamDef((d, m.q_lora_rank), ("embed", "lora")),
        "q_a_norm": ParamDef((m.q_lora_rank,), (None,), 0.0),
        "wq_b": ParamDef((m.q_lora_rank, h * dq), ("lora", "heads_ff")),
        "wkv_a": ParamDef((d, m.kv_lora_rank + m.qk_rope_head_dim),
                          ("embed", "lora")),
        "kv_a_norm": ParamDef((m.kv_lora_rank,), (None,), 0.0),
        "w_uk": ParamDef((m.kv_lora_rank, h, m.qk_nope_head_dim),
                         ("lora", "heads", None)),
        "w_uv": ParamDef((m.kv_lora_rank, h, m.v_head_dim),
                         ("lora", "heads", None)),
        "wo": ParamDef((h * m.v_head_dim, d), ("heads_ff", "embed")),
    }


def ssm_table(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    conv_ch = d_in + 2 * gn
    return {
        "z_proj": ParamDef((d, d_in), ("embed", "ff")),
        "x_proj": ParamDef((d, d_in), ("embed", "ff")),
        "bc_proj": ParamDef((d, 2 * gn), ("embed", None)),
        "dt_proj": ParamDef((d, H), ("embed", "heads")),
        "conv_w": ParamDef((s.d_conv, conv_ch), (None, "ff")),
        "conv_b": ParamDef((conv_ch,), ("ff",), 0.0),
        "A_log": ParamDef((H,), ("heads",), 1.0),
        "D": ParamDef((H,), ("heads",), 1.0),
        "dt_bias": ParamDef((H,), ("heads",), 1.0),
        "ssm_norm": ParamDef((d_in,), ("ff",), 0.0),
        "out_proj": ParamDef((d_in, d), ("ff", "embed")),
    }


def ffn_table(cfg: ModelConfig, spec: LayerSpec) -> dict:
    d = cfg.d_model
    if spec.ffn == DENSE:
        if cfg.d_ff == 0:
            return {}
        return _maybe_quant(cfg, {
            "w_gate": ParamDef((d, cfg.d_ff), ("embed", "ff")),
            "w_up": ParamDef((d, cfg.d_ff), ("embed", "ff")),
            "w_down": ParamDef((cfg.d_ff, d), ("ff", "embed")),
        })
    m = cfg.moe
    # experts sharded over `model` (EP); the per-expert ff dim is *storage*
    # sharded over `data` (ZeRO-3 flavor) — gathered just-in-time in the
    # train path, consumed as partial-sum slices in the decode path.
    t = {
        "wg": ParamDef((d, m.num_experts), ("embed", None)),
        "w_gate": ParamDef((m.num_experts, d, m.expert_d_ff),
                           ("experts", "embed", "expert_ff")),
        "w_up": ParamDef((m.num_experts, d, m.expert_d_ff),
                         ("experts", "embed", "expert_ff")),
        "w_down": ParamDef((m.num_experts, m.expert_d_ff, d),
                           ("experts", "expert_ff", "embed")),
    }
    if m.num_shared:
        sf = m.shared_d_ff * m.num_shared
        t.update({
            "ws_gate": ParamDef((d, sf), ("embed", "ff")),
            "ws_up": ParamDef((d, sf), ("embed", "ff")),
            "ws_down": ParamDef((sf, d), ("ff", "embed")),
        })
    return t


def mixer_table(cfg: ModelConfig, spec: LayerSpec) -> dict:
    if spec.mixer in (ATTN, ATTN_LOCAL, ENC):
        return attn_table(cfg)
    if spec.mixer == CROSS:
        return {**attn_table(cfg), **attn_table(cfg, cross=True),
                "norm_cross": ParamDef((cfg.d_model,), (None,), 0.0)}
    if spec.mixer == MLA:
        return mla_table(cfg)
    if spec.mixer == SSM:
        return ssm_table(cfg)
    raise ValueError(spec.mixer)


def layer_table(cfg: ModelConfig, spec: LayerSpec) -> dict:
    t = {"norm_mixer": ParamDef((cfg.d_model,), (None,), 0.0)}
    t.update(mixer_table(cfg, spec))
    ft = ffn_table(cfg, spec)
    if ft:
        t["norm_ffn"] = ParamDef((cfg.d_model,), (None,), 0.0)
        t.update(ft)
    return t


def padded_vocab(cfg: ModelConfig, multiple: int = 256) -> int:
    """Vocab padded to a mesh-divisible multiple (masked in the LM head);
    covers model-axis sizes up to 256 for any real vocab."""
    return -(-cfg.vocab_size // multiple) * multiple


def embed_table(cfg: ModelConfig) -> dict:
    vp = padded_vocab(cfg)
    t = {"emb": ParamDef((vp, cfg.d_model), ("vocab", "embed"),
                         1.0 / math.sqrt(cfg.d_model))}
    if not cfg.tie_embeddings:
        t["w_out"] = ParamDef((cfg.d_model, vp), ("embed", "vocab"))
    return t


# ===========================================================================
# Layer context: everything apply functions need besides params/x.
# ===========================================================================


@dataclass
class Ctx:
    cfg: ModelConfig
    dist: Dist
    mode: str                        # train | prefill | decode
    angles: Optional[jnp.ndarray] = None    # (s, half) rope angles
    pos: Optional[jnp.ndarray] = None       # scalar decode position
    memory: Optional[jnp.ndarray] = None    # (b, s_enc, d) enc-dec memory
    cache_len: int = 0               # decode/prefill cache allocation length
    is_encoder: bool = False
    batch_size: int = 0              # global batch (0 = assume shardable)
    # lossy-KV roundtrip for the speculative verify pass: applied to the
    # pass's fresh rows that LATER queries attend (they round-trip the
    # host tier between sequential steps); None = cache tier is lossless
    kv_roundtrip: Optional[Any] = None

    @property
    def dp(self):
        """Batch-dim sharding axes; None when the batch can't shard (b=1)."""
        ax = self.dist.data_axes
        if not ax:
            return None
        if self.batch_size and self.dist.is_dist:
            n = 1
            for a in ax:
                n *= self.dist.mesh.shape[a]
            if self.batch_size % n != 0 or self.batch_size < n:
                return None
        return ax if len(ax) > 1 else ax[0]

    def act_spec(self):
        """PartitionSpec dims for (b, s, ...) activations."""
        if self.mode == "decode":
            return (self.dp, None)
        return (self.dp, self.dist.model_axis)

    def seq_axis(self):
        return self.dist.model_axis if self.mode != "decode" else None


def _shard_map(ctx: Ctx, fn, in_specs, out_specs):
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # jax < 0.6: experimental namespace
        from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=ctx.dist.mesh, in_specs=in_specs,
                     out_specs=out_specs)


# ===========================================================================
# Attention layers
# ===========================================================================


def _qkv(p, xn, cfg, pre=""):
    b, s, _ = xn.shape
    q = _mm(xn, p, pre + "wq").reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = _mm(xn, p, pre + "wk").reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = _mm(xn, p, pre + "wv").reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def apply_attention(p, x, ctx: Ctx, cache, spec: LayerSpec):
    cfg = ctx.cfg
    b, s, d = x.shape
    window = cfg.window if spec.mixer == ATTN_LOCAL else 0
    causal = spec.mixer != ENC
    xn = rms_norm(x, p["norm_mixer"], cfg.norm_eps)
    q, k, v = _qkv(p, xn, cfg)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if ctx.angles is not None and spec.mixer != ENC:
        q = apply_rope(q, ctx.angles)
        k = apply_rope(k, ctx.angles)

    new_cache = cache
    if ctx.mode == "decode":
        out, new_cache = _decode_attn(q, k, v, ctx, cache, window)
    else:
        out = _seq_attn(q, k, v, ctx, causal, window)
        if ctx.mode == "prefill":
            new_cache = _build_cache(k, v, ctx, window)
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    x = x + _mm(out, p, "wo")
    return x, new_cache


def _seq_attn(q, k, v, ctx: Ctx, causal, window, softcap=0.0):
    """Full-sequence attention: ring over the model axis when distributed."""
    cfg = ctx.cfg
    axis = ctx.seq_axis()
    q_chunk = 512
    if not ctx.dist.is_dist or axis is None:
        return attn.ring_attention(q, k, v, axis=None, causal=causal,
                                   window=window, softcap=softcap,
                                   q_chunk=q_chunk)
    sp = P(ctx.dp, axis, None, None)
    fn = _shard_map(ctx, partial(attn.ring_attention, axis=axis,
                                 causal=causal, window=window,
                                 softcap=softcap, q_chunk=q_chunk),
                    in_specs=(sp, sp, sp), out_specs=sp)
    return fn(q, k, v)


def _build_cache(k, v, ctx: Ctx, window):
    """Prefill: lay k/v into the allocated cache buffer."""
    b, s, hkv, dh = k.shape
    if window:
        W = window
        if s < W:
            pad = W - s
            kw = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vw = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return {"k": kw, "v": vw}
        # rolling buffer invariant: slot j holds the latest position p < s
        # with p % W == j  ->  p_j = s - W + ((j - s % W) % W)
        p_idx = s - W + ((jnp.arange(W) - (s % W)) % W)
        return {"k": jnp.take(k, p_idx, axis=1),
                "v": jnp.take(v, p_idx, axis=1)}
    L = ctx.cache_len or s
    if L == s:
        return {"k": k, "v": v}
    dt = k.dtype
    zk = jnp.zeros((b, L, hkv, dh), dt)
    zv = jnp.zeros((b, L, hkv, dh), dt)
    return {"k": lax.dynamic_update_slice(zk, k, (0, 0, 0, 0)),
            "v": lax.dynamic_update_slice(zv, v, (0, 0, 0, 0))}


def _decode_attn(q, k_new, v_new, ctx: Ctx, cache, window):
    cfg = ctx.cfg
    if q.shape[1] > 1 and not window:
        # speculative verify pass: one ragged decode step appends s = k+1
        # rows (current token + draft proposals) and scores every position
        # through its own causal prefix.  Single-device path only — the
        # speculative engines run Dist.local() and spec_decode_capability
        # gates out window/MLA/SSM mixers.
        out, kc, vc = attn.spec_decode_attention(q, cache["k"], cache["v"],
                                                 k_new, v_new, ctx.pos,
                                                 kv_roundtrip=ctx.kv_roundtrip)
        return out, {"k": kc, "v": vc}
    if window:
        # the window cache is replicated over `model`; without a constraint
        # GSPMD replicates the *updated cache* by all-gathering cache-sized
        # tensors every layer (167 MB x10 on gemma3 decode — the dominant
        # collective).  Constraining the 1-token q/k/v first makes the
        # gather 3 orders of magnitude smaller.  (§Perf B, iteration B1)
        q = ctx.dist.constrain(q, ctx.dp, None, None, None)
        k_new = ctx.dist.constrain(k_new, ctx.dp, None, None, None)
        v_new = ctx.dist.constrain(v_new, ctx.dp, None, None, None)
        ck = ctx.dist.constrain(cache["k"], ctx.dp, None, None, None)
        cv = ctx.dist.constrain(cache["v"], ctx.dp, None, None, None)
        out, kc, vc = attn.local_decode_attention(
            q, ck, cv, k_new, v_new, ctx.pos, window)
        kc = ctx.dist.constrain(kc, ctx.dp, None, None, None)
        vc = ctx.dist.constrain(vc, ctx.dp, None, None, None)
        return out, {"k": kc, "v": vc}
    axes = ctx.dist.kv_shard_axes
    if not ctx.dist.is_dist or not axes:
        out, kc, vc = attn.decode_attention(q, cache["k"], cache["v"],
                                            k_new, v_new, ctx.pos, axes=())
        return out, {"k": kc, "v": vc}
    dp = ctx.dp
    b_spec = None if (len(axes) > 1) else dp   # long_500k: batch replicated
    qsp = P(b_spec, None, None, None)
    csp = P(b_spec, axes if len(axes) > 1 else axes[0], None, None)
    fn = _shard_map(ctx, partial(attn.decode_attention, axes=axes),
                    in_specs=(qsp, csp, csp, qsp, qsp, P()),
                    out_specs=(qsp, csp, csp))
    out, kc, vc = fn(q, cache["k"], cache["v"], k_new, v_new, ctx.pos)
    return out, {"k": kc, "v": vc}


def apply_layer_chunk(p, x, ctx: Ctx, prefix_k, prefix_v, q_offset: int):
    """One dense global-attention layer applied to a prefill CHUNK.

    ``x`` holds the chunk's rows (global positions ``q_offset ..``);
    ``prefix_k``/``prefix_v`` are the engine-held FRESH K/V of the
    earlier chunks (post-rope, compute precision — the same values the
    monolithic ``apply_attention`` prefill would have in-pass, NOT the
    cache-tier copies).  Every op here is the row-wise twin of the
    ``apply_attention`` prefill path, so the chunk's output rows equal
    the monolithic pass's rows bit for bit (``chunked_prefill_capability``
    gates callers to ATTN mixers + dense FFN).  Returns ``(x, k, v)``
    with the chunk's fresh rope'd K/V for the caller to extend the
    prefix and append to the KV store."""
    cfg = ctx.cfg
    b, s, d = x.shape
    xn = rms_norm(x, p["norm_mixer"], cfg.norm_eps)
    q, k, v = _qkv(p, xn, cfg)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if ctx.angles is not None:
        q = apply_rope(q, ctx.angles)
        k = apply_rope(k, ctx.angles)
    kk = k if prefix_k is None else jnp.concatenate([prefix_k, k], axis=1)
    vv = v if prefix_v is None else jnp.concatenate([prefix_v, v], axis=1)
    out = attn.chunk_prefill_attention(q, kk, vv, q_offset=q_offset,
                                       q_chunk=512)
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    x = x + _mm(out, p, "wo")
    x, _ = apply_dense_ffn(p, x, ctx)
    return x, k, v


# ===========================================================================
# Cross-attention (whisper decoder)
# ===========================================================================


def apply_cross_layer(p, x, ctx: Ctx, cache, spec: LayerSpec):
    """Decoder layer: causal self-attn + cross-attn over encoder memory."""
    cfg = ctx.cfg
    b, s, d = x.shape
    # self attention (reuses apply_attention mechanics)
    x, new_cache = apply_attention(p, x, ctx, cache, LayerSpec(ATTN, spec.ffn))
    # cross attention
    xn = rms_norm(x, p["norm_cross"], cfg.norm_eps)
    q = (xn @ p["cwq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    if ctx.mode == "decode":
        ck, cv = cache["ck"], cache["cv"]
        new_cache = {**new_cache, "ck": ck, "cv": cv}
    else:
        mem = ctx.memory
        sm = mem.shape[1]
        ck = (mem @ p["cwk"]).reshape(b, sm, cfg.num_kv_heads, cfg.head_dim)
        cv = (mem @ p["cwv"]).reshape(b, sm, cfg.num_kv_heads, cfg.head_dim)
        if ctx.mode == "prefill":
            new_cache = {**new_cache, "ck": ck, "cv": cv}
    out = attn.ref_attention(q, ck, cv, causal=False)
    x = x + out.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["cwo"]
    return x, new_cache


# ===========================================================================
# MLA (DeepSeek)
# ===========================================================================


def apply_mla(p, x, ctx: Ctx, cache, spec: LayerSpec):
    cfg = ctx.cfg
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    xn = rms_norm(x, p["norm_mixer"], cfg.norm_eps)

    qa = rms_norm(xn @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    qb = (qa @ p["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = qb[..., :dn], qb[..., dn:]
    kv_a = xn @ p["wkv_a"]                                # (b, s, r + dr)
    c = rms_norm(kv_a[..., :m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:]                   # (b, s, dr)
    if ctx.angles is not None:
        q_rope = apply_rope(q_rope, ctx.angles)
        k_rope = apply_rope(k_rope[:, :, None, :], ctx.angles)[:, :, 0]
    scale = 1.0 / math.sqrt(dn + dr)

    new_cache = cache
    if ctx.mode == "decode":
        # absorbed path over the latent cache
        q_eff = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"])
        axes = ctx.dist.kv_shard_axes if ctx.dist.is_dist else ()
        if axes:
            dp = ctx.dp
            b_spec = None if len(axes) > 1 else dp
            qsp = P(b_spec, None, None, None)
            csp = P(b_spec, axes if len(axes) > 1 else axes[0], None)
            nsp = P(b_spec, None, None)
            fn = _shard_map(ctx, partial(attn.mla_decode_attention,
                                         scale=scale, axes=axes),
                            in_specs=(qsp, qsp, csp, csp, nsp, nsp, P()),
                            out_specs=(qsp, csp, csp))
            ctxl, cc, krc = fn(q_eff, q_rope, cache["c"], cache["kr"],
                               c, k_rope, ctx.pos)
        else:
            ctxl, cc, krc = attn.mla_decode_attention(
                q_eff, q_rope, cache["c"], cache["kr"], c, k_rope, ctx.pos,
                scale=scale, axes=())
        new_cache = {"c": cc, "kr": krc}
        out = jnp.einsum("bshr,rhv->bshv", ctxl.astype(x.dtype), p["w_uv"])
    else:
        # MLA-aware ring: rotate the 576-dim latent, expand per step in the
        # ring body (71x less ICI than rotating expanded K/V — §Perf C1).
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        axis = ctx.seq_axis()
        if ctx.dist.is_dist and axis is not None:
            sp_q = P(ctx.dp, axis, None, None)
            sp_c = P(ctx.dp, axis, None)
            fn = _shard_map(ctx, partial(attn.mla_ring_attention, axis=axis),
                            in_specs=(sp_q, sp_c, sp_c,
                                      P(None, None, None), P(None, None, None)),
                            out_specs=sp_q)
            out = fn(q, c, k_rope, p["w_uk"], p["w_uv"])
        else:
            out = attn.mla_ring_attention(q, c, k_rope, p["w_uk"], p["w_uv"],
                                          axis=None)
        if ctx.mode == "prefill":
            L = ctx.cache_len or s
            cc = jnp.zeros((b, L, m.kv_lora_rank), x.dtype)
            krc = jnp.zeros((b, L, dr), x.dtype)
            new_cache = {
                "c": lax.dynamic_update_slice(cc, c.astype(x.dtype), (0, 0, 0)),
                "kr": lax.dynamic_update_slice(krc, k_rope.astype(x.dtype),
                                               (0, 0, 0))}
    x = x + out.reshape(b, s, h * dv) @ p["wo"]
    return x, new_cache


# ===========================================================================
# SSM layer (Mamba2)
# ===========================================================================


def _pick_chunk(length: int, target: int) -> int:
    """Largest divisor of ``length`` that is <= target (static)."""
    for c in range(min(target, length), 0, -1):
        if length % c == 0:
            return c
    return 1


def _causal_conv(x, w, b, halo=None):
    """Depthwise causal conv via shifted adds.  x: (b, l, ch); w: (width, ch);
    halo: (b, width-1, ch) previous context or None (zeros)."""
    width = w.shape[0]
    if halo is None:
        halo = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([halo, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out + b


def apply_ssm(p, x, ctx: Ctx, cache, spec: LayerSpec):
    cfg = ctx.cfg
    s_cfg = cfg.ssm
    b, l, d = x.shape
    d_in = s_cfg.expand * d
    H = d_in // s_cfg.head_dim
    hd = s_cfg.head_dim
    G, N = s_cfg.n_groups, s_cfg.d_state
    gn = G * N
    xn = rms_norm(x, p["norm_mixer"], cfg.norm_eps)

    z = xn @ p["z_proj"]                                  # (b, l, d_in)
    xin = xn @ p["x_proj"]
    bc = xn @ p["bc_proj"]                                # (b, l, 2gn)
    dt_raw = xn @ p["dt_proj"]                            # (b, l, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    conv_in = jnp.concatenate([xin, bc], axis=-1)         # (b, l, conv_ch)
    new_cache = cache
    if ctx.mode == "decode":
        halo = cache["conv"]                              # (b, width-1, ch)
        conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], halo)
        new_halo = jnp.concatenate([halo, conv_in], axis=1)[:, 1:]
        conv = silu(conv)
        xc = conv[..., :d_in].reshape(b, H, hd)
        Bc = conv[..., d_in:d_in + gn].reshape(b, G, N)
        Cc = conv[..., d_in + gn:].reshape(b, G, N)
        dt = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"])  # (b, H)
        y, h_new = ssm_mod.ssd_decode_step(xc, dt, A, Bc, Cc, cache["state"])
        y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
        y = y.reshape(b, 1, d_in)
        new_cache = {"conv": new_halo, "state": h_new.astype(jnp.float32)}
    else:
        axis = ctx.seq_axis()
        dt = jax.nn.softplus(dt_raw + p["dt_bias"])

        def inner(conv_in, dt):
            # inside shard_map: fetch conv halo from previous shard
            if axis is not None:
                tail = conv_in[:, -(s_cfg.d_conv - 1):]
                from repro.models.common import axis_size as _axis_size
                prev = lax.ppermute(
                    tail, axis,
                    [(i, i + 1) for i in range(_axis_size(axis) - 1)])
            else:
                prev = None
            conv = silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"], prev))
            bl, ll = conv.shape[0], conv.shape[1]   # local shapes (shard_map)
            xc = conv[..., :d_in].reshape(bl, ll, H, hd)
            Bc = conv[..., d_in:d_in + gn].reshape(bl, ll, G, N)
            Cc = conv[..., d_in + gn:].reshape(bl, ll, G, N)
            y, h_fin = ssm_mod.ssd_sharded(xc, dt, A, Bc, Cc,
                                           _pick_chunk(ll, s_cfg.chunk_size),
                                           axis)
            y = y + xc.astype(jnp.float32) * p["D"].astype(
                jnp.float32)[:, None]
            return y.reshape(bl, ll, d_in), h_fin

        if ctx.dist.is_dist and axis is not None:
            sp2 = P(ctx.dp, axis, None)
            fn = _shard_map(ctx, inner,
                            in_specs=(sp2, sp2),
                            out_specs=(sp2, P(ctx.dp, None, None, None)))
            y, h_fin = fn(conv_in, dt)
        else:
            y, h_fin = inner(conv_in, dt)
        if ctx.mode == "prefill":
            width = s_cfg.d_conv
            new_cache = {"conv": conv_in[:, -(width - 1):],
                         "state": h_fin.astype(jnp.float32)}

    # gated RMSNorm + out projection
    y = rms_norm(y.astype(x.dtype) * silu(z), p["ssm_norm"], cfg.norm_eps)
    x = x + y @ p["out_proj"]
    return x, new_cache


# ===========================================================================
# FFN layers
# ===========================================================================


def apply_dense_ffn(p, x, ctx: Ctx):
    cfg = ctx.cfg
    if cfg.d_ff == 0 or "w_gate" not in p:
        return x, jnp.float32(0.0)
    xn = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
    h = silu(_mm(xn, p, "w_gate")) * _mm(xn, p, "w_up")
    return x + _mm(h, p, "w_down"), jnp.float32(0.0)


def _moe_ff_axis(ctx: Ctx):
    """The mesh axis the expert ff dim is storage-sharded over, or None.
    Must mirror the divisibility rule in launch/sharding.py::AXIS_RULES."""
    if not ctx.dist.is_dist or "data" not in ctx.dist.mesh.axis_names:
        return None
    f = ctx.cfg.moe.expert_d_ff
    n = ctx.dist.mesh.shape["data"]
    return "data" if (f % n == 0 and f >= n) else None


def _dequant_moe_stacks(p, dtype):
    """INT4-resident MoE (plan ``moe_quant='int4'``): the routed expert
    stacks arrive packed (``w_gate#q``/``#s`` etc., per
    ``QuantPolicy.prepare_moe_params``) — unpack them under the
    ``vreg_fused_int4`` scope so the roofline analyzer prices packed
    bytes as the HBM traffic, same as the 2-D ``_mm`` path.  The router
    (``wg``) and shared experts stay at compute precision."""
    if "w_gate#q" not in p:
        return p
    from repro.quant.int4 import dequantize_int4_stack
    out = dict(p)
    with jax.named_scope("vreg_fused_int4"):
        for name in ("w_gate", "w_up", "w_down"):
            q, s = out.pop(name + "#q"), out.pop(name + "#s")
            out[name] = dequantize_int4_stack(q, s, dtype)
    return out


def apply_moe_ffn(p, x, ctx: Ctx):
    cfg = ctx.cfg
    m = cfg.moe
    b, s, d = x.shape
    xn = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
    p = _dequant_moe_stacks(p, xn.dtype)
    moe_params = {k: p[k] for k in ("wg", "w_gate", "w_up", "w_down")}

    axis = ctx.dist.model_axis if ctx.dist.is_dist else None
    ff_axis = _moe_ff_axis(ctx) if axis is not None else None
    w_specs = (P(None, None),
               P(axis, None, ff_axis), P(axis, None, ff_axis),
               P(axis, ff_axis, None))

    if axis is None:
        out, aux = moe_mod.moe_ffn(xn.reshape(b * s, d), moe_params, m,
                                   axis=None)
        out = out.reshape(b, s, d)
    elif ctx.mode == "decode":
        if ff_axis is not None:
            # combine over exactly the sharded axes (ff partial-sums over
            # `data`, cross-expert over `model`); the pod axis is pure DP
            # with replicated x/weights — no reduction there.
            combine = (ff_axis, axis)

            def body(xn_, wg, wga, wup, wdn):
                T = xn_.shape[0] * xn_.shape[1]
                o, a = moe_mod.moe_ffn_decode(
                    xn_.reshape(T, d),
                    dict(wg=wg, w_gate=wga, w_up=wup, w_down=wdn), m,
                    ep_axis=axis, ff_axis=ff_axis, combine_axes=combine)
                return o.reshape(xn_.shape), a[None]
            fn = _shard_map(ctx, body,
                            in_specs=(P(None, None, None),) + w_specs,
                            out_specs=(P(None, None, None), P(None)))
        else:
            def body(xn_, wg, wga, wup, wdn):
                T = xn_.shape[0] * xn_.shape[1]
                o, a = moe_mod.moe_ffn_replicated(
                    xn_.reshape(T, d), dict(wg=wg, w_gate=wga, w_up=wup,
                                            w_down=wdn), m, axis=axis)
                if ctx.dp:
                    a = lax.pmean(a, ctx.dist.data_axes)
                return o.reshape(xn_.shape), a[None]
            fn = _shard_map(ctx, body,
                            in_specs=(P(ctx.dp, None, None),) + w_specs,
                            out_specs=(P(ctx.dp, None, None), P(None)))
        out, aux = fn(xn, *(moe_params[k] for k in
                            ("wg", "w_gate", "w_up", "w_down")))
        aux = aux[0]
    else:
        P_model = ctx.dist.model_size
        T_loc = (b // max(1, _dp_size(ctx) if ctx.dp else 1)) * (s // P_model)
        capacity = int(m.capacity_factor * T_loc * m.top_k / m.num_experts) + 1

        def body(xn_, wg, wga, wup, wdn):
            bl, sl, _ = xn_.shape
            if ff_axis is not None:
                # JIT FSDP gather of this layer's expert slices (ZeRO-3)
                wga = lax.all_gather(wga, ff_axis, axis=2, tiled=True)
                wup = lax.all_gather(wup, ff_axis, axis=2, tiled=True)
                wdn = lax.all_gather(wdn, ff_axis, axis=1, tiled=True)
            o, a = moe_mod.moe_ffn(
                xn_.reshape(bl * sl, d),
                dict(wg=wg, w_gate=wga, w_up=wup, w_down=wdn), m,
                axis=axis, capacity=capacity)
            a = lax.pmean(a, ctx.dist.data_axes + (axis,)) if ctx.dp \
                else lax.pmean(a, axis)
            return o.reshape(bl, sl, d), a[None]
        fn = _shard_map(ctx, body,
                        in_specs=(P(ctx.dp, axis, None),) + w_specs,
                        out_specs=(P(ctx.dp, axis, None), P(None)))
        out, aux = fn(xn, *(moe_params[k] for k in
                            ("wg", "w_gate", "w_up", "w_down")))
        aux = aux[0]

    x = x + out
    if m.num_shared:
        h = silu(xn @ p["ws_gate"]) * (xn @ p["ws_up"])
        x = x + h @ p["ws_down"]
    return x, jnp.mean(aux)


def _dp_size(ctx: Ctx):
    n = 1
    for a in ctx.dist.data_axes:
        n *= ctx.dist.mesh.shape[a]
    return n


# ===========================================================================
# Whole layer
# ===========================================================================


def apply_layer(p, x, ctx: Ctx, cache, spec: LayerSpec):
    if spec.mixer in (ATTN, ATTN_LOCAL, ENC):
        x, new_cache = apply_attention(p, x, ctx, cache, spec)
    elif spec.mixer == CROSS:
        x, new_cache = apply_cross_layer(p, x, ctx, cache, spec)
    elif spec.mixer == MLA:
        x, new_cache = apply_mla(p, x, ctx, cache, spec)
    elif spec.mixer == SSM:
        x, new_cache = apply_ssm(p, x, ctx, cache, spec)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == MOE:
        x, aux = apply_moe_ffn(p, x, ctx)
    else:
        x, aux = apply_dense_ffn(p, x, ctx)
    return x, new_cache, aux


# ===========================================================================
# Embedding / LM head (vocab-sharded)
# ===========================================================================


def embed_tokens(p, tokens, ctx: Ctx):
    """tokens (b, s) -> (b, s, d); vocab-sharded masked-psum lookup."""
    cfg = ctx.cfg
    axis = ctx.dist.model_axis if ctx.dist.is_dist else None
    if axis is None:
        return jnp.take(p["emb"], tokens, axis=0)

    s_sharded = ctx.mode != "decode"

    def body(emb_loc, tok):
        V_loc = emb_loc.shape[0]
        start = lax.axis_index(axis) * V_loc
        if s_sharded:
            # tokens are s-sharded on the SAME axis as the vocab: every
            # shard must see every token (a shard can only resolve ids in
            # its own vocab slice) -> gather tokens (cheap ints), emit
            # partials for the full s, then reduce-scatter back to s-shards
            # (comm = 1/P of a full psum).
            tok = lax.all_gather(tok, axis, axis=1, tiled=True)
        rel = tok - start
        ok = (rel >= 0) & (rel < V_loc)
        e = jnp.take(emb_loc, jnp.clip(rel, 0, V_loc - 1), axis=0)
        e = jnp.where(ok[..., None], e, 0)
        if s_sharded:
            return lax.psum_scatter(e, axis, scatter_dimension=1, tiled=True)
        return lax.psum(e, axis)

    s_spec = ctx.dist.model_axis if s_sharded else None
    fn = _shard_map(ctx, body,
                    in_specs=(P(axis, None), P(ctx.dp, s_spec)),
                    out_specs=P(ctx.dp, s_spec, None))
    return fn(p["emb"], tokens)


def _w_out(p, cfg):
    return p["emb"].T if cfg.tie_embeddings else p["w_out"]


def lm_head_loss(p, x, labels, ctx: Ctx, s_chunk: int = 512):
    """Mean token cross-entropy with a vocab-sharded head.

    Distributed: x (b, s@model, d) is all-gathered over model, logits are
    computed per vocab shard in s-chunks, and the softmax statistics are
    psum-merged — full logits are never materialized globally.
    """
    cfg = ctx.cfg
    axis = ctx.dist.model_axis if ctx.dist.is_dist else None
    w = _w_out(p, cfg)
    V = cfg.vocab_size                                 # real vocab; pad masked

    if axis is None:
        logits = (x @ w).astype(jnp.float32)
        logits = jnp.where(jnp.arange(logits.shape[-1]) < V, logits, NEG_INF)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    def body(x_loc, w_loc, labels_loc):
        # x_loc (b_loc, s_loc, d) -> gather full s on every model shard
        x_all = lax.all_gather(x_loc, axis, axis=1, tiled=True)
        lab = lax.all_gather(labels_loc, axis, axis=1, tiled=True)
        V_loc = w_loc.shape[1]
        start = lax.axis_index(axis) * V_loc
        pad_mask = (start + jnp.arange(V_loc)) < V     # mask vocab padding
        b_loc, s, d = x_all.shape
        n = max(1, s // s_chunk) if s % s_chunk == 0 else 1
        cs = s // n

        def chunk(args):
            xc, lc = args                              # (b, cs, d), (b, cs)
            lg = (xc @ w_loc).astype(jnp.float32)      # (b, cs, V_loc)
            lg = jnp.where(pad_mask, lg, NEG_INF)
            m = pmax(jnp.max(lg, axis=-1), axis)       # stop-grad pmax (exact)
            se = lax.psum(jnp.sum(jnp.exp(lg - m[..., None]), axis=-1), axis)
            lse = m + jnp.log(se)
            rel = lc - start
            ok = (rel >= 0) & (rel < V_loc)
            ll = jnp.take_along_axis(
                lg, jnp.clip(rel, 0, V_loc - 1)[..., None], axis=-1)[..., 0]
            ll = lax.psum(jnp.where(ok, ll, 0.0), axis)
            return lse - ll

        xs = (jnp.moveaxis(x_all.reshape(b_loc, n, cs, d), 1, 0),
              jnp.moveaxis(lab.reshape(b_loc, n, cs), 1, 0))
        losses = lax.map(chunk, xs)                    # (n, b, cs)
        loss = jnp.mean(losses)
        # already invariant over `axis` (psum-reduced); average over data
        return lax.pmean(loss, ctx.dist.data_axes)[None]

    fn = _shard_map(ctx, body,
                    in_specs=(P(ctx.dp, axis, None), P(None, axis),
                              P(ctx.dp, axis)),
                    out_specs=P(None))
    return fn(x, w, labels)[0]


def lm_head_argmax(p, x, ctx: Ctx):
    """Greedy next token from the last position.  x: (b, 1, d) -> (b,)."""
    cfg = ctx.cfg
    axis = ctx.dist.model_axis if ctx.dist.is_dist else None
    w = _w_out(p, cfg)
    V = cfg.vocab_size
    if axis is None:
        logits = (x[:, -1] @ w).astype(jnp.float32)
        logits = jnp.where(jnp.arange(logits.shape[-1]) < V, logits, NEG_INF)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def body(x_loc, w_loc):
        V_loc = w_loc.shape[1]
        start = lax.axis_index(axis) * V_loc
        lg = (x_loc[:, -1] @ w_loc).astype(jnp.float32)   # (b_loc, V_loc)
        lg = jnp.where((start + jnp.arange(V_loc)) < V, lg, NEG_INF)
        m_loc = jnp.max(lg, axis=-1)
        i_loc = jnp.argmax(lg, axis=-1).astype(jnp.int32) + start
        m = lax.pmax(m_loc, axis)
        idx = lax.pmax(jnp.where(m_loc >= m, i_loc, -1), axis)
        return idx

    fn = _shard_map(ctx, body,
                    in_specs=(P(ctx.dp, None, None), P(None, axis)),
                    out_specs=P(ctx.dp))
    return fn(x, w)
