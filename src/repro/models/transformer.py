"""Model assembly: parameter init from tables, period-scan layer stack,
train/prefill/decode entry points.

Layer-pattern scan: each *pattern position* holds its params stacked over
``num_periods`` (leading axis), and ``lax.scan`` iterates periods with the
heterogeneous pattern unrolled inside the body.  This keeps the HLO small
(one period body) for 36–80 layer models — critical for 512-device SPMD
compile times — while supporting heterogeneous stacks (gemma3 local:global,
jamba SSM/attn/MoE interleave).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (ATTN, ATTN_LOCAL, CROSS, DENSE, ENC, MLA, MOE,
                                SSM, LayerSpec, ModelConfig)
from repro.models import layers as L
from repro.models.common import Dist, init_leaf
from repro.models.rope import rope_angles, sinusoidal_positions

AUX_WEIGHT = 0.01  # load-balance loss weight


# ===========================================================================
# Tables & init
# ===========================================================================


def model_tables(cfg: ModelConfig):
    t = {
        "embed": L.embed_table(cfg),
        "final_norm": {"scale": L.ParamDef((cfg.d_model,), (None,), 0.0)},
        "pat": tuple(L.layer_table(cfg, s) for s in cfg.pattern),
        "rem": tuple(L.layer_table(cfg, s) for s in cfg.remainder),
    }
    if cfg.enc_dec:
        enc_spec = LayerSpec(ENC, DENSE)
        t["enc"] = {
            "pat": (L.layer_table(cfg, enc_spec),),
            "final_norm": {"scale": L.ParamDef((cfg.d_model,), (None,), 0.0)},
        }
    return t


def _init_entry(key, name, pd: L.ParamDef, stack: int, dtype):
    shape = ((stack,) + pd.shape) if stack else pd.shape
    if name.endswith("#q"):      # packed INT4 weights
        return jax.random.randint(key, shape, 0, 255, jnp.uint8)
    if name.endswith("#s"):      # groupwise scales
        return jax.random.uniform(key, shape, jnp.float32, 1e-3, 2e-3)
    if name == "A_log":
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(jnp.float32)
    if name == "dt_bias":
        u = jax.random.uniform(key, shape, jnp.float32, 1e-3, 0.1)
        return jnp.log(jnp.expm1(u)).astype(jnp.float32)
    if name == "D":
        return jnp.ones(shape, jnp.float32)
    scale = pd.scale if pd.scale >= 0 else 1.0 / math.sqrt(max(1, pd.shape[0] if not stack else pd.shape[0]))
    # fan-in for matrices: first non-stacked dim
    if pd.scale < 0:
        fan = pd.shape[0] if len(pd.shape) > 1 else pd.shape[0]
        scale = 1.0 / math.sqrt(max(1, fan))
    return init_leaf(key, shape, scale, dtype)


def _init_table(table, key, stack: int, dtype):
    out = {}
    for i, (name, pd) in enumerate(sorted(table.items())):
        out[name] = _init_entry(jax.random.fold_in(key, i), name, pd, stack,
                                dtype)
    return out


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    tabs = model_tables(cfg)
    params = {
        "embed": _init_table(tabs["embed"], jax.random.fold_in(key, 0), 0,
                             dtype),
        "final_norm": _init_table(tabs["final_norm"],
                                  jax.random.fold_in(key, 1), 0, dtype),
        "pat": tuple(
            _init_table(t, jax.random.fold_in(key, 10 + i), cfg.num_periods,
                        dtype)
            for i, t in enumerate(tabs["pat"])),
        "rem": tuple(
            _init_table(t, jax.random.fold_in(key, 100 + i), 0, dtype)
            for i, t in enumerate(tabs["rem"])),
    }
    if cfg.enc_dec:
        params["enc"] = {
            "pat": tuple(
                _init_table(t, jax.random.fold_in(key, 200 + i),
                            cfg.num_encoder_layers, dtype)
                for i, t in enumerate(tabs["enc"]["pat"])),
            "final_norm": _init_table(tabs["enc"]["final_norm"],
                                      jax.random.fold_in(key, 299), 0, dtype),
        }
    return params


def map_params_tree(cfg: ModelConfig, fn):
    """Build a pytree with the exact structure of ``init_params`` output,
    with leaf = fn(name, ParamDef, stacked: bool)."""
    tabs = model_tables(cfg)

    def tab(t, stacked):
        return {name: fn(name, pd, stacked) for name, pd in t.items()}

    out = {
        "embed": tab(tabs["embed"], False),
        "final_norm": tab(tabs["final_norm"], False),
        "pat": tuple(tab(t, True) for t in tabs["pat"]),
        "rem": tuple(tab(t, False) for t in tabs["rem"]),
    }
    if cfg.enc_dec:
        out["enc"] = {
            "pat": tuple(tab(t, True) for t in tabs["enc"]["pat"]),
            "final_norm": tab(tabs["enc"]["final_norm"], False),
        }
    return out


def param_struct(cfg: ModelConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree matching init_params (fp32 SSM scalars)."""
    f32_names = ("A_log", "dt_bias", "D")

    def fn(name, pd, stacked):
        stack = (cfg.num_encoder_layers if False else
                 (cfg.num_periods if stacked else 0))
        shape = ((stack,) + pd.shape) if stack else pd.shape
        if name.endswith("#q"):
            dt = jnp.uint8
        elif name.endswith("#s") or name in f32_names:
            dt = jnp.float32
        else:
            dt = dtype
        return jax.ShapeDtypeStruct(shape, dt)

    tree = map_params_tree(cfg, fn)
    if cfg.enc_dec:
        # encoder stacks over num_encoder_layers, not num_periods
        def fn_enc(name, pd, stacked):
            shape = ((cfg.num_encoder_layers,) + pd.shape) if stacked else pd.shape
            dt = jnp.float32 if name in f32_names else dtype
            return jax.ShapeDtypeStruct(shape, dt)
        tabs = model_tables(cfg)
        tree["enc"]["pat"] = tuple(
            {name: fn_enc(name, pd, True) for name, pd in t.items()}
            for t in tabs["enc"]["pat"])
    return tree


def param_axes(cfg: ModelConfig):
    """Same pytree structure as params, leaves = logical axes tuples."""
    tabs = model_tables(cfg)

    def tab_axes(table, stacked):
        return {name: ((None,) + pd.axes if stacked else pd.axes)
                for name, pd in table.items()}

    out = {
        "embed": tab_axes(tabs["embed"], False),
        "final_norm": tab_axes(tabs["final_norm"], False),
        "pat": tuple(tab_axes(t, True) for t in tabs["pat"]),
        "rem": tuple(tab_axes(t, False) for t in tabs["rem"]),
    }
    if cfg.enc_dec:
        out["enc"] = {
            "pat": tuple(tab_axes(t, True) for t in tabs["enc"]["pat"]),
            "final_norm": tab_axes(tabs["enc"]["final_norm"], False),
        }
    return out


# ===========================================================================
# Caches
# ===========================================================================


def _layer_cache_shape(cfg: ModelConfig, spec: LayerSpec, b: int, L_: int):
    """dict name -> (shape, dtype, kind) for one layer; kind tags the
    sharding rule ('kv' = sequence-sharded, 'rep' = replicated)."""
    dh, hkv = cfg.head_dim, cfg.num_kv_heads
    bf = jnp.bfloat16
    if spec.mixer == ATTN:
        return {"k": ((b, L_, hkv, dh), bf, "kv"),
                "v": ((b, L_, hkv, dh), bf, "kv")}
    if spec.mixer == ATTN_LOCAL:
        W = cfg.window
        return {"k": ((b, W, hkv, dh), bf, "rep"),
                "v": ((b, W, hkv, dh), bf, "rep")}
    if spec.mixer == MLA:
        m = cfg.mla
        return {"c": ((b, L_, m.kv_lora_rank), bf, "kv"),
                "kr": ((b, L_, m.qk_rope_head_dim), bf, "kv")}
    if spec.mixer == SSM:
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        H = d_in // s.head_dim
        conv_ch = d_in + 2 * s.n_groups * s.d_state
        return {"conv": ((b, s.d_conv - 1, conv_ch), bf, "rep"),
                "state": ((b, H, s.head_dim, s.d_state), jnp.float32, "state")}
    if spec.mixer == CROSS:
        enc_s = cfg.encoder_seq_len
        return {"k": ((b, L_, hkv, dh), bf, "kv"),
                "v": ((b, L_, hkv, dh), bf, "kv"),
                "ck": ((b, enc_s, hkv, dh), bf, "rep"),
                "cv": ((b, enc_s, hkv, dh), bf, "rep")}
    raise ValueError(spec.mixer)


def cache_struct(cfg: ModelConfig, b: int, cache_len: int, enc_len=None):
    """ShapeDtypeStruct pytree of the decode cache (+ kind tree)."""
    def one(spec, stack):
        shapes = _layer_cache_shape(cfg, spec, b, cache_len)
        if enc_len is not None and spec.mixer == CROSS:
            shapes = {k: (((v[0][0], enc_len) + v[0][2:]) if k in ("ck", "cv")
                          else v[0], v[1], v[2]) for k, v in shapes.items()}
        sds = {k: jax.ShapeDtypeStruct(((stack,) + s) if stack else s, d)
               for k, (s, d, _) in shapes.items()}
        kinds = {k: kind for k, (_, _, kind) in shapes.items()}
        return sds, kinds
    pat, pat_kinds = [], []
    for spec in cfg.pattern:
        s, k = one(spec, cfg.num_periods)
        pat.append(s)
        pat_kinds.append(k)
    rem, rem_kinds = [], []
    for spec in cfg.remainder:
        s, k = one(spec, 0)
        rem.append(s)
        rem_kinds.append(k)
    return ({"pat": tuple(pat), "rem": tuple(rem)},
            {"pat": tuple(pat_kinds), "rem": tuple(rem_kinds)})


def init_cache(cfg: ModelConfig, b: int, cache_len: int, enc_len=None):
    struct, _ = cache_struct(cfg, b, cache_len, enc_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)


# ===========================================================================
# Forward passes
# ===========================================================================


def _angles(cfg: ModelConfig, positions):
    if cfg.rope_theta == 0:
        return None
    rope_dim = (cfg.mla.qk_rope_head_dim if cfg.mla is not None
                else cfg.head_dim)
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(positions, (3,) + positions.shape)
        return rope_angles(pos3, rope_dim, cfg.rope_theta,
                           cfg.mrope_sections)
    return rope_angles(positions, rope_dim, cfg.rope_theta)


@jax.custom_jvp
def _opt_barrier(ps):
    """``lax.optimization_barrier`` with a differentiation rule for jax
    versions that lack one (< 0.5): barrier the primals, pass tangents
    through — the barrier is a scheduling hint, semantically identity."""
    return lax.optimization_barrier(ps)


@_opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    (ps,), (ts,) = primals, tangents
    return _opt_barrier(ps), ts


def _run_stack(params, x, ctx: L.Ctx, caches, cfg: ModelConfig,
               pattern, remainder, remat: bool):
    aux0 = jnp.float32(0.0)
    empty = caches is None
    pat_caches = (tuple({} for _ in pattern) if empty else caches["pat"])
    rem_caches = (tuple({} for _ in remainder) if empty else caches["rem"])

    def body(carry, xs):
        x, aux = carry
        ps, cs = xs
        # Barrier on the per-period param slices: without it, XLA:CPU hoists
        # the bf16->f32 dot-operand converts of loop-invariant stacked params
        # out of the while loop, doubling resident param memory (observed on
        # jamba/deepseek: +100GiB/device).  TPU has native bf16 dots; the
        # barrier is a no-op for performance there.
        ps = _opt_barrier(ps)
        new_cs = []
        for idx, spec in enumerate(pattern):
            x, nc, a = L.apply_layer(ps[idx], x, ctx,
                                     cs[idx] if not empty else None, spec)
            new_cs.append(nc if nc is not None else {})
            aux = aux + a
        return (x, aux), tuple(new_cs)

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), new_pat = lax.scan(body, (x, aux0), (params["pat"], pat_caches))

    new_rem = []
    for i, spec in enumerate(remainder):
        x, nc, a = L.apply_layer(params["rem"][i], x, ctx,
                                 rem_caches[i] if not empty else None, spec)
        new_rem.append(nc if nc is not None else {})
        aux = aux + a
    new_caches = {"pat": new_pat, "rem": tuple(new_rem)}
    return x, aux, new_caches


def _encode(params, cfg: ModelConfig, dist: Dist, enc_embeds, mode):
    """Whisper-style encoder over precomputed frame embeddings (stub)."""
    b, s_enc, d = enc_embeds.shape
    x = enc_embeds + sinusoidal_positions(s_enc, d, enc_embeds.dtype)[None]
    ctx = L.Ctx(cfg=cfg, dist=dist, mode="train" if mode == "train" else
                "prefill", angles=None, is_encoder=True, batch_size=b)
    x = ctx.dist.constrain(x, *ctx.act_spec(), None)
    enc_params = {"pat": params["enc"]["pat"], "rem": ()}
    x, _, _ = _run_stack(enc_params, x, ctx, None, cfg,
                         (LayerSpec(ENC, DENSE),), (), remat=(mode == "train"))
    return L.rms_norm(x, params["enc"]["final_norm"]["scale"], cfg.norm_eps)


def _inputs_to_x(params, cfg, ctx, batch):
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        key = "tokens" if "tokens" in batch else "token"
        x = L.embed_tokens(params["embed"], batch[key], ctx)
    if cfg.rope_theta == 0:  # sinusoidal positions (whisper decoder)
        s = x.shape[1]
        if ctx.mode == "decode":
            tab = sinusoidal_positions(cfg.max_seq_len, cfg.d_model, x.dtype)
            if jnp.ndim(ctx.pos) == 1:
                x = x + jnp.take(tab, ctx.pos, axis=0)[:, None]
            else:
                x = x + lax.dynamic_slice(tab, (ctx.pos, 0),
                                          (1, cfg.d_model))[None]
        else:
            x = x + sinusoidal_positions(s, cfg.d_model, x.dtype)[None]
    return x


def train_loss(params, batch, cfg: ModelConfig, dist: Dist):
    """batch: tokens|embeds (+ enc_embeds for enc-dec), labels."""
    lab = batch["labels"]
    b, s = lab.shape
    positions = jnp.arange(s)
    memory = None
    if cfg.enc_dec:
        memory = _encode(params, cfg, dist, batch["enc_embeds"], "train")
    ctx = L.Ctx(cfg=cfg, dist=dist, mode="train", angles=_angles(cfg, positions),
                memory=memory, batch_size=b)
    x = _inputs_to_x(params, cfg, ctx, batch)
    x = dist.constrain(x, *ctx.act_spec(), None)
    x, aux, _ = _run_stack(params, x, ctx, None, cfg, cfg.pattern,
                           cfg.remainder, remat=True)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    loss = L.lm_head_loss(params["embed"], x, lab, ctx)
    n_moe = (cfg.num_periods * sum(1 for sp in cfg.pattern if sp.ffn == MOE)
             + sum(1 for sp in cfg.remainder if sp.ffn == MOE))
    if n_moe:
        loss = loss + AUX_WEIGHT * aux / n_moe
    return loss


def prefill(params, batch, cfg: ModelConfig, dist: Dist, cache_len: int):
    """Process the prompt; returns (next_token (b,), caches)."""
    key = "embeds" if "embeds" in batch else "tokens"
    b, s = batch[key].shape[:2]
    positions = jnp.arange(s)
    memory = None
    if cfg.enc_dec:
        memory = _encode(params, cfg, dist, batch["enc_embeds"], "prefill")
    ctx = L.Ctx(cfg=cfg, dist=dist, mode="prefill",
                angles=_angles(cfg, positions), memory=memory,
                cache_len=cache_len, batch_size=b)
    x = _inputs_to_x(params, cfg, ctx, batch)
    x = dist.constrain(x, *ctx.act_spec(), None)
    x, _, caches = _run_stack(params, x, ctx, None, cfg, cfg.pattern,
                              cfg.remainder, remat=False)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    next_tok = L.lm_head_argmax(params["embed"], x[:, -1:], ctx)
    return next_tok, caches


def decode_step(params, batch, caches, cfg: ModelConfig, dist: Dist):
    """One decode step.  batch: {"token": (b,1) or "embeds": (b,1,d),
    "pos": scalar}.  Returns (next_token (b,), caches')."""
    pos = batch["pos"]
    b = (batch["token"] if "token" in batch else batch["embeds"]).shape[0]
    # pos may be scalar (uniform batch) or (b,) ragged (continuous batching)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos[:, None]
    ctx = L.Ctx(cfg=cfg, dist=dist, mode="decode",
                angles=_angles(cfg, positions) if cfg.rope_theta else None,
                pos=pos, batch_size=b)
    x = _inputs_to_x(params, cfg, ctx, batch)
    x = dist.constrain(x, *ctx.act_spec(), None)
    x, _, new_caches = _run_stack(params, x, ctx, caches, cfg, cfg.pattern,
                                  cfg.remainder, remat=False)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    next_tok = L.lm_head_argmax(params["embed"], x, ctx)
    return next_tok, new_caches
