"""Rotary position embeddings: standard RoPE, Qwen2-VL M-RoPE, sinusoidal.

M-RoPE [arXiv:2409.12191]: head_dim/2 frequency slots are split into
(t, h, w) sections; each section rotates by its own position component.
For the stubbed text-only path all three components equal the token index,
which makes M-RoPE coincide with 1-D RoPE (a property we test).
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions, head_dim: int, theta: float, mrope_sections=()):
    """positions: (..., s) int or (3, ..., s) for M-RoPE -> angles (..., s, half)."""
    freqs = rope_freqs(head_dim, theta)           # (half,)
    half = head_dim // 2
    if mrope_sections:
        assert positions.ndim >= 2 and positions.shape[0] == len(mrope_sections)
        assert sum(mrope_sections) == half, (mrope_sections, half)
        parts = []
        start = 0
        for i, sec in enumerate(mrope_sections):
            f = freqs[start:start + sec]
            parts.append(positions[i][..., None].astype(jnp.float32) * f)
            start += sec
        return jnp.concatenate(parts, axis=-1)    # (..., s, half)
    return positions[..., None].astype(jnp.float32) * freqs


def apply_rope(x, angles):
    """x: (..., s, n_heads, head_dim), angles: broadcastable (..., s, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # angles (..., s, half) -> (..., s, 1, half): broadcast over the heads axis.
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def sinusoidal_positions(seq_len: int, d_model: int, dtype=jnp.float32):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    out = jnp.zeros((seq_len, d_model), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(angle))
    out = out.at[:, 1::2].set(jnp.cos(angle))
    return out.astype(dtype)
