"""Mamba2 / SSD (state-space duality) [arXiv:2405.21060].

Chunked SSD with:
  * intra-chunk quadratic path (the "attention-like" dual form),
  * inter-chunk linear recurrence via ``lax.associative_scan`` (log-depth),
  * sequence sharding across the `model` axis: each shard scans locally from
    h0 = 0, shards exchange (decay, state) summaries via all_gather, and a
    rank-1-in-state linear correction applies the true incoming state —
    communication is O(state), independent of sequence length.

The sequential token-by-token recurrence is the oracle (``ssd_sequential``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import axis_index, axis_size


def segsum(a):
    """a: (..., cs) -> (..., cs, cs) lower-triangular segment sums:
    out[i, j] = sum(a[j+1..i]) for i >= j, -inf otherwise."""
    cs = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]     # sum(a[j+1..i])
    i = jnp.arange(cs)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, B, C, chunk: int, h_init=None):
    """Chunked SSD.

    xh: (b, l, H, hd); dt: (b, l, H) (already softplus'd);
    A: (H,) negative; B, C: (b, l, G, N).
    Returns (y (b, l, H, hd), h_final (b, H, hd, N), state_factor
    (b, l, H)) where ``state_factor`` is the per-position decay from
    sequence start — multiply by C to apply an external initial state.
    """
    b, l, H, hd = xh.shape
    G, N = B.shape[-2:]
    Hg = H // G
    assert l % chunk == 0, (l, chunk)
    nc, cs = l // chunk, chunk

    f32 = jnp.float32
    xh = xh.astype(f32).reshape(b, nc, cs, H, hd)
    dt = dt.astype(f32).reshape(b, nc, cs, H)
    B_ = B.astype(f32).reshape(b, nc, cs, G, N)
    C_ = C.astype(f32).reshape(b, nc, cs, G, N)
    dA = dt * A.astype(f32)                               # (b, nc, cs, H) <= 0
    Acs = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum
    dtx = dt[..., None] * xh                              # (b, nc, cs, H, hd)

    # ---- intra-chunk (quadratic dual form) -------------------------------
    L = jnp.exp(segsum(jnp.moveaxis(dA, 2, -1)))          # (b, nc, H, cs, cs)
    CB = jnp.einsum("bcigr,bcjgr->bcgij", C_, B_)         # (b, nc, G, cs, cs)
    CB = jnp.repeat(CB, Hg, axis=2)                       # (b, nc, H, cs, cs)
    M = CB * L
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, dtx)     # (b, nc, cs, H, hd)

    # ---- chunk summaries -> inter-chunk recurrence -----------------------
    # state contribution of chunk c: sum_j exp(A_end - Acs_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(Acs[:, :, -1:, :] - Acs)       # (b, nc, cs, H)
    # group-broadcast B over heads: (b, nc, cs, H, N)
    B_heads = jnp.repeat(B_.reshape(b, nc, cs, G, 1, N), Hg, axis=4).reshape(
        b, nc, cs, H, N)
    S = jnp.einsum("bcjhn,bcjhp,bcjh->bchpn", B_heads, dtx, decay_to_end)
    chunk_decay = jnp.exp(Acs[:, :, -1, :])               # (b, nc, H)

    # associative scan over chunks: (a2,s2) o (a1,s1) = (a1*a2, s1*a2 + s2)
    def combine(left, right):
        a1, s1 = left
        a2, s2 = right
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_scan, s_scan = lax.associative_scan(
        combine, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S, 1, 0)))
    a_scan = jnp.moveaxis(a_scan, 0, 1)                   # (b, nc, H) prefix decay incl. c
    s_scan = jnp.moveaxis(s_scan, 0, 1)                   # (b, nc, H, hd, N) state at end of c
    # state at *start* of each chunk (from h0 = 0): shift right
    h_start = jnp.concatenate(
        [jnp.zeros_like(s_scan[:, :1]), s_scan[:, :-1]], axis=1)
    h_final = s_scan[:, -1]                               # (b, H, hd, N)

    # ---- apply inter-chunk states to outputs -----------------------------
    C_heads = jnp.repeat(C_.reshape(b, nc, cs, G, 1, N), Hg, axis=4).reshape(
        b, nc, cs, H, N)
    in_decay = jnp.exp(Acs)                               # decay chunk-start -> i
    y_off = jnp.einsum("bcihn,bchpn,bcih->bcihp", C_heads, h_start, in_decay)
    y = y_diag + y_off

    # decay from *sequence start* to position i (for external initial state)
    prefix_excl = jnp.concatenate(
        [jnp.ones_like(a_scan[:, :1]), a_scan[:, :-1]], axis=1)  # (b, nc, H)
    state_factor = (in_decay * prefix_excl[:, :, None, :]).reshape(b, l, H)
    total_decay = a_scan[:, -1]                           # (b, H)

    if h_init is not None:
        y = y + jnp.einsum(
            "bihn,bhpn,bih->bihp",
            C_heads.reshape(b, l, H, N), h_init.astype(f32),
            state_factor).reshape(b, nc, cs, H, hd)
        h_final = h_final + h_init.astype(f32) * total_decay[..., None, None]

    return y.reshape(b, l, H, hd), h_final, (state_factor, total_decay)


def ssd_sequential(xh, dt, A, B, C, h_init=None):
    """Oracle: token-by-token recurrence."""
    b, l, H, hd = xh.shape
    G, N = B.shape[-2:]
    Hg = H // G
    f32 = jnp.float32
    h = jnp.zeros((b, H, hd, N), f32) if h_init is None else h_init.astype(f32)
    B_heads = jnp.repeat(B.reshape(b, l, G, 1, N), Hg, axis=3).reshape(b, l, H, N)
    C_heads = jnp.repeat(C.reshape(b, l, G, 1, N), Hg, axis=3).reshape(b, l, H, N)

    def step(h, t):
        xt, dtt, Bt, Ct = t
        decay = jnp.exp(dtt.astype(f32) * A.astype(f32))  # (b, H)
        upd = jnp.einsum("bhn,bhp,bh->bhpn", Bt.astype(f32), xt.astype(f32),
                         dtt.astype(f32))
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", Ct.astype(f32), h)
        return h, y

    xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B_heads, 1, 0), jnp.moveaxis(C_heads, 1, 0))
    h, ys = lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h


def ssd_decode_step(xh, dt, A, B, C, h):
    """One-token recurrent update.  xh: (b, H, hd); dt: (b, H);
    B, C: (b, G, N); h: (b, H, hd, N).  Returns (y (b,H,hd), h')."""
    b, H, hd = xh.shape
    G, N = B.shape[-2:]
    Hg = H // G
    f32 = jnp.float32
    B_heads = jnp.repeat(B.reshape(b, G, 1, N), Hg, axis=2).reshape(b, H, N)
    C_heads = jnp.repeat(C.reshape(b, G, 1, N), Hg, axis=2).reshape(b, H, N)
    decay = jnp.exp(dt.astype(f32) * A.astype(f32))
    upd = jnp.einsum("bhn,bhp,bh->bhpn", B_heads.astype(f32), xh.astype(f32),
                     dt.astype(f32))
    h = h.astype(f32) * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", C_heads.astype(f32), h)
    return y, h


def ssd_sharded(xh, dt, A, B, C, chunk: int, axis: Optional[str]):
    """Sequence-sharded SSD: call inside shard_map with the l dim sharded
    over ``axis``.  Cross-shard state handoff via one all_gather of
    (decay, state) summaries; each shard applies its true incoming state
    through the linear ``state_factor`` correction."""
    y, h_final, (state_factor, total_decay) = ssd_chunked(
        xh, dt, A, B, C, chunk, h_init=None)
    if axis is None or axis_size(axis) == 1:
        return y, h_final
    P = axis_size(axis)
    i = axis_index(axis)
    decays = lax.all_gather(total_decay, axis)            # (P, b, H)
    states = lax.all_gather(h_final, axis)                # (P, b, H, hd, N)
    # incoming state for shard i: sum_{j<i} states[j] * prod_{j<m<i} decays[m]
    b, l, H, hd = xh.shape
    N = B.shape[-1]
    h_in = jnp.zeros_like(h_final)
    run = jnp.ones_like(total_decay)
    # walk backwards j = i-1 .. 0 with a static loop over P candidates
    for step_back in range(1, P):
        j = i - step_back
        valid = j >= 0
        contrib = jnp.where(valid, states[jnp.maximum(j, 0)], 0.0)
        h_in = h_in + contrib * run[..., None, None]
        run = run * jnp.where(valid, decays[jnp.maximum(j, 0)], 1.0)
    # apply correction
    G = B.shape[-2]
    Hg = H // G
    C_heads = jnp.repeat(
        C.astype(jnp.float32).reshape(b, l, G, 1, N), Hg, axis=3).reshape(
        b, l, H, N)
    y = y + jnp.einsum("bihn,bhpn,bih->bihp", C_heads, h_in, state_factor)
    h_final = h_final + h_in * total_decay[..., None, None]
    # the *global* final state is the last shard's corrected state; select it
    # via a tiny psum so every shard returns the same (replicated) value.
    h_final = lax.psum(jnp.where(i == P - 1, h_final, 0.0), axis)
    return y, h_final
