"""Public model facade: one object binding a ModelConfig to init / train /
prefill / decode plus input-spec construction for the dry-run."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.common import Dist


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- parameters -------------------------------------------------------
    def init(self, key, dtype=jnp.bfloat16):
        return T.init_params(self.cfg, key, dtype)

    def param_axes(self):
        return T.param_axes(self.cfg)

    # ---- compute entry points ---------------------------------------------
    def train_loss(self, params, batch, dist: Dist):
        return T.train_loss(params, batch, self.cfg, dist)

    def prefill(self, params, batch, dist: Dist, cache_len: int):
        return T.prefill(params, batch, self.cfg, dist, cache_len)

    def decode_step(self, params, batch, caches, dist: Dist):
        return T.decode_step(params, batch, caches, self.cfg, dist)

    # ---- caches ------------------------------------------------------------
    def init_cache(self, b: int, cache_len: int, enc_len: Optional[int] = None):
        return T.init_cache(self.cfg, b, cache_len, enc_len)

    def cache_struct(self, b: int, cache_len: int,
                     enc_len: Optional[int] = None):
        return T.cache_struct(self.cfg, b, cache_len, enc_len)

    # ---- dry-run input specs ------------------------------------------------
    def input_struct(self, shape: ShapeConfig, enc_pad: int = 0):
        """ShapeDtypeStructs for the model inputs of a given workload shape.

        Modality frontends are STUBS: vlm/audio archs receive precomputed
        embeddings (`embeds` / `enc_embeds`) per the assignment.
        """
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        bf = jnp.bfloat16
        enc_len = enc_pad or cfg.encoder_seq_len
        if shape.kind == "train":
            batch = {"labels": jax.ShapeDtypeStruct((b, s), i32)}
            if cfg.frontend == "embeds" and not cfg.enc_dec:
                batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), bf)
            else:
                batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            if cfg.enc_dec:
                batch["enc_embeds"] = jax.ShapeDtypeStruct(
                    (b, enc_len, cfg.d_model), bf)
            return batch
        if shape.kind == "prefill":
            batch = {}
            if cfg.frontend == "embeds" and not cfg.enc_dec:
                batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), bf)
            else:
                batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            if cfg.enc_dec:
                batch["enc_embeds"] = jax.ShapeDtypeStruct(
                    (b, enc_len, cfg.d_model), bf)
            return batch
        # decode: one new token against a cache of length seq_len
        return {"token": jax.ShapeDtypeStruct((b, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
