"""Mixture-of-Experts: sort-based capacity dispatch with expert parallelism.

Dispatch is gather/scatter based (O(T*k*d) data movement, *no* (T,E,C)
one-hot einsum — at pod scale that einsum would dwarf the expert FLOPs).

Expert parallelism (the paper's Appendix D "EP" integration): experts are
sharded over the `model` axis; tokens move through two all-to-alls
(dispatch / return) inside shard_map.  With axis=None the same code is the
single-device reference — tested against a dense per-token loop oracle.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MoEConfig
from repro.models.common import axis_index, axis_size, silu


def router_topk(logits, k: int):
    """logits (T, E) -> (weights (T,k) softmaxed over chosen, ids (T,k))."""
    vals, ids = lax.top_k(logits, k)
    w = jax.nn.softmax(vals.astype(jnp.float32), axis=-1)
    return w, ids


def load_balance_loss(logits, ids, num_experts: int):
    """GShard-style auxiliary loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T, E)
    onehot = jax.nn.one_hot(ids[..., 0], num_experts)             # top-1 share
    f = jnp.mean(onehot, axis=0)
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def _dispatch_indices(ids, num_experts: int, capacity: int):
    """ids: (T, k) expert assignment.  Returns (expert, slot, valid) each
    (T, k): the capacity slot each (token, choice) lands in, dropping
    overflow (slot >= capacity)."""
    T, k = ids.shape
    flat = ids.reshape(-1)                                        # (T*k,)
    # Stable sort by expert; rank within expert = position - segment start.
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    counts = jnp.bincount(flat, length=num_experts)
    starts = jnp.cumsum(counts) - counts                          # (E,)
    ranks_sorted = jnp.arange(T * k) - starts[sorted_e]
    ranks = jnp.zeros(T * k, jnp.int32).at[order].set(
        ranks_sorted.astype(jnp.int32))
    valid = ranks < capacity
    return flat.reshape(T, k), ranks.reshape(T, k), valid.reshape(T, k)


def _expert_ffn(w_gate, w_up, w_down, xb):
    """Batched experts: weights (E, d, f)/(E, f, d); xb (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xb, w_up)
    return jnp.einsum("ecf,efd->ecd", silu(g) * u, w_down)


def moe_ffn(x, params, cfg: MoEConfig, *, axis: Optional[str] = None,
            capacity: Optional[int] = None):
    """x: (T, d) local tokens.  params: wg (d,E), w_gate/w_up (E,d,f),
    w_down (E,f,d) — under EP the E axis is sharded over ``axis``;
    inside shard_map each shard sees E_loc = E/P experts but routes over all
    E (router weights wg replicated).  Returns (out (T,d), aux_loss)."""
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    P = axis_size(axis)
    E_loc = params["w_gate"].shape[0]           # E/P under shard_map, E locally
    assert E_loc * P == E, (E_loc, P, E)

    logits = (x @ params["wg"]).astype(jnp.float32)               # (T, E)
    w, ids = router_topk(logits, k)
    aux = load_balance_loss(logits, ids, E)

    if capacity is None:
        capacity = int(cfg.capacity_factor * T * k / E) + 1
    # capacity must be identical across shards (static) — it is: T static.
    e_id, slot, valid = _dispatch_indices(ids, E, capacity)

    # Scatter tokens into the dispatch buffer (E, C, d).  Overflow slots are
    # clamped and their updates zeroed (dropped-token semantics).
    slot_c = jnp.minimum(slot, capacity - 1)
    buf = jnp.zeros((E, capacity, d), x.dtype)
    flat_t = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[e_id.reshape(-1), slot_c.reshape(-1)].add(
        jnp.where(valid.reshape(-1, 1), x[flat_t], 0))

    if axis is not None:
        # EP all-to-all #1 (dispatch): device i's block p goes to shard p.
        # Symmetric tiled a2a (split==concat axis) + explicit transpose: the
        # asymmetric split/concat form has a broken VJP layout in jax 0.8.
        buf = buf.reshape(P, E_loc, capacity, d)
        buf = lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                             tiled=True)          # out[j] = from shard j
        buf = jnp.moveaxis(buf, 0, 1).reshape(E_loc, P * capacity, d)

    out_buf = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                          buf)

    if axis is not None:
        # EP all-to-all #2 (return): inverse of dispatch.
        out_buf = out_buf.reshape(E_loc, P, capacity, d)
        out_buf = jnp.moveaxis(out_buf, 1, 0)     # (P, E_loc, C, d)
        out_buf = lax.all_to_all(out_buf, axis, split_axis=0, concat_axis=0,
                                 tiled=True)
        out_buf = out_buf.reshape(E, capacity, d)

    # Gather back + weighted combine.
    gathered = out_buf[e_id.reshape(-1), slot_c.reshape(-1)]      # (T*k, d)
    gathered = jnp.where(valid.reshape(-1, 1), gathered, 0)
    gathered = gathered.reshape(T, k, d) * w[..., None].astype(x.dtype)
    return jnp.sum(gathered, axis=1), aux


def moe_ffn_union(x, w, ids, params, capacity: int):
    """Compact routed-union combine for offloaded serving: the expert
    stacks in ``params`` hold ONLY the ``U`` routed experts of this step
    (``w_gate``/``w_up`` ``(U, d, f)``, ``w_down`` ``(U, f, d)``) and
    ``ids`` (T, k) are remapped into ``[0, U)`` — so every dispatch
    buffer and einsum here is union-sized, never bank-sized.

    Bit parity with the full-bank ``moe_ffn`` path holds because (a) the
    caller passes the SAME router outputs ``w``/``ids`` (remap done
    outside), (b) ``capacity`` is computed from the FULL bank exactly as
    ``moe_ffn`` does, and (c) the id remap is order-preserving (sorted
    union -> rank), so the stable dispatch sort assigns identical slots
    and drops identical overflow tokens; each expert's batched einsum is
    independent of the other bank rows, so its values are unchanged."""
    T, d = x.shape
    U = params["w_gate"].shape[0]
    k = ids.shape[1]
    e_id, slot, valid = _dispatch_indices(ids, U, capacity)
    slot_c = jnp.minimum(slot, capacity - 1)
    buf = jnp.zeros((U, capacity, d), x.dtype)
    flat_t = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[e_id.reshape(-1), slot_c.reshape(-1)].add(
        jnp.where(valid.reshape(-1, 1), x[flat_t], 0))
    out_buf = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                          buf)
    gathered = out_buf[e_id.reshape(-1), slot_c.reshape(-1)]
    gathered = jnp.where(valid.reshape(-1, 1), gathered, 0)
    gathered = gathered.reshape(T, k, d) * w[..., None].astype(x.dtype)
    return jnp.sum(gathered, axis=1)


def moe_ffn_replicated(x, params, cfg: MoEConfig, *, axis: Optional[str]):
    """Decode-mode EP: tokens x (T, d) are *replicated* over ``axis`` while
    experts stay sharded.  Every shard routes all T tokens, computes only its
    local experts (capacity = T, zero drops), and contributions are merged
    with one tiny psum — the comm volume is O(T*d), not O(expert weights),
    which is the PIPO Appendix-D point about EP being offload-friendly.
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    P = axis_size(axis)
    E_loc = params["w_gate"].shape[0]
    assert E_loc * P == E

    logits = (x @ params["wg"]).astype(jnp.float32)
    w, ids = router_topk(logits, k)
    aux = load_balance_loss(logits, ids, E)

    capacity = T
    e_id, slot, valid = _dispatch_indices(ids, E, capacity)
    slot_c = jnp.minimum(slot, capacity - 1)
    buf = jnp.zeros((E, capacity, d), x.dtype)
    flat_t = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[e_id.reshape(-1), slot_c.reshape(-1)].add(
        jnp.where(valid.reshape(-1, 1), x[flat_t], 0))

    i = axis_index(axis)
    start = i * E_loc
    buf_loc = lax.dynamic_slice(buf, (start, 0, 0), (E_loc, capacity, d))
    out_loc = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                          buf_loc)

    rel_e = e_id - start
    mine = (rel_e >= 0) & (rel_e < E_loc) & valid
    gathered = out_loc[jnp.clip(rel_e, 0, E_loc - 1).reshape(-1),
                       slot_c.reshape(-1)]
    gathered = jnp.where(mine.reshape(-1, 1), gathered, 0)
    gathered = gathered.reshape(T, k, d) * w[..., None].astype(x.dtype)
    out = jnp.sum(gathered, axis=1)
    if axis is not None:
        out = lax.psum(out, axis)
    return out, aux


def moe_ffn_decode(x, params, cfg: MoEConfig, *, ep_axis, ff_axis,
                   combine_axes):
    """Decode-mode EP for pod-scale experts: tokens x (T, d) fully
    *replicated* over ``combine_axes``; experts sharded over ``ep_axis``
    AND each expert's ff dim sharded over ``ff_axis`` (expert tensor
    parallelism).  Every chip computes its expert slice for all T tokens;
    ONE psum over ``combine_axes`` merges both the within-expert ff
    partial sums and the cross-expert combine.  Comm volume is O(T*d) —
    independent of expert weights, the property that makes EP
    offload-friendly (paper Appendix D)."""
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    E_loc = params["w_gate"].shape[0]

    logits = (x @ params["wg"]).astype(jnp.float32)
    w, ids = router_topk(logits, k)
    aux = load_balance_loss(logits, ids, E)

    capacity = T
    e_id, slot, valid = _dispatch_indices(ids, E, capacity)
    slot_c = jnp.minimum(slot, capacity - 1)
    buf = jnp.zeros((E, capacity, d), x.dtype)
    flat_t = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[e_id.reshape(-1), slot_c.reshape(-1)].add(
        jnp.where(valid.reshape(-1, 1), x[flat_t], 0))

    i_ep = axis_index(ep_axis)
    start = i_ep * E_loc
    buf_loc = lax.dynamic_slice(buf, (start, 0, 0), (E_loc, capacity, d))
    # ff-sliced expert compute: g/u are FULL values for this chip's ff
    # coords (contraction over d is complete); down output is a partial
    # sum over ff, finalized by the psum below.
    g = jnp.einsum("ecd,edf->ecf", buf_loc, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf_loc, params["w_up"])
    part = jnp.einsum("ecf,efd->ecd", silu(g) * u, params["w_down"])

    rel_e = e_id - start
    mine = (rel_e >= 0) & (rel_e < E_loc) & valid
    gathered = part[jnp.clip(rel_e, 0, E_loc - 1).reshape(-1),
                    slot_c.reshape(-1)]
    gathered = jnp.where(mine.reshape(-1, 1), gathered, 0)
    gathered = gathered.reshape(T, k, d) * w[..., None].astype(x.dtype)
    out = lax.psum(jnp.sum(gathered, axis=1), combine_axes)
    return out, aux


def moe_ffn_dense_oracle(x, params_full, cfg: MoEConfig):
    """Oracle: every token through its top-k experts with no capacity, via a
    dense (T, E) loop.  For tests (small T, E)."""
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    logits = (x @ params_full["wg"]).astype(jnp.float32)
    w, ids = router_topk(logits, k)
    out = jnp.zeros((T, d), x.dtype)
    for e in range(E):
        ye = _expert_ffn(params_full["w_gate"][e:e + 1],
                         params_full["w_up"][e:e + 1],
                         params_full["w_down"][e:e + 1],
                         x[None])[0]                               # (T, d)
        for j in range(k):
            sel = (ids[:, j] == e)
            out = out + jnp.where(sel[:, None], ye * w[:, j:j + 1].astype(x.dtype), 0)
    return out
