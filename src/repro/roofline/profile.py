"""Per-op HBM/FLOP profile from compiled HLO — the dry-run 'profiler' the
§Perf hypothesis loop reads (no wall clocks on this container).

Aggregates bytes/flops per (op kind, shape) with while-loop trip-count
multipliers and attributes them to jax-level op_name metadata, so 'what
dominates the memory term' is answerable at the granularity of model code.
"""
from __future__ import annotations

import re
from collections import defaultdict

from repro.roofline.analysis import (_OPERAND_RE, _TRIP_RE, COLLECTIVES,
                                     _SKIP_TRAFFIC, _cond_trip_count,
                                     _dot_flops, _fusion_root,
                                     _instr_traffic, _shape_bytes_elems,
                                     parse_hlo)

_META_RE = re.compile(r'op_name="([^"]*)"')


def profile_hlo(text: str, top: int = 25) -> list[dict]:
    comps = parse_hlo(text)
    entry = next(c for c in comps.values() if c.entry)
    agg = defaultdict(lambda: {"bytes": 0.0, "flops": 0.0, "count": 0.0})

    def visit(comp, mult, depth=0):
        if depth > 64:
            return
        for ins in comp.instrs:
            op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if op.endswith("-done"):
                continue
            out_bytes, out_elems, _ = _shape_bytes_elems(ins.shape)
            if ins.op not in _SKIP_TRAFFIC:
                traffic = _instr_traffic(ins, comp, comps)
                meta = _META_RE.search(ins.rest)
                tag = meta.group(1) if meta else None
                disp_op = op
                if tag is None and ins.op == "fusion":
                    # name anonymous fusions by their root instruction
                    root, rc = _fusion_root(ins, comps)
                    if root is not None:
                        disp_op = f"fusion:{root.op}"
                        m2 = _META_RE.search(root.rest)
                        tag = m2.group(1) if m2 else None
                        if tag is None and rc is not None:
                            for sub in reversed(rc.instrs):
                                m3 = _META_RE.search(sub.rest)
                                if m3:
                                    tag = m3.group(1)
                                    break
                tag = tag or "(no-meta)"
                tag = "/".join(tag.split("/")[-4:])[:110]
                key = (disp_op, tag, ins.shape[:40])
                agg[key]["bytes"] += mult * traffic
                agg[key]["count"] += mult
                if op == "dot":
                    agg[key]["flops"] += mult * _dot_flops(ins, comp)
            if ins.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                mt = _TRIP_RE.search(ins.rest)
                trips = (int(mt.group(1)) if mt else
                         _cond_trip_count(comps[mc.group(1)])
                         if mc and mc.group(1) in comps else 1)
                if mb and mb.group(1) in comps:
                    visit(comps[mb.group(1)], mult * trips, depth + 1)
            elif ins.op == "call":
                m = re.search(r"to_apply=%?([\w\.\-]+)", ins.rest)
                if m and m.group(1) in comps:
                    visit(comps[m.group(1)], mult, depth + 1)

    visit(entry, 1.0)
    rows = [{"op": k[0], "tag": k[1], "shape": k[2], **v}
            for k, v in agg.items()]
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:top]


def print_profile(text: str, top: int = 25):
    rows = profile_hlo(text, top)
    total = sum(r["bytes"] for r in profile_hlo(text, 10_000))
    print(f"{'GB':>9} {'%':>5} {'x':>7}  op | shape | jax op_name")
    for r in rows:
        print(f"{r['bytes']/1e9:9.2f} {100*r['bytes']/total:5.1f} "
              f"{r['count']:7.0f}  {r['op']:28s} {r['shape']:36s} {r['tag']}")
    print(f"{total/1e9:9.2f} total GB")
    return rows
