from repro.roofline.analysis import (HW, analyze_hlo, roofline_report,
                                     model_flops)

__all__ = ["HW", "analyze_hlo", "roofline_report", "model_flops"]
