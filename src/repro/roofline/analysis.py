"""Static roofline analysis from compiled (post-SPMD) HLO text.

Why text parsing: ``compiled.cost_analysis()`` visits every instruction
*once* — a scanned 61-layer body is counted as one layer (verified
empirically).  We therefore parse the per-device HLO module, build the
computation call graph, recover `while` trip counts (from the
``known_trip_count`` backend config, falling back to the condition
computation's compare constant), and accumulate:

  * FLOPs: dot/convolution ops (2 * out_elems * contraction_elems) — the
    dominant term for transformer workloads;
  * HBM bytes: per top-level instruction, operand + output bytes (fusion
    internals excluded: a fusion reads its operands and writes its output
    once — exactly the HBM-traffic model);
  * collective link bytes per device, by kind, with ring-model factors.

Shapes in post-SPMD HLO are already per-device, so every figure is
per-chip.  Hardware model: TPU v5e-like (197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI, ~25 GB/s DCN for pod-spanning groups).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}


@dataclass
class HW:
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link (1-link model)
    dcn_bw: float = 25e9              # pod-spanning groups
    chips_per_pod: int = 256


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes_elems(shape_str: str):
    """'bf16[2,16,128]{1,0}' -> (bytes, elems, first-array dims).
    Tuple shapes are summed."""
    total_b, total_e = 0, 0
    dims_first = None
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        dims = [int(x) for x in dims_s.split(",") if x] if dims_s else []
        e = 1
        for d in dims:
            e *= d
        total_b += e * DTYPE_BYTES[dt]
        total_e += e
        if dims_first is None:
            dims_first = dims
    return total_b, total_e, (dims_first or [])


_REPL_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_REPL_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_TRAFFIC = ("parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "call", "conditional", "after-all",
                 "custom-call")


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    args: str          # operand list text (inside parens, unbalanced tail ok)
    rest: str          # everything after '=' (for attribute regexes)


@dataclass
class Computation:
    name: str
    entry: bool = False
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)


def _split_instr(line: str):
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    if not line.startswith("%"):
        return None
    eq = line.find(" = ")
    if eq < 0:
        return None
    name = line[:eq].strip().lstrip("%")
    rhs = line[eq + 3:]
    if rhs.startswith("("):
        depth = 0
        i = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        shape = rhs[:i + 1]
        rest = rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape = rhs[:sp]
        rest = rhs[sp + 1:].strip()
    par = rest.find("(")
    if par < 0:
        return None
    op = rest[:par].strip()
    args = rest[par + 1:]
    return name, shape, op, args, rest


def parse_hlo(text: str) -> dict:
    comps = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        if cur is None:
            if (s.startswith("%") or s.startswith("ENTRY")) and s.endswith("{"):
                entry = s.startswith("ENTRY")
                name = s.split()[1 if entry else 0].split("(")[0].lstrip("%")
                if not name:
                    name = s.split()[1].lstrip("%").split("(")[0]
                cur = Computation(name=name, entry=entry)
                comps[name] = cur
            continue
        if s == "}":
            cur = None
            continue
        parsed = _split_instr(line)
        if parsed:
            nm, shape, op, args, rest = parsed
            ins = Instr(nm, shape, op, args, rest)
            cur.instrs.append(ins)
            cur.shapes[nm] = shape
    return comps


def _group_size(rest: str, total_devices: int) -> int:
    m = _REPL_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _REPL_BRACE_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _collective_bytes(op: str, out_bytes: int, p: int) -> float:
    if p <= 1:
        return 0.0
    if op == "all-gather":
        return out_bytes * (p - 1) / p
    if op == "all-reduce":
        return 2.0 * out_bytes * (p - 1) / p
    if op == "reduce-scatter":
        return out_bytes * (p - 1)
    if op == "all-to-all":
        return out_bytes * (p - 1) / p
    if op == "collective-permute":
        return float(out_bytes)
    return 0.0


def _dot_flops(ins: Instr, comp: Computation) -> float:
    _, out_e, _ = _shape_bytes_elems(ins.shape)
    first_op = _OPERAND_RE.search(ins.args)
    lhs_shape = comp.shapes.get(first_op.group(1), "") if first_op else ""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    _, _, lhs_dims = _shape_bytes_elems(lhs_shape)
    contract = 1
    if m and lhs_dims:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    return 2.0 * out_e * contract


def _fusion_root(ins: Instr, comps: dict):
    m = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
    if m and m.group(1) in comps:
        c = comps[m.group(1)]
        if c.instrs:
            return c.instrs[-1], c
    return None, None


def _sliced_param_reads(fused: Computation) -> dict:
    """parameter index -> bytes actually read, for fusion parameters whose
    only uses are dynamic-slice ops (XLA reads the slice region, not the
    whole — scanning stacked weights would otherwise count 80x)."""
    pidx = {}
    for ins in fused.instrs:
        if ins.op == "parameter":
            m = re.search(r"^\s*(\d+)", ins.args)
            if m:
                pidx[ins.name] = int(m.group(1))
    uses = {name: [] for name in pidx}
    for ins in fused.instrs:
        for opname in _OPERAND_RE.findall(ins.args):
            if opname in uses:
                uses[opname].append(ins)
    out = {}
    for name, idx in pidx.items():
        us = uses[name]
        if us and all(u.op == "dynamic-slice" for u in us):
            out[idx] = sum(_shape_bytes_elems(u.shape)[0] for u in us)
    return out


def _instr_traffic(ins: Instr, comp: Computation, comps: dict,
                   skip=frozenset()) -> float:
    """HBM bytes for one top-level instruction.

    Corrections to the naive operand+output model (each was an order-of-
    magnitude miscount, found via roofline/profile.py):
      * in-place updates (dynamic-update-slice / scatter, incl. fusions
        rooted in one) alias the big operand: traffic = small operands +
        update-sized write;
      * fusion parameters consumed only through dynamic-slice read the
        slice region, not the full (stacked) array."""
    out_bytes, _, _ = _shape_bytes_elems(ins.shape)
    operand_sizes = []
    for opname in _OPERAND_RE.findall(ins.args):
        if opname in comp.shapes:
            b = 0 if opname in skip else \
                _shape_bytes_elems(comp.shapes[opname])[0]
            operand_sizes.append(b)
    op = ins.op
    root_op = op
    fused = None
    if op == "fusion":
        root, fused = _fusion_root(ins, comps)
        if root is not None:
            root_op = root.op
    if op == "dynamic-slice" and operand_sizes:
        return out_bytes * 2.0
    if fused is not None:
        sliced = _sliced_param_reads(fused)
        for idx, rd in sliced.items():
            if idx < len(operand_sizes):
                operand_sizes[idx] = min(operand_sizes[idx], rd)
    if root_op in ("dynamic-update-slice", "scatter") and operand_sizes:
        big = max(operand_sizes)
        rest = sum(operand_sizes) - big
        return 2.0 * rest + max(0, out_bytes - big)
    return out_bytes + sum(operand_sizes)


def _cond_trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"^\s*(-?\d+)", ins.args)
            if m:
                best = max(best, int(m.group(1)))
    return best


def f32_shadow_bytes(text: str) -> int:
    """Total bytes of f32 buffers produced by bf16->f32 `convert` ops.

    XLA:CPU has no native bf16 dot: it materializes f32 copies of bf16
    operands and hoists loop-invariant ones out of while loops (it also
    strips optimization barriers, so this can't be prevented at HLO
    level).  On TPU these converts don't exist — the MXU consumes bf16
    directly — so this figure is subtracted to produce the TPU-adjusted
    memory estimate reported next to the raw CPU one.
    """
    comps = parse_hlo(text)
    total = 0
    for c in comps.values():
        for ins in c.instrs:
            if ins.op != "convert" or not ins.shape.startswith("f32"):
                continue
            src = _OPERAND_RE.search(ins.args)
            if not src:
                continue
            src_shape = c.shapes.get(src.group(1), "")
            if src_shape.startswith("bf16"):
                b, _, _ = _shape_bytes_elems(ins.shape)
                total += b
    return total


def analyze_hlo(text: str, total_devices: int, hw: HW = HW()) -> dict:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    acc = {"flops": 0.0, "hbm_bytes": 0.0, "cast_bytes": 0.0,
           "ici_bytes": 0.0, "dcn_bytes": 0.0, "coll_count": 0.0}

    def visit(comp: Computation, mult: float, depth=0):
        if depth > 64:
            return
        # values produced inside a `vreg_fused_*` scope never hit HBM: they
        # model the Pallas kernels (kernels/) that unpack/scale INT4 in
        # VREGs — only the packed operands cross HBM.  Consumers of these
        # values skip the corresponding operand bytes.
        vreg_names = {ins.name for ins in comp.instrs
                      if "vreg_fused" in ins.rest}
        for ins in comp.instrs:
            op = ins.op
            if op.endswith("-start"):
                op = op[:-6]
            if op.endswith("-done"):
                continue
            out_bytes, out_elems, _ = _shape_bytes_elems(ins.shape)
            if op in COLLECTIVES:
                p = _group_size(ins.rest, total_devices)
                link = _collective_bytes(op, out_bytes, p)
                spans_pod = p > hw.chips_per_pod
                key = "dcn_bytes" if spans_pod else "ici_bytes"
                acc[key] += mult * link
                acc["coll_" + op] = acc.get("coll_" + op, 0.0) + mult * link
                acc["coll_count"] += mult
            if op == "dot":
                acc["flops"] += mult * _dot_flops(ins, comp)
            elif op == "convolution":
                first = _OPERAND_RE.findall(ins.args)
                ker = comp.shapes.get(first[1], "") if len(first) > 1 else ""
                _, ker_e, ker_dims = _shape_bytes_elems(ker)
                ch_out = ker_dims[-1] if ker_dims else 1
                acc["flops"] += mult * 2.0 * out_elems * max(
                    1, ker_e // max(1, ch_out))
            if ins.op not in _SKIP_TRAFFIC:
                if "vreg_fused" in ins.rest:
                    # only the packed/scale operands are HBM reads
                    rd = 0
                    for opname in _OPERAND_RE.findall(ins.args):
                        if opname in comp.shapes and opname not in vreg_names:
                            rd += _shape_bytes_elems(comp.shapes[opname])[0]
                    acc["hbm_bytes"] += mult * rd
                    continue
                traffic = mult * _instr_traffic(ins, comp, comps,
                                                skip=vreg_names)
                root_op = op
                if ins.op == "fusion":
                    root, _ = _fusion_root(ins, comps)
                    if root is not None:
                        root_op = root.op
                if root_op == "convert":
                    # bf16<->f32 casts: XLA:CPU artifacts (no native bf16
                    # dot); the MXU consumes bf16 directly -> separate
                    # bucket, excluded from the TPU memory term.
                    acc["cast_bytes"] += traffic
                else:
                    acc["hbm_bytes"] += traffic
            # ---- recursion ----
            if ins.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trips = int(mt.group(1))
                elif mc and mc.group(1) in comps:
                    trips = _cond_trip_count(comps[mc.group(1)])
                else:
                    trips = 1
                if mb and mb.group(1) in comps:
                    visit(comps[mb.group(1)], mult * trips, depth + 1)
            elif ins.op == "call":
                m = re.search(r"to_apply=%?([\w\.\-]+)", ins.rest)
                if m and m.group(1) in comps:
                    visit(comps[m.group(1)], mult, depth + 1)
            elif ins.op == "conditional":
                for b in re.findall(
                        r"(?:branch_computations=\{|true_computation=|"
                        r"false_computation=)([^,}]+)", ins.rest):
                    for name in b.split(","):
                        name = name.strip().lstrip("%")
                        if name in comps:
                            visit(comps[name], mult, depth + 1)

    visit(entry, 1.0)
    return acc


def roofline_report(acc: dict, hw: HW = HW()) -> dict:
    t_comp = acc["flops"] / hw.peak_flops
    t_mem = acc["hbm_bytes"] / hw.hbm_bw
    t_coll = acc["ici_bytes"] / hw.ici_bw + acc["dcn_bytes"] / hw.dcn_bw
    bound = max(("compute", t_comp), ("memory", t_mem),
                ("collective", t_coll), key=lambda kv: kv[1])
    return {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_memory_cpu_cast_s": acc.get("cast_bytes", 0.0) / hw.hbm_bw,
        "t_collective_s": t_coll,
        "bottleneck": bound[0],
        "t_bound_s": bound[1],
        **acc,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D (train), 2·N_active·D (prefill),
    2·N_active·b (decode step) — whole-job figures (all chips)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * shape.global_batch
