"""Tokenized training data pipeline.

Production posture: per-host sharding (each host reads only its slice of
the global batch), deterministic step-indexed sampling (resume needs no
iterator state — the checkpoint stores only the step), and a background
prefetch thread that keeps ``prefetch`` batches ready while the device
computes (the data-side of PIPO's overlap discipline).

Sources: SyntheticSource (zipf-ish token stream for benches/examples) and
MemmapSource (a flat token .bin on disk, read via np.memmap — real disk
I/O on this container).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab_size: int = 32000
    host_index: int = 0
    host_count: int = 1
    prefetch: int = 2
    seed: int = 0


class SyntheticSource:
    """Deterministic pseudo-corpus: step+index-seeded zipf-ish tokens."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def sample(self, step: int, index: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + index)
        # zipf-flavored distribution clipped to vocab
        z = rng.zipf(1.3, size=cfg.seq_len + 1)
        return np.minimum(z - 1, cfg.vocab_size - 1).astype(np.int32)


class MemmapSource:
    """Flat int32 token file; window sampling by deterministic offsets."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        assert len(self.tokens) > cfg.seq_len + 1, "corpus too small"

    @staticmethod
    def write_corpus(path: str, tokens: np.ndarray):
        np.asarray(tokens, np.int32).tofile(path)

    def sample(self, step: int, index: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + index)
        off = int(rng.integers(0, len(self.tokens) - cfg.seq_len - 1))
        return np.asarray(self.tokens[off:off + cfg.seq_len + 1],
                          np.int32)


class DataPipeline:
    """Iterator of {tokens, labels} host-local batches with prefetch."""

    def __init__(self, source, cfg: DataConfig):
        self.source = source
        self.cfg = cfg
        assert cfg.global_batch % cfg.host_count == 0
        self.local_batch = cfg.global_batch // cfg.host_count
        self._q: queue.Queue = queue.Queue(maxsize=max(1, cfg.prefetch))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_step = 0

    def _make(self, step: int) -> dict:
        cfg = self.cfg
        rows = []
        for i in range(self.local_batch):
            gidx = cfg.host_index * self.local_batch + i
            rows.append(self.source.sample(step, gidx))
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:], "step": step}

    def start(self, from_step: int = 0):
        self._next_step = from_step
        self._stop.clear()

        def loop():
            s = from_step
            while not self._stop.is_set():
                try:
                    self._q.put(self._make(s), timeout=0.1)
                    s += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def __next__(self) -> dict:
        if self._thread is None:
            b = self._make(self._next_step)
            self._next_step += 1
            return b
        return self._q.get()

    def batch_at(self, step: int) -> dict:
        """Random access (deterministic resume verification)."""
        return self._make(step)

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None
