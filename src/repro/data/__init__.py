from repro.data.pipeline import (DataConfig, SyntheticSource, MemmapSource,
                                 DataPipeline)

__all__ = ["DataConfig", "SyntheticSource", "MemmapSource", "DataPipeline"]
