"""AdamW in pure JAX with global-norm clipping and schedules.

Moments are fp32 regardless of parameter dtype (bf16 params + fp32 m/v).
The ZeRO-style sharding of the moment pytree is applied at jit boundary
(launch/sharding.py::zero_pspecs) — the math here is sharding-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / max(1, warmup))
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


@dataclass(frozen=True)
class AdamW:
    lr: Union[float, Callable] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9)) \
            if self.clip_norm else 1.0
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m / (1 - self.b1 ** step.astype(jnp.float32))
            vh = v / (1 - self.b2 ** step.astype(jnp.float32))
            u = mh / (jnp.sqrt(vh) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m, v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return updates, {"m": new_m, "v": new_v, "step": step}, gn


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
