"""Adafactor-style optimizer: factored second moment + bf16 momentum.

Why it exists here: fp32 Adam moments for a 671B-param model are 5.4 TB —
21 GB/chip on a 256-chip pod even perfectly sharded, alone exceeding v5e
HBM.  Factoring V into row/col statistics (Shazeer & Stern, arXiv:1804.04235)
drops second-moment storage to ~(rows+cols) and bf16 momentum halves the
first moment: the dry-run memory_analysis for deepseek-v3/jamba train only
closes with this optimizer (see EXPERIMENTS.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.optim.adamw import global_norm


@dataclass(frozen=True)
class Adafactor:
    lr: Union[float, Callable] = 1e-3
    b1: float = 0.9              # bf16 momentum (0 disables)
    decay: float = 0.99          # second-moment decay
    eps: float = 1e-30
    weight_decay: float = 0.0
    clip_norm: float = 1.0

    def _factored(self, shape):
        return len(shape) >= 2

    def init(self, params):
        def leaf(p):
            st = {}
            if self.b1:
                st["m"] = jnp.zeros(p.shape, jnp.bfloat16)
            if self._factored(p.shape):
                st["vr"] = jnp.zeros(p.shape[:-1], jnp.float32)
                st["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            else:
                st["v"] = jnp.zeros(p.shape, jnp.float32)
            return st
        return {"s": jax.tree.map(leaf, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9)) \
            if self.clip_norm else 1.0
        lr = self.lr(step) if callable(self.lr) else self.lr
        d = self.decay

        def leaf(g, st, p):
            g = g.astype(jnp.float32) * scale
            new = {}
            if self._factored(g.shape):
                vr = d * st["vr"] + (1 - d) * jnp.mean(jnp.square(g), -1)
                vc = d * st["vc"] + (1 - d) * jnp.mean(jnp.square(g), -2)
                new["vr"], new["vc"] = vr, vc
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(jnp.mean(vr, -1, keepdims=True)[..., None],
                                  self.eps) + self.eps)
            else:
                v = d * st["v"] + (1 - d) * jnp.square(g)
                new["v"] = v
                denom = jnp.sqrt(v + self.eps)
            u = g / denom
            if self.b1:
                m = self.b1 * st["m"].astype(jnp.float32) + (1 - self.b1) * u
                new["m"] = m.astype(jnp.bfloat16)
                u = m
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), new

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["s"])
        flat_p = treedef.flatten_up_to(params)
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        new_s = treedef.unflatten([o[1] for o in out])
        return updates, {"s": new_s, "step": step}, gn
