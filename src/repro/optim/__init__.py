from repro.optim.adamw import AdamW, apply_updates, cosine_schedule, global_norm

__all__ = ["AdamW", "apply_updates", "cosine_schedule", "global_norm"]
