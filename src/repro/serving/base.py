"""Slot-based continuous batching shared by both serving engines.

``SlotEngineBase`` owns everything that is *scheduling policy*, not
compute: the request queue, slot assignment, ragged per-slot positions,
completion/preemption bookkeeping, and slot-granularity KV spill/restore
orchestration.  Concrete engines supply the compute:

  * ``ServingEngine`` (serving.engine) — fully-resident weights, one jitted
    whole-model decode per step.  Fastest when the model fits in device
    memory.
  * ``OffloadedServingEngine`` (serving.offload_engine) — weights live on
    host/disk tiers and stream through the PIPO ``PipelineScheduler``
    per layer.  Serves models larger than device memory.

Slot KV offload runs as PIPO ``KV_SAVE`` tasks on a transfer pool when one
is provided (``kv_pool``), overlapping the device->host spill with the
next decode steps instead of blocking the batch; admission to a spilled
slot synchronizes on exactly the pending save task (task-level sync, the
paper's §3.1.2 principle at request scope).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.offload import HostStore
from repro.core.pipeline import ThreadPool
from repro.core.tasks import Task, TaskType


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (s,) int32
    max_new: int = 32
    eos_id: int = -1                   # -1: never stops early
    # filled by the engine
    out: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    # preemption state: >= 0 means this request's KV rows are spilled to the
    # host store (keyed by rid) and it resumes via restore, not prefill
    preempt_pos: int = -1
    resume_token: int = -1


class SlotEngineBase:
    """Continuous batching over a fixed decode batch (b_max): requests
    queue in; a free slot triggers a b=1 prefill; each engine step decodes
    ALL active slots with ragged per-slot positions; completed slots free
    immediately (no padding to the slowest request)."""

    def __init__(self, cfg, *, b_max: int = 4, max_len: int = 256,
                 kv_pool: Optional[ThreadPool] = None):
        self.cfg = cfg
        self.b_max = b_max
        self.max_len = max_len
        self.host = HostStore()
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * b_max
        self.pos = np.zeros(b_max, np.int32)           # next write position
        self.tokens = np.zeros(b_max, np.int32)        # last emitted token
        self.stats: Dict[str, int] = {
            "prefills": 0, "decode_steps": 0, "tokens_out": 0,
            "slot_saves": 0, "slot_restores": 0}
        self._kv_pool = kv_pool
        self._slot_saves: Dict[int, Task] = {}

    # ---- engine-specific compute (implemented by subclasses) ---------------
    def _prefill_into_slot(self, slot: int, req: Request) -> int:
        """Run the prompt, scatter KV rows into the slot; returns the first
        generated token."""
        raise NotImplementedError

    def _decode_active(self, active: List[int]) -> np.ndarray:
        """One batched decode step over all slots; returns (b_max,) next
        tokens (values at inactive slots are ignored)."""
        raise NotImplementedError

    def offload_slot(self, slot: int):
        """KV-save: spill a slot's cache rows to host memory keyed by the
        occupying request's rid (the PIPO KV-save task at request scope)."""
        rid = self.slots[slot].rid if self.slots[slot] else slot
        self._offload_write(rid, self._offload_snapshot(slot))

    def restore_slot(self, slot: int, rid: int):
        """KV-load: bring an offloaded request's rows back into a slot."""
        raise NotImplementedError

    def _offload_snapshot(self, slot: int):
        """Capture whatever the spill needs *now* (cheap; no copies for
        immutable caches) so the write can run on a transfer thread."""
        raise NotImplementedError

    def _offload_write(self, rid: int, snapshot):
        raise NotImplementedError

    # ---- public API ---------------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self._admit()
            self._decode_step(done)
        return done

    def preempt_slot(self, slot: int):
        """Spill an active request's KV rows and push it back to the queue
        head; it resumes later via restore_slot (no re-prefill)."""
        req = self.slots[slot]
        assert req is not None, f"slot {slot} not active"
        self._sync_slot(slot)
        self.offload_slot(slot)                 # sync spill, keyed by rid
        self.stats["slot_saves"] += 1
        req.preempt_pos = int(self.pos[slot])
        req.resume_token = int(self.tokens[slot])
        self.queue.insert(0, req)
        self.slots[slot] = None
        self.pos[slot] = 0

    # ---- internals ----------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _sync_slot(self, slot: int):
        """Wait for any in-flight async spill of this slot's previous
        occupant before its rows are reused."""
        t = self._slot_saves.pop(slot, None)
        if t is not None:
            t.wait()

    def _admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            self._sync_slot(slot)
            if req.preempt_pos >= 0:            # resume a preempted request
                self.restore_slot(slot, req.rid)
                self.stats["slot_restores"] += 1
                self.pos[slot] = req.preempt_pos
                self.tokens[slot] = req.resume_token
                req.preempt_pos = -1
                self.slots[slot] = req
                continue
            tok = self._prefill_into_slot(slot, req)
            self.stats["prefills"] += 1
            req.out.append(tok)
            req.t_first = time.perf_counter()
            self.slots[slot] = req
            self.pos[slot] = len(req.prompt)
            self.tokens[slot] = tok
            self.stats["tokens_out"] += 1

    def _decode_step(self, done: List[Request]):
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        nt = self._decode_active(active)
        self.stats["decode_steps"] += 1
        for i in active:
            req = self.slots[i]
            req.out.append(int(nt[i]))
            self.stats["tokens_out"] += 1
            self.pos[i] += 1
            self.tokens[i] = int(nt[i])
            if (len(req.out) >= req.max_new
                    or int(nt[i]) == req.eos_id
                    or self.pos[i] >= self.max_len - 1):
                req.t_done = time.perf_counter()
                done.append(req)
                self._release_slot(i)

    def _release_slot(self, slot: int):
        """Free a finished slot; the KV spill overlaps with the next decode
        steps when a transfer pool is available."""
        rid = self.slots[slot].rid
        self.stats["slot_saves"] += 1
        if self._kv_pool is not None:
            snap = self._offload_snapshot(slot)
            t = Task(TaskType.KV_SAVE, f"slot_save[{rid}]",
                     lambda rid=rid, snap=snap: self._offload_write(rid, snap))
            self._kv_pool.submit(t, priority=1)   # behind loads, per §3.2.1
            self._slot_saves[slot] = t
        else:
            self.offload_slot(slot)
        self.slots[slot] = None
        self.pos[slot] = 0

    def shutdown(self):
        for t in self._slot_saves.values():
            t.wait()
        self._slot_saves.clear()
