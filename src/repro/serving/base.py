"""Slot-based continuous batching shared by both serving engines.

``SlotEngineBase`` owns everything that is *scheduling policy*, not
compute: the request queue, slot assignment, ragged per-slot positions,
completion/preemption bookkeeping, and slot-granularity KV spill/restore
orchestration.  Concrete engines supply the compute:

  * ``ServingEngine`` (serving.engine) — fully-resident weights, one jitted
    whole-model decode per step.  Fastest when the model fits in device
    memory.
  * ``OffloadedServingEngine`` (serving.offload_engine) — weights live on
    host/disk tiers and stream through the PIPO ``PipelineScheduler``
    per layer.  Serves models larger than device memory.

Slot KV offload runs as PIPO ``KV_SAVE`` tasks on a transfer pool when one
is provided (``kv_pool``), overlapping the device->host spill with the
next decode steps instead of blocking the batch; admission to a spilled
slot synchronizes on exactly the pending save task (task-level sync, the
paper's §3.1.2 principle at request scope).  The offloaded engine's
spill/restore hooks route through its ``core.kvstore.TieredKVStore``
(rows spill packed under ``kv_mode="int4"``); this class only owns the
namespace/LRU/pinning policy, so the same invariants are testable on a
virtual clock with a fake compute engine (tests/test_kvstore.py).

Warm-pipeline engines (OffloadedServingEngine with
``PipelineScheduler(warm=True, depth=D)``) carry in-flight cross-step
state between the steps this class drives: up to D weight preloads and
the window's KV preloads.  Any path here that mutates KV rows outside
the pipeline (restore into a slot, spill reads) must go through the
engine's drain hooks (``drain_saves`` + ``drop_kv_preloads``) first —
with D > 1 there are *several* stale preloads to discard, not one.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.kvstore import PhasedKVExtents
from repro.core.offload import HostStore
from repro.core.pipeline import ThreadPool
from repro.core.tasks import Task, TaskType


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (s,) int32
    max_new: int = 32
    eos_id: int = -1                   # -1: never stops early
    # enc-dec architectures (whisper): precomputed encoder frames
    # (enc_len, d_model); None = zero-frame stub (frontends are stubs
    # per assignment).  Ignored by decoder-only configs.
    enc_embeds: Optional[np.ndarray] = None
    # filled by the engine
    out: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    # per-request latency accounting (both engines, same fields, so TTFT
    # parity is comparable engine-to-engine): ``t_arrive`` is the
    # request's scheduled arrival — a workload driver sets it BEFORE
    # submit to charge queue wait to the request; submit defaults it to
    # t_submit.  ``t_first_token`` mirrors t_first (kept separate so the
    # legacy field keeps its exact historical meaning); ``t_tokens``
    # records one timestamp per emitted token for TBT percentiles.
    t_arrive: float = 0.0
    t_first_token: float = 0.0
    t_tokens: List[float] = field(default_factory=list)
    # preemption state: >= 0 means this request's KV rows are spilled to
    # the host store under ``spill_ns`` and it resumes via restore, not
    # prefill.  The namespace (not the bare rid) is recorded at spill
    # time: rids may be reused across run() epochs, and a parked request
    # must find *its* rows even after the epoch advanced.
    preempt_pos: int = -1
    resume_token: int = -1
    spill_ns: str = ""


class SlotEngineBase(PhasedKVExtents):
    """Continuous batching over a fixed decode batch (b_max): requests
    queue in; a free slot triggers a b=1 prefill; each engine step decodes
    ALL active slots with ragged per-slot positions; completed slots free
    immediately (no padding to the slowest request).

    Thread affinity: the whole scheduling loop (``submit``/``run``/
    ``preempt_slot``) runs on the caller's (main) thread; only slot KV
    spills execute on ``kv_pool`` transfer threads when one is attached.

    Slot KV spills live in ``self.host`` under per-epoch namespaces
    (``e{epoch}/slot{rid}/...``): the epoch advances on every ``run()``
    call, so clients that reuse rids across runs can never alias a stale
    spill.  ``spill_cap`` bounds how many spill namespaces are retained —
    least-recently-written namespaces are evicted first, except those of
    currently-parked (preempted) requests, whose rows are still needed to
    resume."""

    def __init__(self, cfg, *, b_max: int = 4, max_len: int = 256,
                 kv_pool: Optional[ThreadPool] = None, spill_cap: int = 32):
        self.cfg = cfg
        self.b_max = b_max
        self.max_len = max_len
        self.spill_cap = spill_cap
        self.host = HostStore()
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * b_max
        self.pos = np.zeros(b_max, np.int32)           # next write position
        self.tokens = np.zeros(b_max, np.int32)        # last emitted token
        self.stats: Dict[str, int] = {
            "prefills": 0, "prefill_chunks": 0, "decode_steps": 0,
            "tokens_out": 0, "slot_saves": 0, "slot_restores": 0,
            "spill_evictions": 0}
        self._kv_pool = kv_pool
        self._slot_saves: Dict[int, Task] = {}
        self._epoch = 0
        self._spill_lru: "OrderedDict[str, bool]" = OrderedDict()
        self._ns_saves: Dict[str, Task] = {}

    # ---- engine-specific compute (implemented by subclasses) ---------------
    def _prefill_into_slot(self, slot: int, req: Request) -> int:
        """Run the prompt, scatter KV rows into the slot; returns the first
        generated token.  Main thread."""
        raise NotImplementedError

    def _decode_active(self, active: List[int]) -> np.ndarray:
        """One batched decode step over all slots; returns (b_max,) next
        tokens (values at inactive slots are ignored).  Main thread."""
        raise NotImplementedError

    def _spill_ns(self, rid: int) -> str:
        """Host-store namespace for a spill happening NOW: epoch-scoped so
        rids reused across run() epochs can never collide."""
        return f"e{self._epoch}/slot{rid}"

    def offload_slot(self, slot: int):
        """KV-save: spill a slot's cache rows to host memory under the
        occupying request's epoch namespace (the PIPO KV-save task at
        request scope).  Synchronous; main thread."""
        rid = self.slots[slot].rid if self.slots[slot] else slot
        ns = self._spill_ns(rid)
        self._offload_write(ns, self._offload_snapshot(slot))
        self._record_spill(ns)

    def restore_slot(self, slot: int, ns: str):
        """KV-load: bring an offloaded request's rows (spill namespace
        ``ns``, see ``_spill_ns``) back into a slot.  Main thread;
        blocking."""
        raise NotImplementedError

    def _offload_snapshot(self, slot: int):
        """Capture whatever the spill needs *now* (cheap; no copies for
        immutable caches) so the write can run on a transfer thread.
        Main thread."""
        raise NotImplementedError

    def _offload_write(self, ns: str, snapshot):
        """Write a snapshot's rows under host keys ``{ns}/...``.  Runs on
        a transfer-pool thread when ``kv_pool`` is attached, else on the
        main thread."""
        raise NotImplementedError

    # ---- public API ---------------------------------------------------------
    def submit(self, req: Request):
        """Enqueue a request (main thread; non-blocking)."""
        req.t_submit = time.perf_counter()
        if not req.t_arrive:
            req.t_arrive = req.t_submit
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive admission + decode until queue and slots drain (main
        thread; blocking).  Each call is a new spill *epoch*: fresh spill
        namespaces, so rids reused across runs can't alias old rows."""
        self._epoch += 1
        done: List[Request] = []
        for _ in range(max_steps):
            if self.idle():
                break
            self.step(done)
        return done

    def idle(self) -> bool:
        """True when there is nothing to do: empty queue, no occupied
        slots (main thread)."""
        return not self.queue and all(s is None for s in self.slots)

    def step(self, done: List[Request]):
        """One admission + decode step — the unit ``run()`` loops;
        public so workload drivers (``serving.workload.run_trace``) can
        interleave request arrivals with engine steps.  Main thread;
        completed requests are appended to ``done``."""
        self._admit()
        self._decode_step(done)

    def preempt_slot(self, slot: int):
        """Spill an active request's KV rows and push it back to the queue
        head; it resumes later via restore_slot (no re-prefill).  Main
        thread; the spill is synchronous."""
        req = self.slots[slot]
        assert req is not None, f"slot {slot} not active"
        assert slot != self._chunk_slot(), \
            "cannot preempt an in-flight chunked prefill"
        self._sync_slot(slot)
        # mark parked and enqueue BEFORE the spill is recorded: the LRU's
        # parked-pinning set is built from the queue, and the request's
        # own fresh spill must already be pinned when eviction runs
        req.spill_ns = self._spill_ns(req.rid)
        req.preempt_pos = int(self.pos[slot])
        req.resume_token = int(self.tokens[slot])
        self.queue.insert(0, req)
        self.offload_slot(slot)                 # sync spill, epoch-keyed
        self.stats["slot_saves"] += 1
        self.slots[slot] = None
        self.pos[slot] = 0

    # ---- internals ----------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _sync_slot(self, slot: int):
        """Wait for any in-flight async spill of this slot's previous
        occupant before its rows are reused."""
        t = self._slot_saves.pop(slot, None)
        if t is not None:
            t.wait()

    def _admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            if not self._admit_one(slot):
                return

    # chunked-admission hook outcomes (engines with a SchedPolicy seam
    # override _begin_chunked_prefill; the base never chunks)
    CHUNK_OFF = 0        # not chunking: run the monolithic prefill
    CHUNK_STARTED = 1    # slot claimed; first token comes at completion
    CHUNK_BUSY = 2       # a chunked prefill is in flight: stop admitting

    def _begin_chunked_prefill(self, slot: int, req: Request) -> int:
        """Claim ``slot`` for a chunked prefill of ``req`` (which is
        still at the queue head — the caller pops on STARTED/OFF)."""
        return self.CHUNK_OFF

    def _chunk_slot(self) -> Optional[int]:
        """Slot of the in-flight chunked prefill, or None.  The slot is
        occupied (reserved) but not decode-active until the prefill
        completes and ``_finish_prefill`` runs."""
        return None

    def _admit_one(self, slot: int) -> bool:
        """Admit the queue head into ``slot``; False stops this step's
        admission loop (a chunked prefill is already in flight)."""
        req = self.queue[0]
        if req.preempt_pos >= 0:                # resume a preempted request
            self.queue.pop(0)
            self._sync_slot(slot)
            self.restore_slot(slot, req.spill_ns)
            self._drop_spill(req.spill_ns)      # rows are back in the slot
            self.stats["slot_restores"] += 1
            self.pos[slot] = req.preempt_pos
            self.tokens[slot] = req.resume_token
            req.preempt_pos = -1
            req.spill_ns = ""
            self.slots[slot] = req
            return True
        state = self._begin_chunked_prefill(slot, req)
        if state == self.CHUNK_BUSY:
            return False
        self.queue.pop(0)
        self._sync_slot(slot)
        if state == self.CHUNK_STARTED:
            # reserve the slot; chunk steps run inside _decode_step and
            # the first token lands via _finish_prefill at completion
            self.slots[slot] = req
            self.pos[slot] = 0
            return True
        tok = self._prefill_into_slot(slot, req)
        self._finish_prefill(slot, req, tok)
        return True

    def _finish_prefill(self, slot: int, req: Request, tok: int):
        """Shared first-token bookkeeping: runs at monolithic-prefill
        admission AND at chunked-prefill completion, so both paths stamp
        identical timing fields and stats."""
        self.stats["prefills"] += 1
        req.out.append(tok)
        now = time.perf_counter()
        req.t_first = now
        req.t_first_token = now
        req.t_tokens.append(now)
        self.slots[slot] = req
        self.pos[slot] = len(req.prompt)
        self.tokens[slot] = tok
        self.stats["tokens_out"] += 1

    def _emitted_tokens(self, active: List[int],
                        nt: np.ndarray) -> Dict[int, List[int]]:
        """Tokens each active slot emitted this step, in stream order.
        The base emits exactly one per slot (``nt[i]``); speculative
        engines override to surface the whole accepted run of a
        draft-then-verify step (up to k+1 tokens)."""
        return {i: [int(nt[i])] for i in active}

    def _decode_step(self, done: List[Request]):
        # the chunked-prefill slot (if any) is occupied but not yet
        # decode-active: its chunk rides _decode_active's generate call
        # alongside the active batch, and the step must run even when the
        # chunk is the only work in the engine
        cslot = self._chunk_slot()
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and i != cslot]
        if not active and cslot is None:
            return
        nt = self._decode_active(active)
        if not active:
            return
        self.stats["decode_steps"] += 1
        emitted = self._emitted_tokens(active, nt)
        now = time.perf_counter()
        for i in active:
            req = self.slots[i]
            for tok in emitted[i]:
                req.out.append(int(tok))
                req.t_tokens.append(now)
                self.stats["tokens_out"] += 1
                self.pos[i] += 1
                self.tokens[i] = int(tok)
                # completion checks run per emitted token: a speculative
                # run past max_new/eos is cut exactly where sequential
                # decode would have stopped (surplus tokens discarded)
                if (len(req.out) >= req.max_new
                        or int(tok) == req.eos_id
                        or self.pos[i] >= self.max_len - 1):
                    req.t_done = now
                    done.append(req)
                    self._release_slot(i)
                    break

    def _release_slot(self, slot: int):
        """Free a finished slot; the KV spill overlaps with the next decode
        steps when a transfer pool is available.  Main thread; the write
        itself runs on a transfer thread when possible."""
        rid = self.slots[slot].rid
        self.stats["slot_saves"] += 1
        if self._kv_pool is not None:
            ns = self._spill_ns(rid)
            snap = self._offload_snapshot(slot)
            t = Task(TaskType.KV_SAVE, f"slot_save[{ns}]",
                     lambda ns=ns, snap=snap: self._offload_write(ns, snap))
            self._kv_pool.submit(t, priority=1)   # behind loads, per §3.2.1
            self._slot_saves[slot] = t
            self._ns_saves[ns] = t
            self._record_spill(ns)
        else:
            self.offload_slot(slot)
        self.slots[slot] = None
        self.pos[slot] = 0

    # ---- spill retention (LRU with parked-request pinning) ------------------
    def _record_spill(self, ns: str):
        """Mark ``ns`` most-recently-written and evict over-cap spills.
        Main thread."""
        self._spill_lru.pop(ns, None)
        self._spill_lru[ns] = True
        parked = {r.spill_ns for r in self.queue if r.preempt_pos >= 0}
        while len(self._spill_lru) > self.spill_cap:
            victim = next((n for n in self._spill_lru if n not in parked),
                          None)
            if victim is None:
                return          # every retained spill is resumable: keep all
            self._spill_lru.pop(victim)
            t = self._ns_saves.pop(victim, None)
            if t is not None:
                t.wait()        # never delete under an in-flight write
            self._delete_spill_keys(victim)
            self.stats["spill_evictions"] += 1

    def _drop_spill(self, ns: str):
        """Forget a namespace after its rows were restored into a slot."""
        self._spill_lru.pop(ns, None)
        t = self._ns_saves.pop(ns, None)
        if t is not None:
            t.wait()
        self._delete_spill_keys(ns)

    def _delete_spill_keys(self, ns: str):
        for k in list(self.host.keys()):
            if k.startswith(ns + "/"):
                self.host.delete(k)

    def shutdown(self):
        """Drain in-flight slot spills (main thread; blocking)."""
        for t in self._slot_saves.values():
            t.wait()
        self._slot_saves.clear()
        self._ns_saves.clear()
