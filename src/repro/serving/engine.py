"""Resident-weight continuous-batching serving engine.

All parameters stay in device memory; each engine step decodes ALL active
slots with *ragged* per-slot positions (one jitted whole-model decode for
the batch).  Slot admission / completion / preemption policy lives in
``serving.base.SlotEngineBase``; the offloaded twin that streams weights
through the PIPO pipeline is ``serving.offload_engine``.

Slot KV spill/restore (``offload_slot``/``restore_slot``) snapshots the
immutable cache pytree, so when a transfer pool is attached the spill runs
as a PIPO KV_SAVE task overlapping subsequent decode steps.

This engine also carries the architectures the offloaded engine can't
(``serving.spec.offload_capability``): encoder-decoder stacks (whisper —
per-request ``Request.enc_embeds`` frames, zero-frame stub when absent)
and embeds-frontend configs (qwen2-vl — token prompts run through the
shared embedding table, the text-only stub), so ``create_engine`` has a
resident fallback for every registry config.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pipeline import ThreadPool
from repro.models import Dist, build_model
from repro.serving.base import Request, SlotEngineBase
from repro.serving.spec import ResolvedPlan

__all__ = ["Request", "ServingEngine", "KVRoundtripServingEngine"]


class ServingEngine(SlotEngineBase):
    def __init__(self, cfg: "ModelConfig | ResolvedPlan", *, b_max: int = 4,
                 max_len: int = 256, seed: int = 0,
                 kv_pool: Optional[ThreadPool] = None, spill_cap: int = 32):
        if isinstance(cfg, ResolvedPlan):
            self.plan: Optional[ResolvedPlan] = cfg
            cfg = self.plan.model_config()
            b_max, max_len = self.plan.b_max, self.plan.max_len
            seed, spill_cap = self.plan.seed, self.plan.spill_cap
        else:
            self.plan = None
        super().__init__(cfg, b_max=b_max, max_len=max_len, kv_pool=kv_pool,
                         spill_cap=spill_cap)
        self.dist = Dist.local()
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed), jnp.float32)
        if self.plan is not None and self.plan.moe_quant:
            # INT4-resident MoE: pack the routed expert stacks once at
            # load; decode unpacks them through the fused-int4 path
            from repro.serving.spec import quant_policy_for
            self.params = quant_policy_for(
                self.plan.quant, self.plan.kv_mode,
                self.plan.moe_quant).prepare_moe_params(self.params)
        self.caches = self.model.init_cache(
            b_max, max_len, cfg.encoder_seq_len if cfg.enc_dec else None)
        self._jit()

    def _jit(self):
        m, dist = self.model, self.dist

        def decode(params, tok, pos, caches):
            return m.decode_step(params, {"token": tok, "pos": pos}, caches,
                                 dist)
        self._decode = jax.jit(decode, donate_argnums=(3,))

        def prefill1(params, batch, cache_len):
            return m.prefill(params, batch, dist, cache_len)
        self._prefill = jax.jit(prefill1, static_argnums=(2,))

    def _prefill_batch(self, req: Request) -> dict:
        """b=1 prompt batch: token prompts always embed through the
        shared table (the text-only stub for embeds-frontend configs);
        enc-dec configs additionally carry encoder frames — the
        request's ``enc_embeds`` or a zero-frame stub."""
        batch = {"tokens": jnp.asarray(req.prompt)[None]}
        if self.cfg.enc_dec:
            enc = req.enc_embeds
            if enc is None:
                enc = np.zeros((self.cfg.encoder_seq_len, self.cfg.d_model),
                               np.float32)
            batch["enc_embeds"] = jnp.asarray(enc)[None]
        return batch

    # ---- compute ------------------------------------------------------------
    def _prefill_into_slot(self, slot: int, req: Request) -> int:
        nt, cache1 = self._prefill(self.params, self._prefill_batch(req),
                                   self.max_len)
        # scatter the b=1 cache rows into the slot (KV "admission")
        self.caches = self._map_slot(
            self.caches, cache1,
            lambda big, one, idx: big.at[idx].set(one.astype(big.dtype)),
            slot)
        return int(np.asarray(nt)[0])

    def _decode_active(self, active: List[int]) -> np.ndarray:
        tok = jnp.asarray(self.tokens)[:, None]
        pos = jnp.asarray(self.pos)
        nt, self.caches = self._decode(self.params, tok, pos, self.caches)
        return np.asarray(nt)

    # ---- slot cache plumbing ------------------------------------------------
    @staticmethod
    def _batch_axis(path) -> int:
        """Cache leaves under 'pat' are stacked (periods, b, ...); under
        'rem' they are (b, ...)."""
        head = str(getattr(path[0], "key", getattr(path[0], "idx", path[0])))
        return 1 if head == "pat" else 0

    def _map_slot(self, big_tree, one_tree, fn, slot):
        flat_big, treedef = jax.tree_util.tree_flatten_with_path(big_tree)
        flat_one = treedef.flatten_up_to(one_tree) if one_tree is not None \
            else [None] * len(flat_big)
        out = []
        for (path, big), one in zip(flat_big, flat_one):
            ax = self._batch_axis(path)
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(slot, slot + 1)
            out.append(fn(big, one, tuple(idx)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ---- PIPO KV offload at slot granularity --------------------------------
    def _offload_snapshot(self, slot: int):
        # Slice the slot's rows into fresh device arrays NOW: ``_decode`` is
        # jitted with donate_argnums, so the current cache buffers are
        # deleted by the next decode step — a bare reference would be read
        # after free on the transfer thread.  The slices are small
        # device-side copies; the expensive device->host transfer still
        # happens on the pool thread.
        flat_big, _ = jax.tree_util.tree_flatten_with_path(self.caches)
        rows = []
        for path, leaf in flat_big:
            ax = self._batch_axis(path)
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slot
            rows.append(leaf[tuple(idx)])
        for r in rows:
            r.block_until_ready()
        return rows

    def _offload_write(self, ns: str, rows):
        """Device->host spill of one slot's cache rows under ``{ns}/{i}``
        keys.  Runs on a transfer-pool thread when kv_pool is attached."""
        for i, row in enumerate(rows):
            self.host.put(f"{ns}/{i}", np.asarray(row))

    def restore_slot(self, slot: int, ns: str):
        """KV-load: bring an offloaded request's rows (namespace ``ns``)
        back into a slot.  Main thread; blocking."""
        flat_big, treedef = jax.tree_util.tree_flatten_with_path(self.caches)
        out = []
        for i, (path, leaf) in enumerate(flat_big):
            ax = self._batch_axis(path)
            row = jnp.asarray(self.host.get(f"{ns}/{i}"))
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slot
            out.append(leaf.at[tuple(idx)].set(row.astype(leaf.dtype)))
        self.caches = jax.tree_util.tree_unflatten(
            treedef, out)


class KVRoundtripServingEngine(ServingEngine):
    """The ``kv_mode="int4"`` parity reference: a resident engine whose
    newly-written cache rows are roundtripped through the EXACT
    quantize->dequantize the tiered KV store applies to streamed rows
    (``core.kvstore.kv_roundtrip_rows``) — once per row, right after it
    is written, mirroring the store's quantize-at-save discipline.  An
    offloaded engine with ``kv_mode="int4"`` must decode token-identical
    to this reference (the KV analogue of ``quant_roundtrip_params`` for
    weights; asserted per depth x weight-quant in
    tests/test_serving_offload.py).

    Only sequence-extent (kind ``"kv"``) leaves with an even feature
    count roundtrip — the same ``kv_eligible`` predicate the store uses,
    so the two can never drift."""

    def __init__(self, cfg, **kw):
        super().__init__(cfg, **kw)
        from repro.models import transformer as T
        _, self._kv_kinds = T.cache_struct(
            self.cfg, self.b_max, self.max_len,
            self.cfg.encoder_seq_len if self.cfg.enc_dec else None)

    def _leaf_kind(self, path) -> str:
        head = str(getattr(path[0], "key", path[0]))
        idx = int(getattr(path[1], "idx", getattr(path[1], "key", path[1])))
        name = str(getattr(path[2], "key", path[2]))
        return self._kv_kinds[head][idx][name]

    def _roundtrip_slot_rows(self, slot: int, pos=None):
        """Roundtrip slot ``slot``'s eligible cache rows in place: every
        position (after a prefill scattered the whole slot row) or just
        position ``pos`` (after a decode step wrote one row)."""
        from repro.core.kvstore import kv_eligible, kv_roundtrip_rows
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.caches)
        out = []
        for path, leaf in flat:
            ax = self._batch_axis(path)
            kind = self._leaf_kind(path)
            feat = leaf.shape[ax + 2:]
            if not kv_eligible(kind, feat):
                out.append(leaf)
                continue
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slot
            if pos is not None:
                idx[ax + 1] = pos
            rows = np.asarray(leaf[tuple(idx)])
            f = int(np.prod(feat))
            lead = rows.shape[:rows.ndim - len(feat)]
            rt = kv_roundtrip_rows(rows.reshape(lead + (f,)))
            rt = rt.reshape(rows.shape)
            out.append(leaf.at[tuple(idx)].set(
                jnp.asarray(rt).astype(leaf.dtype)))
        self.caches = jax.tree_util.tree_unflatten(treedef, out)

    def _prefill_into_slot(self, slot: int, req: Request) -> int:
        tok = super()._prefill_into_slot(slot, req)
        self._roundtrip_slot_rows(slot)
        return tok

    def _decode_active(self, active):
        nt = super()._decode_active(active)
        for s in active:
            # base increments pos AFTER this returns: pos[s] is the row
            # this step just wrote — roundtrip it exactly once
            self._roundtrip_slot_rows(s, int(self.pos[s]))
        return nt
