"""Continuous-batching serving engine with PIPO-style KV host offload.

Slot-based continuous batching over a fixed decode batch (b_max):
  * requests queue in; a free slot triggers a b=1 prefill whose KV rows are
    scattered into the slot of the shared decode cache;
  * each engine step decodes ALL active slots with *ragged* per-slot
    positions (one jitted decode for the whole batch);
  * completed slots are freed immediately (no padding to the slowest
    request);
  * preempted/finished slots can spill their KV rows to the HostStore and
    restore on resume (``offload_slot``/``restore_slot``) — the PIPO
    KV-save/KV-load tasks at serving granularity.

The engine is single-device (the paper's setting); the pod-scale decode
path lives in launch/ + models (sharded caches).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.offload import HostStore
from repro.models import Dist, build_model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (s,) int32
    max_new: int = 32
    eos_id: int = -1                   # -1: never stops early
    # filled by the engine
    out: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, *, b_max: int = 4,
                 max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.b_max = b_max
        self.max_len = max_len
        self.dist = Dist.local()
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed), jnp.float32)
        self.caches = self.model.init_cache(b_max, max_len)
        self.host = HostStore()
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * b_max
        self.pos = np.zeros(b_max, np.int32)           # next write position
        self.tokens = np.zeros(b_max, np.int32)        # last emitted token
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens_out": 0}
        self._jit()

    def _jit(self):
        m, dist = self.model, self.dist

        def decode(params, tok, pos, caches):
            return m.decode_step(params, {"token": tok, "pos": pos}, caches,
                                 dist)
        self._decode = jax.jit(decode, donate_argnums=(3,))

        def prefill1(params, toks, cache_len):
            return m.prefill(params, {"tokens": toks}, dist, cache_len)
        self._prefill = jax.jit(prefill1, static_argnums=(2,))

    # ---- public API ---------------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self._admit()
            self._decode_step(done)
        return done

    # ---- internals ----------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            s = len(req.prompt)
            nt, cache1 = self._prefill(self.params,
                                       jnp.asarray(req.prompt)[None],
                                       self.max_len)
            self.stats["prefills"] += 1
            # scatter the b=1 cache rows into the slot (KV "admission")
            self.caches = self._map_slot(
                self.caches, cache1,
                lambda big, one, idx: big.at[idx].set(one.astype(big.dtype)),
                slot)
            tok = int(np.asarray(nt)[0])
            req.out.append(tok)
            req.t_first = time.perf_counter()
            self.slots[slot] = req
            self.pos[slot] = s
            self.tokens[slot] = tok
            self.stats["tokens_out"] += 1

    @staticmethod
    def _batch_axis(path) -> int:
        """Cache leaves under 'pat' are stacked (periods, b, ...); under
        'rem' they are (b, ...)."""
        head = str(getattr(path[0], "key", getattr(path[0], "idx", path[0])))
        return 1 if head == "pat" else 0

    def _map_slot(self, big_tree, one_tree, fn, slot):
        flat_big, treedef = jax.tree_util.tree_flatten_with_path(big_tree)
        flat_one = treedef.flatten_up_to(one_tree) if one_tree is not None \
            else [None] * len(flat_big)
        out = []
        for (path, big), one in zip(flat_big, flat_one):
            ax = self._batch_axis(path)
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(slot, slot + 1)
            out.append(fn(big, one, tuple(idx)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def _decode_step(self, done: List[Request]):
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        tok = jnp.asarray(self.tokens)[:, None]
        pos = jnp.asarray(self.pos)
        nt, self.caches = self._decode(self.params, tok, pos, self.caches)
        self.stats["decode_steps"] += 1
        nt = np.asarray(nt)
        for i in active:
            req = self.slots[i]
            req.out.append(int(nt[i]))
            self.stats["tokens_out"] += 1
            self.pos[i] += 1
            self.tokens[i] = int(nt[i])
            if (len(req.out) >= req.max_new
                    or int(nt[i]) == req.eos_id
                    or self.pos[i] >= self.max_len - 1):
                req.t_done = time.perf_counter()
                done.append(req)
                self.offload_slot(i)
                self.slots[i] = None
                self.pos[i] = 0

    # ---- PIPO KV offload at slot granularity --------------------------------
    def offload_slot(self, slot: int):
        """KV-save: spill a slot's cache rows to host memory (freeing the
        device rows for reuse; the PIPO KV-save task at request scope)."""
        rid = self.slots[slot].rid if self.slots[slot] else slot
        flat_big, _ = jax.tree_util.tree_flatten_with_path(self.caches)
        for i, (path, leaf) in enumerate(flat_big):
            ax = self._batch_axis(path)
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slot
            self.host.put(f"slot{rid}/{i}", np.asarray(leaf[tuple(idx)]))

    def restore_slot(self, slot: int, rid: int):
        """KV-load: bring an offloaded request's rows back into a slot."""
        flat_big, treedef = jax.tree_util.tree_flatten_with_path(self.caches)
        out = []
        for i, (path, leaf) in enumerate(flat_big):
            ax = self._batch_axis(path)
            row = jnp.asarray(self.host.get(f"slot{rid}/{i}"))
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slot
            out.append(leaf.at[tuple(idx)].set(row.astype(leaf.dtype)))
        self.caches = jax.tree_util.tree_unflatten(
            treedef, out)
