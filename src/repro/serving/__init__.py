from repro.serving.base import Request, SlotEngineBase
from repro.serving.engine import ServingEngine
from repro.serving.offload_engine import OffloadedServingEngine

__all__ = ["Request", "SlotEngineBase", "ServingEngine",
           "OffloadedServingEngine"]
