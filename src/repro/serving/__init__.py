from repro.serving.base import Request, SlotEngineBase
from repro.serving.spec import (AdaptiveDepth, EngineSpec, PreloadPolicy,
                                Pressure, QuantPolicy, ResolvedPlan,
                                SpecError, StaticDepth,
                                UnsupportedModelError, WeightsInt4,
                                build_lm, create_engine)
from repro.serving.engine import KVRoundtripServingEngine, ServingEngine
from repro.serving.offload_engine import OffloadedServingEngine

__all__ = ["Request", "SlotEngineBase", "ServingEngine",
           "KVRoundtripServingEngine", "OffloadedServingEngine",
           "EngineSpec", "ResolvedPlan",
           "SpecError", "UnsupportedModelError", "create_engine",
           "build_lm", "PreloadPolicy", "StaticDepth", "AdaptiveDepth",
           "Pressure", "QuantPolicy", "WeightsInt4"]
