"""Offloaded continuous-batching serving engine: the PIPO pipeline under a
serving workload.

Where ``ServingEngine`` keeps every parameter resident, this engine keeps
only the embedding/final-norm (and MoE routers) on device; each
transformer layer's weights live as ONE merged buffer (+manifest) on the
host or disk tier (``TieredWeightStore``, shared with
``core.engine.PipelinedLM``) and stream through the 3-thread
``ThreadPool`` + ``PipelineScheduler`` per decode step.  The per-layer KV
cache lives in host memory and moves as ``KV_LOAD``/``KV_SAVE`` pipeline
tasks, so the repo can serve models whose weights + KV exceed device
memory — the paper's headline scenario.

Warm pipeline (default in performance mode): the scheduler persists
across ``generate()`` calls (``PipelineScheduler(warm=True)``), so while
step *t*'s tail layers compute, step *t+1*'s first weight/KV loads are
already in flight — steady-state decode pays no cold-start transfer
bubble per token (ROADMAP item; FlexInfer-style cross-step preloading).
Disable with ``warm=False`` to reproduce the cold per-step baseline.

Preload depth (``depth``): how many layers' transfers the pipeline keeps
in flight beyond the computing one (``depth + 1`` resident).  The
default ``depth=None`` sizes it from the memory budget
(``autoconfig.serving_preload_depth``: device headroom after the KV
cache, host headroom after ``spill_cap`` retained spills, quant mode);
pass an int (or ``launch.serve --preload-depth``) to override.  On
weight-dominated links depth >= 2 keeps multiple transfer workers busy
and cuts ms/step below the paper's two-resident-layer invariant — see
docs/TUNING.md.

INT4 weight streaming (``quant="int4"``): eligible 2-D projections are
stored packed (uint8 nibbles + groupwise scales), so only a quarter-ish
of the FP32 bytes cross the offload link; the dequant runs on a
transfer-pool thread as one jitted op overlapping the main thread's
compute (paper §3.4).  Decoded tokens are bit-identical to a resident
engine holding the same quantize->dequantize roundtripped weights
(``quant_roundtrip_params`` builds that reference).

MoE layers load only the *union of routed experts* per step (paper
Appendix C.4, ported from ``core.engine.PipelinedLM``): the tiny router
stays device-resident, each expert is its own tiered buffer, and after
the gate runs (the paper's sync point) only the experts the batch routed
to are submitted as WEIGHT_LOAD tasks — the shared expert computes while
they stream.  Union bytes << whole-bank bytes at decode batch sizes.

Tiered KV (``core.kvstore.TieredKVStore``): the per-unit decode cache is
owned by the store, not the engine.  KV_LOAD payloads are sliced to the
LIVE extent — occupied slots × written positions, zero-padded back to
the slab shape device-side so the jitted decode fns never retrace — and
``kv_mode="int4"`` (``--kv-mode int4``) stores/streams cache rows packed
with the dequant fused into the decode jit.  Trace events carry the live
extent and the exact link bytes; ``AdaptiveDepth`` prices its window
from those measured bytes plus a bytes/busy bandwidth EWMA fed back from
the Trace each step (see ``_observe_trace``).

Numerics are *identical* to the resident engine: both run the same
``models.layers`` / ``models.moe`` functions on params from the same
``model.init`` seed, so decoded tokens match exactly (asserted in
tests/test_serving_offload.py).

Pipeline modes (pick with ``pipeline=``):
  * "performance" — preload layer j+1's weights during layer j's compute;
    highest throughput, two layers resident (default; ``warm`` adds the
    cross-step preload on top).
  * "memory"      — single layer resident, KV-save synchronized; lowest
    device footprint.
  * "sequential"  — FlexGen-like full serialization; baseline for the
    utilization benchmark (Fig. 9 analogue in benchmarks/run.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MOE, ModelConfig, LayerSpec
from repro.core.draft import accepted_tokens
from repro.core.kvstore import TieredKVStore, kv_roundtrip_traceable
from repro.core.offload import DeviceStore, DiskStore
from repro.core.pipeline import PipelineScheduler, StagedScheduler, ThreadPool
from repro.core.tasks import Task, TaskType, Trace, _merged_busy
from repro.core.transfer import TieredWeightStore, int4_roundtrip
from repro.models import Dist, build_model
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import transformer as T
from repro.models.common import silu
from repro.serving.base import Request, SlotEngineBase
from repro.serving.spec import (AdaptiveDepth, EngineSpec, Pressure,
                                ResolvedPlan, StaticDepth,
                                UnsupportedModelError, draft_policy_for,
                                offload_capability, preload_policy_for,
                                quant_policy_for, sched_policy_for,
                                spec_decode_capability,
                                warn_deprecated_once)

__all__ = ["Request", "OffloadedServingEngine", "quant_roundtrip_params"]

# the pre-spec constructor signature's defaults: the deprecation shim
# overlays provided kwargs on these so a legacy call resolves to the
# exact plan the old constructor would have acted on (kv_mode post-dates
# the shim but rides it for test ergonomics: None = auto -> fp32)
_LEGACY_DEFAULTS = dict(
    b_max=4, max_len=256, seed=0, placement="host", pipeline="performance",
    quant=None, kv_mode=None, fused_int4=True, warm=None, depth=None,
    disk_root="", block_bytes=None, n_io_threads=3,
    cold_reads=False, sim_bw=None, spill_cap=32)


@dataclass
class _Unit:
    """One schedulable layer: period ``p`` of pattern position ``q``
    ('pat'), or remainder layer q ('rem').  MoE layers additionally carry
    a device-resident router and one tiered store key per expert."""
    group: str          # "pat" | "rem"
    p: int              # period index (0 for rem)
    q: int              # pattern / remainder position
    spec: LayerSpec
    key: str            # TieredWeightStore key (mixer + norms + shared)
    moe: bool = False
    router: Any = None                     # device (d, E) gate weights
    expert_keys: List[str] = field(default_factory=list)


def quant_roundtrip_params(cfg: ModelConfig, params):
    """INT4 quantize->dequantize exactly the leaves the offloaded engine
    streams as INT4 — per-layer 2-D projections and per-expert MoE slices
    — leaving embeddings/final-norm/routers (device-resident, never
    streamed) untouched.  Feeding the result to a resident
    ``ServingEngine`` builds the reference the INT4 offloaded engine must
    match token-for-token (tests/test_serving_offload.py)."""
    def do_tab(tab, spec, stacked):
        out = {}
        for name, leaf in tab.items():
            arr = np.asarray(leaf)
            if spec.ffn == MOE and name == "wg":
                out[name] = leaf                      # router: resident
            elif spec.ffn == MOE and name in ("w_gate", "w_up", "w_down"):
                if stacked:                           # (periods, E, ..)
                    new = np.stack([
                        np.stack([int4_roundtrip(arr[p, e])
                                  for e in range(arr.shape[1])])
                        for p in range(arr.shape[0])])
                else:
                    new = np.stack([int4_roundtrip(arr[e])
                                    for e in range(arr.shape[0])])
                out[name] = jnp.asarray(new)
            elif stacked:
                out[name] = jnp.asarray(np.stack(
                    [int4_roundtrip(arr[p]) for p in range(arr.shape[0])]))
            else:
                out[name] = jnp.asarray(int4_roundtrip(arr))
        return out

    return {
        "embed": params["embed"],
        "final_norm": params["final_norm"],
        "pat": tuple(do_tab(params["pat"][q], cfg.pattern[q], True)
                     for q in range(len(cfg.pattern))),
        "rem": tuple(do_tab(params["rem"][q], cfg.remainder[q], False)
                     for q in range(len(cfg.remainder))),
    }


class _StagedWeightStore:
    """Key-routing facade over per-stage ``TieredWeightStore``s: each
    stage owns its own store (and therefore its own ``SimLink``), so N
    stages stream over N independent links — the aggregate-bandwidth
    mechanism of pipeline-parallel offload.  ``route(key) -> stage``
    parses the unit key; the host/device/disk tier OBJECTS are shared
    (keys are globally unique), only the link and IO workers split."""

    def __init__(self, stores, route):
        self.stores = list(stores)
        self._route = route

    def put(self, key: str, tensors):
        return self.stores[self._route(key)].put(key, tensors)

    def load(self, key: str):
        return self.stores[self._route(key)].load(key)

    def nbytes(self, key: str) -> int:
        return self.stores[self._route(key)].nbytes(key)


class _StagedKVStore:
    """Global-unit facade over per-stage ``TieredKVStore``s: unit-indexed
    calls route to the owning stage's store (stage-local index), slot
    ops fan out to every stage, and spill namespaces get a per-stage
    suffix so stage-local unit indices can't collide in the shared host
    tier (``{ns}/s{stage}/{unit}/{name}`` still matches the engine's
    prefix-based spill cleanup)."""

    _UNIT_METHODS = ("load", "load_nbytes", "slab_nbytes", "save_nbytes",
                     "prefill_save_nbytes", "dequant_nbytes",
                     "save_prefill", "save_prefill_batch", "save_decode",
                     "has_kv", "leaf_meta")

    def __init__(self, stores, bounds):
        self.stores = list(stores)
        self.bounds = [tuple(b) for b in bounds]
        self.b_max = self.stores[0].b_max
        self.max_len = self.stores[0].max_len
        self.kv_mode = self.stores[0].kv_mode
        for name in self._UNIT_METHODS:
            setattr(self, name, self._unit_call(name))

    def _unit_call(self, name):
        def call(j, *args, **kwargs):
            for (lo, hi), st in zip(self.bounds, self.stores):
                if lo <= j < hi:
                    return getattr(st, name)(j - lo, *args, **kwargs)
            raise IndexError(f"unit {j} outside staged bounds {self.bounds}")
        return call

    def __len__(self):
        return sum(len(st) for st in self.stores)

    @property
    def dequant_bytes_total(self) -> int:
        return sum(st.dequant_bytes_total for st in self.stores)

    def max_live_load_nbytes(self, live_b: int, live_len: int) -> int:
        return max(st.max_live_load_nbytes(live_b, live_len)
                   for st in self.stores)

    def host_nbytes(self) -> int:
        return sum(st.host_nbytes() for st in self.stores)

    def truncate(self, slot: int, new_len: int) -> None:
        for st in self.stores:
            st.truncate(slot, new_len)

    def spill(self, host, ns: str, slot: int) -> None:
        for s, st in enumerate(self.stores):
            st.spill(host, f"{ns}/s{s}", slot)

    def restore(self, host, ns: str, slot: int) -> None:
        for s, st in enumerate(self.stores):
            st.restore(host, f"{ns}/s{s}", slot)


class _MeshStagedScheduler(StagedScheduler):
    """``StagedScheduler`` whose activation handoff is a device-to-device
    ``device_put`` onto the receiving stage's device (round-robin over
    the local mesh; an on-device no-op when every stage shares one
    device, so single-GPU boxes still run the staged engine)."""

    def __init__(self, *args, devices=None, **kwargs):
        super().__init__(*args, **kwargs)
        self.devices = list(devices or [])

    def handoff(self, stage: int, it: int, x):
        if self.devices and x is not None:
            return jax.device_put(x, self.devices[stage % len(self.devices)])
        return x


class OffloadedServingEngine(SlotEngineBase):
    """See module docstring.  Main-thread object: all public methods run
    on the caller's thread; weight/KV transfers run on the internal
    3-thread pool per Algorithm 1."""

    def __init__(self, plan: "ResolvedPlan | ModelConfig", **legacy_kwargs):
        """Canonical construction takes ONE argument: a ``ResolvedPlan``
        (``EngineSpec.resolve()``; usually via
        ``serving.spec.create_engine``).  Passing a ``ModelConfig`` plus
        the pre-spec keyword arguments still works through a deprecation
        shim — the kwargs are converted to an ``EngineSpec`` and
        resolved, so both paths act on an identical plan (asserted in
        tests/test_spec.py)."""
        if isinstance(plan, ModelConfig):
            warn_deprecated_once(
                "OffloadedServingEngine.legacy_kwargs",
                "OffloadedServingEngine(cfg, **kwargs) is deprecated; "
                "build an EngineSpec and pass its resolved plan "
                "(serving.spec.create_engine) instead")
            unknown = set(legacy_kwargs) - set(_LEGACY_DEFAULTS)
            if unknown:
                raise TypeError(f"unknown kwargs {sorted(unknown)}")
            spec = EngineSpec(arch=plan.name, cfg=plan, offload=True,
                              **{**_LEGACY_DEFAULTS, **legacy_kwargs})
            plan = spec.resolve()
        elif legacy_kwargs:
            raise TypeError("plan construction takes no kwargs; set the "
                            "fields on the EngineSpec instead")
        cfg = plan.model_config()
        cap = offload_capability(cfg)
        if cap is not None or plan.engine != "offloaded":
            raise UnsupportedModelError(
                cap or "resident_plan",
                f"offloaded serving supports token-frontend rope decoder "
                f"stacks only (failing capability: {cap or plan.engine}; "
                f"arch {plan.arch}); create_engine(plan) falls back to "
                f"the resident ServingEngine")
        self.plan = plan
        self.preload_policy = preload_policy_for(plan, cfg)
        self.quant_policy = quant_policy_for(plan.quant, plan.kv_mode)
        self.n_stages = max(1, int(getattr(plan, "stages", 1) or 1))
        self.stage_bounds = self._make_stage_bounds(cfg, plan)
        self.trace = Trace()
        if self.n_stages > 1:
            # one transfer pool per stage, each sized to that stage's
            # window (per-stage warm windows; the StagePlan depths came
            # from the resolver's per-stage budget split)
            sd = ([p.depth for p in plan.stage_plan]
                  if len(plan.stage_plan) == self.n_stages
                  else [max(1, plan.depth)] * self.n_stages)
            self._stage_depths = [
                PipelineScheduler.clamp_depth(plan.pipeline, hi - lo, d)
                for (lo, hi), d in zip(self.stage_bounds, sd)]
            self._stage_pools = [
                ThreadPool(PipelineScheduler.pool_size(d), self.trace)
                for d in self._stage_depths]
            depth = max(self._stage_depths)
            pool = self._stage_pools[0]
        else:
            # window ceiling: adaptive policies may deepen later, so the
            # pool (and its KV headroom) is sized once for the policy's
            # max depth
            max_depth = PipelineScheduler.clamp_depth(
                plan.pipeline, self._n_units(cfg),
                self.preload_policy.max_depth())
            depth = PipelineScheduler.clamp_depth(
                plan.pipeline, self._n_units(cfg), max(1, plan.depth))
            self._stage_depths = [depth]
            self._stage_pools = []
            # pool sized to the window (depth weight loads + KV load +
            # KV save)
            pool = ThreadPool(
                PipelineScheduler.pool_size(max(depth, max_depth)),
                self.trace)
        super().__init__(cfg, b_max=plan.b_max, max_len=plan.max_len,
                         kv_pool=pool, spill_cap=plan.spill_cap)
        self.dist = Dist.local()
        self.model = build_model(cfg)
        self.pipeline_mode = plan.pipeline
        self.quant = plan.quant
        self.warm = plan.warm
        self.device = DeviceStore()
        self.disk = DiskStore(plan.disk_root)
        if self.n_stages > 1:
            # one tiered store per stage = one independent SimLink per
            # stage: each stage streams only its slice and the aggregate
            # host->device bandwidth scales with stage count
            self.weights = _StagedWeightStore(
                [TieredWeightStore(
                    placement=plan.placement, host=self.host,
                    device=self.device, disk=self.disk,
                    quant=self.quant_policy.weight_mode,
                    fused_int4=plan.fused_int4,
                    block_bytes=plan.block_bytes,
                    n_io_threads=plan.n_io_threads,
                    cold_reads=plan.cold_reads, sim_bw=plan.sim_bw)
                 for _ in range(self.n_stages)],
                lambda key: self._stage_of_unit(self._unit_of_key(key)))
        else:
            self.weights = TieredWeightStore(
                placement=plan.placement, host=self.host, device=self.device,
                disk=self.disk, quant=self.quant_policy.weight_mode,
                fused_int4=plan.fused_int4, block_bytes=plan.block_bytes,
                n_io_threads=plan.n_io_threads, cold_reads=plan.cold_reads,
                sim_bw=plan.sim_bw)
        params = self.model.init(jax.random.PRNGKey(plan.seed), jnp.float32)
        self._phase = "prefill"           # until the first _decode_active
        # chunked-prefill admission (SchedPolicy seam): at most ONE
        # prefill is in flight, advanced one chunk per engine step so it
        # shares the step's streamed weight window with the decode batch
        self.sched_policy = sched_policy_for(plan)
        self._chunk = None                # dict(slot, req, done, prefix)
        self._chunk_step = None           # (c0, c, final) during a step
        self._chunk_tok = 0               # first token, set at final chunk
        # bytes staged device-side into compact MoE combine stacks — the
        # |union|-proportionality proof (tests assert it equals loaded
        # experts x per-expert fp32 bytes, strictly below the full bank)
        self.stats["moe_stack_bytes"] = 0
        self.stats["preload_depth"] = depth
        self.stats["depth_resizes"] = 0
        self.units: List[_Unit] = []
        self._split_params(params)
        self._kv_init()
        assert len(self.units) == self._n_units(cfg)
        # live decode view, (scheduler iteration base, live_batch,
        # live_len): ONE tuple so transfer-thread reads are atomic under
        # the GIL.  Refreshed at the top of every _decode_active; a warm
        # tail preload for iteration base+1 prices itself at live_len+1
        # (the only way the extent can grow between steps without an
        # admission, and admissions drop KV preloads anyway).
        self._decode_view = (0, self.b_max, self.max_len)
        self._extent_memo: Dict[int, tuple] = {}
        # per-step Trace cursor + policy feedback (AdaptiveDepth only)
        self._trace_mark = 0
        if isinstance(self.preload_policy, AdaptiveDepth):
            self.preload_policy.set_link_profile(
                sum(self.weights.nbytes(u.key) for u in self.units)
                // max(1, len(self.units)))
        if self.n_stages > 1:
            from repro.launch.mesh import stage_devices
            self.sched = _MeshStagedScheduler(
                self.stage_bounds, plan.pipeline, pools=self._stage_pools,
                trace=self.trace, warm=self.warm,
                depths=self._stage_depths,
                devices=stage_devices(self.n_stages))
        else:
            self.sched = PipelineScheduler(len(self.units), plan.pipeline,
                                           pool=pool, trace=self.trace,
                                           warm=self.warm, depth=depth)
        # stamp the link/precision knobs next to the scheduler's context
        # so a dumped trace is self-describing for core.replay
        self.trace.meta.update(
            arch=plan.arch, b_max=plan.b_max, max_len=plan.max_len,
            sim_bw=plan.sim_bw, quant=plan.quant,
            kv_mode=plan.kv_mode or "fp32")
        self._jit_units()
        # speculative decoding: a device-resident draft proposes spec_k
        # tokens per step; the streamed target verifies them in one
        # ragged k+1-position pass (core.draft module docstring)
        self.draft = None
        self._spec_k = 0
        self._spec_s = 1                  # rows the current step writes
        self._spec_emitted = None         # per-slot tokens of the last step
        for key in ("spec_steps", "spec_proposed", "spec_accepted"):
            self.stats[key] = 0
        dp = draft_policy_for(plan)
        if dp is not None:
            self.attach_draft(
                dp.build(b_max=plan.b_max, max_len=plan.max_len), dp.k)

    @staticmethod
    def _n_units(cfg: ModelConfig) -> int:
        """Schedulable unit count (needed before the units are built, to
        size the transfer pool from the clamped preload depth)."""
        return cfg.num_periods * len(cfg.pattern) + len(cfg.remainder)

    # ---- pipeline-parallel staging ------------------------------------------
    def _make_stage_bounds(self, cfg: ModelConfig, plan) -> List[tuple]:
        """Contiguous per-stage unit ranges: the resolver's ``stage_plan``
        when it tiles this config, else a balanced split (a hand-built
        plan may carry ``stages`` without slices)."""
        nu = self._n_units(cfg)
        if self.n_stages <= 1:
            return [(0, nu)]
        sp = plan.stage_plan
        if (len(sp) == self.n_stages and sp[0].layer_lo == 0
                and sp[-1].layer_hi == nu):
            return [(p.layer_lo, p.layer_hi) for p in sp]
        return [(round(s * nu / self.n_stages),
                 round((s + 1) * nu / self.n_stages))
                for s in range(self.n_stages)]

    def _unit_of_key(self, key: str) -> int:
        """Global unit index of a tiered-store key (``u[p][q]``,
        ``rem[q]``, or an expert sub-key of either)."""
        import re
        base = key.split("/", 1)[0]
        nums = [int(x) for x in re.findall(r"\[(\d+)\]", base)]
        if base.startswith("u["):
            return nums[0] * len(self.cfg.pattern) + nums[1]
        return self.cfg.num_periods * len(self.cfg.pattern) + nums[0]

    def _stage_of_unit(self, j: int) -> int:
        for s, (lo, hi) in enumerate(self.stage_bounds):
            if lo <= j < hi:
                return s
        raise IndexError(f"unit {j} outside stage bounds "
                         f"{self.stage_bounds}")

    # ---- weight tiering -----------------------------------------------------
    def _maybe_quant(self, tensors):
        return self.quant_policy.prepare_unit(tensors)

    def _split_params(self, params):
        """Embeddings/final norm stay on device (small, needed every step);
        each layer's params merge into one tiered buffer.  MoE layers
        split further: the router stays on device (tiny; needed before
        any expert prefetch), each expert becomes its own tiered buffer so
        decode can load just the routed union (paper Appendix C.4).
        Main thread, build time only."""
        self.resident = {
            "embed": jax.device_put(params["embed"]),
            "final_norm": jax.device_put(params["final_norm"]),
        }
        cfg = self.cfg
        for p in range(cfg.num_periods):
            for q, spec in enumerate(cfg.pattern):
                key = f"u[{p}][{q}]"
                tensors = {name: np.asarray(leaf[p])
                           for name, leaf in params["pat"][q].items()}
                self.units.append(self._make_unit("pat", p, q, spec, key,
                                                  tensors))
        for q, spec in enumerate(cfg.remainder):
            key = f"rem[{q}]"
            tensors = {name: np.asarray(leaf)
                       for name, leaf in params["rem"][q].items()}
            self.units.append(self._make_unit("rem", 0, q, spec, key,
                                              tensors))

    def _make_unit(self, group, p, q, spec, key, tensors) -> _Unit:
        u = _Unit(group, p, q, spec, key)
        if spec.ffn == MOE:
            u.moe = True
            m = self.cfg.moe
            u.router = jax.device_put(jnp.asarray(tensors.pop("wg")))
            wga = tensors.pop("w_gate")
            wup = tensors.pop("w_up")
            wdn = tensors.pop("w_down")
            for e in range(m.num_experts):
                ek = f"{key}/exp[{e}]"
                self.weights.put(ek, self._maybe_quant(
                    {"w_gate": wga[e], "w_up": wup[e], "w_down": wdn[e]}))
                u.expert_keys.append(ek)
        self.weights.put(key, self._maybe_quant(tensors))
        return u

    # ---- tiered KV ----------------------------------------------------------
    def _kv_init(self):
        """Hand the per-unit decode cache to a ``TieredKVStore`` (the
        b_max cache the resident engine keeps on device, owned as a host
        tier here): live-row loads, INT4 row packing under
        ``kv_mode='int4'``, and slot spill/restore all route through it.
        KV shares the weight store's ``SimLink`` so both pay the same
        simulated interconnect."""
        struct, kinds = T.cache_struct(self.cfg, self.b_max, self.max_len)
        shapes, kk = [], []
        for u in self.units:
            sds = struct[u.group][u.q]
            shapes.append({n: ((s.shape[1:] if u.group == "pat"
                                else s.shape), s.dtype)
                           for n, s in sds.items()})
            kk.append(dict(kinds[u.group][u.q]))
        self.kv_kinds: List[Dict[str, str]] = kk
        if self.n_stages > 1:
            # one KV store per stage, sharing that stage's weight-store
            # SimLink so both directions pay the same per-stage link
            self.kvstore = _StagedKVStore(
                [TieredKVStore(
                    shapes[lo:hi], kk[lo:hi], b_max=self.b_max,
                    max_len=self.max_len,
                    kv_mode=self.quant_policy.kv_mode,
                    link=self.weights.stores[s].link)
                 for s, (lo, hi) in enumerate(self.stage_bounds)],
                self.stage_bounds)
        else:
            self.kvstore = TieredKVStore(
                shapes, kk, b_max=self.b_max, max_len=self.max_len,
                kv_mode=self.quant_policy.kv_mode, link=self.weights.link)

    # ---- jitted per-unit compute --------------------------------------------
    def _jit_units(self):
        cfg, dist = self.cfg, self.dist
        self._decode_fns = {}
        self._prefill_fns = {}
        self._chunk_fns = {}
        self._moe_fns = {}
        for j, u in enumerate(self.units):
            sig = (u.group, u.q)
            if sig in self._decode_fns:
                continue
            kinds = self.kv_kinds[j]
            # MoE units run the mixer through apply_layer with a DENSE ffn
            # spec: the base params carry no dense "w_gate", so the ffn
            # half no-ops and the MoE ffn runs in _compute_moe (expert
            # loads overlap compute there).
            spec = (LayerSpec(u.spec.mixer) if u.moe else u.spec)

            def decode_fn(w, x, cache, pos, angles, spec=spec, kinds=kinds):
                # INT4 KV already dequantized on the transfer thread
                # (kvstore.load, live rows only) — the cache arrives at
                # compute precision in every kv_mode.  kv_roundtrip hands
                # the speculative verify pass the tier's lossy write-back,
                # so its later queries attend the pass's earlier rows at
                # the precision sequential decode would reload them at
                ctx = L.Ctx(cfg=cfg, dist=dist, mode="decode", angles=angles,
                            pos=pos, batch_size=x.shape[0],
                            kv_roundtrip=kv_roundtrip_traceable
                            if self.quant_policy.kv_mode == "int4" else None)
                x, new_cache, _ = L.apply_layer(w, x, ctx, cache, spec)
                # gather only the newly written sequence rows so KV_SAVE
                # ships (b, s, ...) instead of the whole cache — s new
                # rows per slot at pos..pos+s-1 (s=1 plain decode, k+1
                # for a speculative verify pass)
                s = x.shape[1]
                rows = {}
                for name, kind in kinds.items():
                    leaf = new_cache[name]
                    if kind == "kv":
                        locs = pos.reshape(-1, 1) + jnp.arange(s)[None, :]
                        idx = locs.reshape((-1, s) + (1,) * (leaf.ndim - 2))
                        rows[name] = jnp.take_along_axis(
                            leaf, idx.astype(jnp.int32), axis=1)
                    else:
                        rows[name] = leaf
                return x, rows

            def prefill_fn(w, x, angles, spec=spec):
                ctx = L.Ctx(cfg=cfg, dist=dist, mode="prefill", angles=angles,
                            cache_len=self.max_len, batch_size=x.shape[0])
                x, new_cache, _ = L.apply_layer(w, x, ctx, None, spec)
                return x, new_cache

            def chunk_fn(w, x, pk, pv, angles, q_off):
                # one prefill CHUNK: rows q_off..q_off+c-1 attend the
                # engine-held fp32 prefix (earlier chunks' post-rope k/v)
                # plus themselves — bit-identical to the same rows of a
                # monolithic prefill (attention.chunk_prefill_attention).
                # Retraces per (prefix_len, chunk_len) shape pair, which
                # the fixed chunk cap bounds.
                ctx = L.Ctx(cfg=cfg, dist=dist, mode="prefill",
                            angles=angles, batch_size=x.shape[0])
                return L.apply_layer_chunk(w, x, ctx, pk, pv, q_off)

            self._decode_fns[sig] = jax.jit(decode_fn)
            self._prefill_fns[sig] = jax.jit(prefill_fn)
            self._chunk_fns[sig] = jax.jit(chunk_fn)
            if u.moe:
                self._moe_fns[sig] = self._jit_moe_fns()

        def embed_fn(emb_p, tok, mode):
            ctx = L.Ctx(cfg=cfg, dist=dist, mode=mode, batch_size=tok.shape[0])
            return L.embed_tokens(emb_p, tok, ctx)

        def head_fn(emb_p, fn_p, x):
            ctx = L.Ctx(cfg=cfg, dist=dist, mode="decode",
                        batch_size=x.shape[0])
            x = L.rms_norm(x, fn_p["scale"], cfg.norm_eps)
            return L.lm_head_argmax(emb_p, x[:, -1:], ctx)

        def spec_head_fn(emb_p, fn_p, x):
            # per-POSITION greedy argmax for the verify pass: reshape
            # (b, s, d) -> (b*s, 1, d) so every position goes through the
            # exact lm_head_argmax row arithmetic the plain head uses —
            # per-row numerics identical, hence token parity
            b, s, d = x.shape
            ctx = L.Ctx(cfg=cfg, dist=dist, mode="decode", batch_size=b * s)
            x = L.rms_norm(x, fn_p["scale"], cfg.norm_eps)
            return L.lm_head_argmax(
                emb_p, x.reshape(b * s, 1, d), ctx).reshape(b, s)

        self._embed = jax.jit(embed_fn, static_argnums=(2,))
        self._head = jax.jit(head_fn)
        self._spec_head = jax.jit(spec_head_fn)

    def _jit_moe_fns(self):
        """Four jitted stages replicating ``layers.apply_moe_ffn`` exactly
        (same ops, same order -> bit-identical to the resident engine)
        while exposing the gate output early enough to prefetch only the
        routed experts.  The combine is the compact ``moe_ffn_union``:
        its expert stacks are (|union|, ...)-shaped with remapped ids, so
        nothing bank-sized is ever materialized — it retraces per union
        size, which is bounded by ``num_experts`` distinct shapes."""
        cfg = self.cfg
        m = cfg.moe

        def pre_fn(w, x):
            return L.rms_norm(x, w["norm_ffn"], cfg.norm_eps)

        def gate_fn(xn, wg):
            b, s, d = xn.shape
            logits = (xn.reshape(b * s, d) @ wg).astype(jnp.float32)
            return moe_mod.router_topk(logits, m.top_k)

        def shared_fn(w, xn):
            if not m.num_shared:
                return jnp.zeros_like(xn)
            h = silu(xn @ w["ws_gate"]) * (xn @ w["ws_up"])
            return h @ w["ws_down"]

        def combine_fn(x, xn, gate_w, ids_u, wga, wup, wdn, shared_term):
            b, s, d = x.shape
            # full-bank capacity formula (moe_ffn's) — slot assignment and
            # overflow drops must match the resident path bit-for-bit
            capacity = int(m.capacity_factor * b * s * m.top_k
                           / m.num_experts) + 1
            out = moe_mod.moe_ffn_union(
                xn.reshape(b * s, d), gate_w, ids_u,
                dict(w_gate=wga, w_up=wup, w_down=wdn), capacity)
            x = x + out.reshape(b, s, d)
            if m.num_shared:
                x = x + shared_term
            return x

        return (jax.jit(pre_fn), jax.jit(gate_fn), jax.jit(shared_fn),
                jax.jit(combine_fn))

    # ---- PipelineScheduler callbacks ----------------------------------------
    def is_mha(self, j: int) -> bool:
        """'Has streamed KV state' in scheduler terms — true for every
        cached mixer (ATTN/MLA/SSM), so KV_LOAD/KV_SAVE are scheduled.
        Called on the main (submitter) thread."""
        return bool(self.kv_kinds[j])

    def load_weights(self, j: int):
        """WEIGHT_LOAD body: tier -> device for unit j's base buffer
        (mixer + norms + shared expert).  Transfer-pool thread; blocking
        on the simulated link."""
        return self.weights.load(self.units[j].key)

    def weight_nbytes(self, j: int) -> int:
        """Bytes unit j's base WEIGHT_LOAD moves (INT4: packed bytes) —
        recorded on trace events for transfer-volume assertions."""
        return self.weights.nbytes(self.units[j].key)

    def release_weights(self, j: int, handle):
        del handle  # device arrays freed by GC; tier stores unaffected

    def _live_extent(self, i: int):
        """(live_batch, live_len) iteration ``i``'s KV_LOAD ships.
        Computed from the atomic ``_decode_view`` snapshot — a warm tail
        preload (``i`` one past the current step's base) adds one
        position, the row the current step's save is writing, which the
        save-before-load check guarantees has landed before the preload
        executes — then MEMOIZED per iteration (first query wins, via
        setdefault): ``kv_nbytes`` prices the payload at submit time on
        the main thread and ``load_kv`` ships on a pool thread possibly
        after the view refreshed, and the two must agree or the trace
        would overstate what crossed (and bias the bandwidth EWMA).
        The memo only ever stores a superset-or-exact extent, so a
        later, smaller view never makes a priced load under-ship.  Any
        thread (dict ops atomic under the GIL)."""
        ext = self._extent_memo.get(i)
        if ext is None:
            base, lb, ll = self._decode_view
            ext = self._extent_memo.setdefault(
                i, (lb, min(ll + max(0, i - base), self.max_len)))
        return ext

    # ``kv_nbytes``/``kv_extent``/``kv_save_nbytes``/``load_kv`` come
    # from ``PhasedKVExtents`` (via SlotEngineBase — the phase-aware
    # logic shared with ``PipelinedLM``); the host hooks below feed it.
    # Loads return None outside decode (prefill builds, chunks extend,
    # caches in-pass) — warm cross-step preloads issued at the tail of a
    # monolithic prefill or a chunk-only step are therefore poisoned and
    # dropped before the next decode consumes them.
    def _kv_phase(self, i: int) -> str:
        return self._phase                # "prefill" | "decode" | "chunk"

    def _kv_live(self, i: int):
        return self._live_extent(i)

    def _kv_streams(self, j: int) -> bool:
        return bool(self.kv_kinds[j])

    def _kv_prefill_save_nbytes(self, j: int) -> int:
        return self.kvstore.prefill_save_nbytes(j)

    def _kv_chunk_save_nbytes(self, j: int) -> int:
        """The in-flight prefill chunk's KV append: one slot's ``c``
        fresh rows ride this step's KV_SAVE alongside the decode rows."""
        if self._chunk_step is None:
            return 0
        _, c, _ = self._chunk_step
        return self.kvstore.save_nbytes(j, 1, rows=c)

    def save_kv(self, i: int, j: int, new_kv):
        """KV_SAVE body: scatter freshly-written cache rows back into the
        tiered store (which quantizes them — once per row — under
        kv_mode='int4').  Transfer-pool thread; the scheduler guarantees
        the save lands before iteration i+1's KV_LOAD of the same
        unit."""
        phase, payload, meta = new_kv
        if phase == "prefill":
            slot = meta
            self.kvstore.save_prefill(
                j, slot, {n: np.asarray(l[0]) for n, l in payload.items()})
        elif phase == "mixed":
            # a step carrying a prefill chunk: the decode batch's rows
            # (when a decode rode along) plus the chunk's per-position
            # append — the same quantize-once ``save_decode`` row path,
            # so the stored bytes match a monolithic prefill's exactly
            if payload is not None:
                rows_d, (active, pos, live_b) = payload
                rows = {n: np.asarray(l[:live_b])
                        for n, l in rows_d.items()}
                self.kvstore.save_decode(j, rows, active, pos)
            k_ck, v_ck, slot, c0 = meta
            rows = {}
            for name, arr in (("k", k_ck), ("v", v_ck)):
                a = np.asarray(arr)                     # (1, c, *feat)
                buf = np.zeros((slot + 1,) + a.shape[1:], a.dtype)
                buf[slot] = a[0]
                rows[name] = buf
            self.kvstore.save_decode(
                j, rows, [slot], np.full(slot + 1, c0, np.int32))
        else:
            active, pos, live_b = meta
            rows = {n: np.asarray(l[:live_b])
                    for n, l in payload.items()}
            self.kvstore.save_decode(j, rows, active, pos)

    def compute(self, i: int, j: int, x, weights, kv):
        """COMPUTE body (main thread): one unit's jitted forward.  MoE
        units additionally gate, prefetch the routed-expert union through
        the pool, and combine (see _compute_moe)."""
        u = self.units[j]
        sig = (u.group, u.q)
        if self._phase == "prefill":
            x, cache1 = self._prefill_fns[sig](weights, x, self._angles)
            payload = ("prefill", cache1, self._slot)
        elif self._chunk_step is not None:
            return self._compute_mixed(sig, j, x, weights, kv)
        else:
            x, rows = self._decode_fns[sig](weights, x, kv, self._pos_dev,
                                            self._angles)
            payload = ("decode", rows,
                       (self._active, self._pos_snap, self._decode_view[1]))
        if u.moe:
            x = self._compute_moe(u, x, weights)
        return x, payload

    def _compute_mixed(self, sig, j: int, x, weights, kv):
        """One unit of a step carrying a prefill chunk (main thread):
        the decode batch (when present) and the chunk run back-to-back
        under the SAME streamed weights handle — one WEIGHT_LOAD per
        layer serves both, the tentpole invariant.  The chunk attends
        the engine-held fp32 prefix (earlier chunks' post-rope k/v —
        the same values a monolithic prefill attends in-pass) and the
        fresh rows append to the tiered store via the step's KV_SAVE.
        Capability gating guarantees dense global-attention units only
        (no MoE)."""
        x_dec, x_ck = x
        dec = None
        if x_dec is not None:
            x_dec, rows = self._decode_fns[sig](weights, x_dec, kv,
                                                self._pos_dev, self._angles)
            dec = (rows, (self._active, self._pos_snap,
                          self._decode_view[1]))
        pref = self._chunk["prefix"].get(j)
        pk, pv = pref if pref is not None else (None, None)
        c0, _, _ = self._chunk_step
        x_ck, k_ck, v_ck = self._chunk_fns[sig](
            weights, x_ck, pk, pv, self._chunk_angles, jnp.int32(c0))
        self._chunk["prefix"][j] = (
            k_ck if pk is None else jnp.concatenate([pk, k_ck], axis=1),
            v_ck if pv is None else jnp.concatenate([pv, v_ck], axis=1))
        ck = (k_ck, v_ck, self._chunk["slot"], c0)
        return (x_dec, x_ck), ("mixed", dec, ck)

    def _compute_moe(self, u: _Unit, x, weights):
        """Routed-union MoE (paper Appendix C.4, serving port): the gate
        forces a sync (experts unknown until it runs); then ONLY the union
        of routed experts streams through the pool as WEIGHT_LOAD tasks
        while the shared expert computes.  The combine is *compact*:
        expert ids are remapped onto the sorted union and the loaded
        device buffers are stacked into (|union|, ...) arrays, so the
        host->device boundary moves |union|-proportional bytes — the only
        link crossings are the per-expert WEIGHT_LOADs themselves (traced
        with their nbytes), never a bank-sized padded stack.  Numerics
        still match ``layers.apply_moe_ffn`` bit-for-bit (see
        ``moe.moe_ffn_union``).  Main thread (loads on pool threads)."""
        m = self.cfg.moe
        pre, gate, shared, combine = self._moe_fns[(u.group, u.q)]
        xn = pre(weights, x)
        gate_w, ids = gate(xn, u.router)          # sync point (paper)
        ids = np.asarray(ids)
        union = np.unique(ids.reshape(-1))        # sorted routed experts
        tasks = []
        for e in union:
            key = u.expert_keys[int(e)]
            t = Task(TaskType.WEIGHT_LOAD, f"w[{key}]",
                     lambda key=key: self.weights.load(key))
            t.nbytes = self.weights.nbytes(key)
            self.sched.pool.submit(t)
            tasks.append(t)
        shared_term = shared(weights, xn)         # overlaps expert loads
        ids_u = np.searchsorted(union, ids)       # order-preserving remap
        loaded = [t.wait() for t in tasks]        # device arrays (deq'd)
        wga = jnp.stack([we["w_gate"] for we in loaded])
        wup = jnp.stack([we["w_up"] for we in loaded])
        wdn = jnp.stack([we["w_down"] for we in loaded])
        self.stats["moe_stack_bytes"] += int(wga.nbytes + wup.nbytes
                                             + wdn.nbytes)
        return combine(x, xn, gate_w, jnp.asarray(ids_u), wga, wup, wdn,
                       shared_term)

    def finalize(self, i: int, x):
        if self._chunk_step is not None:
            x_dec, x_ck = x
            _, _, final = self._chunk_step
            if final:
                # first generated token of the chunked request: argmax
                # over the LAST prompt position, exactly what the
                # monolithic prefill head computes
                tok = self._head(self.resident["embed"],
                                 self.resident["final_norm"], x_ck)
                self._chunk_tok = int(np.asarray(tok)[0])
            if x_dec is None:
                return np.zeros(self.b_max, np.int32)
            x = x_dec
        if self._phase == "decode" and x.shape[1] > 1:
            # speculative verify: per-position argmax, (b, k+1)
            tok = self._spec_head(self.resident["embed"],
                                  self.resident["final_norm"], x)
        else:
            tok = self._head(self.resident["embed"],
                             self.resident["final_norm"], x)
        return np.asarray(tok)

    # ---- SlotEngineBase compute hooks ---------------------------------------
    def _begin_chunked_prefill(self, slot: int, req: Request) -> int:
        """Admission-time hook: under a chunked policy, claim the slot
        and stage the prompt for chunk-at-a-time prefill interleaved
        with decode steps.  At most ONE chunked prefill is in flight —
        a second arrival waits (BUSY) so its chunks don't compete for
        the same shared weight sweeps."""
        if not self.sched_policy.chunked:
            return self.CHUNK_OFF
        if self._chunk is not None:
            return self.CHUNK_BUSY
        self._chunk = dict(slot=slot, req=req, done=0, prefix={})
        return self.CHUNK_STARTED

    def _chunk_slot(self):
        return self._chunk["slot"] if self._chunk is not None else None

    def _mixed_step(self, active: List[int]) -> np.ndarray:
        """One pipeline step carrying the next prompt chunk of the
        in-flight chunked prefill — alongside the decode batch when one
        exists (main thread).  Both rides the SAME ``sched.generate``
        call, so each layer's weights stream exactly once for the pair.
        The decode view is widened to a SUPERSET covering the chunk
        slot/extent so warm tail preloads priced during this step stay
        valid once the chunk's rows land (stale rows are masked by
        ``kv_pos <= pos`` downstream, the established inactive-slot
        precedent)."""
        ck = self._chunk
        req, slot = ck["req"], ck["slot"]
        cap = max(1, self.sched_policy.chunk_cap())
        c0 = ck["done"]
        c1 = min(len(req.prompt), c0 + cap)
        final = c1 == len(req.prompt)
        self._chunk_step = (c0, c1 - c0, final)
        if active:
            self._step_setup(active)
            base, lb, ll = self._decode_view
            self._decode_view = (base, max(lb, slot + 1), max(ll, c1))
            self._pos_dev = jnp.asarray(self.pos)
            self._angles = T._angles(self.cfg, self._pos_dev[:, None])
            x_dec = self._embed(self.resident["embed"],
                                jnp.asarray(self.tokens)[:, None], "decode")
        else:
            # chunk-only step: nothing to load — the chunk attends only
            # the engine-held fp32 prefix of its own earlier chunks
            self._phase = "chunk"
            x_dec = None
        self._chunk_angles = T._angles(self.cfg, jnp.arange(c0, c1))
        x_ck = self._embed(self.resident["embed"],
                           jnp.asarray(req.prompt[c0:c1])[None], "prefill")
        toks = self.sched.generate(self, lambda i: (x_dec, x_ck), 1)
        self.stats["prefill_chunks"] += 1
        ck["done"] = c1
        chunk_only = x_dec is None
        self._chunk_step = None
        if chunk_only:
            # warm tail preloads captured phase "chunk" (value None)
            self.sched.drop_kv_preloads()
        if final:
            self._chunk = None
            if self.draft is not None:
                self.draft.prefill_slot(slot, req.prompt)
            self._finish_prefill(slot, req, self._chunk_tok)
        return (toks[-1] if not chunk_only
                else np.zeros(self.b_max, np.int32))

    def _prefill_into_slot(self, slot: int, req: Request) -> int:
        """b=1 prompt pass through the pipeline (main thread).  Any warm
        KV preload issued at the tail of this call captured the prefill
        phase (value None) and is dropped — the next decode step reloads
        fresh; its weight preload stays valid (weights are immutable)."""
        self._phase = "prefill"
        self._slot = slot
        s = len(req.prompt)
        positions = jnp.arange(s)
        self._angles = T._angles(self.cfg, positions)
        x0 = self._embed(self.resident["embed"],
                         jnp.asarray(req.prompt)[None], "prefill")
        toks = self.sched.generate(self, lambda i: x0, 1)
        self.sched.drop_kv_preloads()
        if self.draft is not None:
            # admit the prompt into the draft's device cache too (the
            # draft is slaved to the same slot/pos state)
            self.draft.prefill_slot(slot, req.prompt)
        # skip the prefill's trace window for the bandwidth feedback: a
        # full-prompt forward is far costlier per layer than a decode
        # step, and folding it into the compute EWMA would resolve the
        # window too shallow exactly while request load is ramping
        self._trace_mark = len(self.trace.events())
        return int(toks[-1][0])

    def _observe_trace(self):
        """Feed the Trace delta since the last step into the adaptive
        policy's bandwidth/compute EWMAs (main thread, between steps):
        transfer bytes over merged transfer busy time is the MEASURED
        link bandwidth — the feedback that replaces the budget's assumed
        bw in the window sizing."""
        observe = getattr(self.preload_policy, "observe", None)
        if observe is None:
            return
        evs = self.trace.events()
        new, self._trace_mark = evs[self._trace_mark:], len(evs)
        if not new:
            return
        xfer = [e for e in new if e.kind in ("weight_load", "kv_load")]
        comp = [e for e in new if e.kind == "compute"]
        observe(
            transfer_bytes=sum(e.nbytes for e in xfer),
            transfer_busy_s=_merged_busy((e.t_start, e.t_end)
                                         for e in xfer),
            compute_busy_s=_merged_busy((e.t_start, e.t_end)
                                        for e in comp),
            layers=len(comp))

    def _resize_window(self, active: List[int]):
        """Consult the preload policy with the LIVE pressure snapshot
        and re-size the scheduler's window between steps (main thread).
        ``StaticDepth`` always answers the same, so the pre-spec engines
        are reproduced bit for bit; ``AdaptiveDepth`` deepens under
        light load and shrinks as KV/spill pressure ramps — pricing the
        per-layer KV term at the store's EXACT live payload and the
        link at the measured-bandwidth EWMA."""
        if isinstance(self.preload_policy, StaticDepth):
            return
        self._observe_trace()
        lb = max(active) + 1
        max_pos = int(max(self.pos[s] for s in active))
        p = Pressure(active=len(active), max_pos=max_pos,
                     spills=len(self._spill_lru),
                     kv_layer_bytes=self.kvstore.max_live_load_nbytes(
                         lb, max(1, max_pos)))
        d = self.sched.set_depth(self.preload_policy.depth(p))
        if d != self.stats["preload_depth"]:
            self.stats["depth_resizes"] += 1
            self.stats["preload_depth"] = d

    def _step_setup(self, active: List[int]):
        """Shared per-step state refresh (main thread): preload-policy
        resize, phase flip, position snapshot, and the atomic live view
        for this step's (and its tail preloads') KV extents — scheduler
        iteration base + occupied slots + written positions.  live_len =
        max(pos) covers every row attention can read below the write
        position; the rows AT pos.. are written by this step's compute
        before they are attended."""
        self._resize_window(active)
        self._phase = "decode"
        self._active = list(active)
        self._pos_snap = self.pos.copy()
        base = self.sched._iter0
        self._decode_view = (base, max(active) + 1,
                             max(1, int(max(self.pos[s] for s in active))))
        # prune dead extent memos (iterations before this step can no
        # longer have loads in flight; main thread, GIL-atomic dels)
        for k in [k for k in self._extent_memo if k < base]:
            del self._extent_memo[k]

    def attach_draft(self, draft, k: int):
        """Enable speculative decoding with ``draft`` — anything with
        ``prefill_slot(slot, prompt)`` and ``propose(tokens, pos, k) ->
        (b_max, k)`` (``core.draft.ResidentDraft``, or a test fake).
        Greedy accept/reject keeps the emitted stream bit-identical to
        non-speculative decode for ANY proposal stream, so a draft whose
        cache went stale (e.g. a preemption resume skips the draft
        prefill) only costs acceptance length, never correctness.  Main
        thread, between steps."""
        cap = spec_decode_capability(self.cfg)
        if cap is not None:
            raise UnsupportedModelError(
                cap, f"speculative decoding needs a global-attention "
                     f"dense decoder target (failing capability: {cap})")
        self.draft = draft
        self._spec_k = max(1, int(k))
        self.trace.meta.update(spec_k=self._spec_k)

    def _emitted_tokens(self, active, nt):
        if self._spec_emitted is not None:
            return self._spec_emitted
        return super()._emitted_tokens(active, nt)

    def _decode_active(self, active: List[int]) -> np.ndarray:
        """One batched decode step through the pipeline (main thread).
        With a warm scheduler the step's first weight/KV loads were
        pre-submitted during the previous step's tail compute.  With a
        draft attached the step is a draft-then-verify pass emitting up
        to spec_k + 1 tokens per slot (``_emitted_tokens``)."""
        self._spec_emitted = None
        self._spec_s = 1
        if self._chunk is not None:
            # a chunked prefill is in flight: run the mixed step (decode
            # batch + one prompt chunk under shared weight loads).  Spec
            # decode resumes once the chunk completes.
            return self._mixed_step(active)
        k = 0
        if self.draft is not None:
            # headroom: the verify writes rows pos..pos+k, and the last
            # emitted token must still fit under the max_len-1 release
            # bound the base class enforces per token
            head = self.max_len - 1 - int(max(self.pos[s] for s in active))
            k = max(0, min(self._spec_k, head))
        if k >= 1:
            return self._decode_spec(active, k)
        self._step_setup(active)
        self._pos_dev = jnp.asarray(self.pos)
        self._angles = T._angles(self.cfg, self._pos_dev[:, None])
        x0 = self._embed(self.resident["embed"],
                         jnp.asarray(self.tokens)[:, None], "decode")
        toks = self.sched.generate(self, lambda i: x0, 1)
        return toks[-1]

    def _decode_spec(self, active: List[int], k: int) -> np.ndarray:
        """Draft-then-verify decode step (main thread): the resident
        draft proposes ``k`` tokens while ``prime_weights`` streams the
        verify pass's first weight loads over the otherwise-idle link;
        the target then scores all ``k+1`` positions in ONE trip through
        the streamed layer stack and the greedy accept rule
        (``core.draft.accepted_tokens``) emits the longest prefix that
        matches non-speculative decode — plus the target's bonus token
        at the divergence.  Rejected rows are invalidated in the tiered
        store (``truncate``) and the stale KV preloads dropped."""
        self._step_setup(active)
        self._spec_s = k + 1
        # verify-pass weight loads stream while the draft computes (the
        # warm-window generalization of the cross-step preload; a warm
        # tail already has them in flight, making this a no-op)
        t0 = time.perf_counter()
        primed = self.sched.prime_weights(self)
        props = np.asarray(self.draft.propose(self.tokens, self.pos, k),
                           np.int32)                       # (b_max, k)
        draft_s = time.perf_counter() - t0
        # verify input: [current token, d1..dk] at positions pos..pos+k
        seq = np.concatenate(
            [np.asarray(self.tokens, np.int32)[:, None], props], axis=1)
        self._pos_dev = jnp.asarray(self.pos)
        pos_mat = self._pos_dev[:, None] + jnp.arange(k + 1)[None, :]
        self._angles = T._angles(self.cfg, pos_mat)
        x0 = self._embed(self.resident["embed"], jnp.asarray(seq), "decode")
        toks = self.sched.generate(self, lambda i: x0, 1)
        tgt = np.asarray(toks[-1])                         # (b_max, k+1)
        # greedy accept/reject + row invalidation.  Saves may still be in
        # flight (warm mode) and would re-write rejected rows after the
        # truncate; drain first.  The in-flight KV preloads are stale
        # either way — a spec step advances the extent by up to k+1,
        # past the +1 the warm tail priced — so they are dropped and the
        # next step reloads fresh (weight preloads stay: immutable).
        self.sched.drain_saves()
        self.sched.drop_kv_preloads()
        # the dropped preloads memoized their extents (priced at the old
        # +1-per-step heuristic); with the tasks gone the memos are dead
        # weight, and the next step's fresh loads must re-price at the
        # advanced positions — a stale memo under-ships rows the verify
        # mask then admits as zeros, corrupting the softmax
        self._extent_memo.clear()
        emitted: Dict[int, List[int]] = {}
        accepts = []
        for i in active:
            acc = accepted_tokens(props[i], tgt[i])
            emitted[i] = acc
            accepts.append(len(acc) - 1)
            # valid rows: inputs [cur, d1..da] at pos..pos+a
            self.kvstore.truncate(i, int(self._pos_snap[i]) + len(acc))
        self._spec_emitted = emitted
        self.stats["spec_steps"] += 1
        self.stats["spec_proposed"] += k * len(active)
        self.stats["spec_accepted"] += int(sum(accepts))
        self.trace.meta.setdefault("spec_steps", []).append(dict(
            k=int(k), primed=int(primed), draft_s=float(draft_s),
            accepts=[int(a) for a in accepts]))
        nt = np.zeros(self.b_max, np.int32)
        for i in active:
            nt[i] = emitted[i][-1]
        return nt

    # ---- slot spill/restore (host<->host; rows already offloaded) -----------
    def _offload_snapshot(self, slot: int):
        """The KV already lives on host, so the snapshot is just the slot
        id — but in warm mode pipeline saves may still be in flight, and
        the spill's row reads must not race them (main thread; blocks on
        outstanding saves)."""
        self.sched.drain_saves()
        return slot

    def _offload_write(self, ns: str, slot: int):
        """Spill: row copies out of the tiered KV store under
        ``{ns}/{unit}/{name}`` keys so the slot can be reused while the
        request is parked (packed rows spill packed — lossless, ~3x
        below the bf16 rows under kv_mode='int4').  Transfer-pool
        thread when async."""
        self.kvstore.spill(self.host, ns, slot)

    def restore_slot(self, slot: int, ns: str):
        """Bring a parked request's rows back into a slot (main thread).
        Mutates the store's host rows outside the pipeline, so
        outstanding saves are drained first and any warm KV preloads
        (now stale device copies) are dropped."""
        self.sched.drain_saves()
        self.sched.drop_kv_preloads()
        self.kvstore.restore(self.host, ns, slot)

    # ---- lifecycle / introspection ------------------------------------------
    def pipeline_report(self):
        """Per-task-type busy time/bytes, compute-thread utilization and
        bubble accounting derived from the Trace (paper Fig. 8/9
        analogue).  Main thread; safe while transfers are in flight."""
        return self.trace.report()

    def shutdown(self):
        """Drain slot spills + pipeline saves, stop the pool(s) (main
        thread; blocking).  Staged engines own one pool per stage; pool 0
        doubles as the slot-spill pool and is stopped last."""
        super().shutdown()
        self.sched.shutdown()
        for p in self._stage_pools[1:]:
            p.shutdown()
        self._kv_pool.shutdown()
