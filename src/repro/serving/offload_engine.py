"""Offloaded continuous-batching serving engine: the PIPO pipeline under a
serving workload.

Where ``ServingEngine`` keeps every parameter resident, this engine keeps
only the embedding/final-norm on device; each transformer layer's weights
live as ONE merged buffer (+manifest) on the host or disk tier
(``TieredWeightStore``, shared with ``core.engine.PipelinedLM``) and
stream through the 3-thread ``ThreadPool`` + ``PipelineScheduler`` per
decode step.  The per-layer KV cache lives in host memory and moves as
``KV_LOAD``/``KV_SAVE`` pipeline tasks, so the repo can serve models whose
weights + KV exceed device memory — the paper's headline scenario.

Numerics are *identical* to the resident engine: both run the same
``models.layers.apply_layer`` / ``embed_tokens`` / ``lm_head_argmax``
functions on params from the same ``model.init`` seed, so decoded tokens
match exactly (asserted in tests/test_serving_offload.py).

Pipeline modes (pick with ``pipeline=``):
  * "performance" — preload layer j+1's weights during layer j's compute;
    highest throughput, two layers resident (default).
  * "memory"      — single layer resident, KV-save synchronized; lowest
    device footprint.
  * "sequential"  — FlexGen-like full serialization; baseline for the
    utilization benchmark (Fig. 9 analogue in benchmarks/run.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, LayerSpec
from repro.core.offload import DeviceStore, DiskStore
from repro.core.pipeline import PipelineScheduler, ThreadPool
from repro.core.tasks import Trace
from repro.core.transfer import TieredWeightStore
from repro.models import Dist, build_model
from repro.models import layers as L
from repro.models import transformer as T
from repro.serving.base import Request, SlotEngineBase

__all__ = ["Request", "OffloadedServingEngine"]


@dataclass
class _Unit:
    """One schedulable layer: period ``p`` of pattern position ``q``
    ('pat'), or remainder layer q ('rem')."""
    group: str          # "pat" | "rem"
    p: int              # period index (0 for rem)
    q: int              # pattern / remainder position
    spec: LayerSpec
    key: str            # TieredWeightStore key


class OffloadedServingEngine(SlotEngineBase):
    def __init__(self, cfg: ModelConfig, *, b_max: int = 4,
                 max_len: int = 256, seed: int = 0,
                 placement: str = "host", pipeline: str = "performance",
                 disk_root: str = "/tmp/pipo_serve_disk",
                 block_bytes: int = 8 << 20, n_io_threads: int = 3,
                 cold_reads: bool = False, sim_bw: Optional[float] = None):
        assert cfg.rope_theta != 0 and not cfg.enc_dec and \
            cfg.frontend != "embeds", \
            "offloaded serving supports token-frontend rope decoder stacks"
        self.trace = Trace()
        pool = ThreadPool(3, self.trace)
        super().__init__(cfg, b_max=b_max, max_len=max_len, kv_pool=pool)
        self.dist = Dist.local()
        self.model = build_model(cfg)
        self.pipeline_mode = pipeline
        self.device = DeviceStore()
        self.disk = DiskStore(disk_root)
        self.weights = TieredWeightStore(
            placement=placement, host=self.host, device=self.device,
            disk=self.disk, block_bytes=block_bytes,
            n_io_threads=n_io_threads, cold_reads=cold_reads, sim_bw=sim_bw)
        params = self.model.init(jax.random.PRNGKey(seed), jnp.float32)
        self.units: List[_Unit] = []
        self._split_params(params)
        self._kv_init()
        self.sched = PipelineScheduler(len(self.units), pipeline, pool=pool,
                                       trace=self.trace)
        self._jit_units()

    # ---- weight tiering -----------------------------------------------------
    def _split_params(self, params):
        """Embeddings/final norm stay on device (small, needed every step);
        each layer's params merge into one tiered buffer."""
        self.resident = {
            "embed": jax.device_put(params["embed"]),
            "final_norm": jax.device_put(params["final_norm"]),
        }
        cfg = self.cfg
        for p in range(cfg.num_periods):
            for q, spec in enumerate(cfg.pattern):
                key = f"u[{p}][{q}]"
                tensors = {name: np.asarray(leaf[p])
                           for name, leaf in params["pat"][q].items()}
                self.weights.put(key, tensors)
                self.units.append(_Unit("pat", p, q, spec, key))
        for q, spec in enumerate(cfg.remainder):
            key = f"rem[{q}]"
            tensors = {name: np.asarray(leaf)
                       for name, leaf in params["rem"][q].items()}
            self.weights.put(key, tensors)
            self.units.append(_Unit("rem", 0, q, spec, key))

    # ---- host KV ------------------------------------------------------------
    def _kv_init(self):
        """Per-unit host-resident cache arrays (the b_max decode cache the
        resident engine keeps on device, spread over host RAM here)."""
        struct, kinds = T.cache_struct(self.cfg, self.b_max, self.max_len)
        self.kv: List[Dict[str, np.ndarray]] = []
        self.kv_kinds: List[Dict[str, str]] = []
        for u in self.units:
            sds = struct[u.group][u.q]
            shapes = {n: (s.shape[1:] if u.group == "pat" else s.shape, s.dtype)
                      for n, s in sds.items()}
            self.kv.append({n: np.zeros(sh, dt) for n, (sh, dt) in
                            shapes.items()})
            self.kv_kinds.append(dict(kinds[u.group][u.q]))

    # ---- jitted per-unit compute --------------------------------------------
    def _jit_units(self):
        cfg, dist = self.cfg, self.dist
        self._decode_fns = {}
        self._prefill_fns = {}
        for j, u in enumerate(self.units):
            sig = (u.group, u.q)
            if sig in self._decode_fns:
                continue
            spec, kinds = u.spec, self.kv_kinds[j]

            def decode_fn(w, x, cache, pos, angles, spec=spec, kinds=kinds):
                ctx = L.Ctx(cfg=cfg, dist=dist, mode="decode", angles=angles,
                            pos=pos, batch_size=x.shape[0])
                x, new_cache, _ = L.apply_layer(w, x, ctx, cache, spec)
                # gather only the newly written sequence rows so KV_SAVE
                # ships (b, 1, ...) instead of the whole cache
                rows = {}
                for name, kind in kinds.items():
                    leaf = new_cache[name]
                    if kind == "kv":
                        idx = pos.reshape((-1,) + (1,) * (leaf.ndim - 1))
                        rows[name] = jnp.take_along_axis(
                            leaf, idx.astype(jnp.int32), axis=1)
                    else:
                        rows[name] = leaf
                return x, rows

            def prefill_fn(w, x, angles, spec=spec):
                ctx = L.Ctx(cfg=cfg, dist=dist, mode="prefill", angles=angles,
                            cache_len=self.max_len, batch_size=x.shape[0])
                x, new_cache, _ = L.apply_layer(w, x, ctx, None, spec)
                return x, new_cache

            self._decode_fns[sig] = jax.jit(decode_fn)
            self._prefill_fns[sig] = jax.jit(prefill_fn)

        def embed_fn(emb_p, tok, mode):
            ctx = L.Ctx(cfg=cfg, dist=dist, mode=mode, batch_size=tok.shape[0])
            return L.embed_tokens(emb_p, tok, ctx)

        def head_fn(emb_p, fn_p, x):
            ctx = L.Ctx(cfg=cfg, dist=dist, mode="decode",
                        batch_size=x.shape[0])
            x = L.rms_norm(x, fn_p["scale"], cfg.norm_eps)
            return L.lm_head_argmax(emb_p, x[:, -1:], ctx)

        self._embed = jax.jit(embed_fn, static_argnums=(2,))
        self._head = jax.jit(head_fn)

    # ---- PipelineScheduler callbacks ----------------------------------------
    def is_mha(self, j: int) -> bool:
        """'Has streamed KV state' in scheduler terms — true for every
        cached mixer (ATTN/MLA/SSM), so KV_LOAD/KV_SAVE are scheduled."""
        return bool(self.kv_kinds[j])

    def load_weights(self, j: int):
        return self.weights.load(self.units[j].key)

    def release_weights(self, j: int, handle):
        del handle  # device arrays freed by GC; tier stores unaffected

    def load_kv(self, i: int, j: int):
        if self._phase != "decode":
            return None                       # prefill builds fresh caches
        t0 = time.perf_counter()
        dev = {n: jax.device_put(a) for n, a in self.kv[j].items()}
        for a in dev.values():
            a.block_until_ready()
        # KV crosses the same simulated link as the weights
        self.weights.sim_floor(sum(a.nbytes for a in self.kv[j].values()), t0)
        return dev

    def save_kv(self, i: int, j: int, new_kv):
        phase, payload, meta = new_kv
        host_kv, kinds = self.kv[j], self.kv_kinds[j]
        if phase == "prefill":
            slot = meta
            for name, leaf in payload.items():
                host_kv[name][slot] = np.asarray(leaf[0])
        else:
            active, pos = meta
            rows = {name: np.asarray(leaf) for name, leaf in payload.items()}
            for name, kind in kinds.items():
                if kind == "kv":
                    for s in active:
                        host_kv[name][s, pos[s]] = rows[name][s, 0]
                else:
                    for s in active:
                        host_kv[name][s] = rows[name][s]

    def compute(self, i: int, j: int, x, weights, kv):
        u = self.units[j]
        sig = (u.group, u.q)
        if self._phase == "prefill":
            x, cache1 = self._prefill_fns[sig](weights, x, self._angles)
            return x, ("prefill", cache1, self._slot)
        x, rows = self._decode_fns[sig](weights, x, kv, self._pos_dev,
                                        self._angles)
        return x, ("decode", rows, (self._active, self._pos_snap))

    def finalize(self, i: int, x):
        tok = self._head(self.resident["embed"], self.resident["final_norm"],
                         x)
        return np.asarray(tok)

    # ---- SlotEngineBase compute hooks ---------------------------------------
    def _prefill_into_slot(self, slot: int, req: Request) -> int:
        self._phase = "prefill"
        self._slot = slot
        s = len(req.prompt)
        positions = jnp.arange(s)
        self._angles = T._angles(self.cfg, positions)
        x0 = self._embed(self.resident["embed"],
                         jnp.asarray(req.prompt)[None], "prefill")
        toks = self.sched.generate(self, lambda i: x0, 1)
        return int(toks[-1][0])

    def _decode_active(self, active: List[int]) -> np.ndarray:
        self._phase = "decode"
        self._active = list(active)
        self._pos_snap = self.pos.copy()
        self._pos_dev = jnp.asarray(self.pos)
        self._angles = T._angles(self.cfg, self._pos_dev[:, None])
        x0 = self._embed(self.resident["embed"],
                         jnp.asarray(self.tokens)[:, None], "decode")
        toks = self.sched.generate(self, lambda i: x0, 1)
        return toks[-1]

    # ---- slot spill/restore (host<->host; rows already offloaded) -----------
    def _offload_snapshot(self, slot: int):
        return slot

    def _offload_write(self, rid: int, slot: int):
        # KV already lives on host: the spill is a row copy out of the shared
        # decode cache so the slot can be reused while rid is parked.
        for j, host_kv in enumerate(self.kv):
            for name, arr in host_kv.items():
                self.host.put(f"slot{rid}/{j}/{name}", arr[slot].copy())

    def restore_slot(self, slot: int, rid: int):
        for j, host_kv in enumerate(self.kv):
            for name, arr in host_kv.items():
                arr[slot] = self.host.get(f"slot{rid}/{j}/{name}")

    # ---- lifecycle / introspection ------------------------------------------
    def pipeline_report(self):
        """Per-task-type busy time, compute-thread utilization and bubble
        accounting derived from the Trace (paper Fig. 8/9 analogue)."""
        return self.trace.report()

    def shutdown(self):
        super().shutdown()
        self.sched.shutdown()
        self._kv_pool.shutdown()
