"""EngineSpec: one declarative, resolvable plan for every engine.

The pipeline grew many interacting knobs — placement, pipeline mode,
warm, preload depth, quant, spill cap, io threads, sim link — and they
used to be duplicated across three engine constructors and mirrored by
hand in the launch CLIs.  This module replaces the kwarg sprawl with one
API (FlexInfer's thesis: offloading strategies are *declared* and
resolved against the device at runtime, not hard-coded per engine):

  spec = EngineSpec(arch="tinyllama-1.1b", scaled=True, offload=True)
  plan = spec.resolve()          # every auto field materialized + why
  eng  = create_engine(plan)     # ServingEngine | OffloadedServingEngine
  lm   = build_lm(plan)          # the batch-generation PipelinedLM

``EngineSpec`` is the *intent*: fields may be ``None``/"auto" and are
validated with typed errors (``SpecError``).  ``resolve(budget)`` runs
the paper's §3.5 memory model (``core.autoconfig``) and returns a
``ResolvedPlan`` — fully materialized, JSON round-trippable, and
carrying a per-field *provenance* map: every auto decision (engine,
placement, warm, depth, block_bytes, int4 kernel) records the why
string from the memory model, so a dumped plan is an auditable record
of what the resolver decided and why (``launch.serve --plan-json``).

Engines accept a ``ResolvedPlan`` as their single constructor argument;
thin shims keep old constructor kwargs working (one DeprecationWarning,
converted to a spec internally — old-kwarg and spec construction yield
identical plans, asserted in tests/test_spec.py).

Several policy seams live behind the plan:

  * ``PreloadPolicy`` — who decides the preload window per decode step.
    ``StaticDepth(D)`` reproduces the fixed budget-sized window
    bit-for-bit; ``AdaptiveDepth`` re-sizes it *between* decode steps
    from live KV/spill pressure (requests in flight, longest position
    actually used, retained spills) via ``memory_model.live_depth`` —
    the ROADMAP "depth is static per engine" gap.
  * ``QuantPolicy`` — what crosses the offload link quantized.
    ``weight_mode`` drives packed-weight streaming (``WeightsInt4``);
    ``kv_mode`` drives the tiered KV store (``core.kvstore``):
    ``"fp32"`` streams the cache at compute precision (bit-exact with
    the pre-store engines), ``"int4"`` stores and streams cache rows
    group-quantized (packed nibbles + scales, dequant fused into the
    consuming jit).
  * ``SchedPolicy`` — how new requests' prefills meet the streamed
    weight window.  ``"monolithic"`` (default) runs a dedicated b=1
    prefill pass per admission; ``OnlineSLO`` admits eagerly and caps
    prefill tokens per engine step so chunks ride the decode step's
    WEIGHT_LOADs (bounded decode stall, low TTFT); ``OfflineThroughput``
    runs whole-prompt chunks through the same shared window (the
    PipeMax run-to-completion regime).

The CLI speaks the same API: ``CLI_FLAGS`` is the single flag<->field
table ``launch.serve`` generates its argparse from, and
``tools/check_docs.py`` cross-checks table, live argparse, and the
``EngineSpec`` dataclass three ways in CI.
"""
from __future__ import annotations

import dataclasses
import json
import math
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.offload import MemoryBudget
from repro.core.pipeline import PIPELINE_MODES

__all__ = [
    "EngineSpec", "ResolvedPlan", "StagePlan", "SpecError",
    "UnsupportedModelError",
    "create_engine", "build_lm", "offload_capability",
    "spec_decode_capability", "chunked_prefill_capability",
    "PreloadPolicy", "StaticDepth", "AdaptiveDepth", "Pressure",
    "QuantPolicy", "WeightsInt4", "quant_policy_for",
    "DraftPolicy", "draft_policy_for",
    "SchedPolicy", "OnlineSLO", "OfflineThroughput", "sched_policy_for",
    "warn_deprecated_once", "reset_deprecation_warnings",
    "CLI_FLAGS", "FlagSpec", "NO_FLAG_FIELDS", "WORKLOAD_FLAGS",
    "add_spec_args", "spec_from_args",
]

QUANT_MODES = (None, "int4")
KV_MODES = (None, "fp32", "int4")       # None = auto (resolves to fp32)
DEPTH_POLICIES = ("static", "adaptive")
PLACEMENTS = ("auto", "device", "host", "disk")
SCHED_MODES = (None, "online", "offline", "monolithic")
STAGE_AXES = (None, "layer")            # None = auto (resolves to "layer")


# ---------------------------------------------------------------------------
# deprecation plumbing: the legacy-kwarg shims warn once per construction
# site per process, not per call (a serving loop constructing shimmed
# engines used to emit thousands of identical warnings)
# ---------------------------------------------------------------------------

_WARNED_DEPRECATIONS: set = set()


def warn_deprecated_once(key: str, message: str, stacklevel: int = 3):
    """Emit ``DeprecationWarning`` for ``key`` at most once per process.
    Tests that assert the warning fires call
    ``reset_deprecation_warnings()`` first."""
    if key in _WARNED_DEPRECATIONS:
        return
    _WARNED_DEPRECATIONS.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def reset_deprecation_warnings():
    _WARNED_DEPRECATIONS.clear()


class SpecError(ValueError):
    """An EngineSpec field (or field combination) is invalid."""


class UnsupportedModelError(RuntimeError):
    """The offloaded engine cannot serve this architecture.  Carries the
    failing capability so callers can dispatch on it; ``create_engine``
    falls back to the resident ``ServingEngine`` instead of raising."""

    def __init__(self, capability: str, message: str):
        super().__init__(message)
        self.capability = capability


def offload_capability(cfg: ModelConfig) -> Optional[str]:
    """The capability that rules out offloaded serving for ``cfg``, or
    None when the offloaded engine supports it (token-frontend rope
    decoder stacks only)."""
    if cfg.enc_dec:
        return "enc_dec"
    if cfg.frontend == "embeds":
        return "embeds_frontend"
    if cfg.rope_theta == 0:
        return "no_rope"
    return None


def _dense_global_attn_capability(cfg: ModelConfig) -> Optional[str]:
    """Shared gate for features that need a dense global-attention
    decoder stack on the offloaded engine (speculative verify, chunked
    prefill)."""
    cap = offload_capability(cfg)
    if cap is not None:
        return cap
    from repro.configs.base import ATTN, MOE
    for spec in tuple(cfg.pattern) + tuple(cfg.remainder):
        if spec.mixer != ATTN:
            return f"mixer_{spec.mixer}"
        if spec.ffn == MOE:
            return "moe_ffn"
    return None


def spec_decode_capability(cfg: ModelConfig) -> Optional[str]:
    """The capability that rules out speculative decoding for ``cfg`` as
    the TARGET model, or None when supported.  The verify pass scores
    k+1 positions in one ragged decode step
    (``attention.spec_decode_attention``), which exists for global
    attention only — window/MLA/SSM mixers keep single-token decode
    state.  MoE is out too: routing k+1 tokens jointly changes the
    capacity/slot assignment versus k+1 sequential steps, which would
    break the bit-exact parity speculation promises."""
    return _dense_global_attn_capability(cfg)


def chunked_prefill_capability(cfg: ModelConfig) -> Optional[str]:
    """The capability that rules out chunked prefill for ``cfg``, or
    None when supported.  A prefill chunk attends its fresh rows against
    the engine-held running prefix (``attention.chunk_prefill_attention``)
    — global attention only: window mixers need rolling-buffer chunk
    state and MLA/SSM keep latent/conv state the chunk path doesn't
    carry.  MoE is out for the same reason as speculation: expert
    capacity depends on the token count per pass, so chunked routing
    diverges bitwise from the monolithic pass."""
    return _dense_global_attn_capability(cfg)


# ---------------------------------------------------------------------------
# shared JSON/registry plumbing (EngineSpec and ResolvedPlan)
# ---------------------------------------------------------------------------


def _registry_config(arch: str, scaled: bool,
                     cfg: Optional[ModelConfig]) -> ModelConfig:
    if cfg is not None:
        return cfg
    from repro.configs import get_config, scaled_down
    try:
        base = get_config(arch)
    except KeyError as e:
        raise SpecError(str(e)) from e
    return scaled_down(base) if scaled else base


def _json_dict(obj) -> Dict[str, Any]:
    d = dataclasses.asdict(obj)
    d.pop("cfg")                       # not serializable, not compared
    return d


def _from_json_dict(cls, d: "Dict[str, Any] | str", *, require_all: bool):
    if isinstance(d, str):
        d = json.loads(d)
    known = {f.name for f in dataclasses.fields(cls)} - {"cfg"}
    unknown = set(d) - known
    if unknown:
        raise SpecError(f"unknown {cls.__name__} field(s) "
                        f"{sorted(unknown)}")
    if require_all:
        missing = known - set(d)
        if missing:
            raise SpecError(f"{cls.__name__} JSON missing "
                            f"{sorted(missing)}")
    return cls(**d)


# ---------------------------------------------------------------------------
# EngineSpec — declarative intent
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EngineSpec:
    """Declarative engine plan.  ``None`` / ``"auto"`` fields are
    resolved against the memory budget by ``resolve()``; everything else
    is validated as-is.  ``cfg`` optionally overrides the registry
    lookup (ad-hoc benchmark configs); it is excluded from JSON and
    equality — a spec is registry-reconstructable iff ``cfg`` is None."""

    arch: str = "tinyllama-1.1b"
    scaled: bool = False
    # -- batch + lengths ---------------------------------------------------
    b_max: int = 4
    max_len: int = 256
    seed: int = 0
    # -- engine + placement ------------------------------------------------
    offload: Optional[bool] = None      # None: memory model decides
    placement: str = "auto"             # auto|device|host|disk
    # -- pipeline ----------------------------------------------------------
    pipeline: str = "performance"
    warm: Optional[bool] = None         # None: performance => warm
    depth: Optional[int] = None         # None: budget-sized
    depth_policy: str = "static"        # static|adaptive
    # -- quant -------------------------------------------------------------
    quant: Optional[str] = None         # None|int4
    kv_mode: Optional[str] = None       # None(auto->fp32)|fp32|int4
    fused_int4: Optional[bool] = None   # None: §3.5 batch<16 rule
    moe_quant: Optional[str] = None     # None|int4 resident expert stacks
    # -- spill / io / sim --------------------------------------------------
    spill_cap: int = 32
    cache_on: str = "host"              # PipelinedLM only: host|device
    disk_root: str = ""                 # "": default root
    block_bytes: Optional[int] = None   # None: 8 MiB (Appendix A)
    n_io_threads: int = 3
    cold_reads: bool = False
    sim_bw: Optional[float] = None
    # -- speculative decoding ----------------------------------------------
    draft_arch: Optional[str] = None    # device-resident draft arch; None=off
    spec_k: Optional[int] = None        # proposals per verify (None: auto)
    # -- traffic scheduling ------------------------------------------------
    sched: Optional[str] = None         # None(auto->monolithic)|online|offline
    prefill_chunk: Optional[int] = None  # prompt tokens per step (None: auto)
    # -- pipeline parallelism ----------------------------------------------
    stages: Optional[int] = None        # None(auto->1)|N contiguous stages
    stage_axis: Optional[str] = None    # None(auto)|"layer"
    # -- ad-hoc config override (not serialized, not compared) -------------
    cfg: Optional[ModelConfig] = field(default=None, compare=False,
                                       repr=False)

    # ---- JSON ------------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return _json_dict(self)

    @classmethod
    def from_json(cls, d: "Dict[str, Any] | str") -> "EngineSpec":
        return _from_json_dict(cls, d, require_all=False)

    # ---- validation ------------------------------------------------------
    def model_config(self) -> ModelConfig:
        return _registry_config(self.arch, self.scaled, self.cfg)

    def validate(self) -> None:
        """Typed field/combination checks; raises ``SpecError``."""
        def bad(msg):
            raise SpecError(msg)
        if self.placement not in PLACEMENTS:
            bad(f"placement {self.placement!r} not in {PLACEMENTS}")
        if self.pipeline not in PIPELINE_MODES:
            bad(f"pipeline {self.pipeline!r} not in {PIPELINE_MODES}")
        if self.quant not in QUANT_MODES:
            bad(f"quant {self.quant!r} not in {QUANT_MODES}")
        if self.kv_mode not in KV_MODES:
            bad(f"kv_mode {self.kv_mode!r} not in {KV_MODES}")
        if self.moe_quant not in QUANT_MODES:
            bad(f"moe_quant {self.moe_quant!r} not in {QUANT_MODES}")
        if self.moe_quant is not None and self.model_config().moe is None:
            bad(f"moe_quant={self.moe_quant!r} needs an MoE architecture "
                f"({self.arch!r} has no expert stacks)")
        if self.depth_policy not in DEPTH_POLICIES:
            bad(f"depth_policy {self.depth_policy!r} not in "
                f"{DEPTH_POLICIES}")
        if self.cache_on not in ("host", "device"):
            bad(f"cache_on {self.cache_on!r} not in ('host', 'device')")
        if self.b_max < 1:
            bad(f"b_max must be >= 1, got {self.b_max}")
        if self.max_len < 2:
            bad(f"max_len must be >= 2, got {self.max_len}")
        if self.depth is not None and self.depth < 1:
            bad(f"depth must be >= 1 (or None for auto), got {self.depth}")
        if self.spill_cap < 0:
            bad(f"spill_cap must be >= 0, got {self.spill_cap}")
        if self.n_io_threads < 1:
            bad(f"n_io_threads must be >= 1, got {self.n_io_threads}")
        if self.block_bytes is not None and self.block_bytes < 4096:
            bad(f"block_bytes must be >= 4096, got {self.block_bytes}")
        if self.sim_bw is not None and self.sim_bw <= 0:
            bad(f"sim_bw must be > 0, got {self.sim_bw}")
        if self.spec_k is not None and self.spec_k < 1:
            bad(f"spec_k must be >= 1 (or None for auto), got {self.spec_k}")
        if self.sched not in SCHED_MODES:
            bad(f"sched {self.sched!r} not in {SCHED_MODES}")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            bad(f"prefill_chunk must be >= 1 (or None for auto), got "
                f"{self.prefill_chunk}")
        if self.prefill_chunk is not None and self.sched not in ("online",
                                                                 "offline"):
            bad("prefill_chunk needs a chunking policy (set sched='online' "
                "or 'offline'; monolithic prefill has no chunks)")
        if self.stages is not None and self.stages < 1:
            bad(f"stages must be >= 1 (or None for auto), got {self.stages}")
        if self.stage_axis not in STAGE_AXES:
            bad(f"stage_axis {self.stage_axis!r} not in {STAGE_AXES}")
        if self.spec_k is not None and self.draft_arch is None:
            bad("spec_k needs a draft model (set draft_arch; speculation "
                "is draft-proposes, target-verifies)")
        if self.draft_arch is not None:
            dcfg = _registry_config(self.draft_arch, self.scaled, None)
            if dcfg.vocab_size != self.model_config().vocab_size:
                bad(f"draft_arch {self.draft_arch!r} vocab "
                    f"({dcfg.vocab_size}) != target vocab "
                    f"({self.model_config().vocab_size}); the draft "
                    f"proposes target token ids")
            cap = spec_decode_capability(self.model_config())
            if cap is not None:
                bad(f"draft_arch needs a speculation-capable target "
                    f"(failing capability: {cap}; global-attention dense "
                    f"decoder stacks only)")
        if self.offload is False:
            for name in ("quant", "kv_mode", "sim_bw", "depth", "warm",
                         "draft_arch", "spec_k", "sched", "prefill_chunk",
                         "stages", "stage_axis"):
                if getattr(self, name) is not None:
                    bad(f"{name} only applies to the offloaded engine "
                        f"(offload=False pins the resident ServingEngine)")
            if self.depth_policy != "static":
                bad("depth_policy only applies to the offloaded engine")
            if self.placement not in ("auto", "device"):
                bad(f"placement={self.placement!r} only applies to the "
                    f"offloaded engine")
        if self.depth_policy == "adaptive" and self.pipeline != "performance":
            bad("depth_policy='adaptive' needs the performance pipeline "
                "(other modes pin a single-layer window)")
        self.model_config()          # arch resolvable (raises SpecError)

    # ---- resolution ------------------------------------------------------
    def resolve(self, budget: Optional[MemoryBudget] = None,
                trace=None) -> "ResolvedPlan":
        """Materialize every auto field against ``budget`` (paper §3.5 /
        Eq. 1 via ``core.autoconfig``), recording each decision's why in
        the plan's provenance map.

        ``trace`` (a recorded ``core.tasks.Trace``, e.g. loaded with
        ``Trace.from_json``) switches depth resolution from the
        closed-form heuristic to the trace-replay simulator
        (``core.replay``): the memory model still sets the affordable
        cap, but WITHIN the cap the simulated-argmin depth wins and the
        provenance records ``replay`` as the source.  Explicit depths
        and non-performance pipelines ignore the trace."""
        from repro.core.autoconfig import (choose_placement,
                                           replay_depth_decision,
                                           serving_depth_decision)
        self.validate()
        budget = budget or MemoryBudget()
        cfg = self.model_config()
        prov: Dict[str, str] = {}
        cap = offload_capability(cfg)

        # ---- engine + placement (capability gate, then Eq. 1) ----
        eq1: Dict[str, str] = {}

        def eq1_placement():
            if not eq1:
                pl, why = choose_placement(cfg, batch=self.b_max,
                                           seq=self.max_len,
                                           precision_bytes=4, budget=budget,
                                           quant=self.quant)
                eq1["placement"], eq1["why"] = pl, why
            return eq1["placement"], eq1["why"]

        if self.offload is False:
            engine = "resident"
            prov["engine"] = "explicit: offload=False (resident weights)"
        elif cap is not None:
            engine = "resident"
            detail = {"enc_dec": "encoder-decoder stack",
                      "embeds_frontend": "embeds frontend",
                      "no_rope": "non-rope positions"}[cap]
            if self.offload:
                prov["engine"] = (f"offload requested but unsupported "
                                  f"({cap}: {detail}); fell back to the "
                                  f"resident ServingEngine")
            else:
                prov["engine"] = (f"auto: offloading unsupported "
                                  f"({cap}: {detail}); resident")
        elif self.offload is True:
            engine = "offloaded"
            prov["engine"] = "explicit: offload=True"
        elif self.placement == "device":
            engine = "resident"
            prov["engine"] = "explicit: placement='device' (resident)"
        elif self.placement in ("host", "disk"):
            engine = "offloaded"
            prov["engine"] = (f"explicit placement={self.placement!r} "
                              f"implies the offloaded engine")
        else:
            pl, why = eq1_placement()
            engine = "resident" if pl == "device" else "offloaded"
            prov["engine"] = f"auto (Eq. 1): {why}"

        if engine == "resident":
            placement = "device"
            prov.setdefault("placement",
                            "resident engine: weights live on device")
        elif self.placement != "auto":
            placement = self.placement
            prov["placement"] = f"explicit: {self.placement}"
        else:
            pl, why = eq1_placement()
            if pl == "device":
                placement = "host"
                prov["placement"] = ("auto: weights would fit the device, "
                                     "but offloading was requested; host "
                                     "is the fastest streaming tier")
            else:
                placement = pl
                prov["placement"] = f"auto (Eq. 1): {why}"

        # ---- offload-only fields ----
        if engine == "resident":
            quant, warm, depth, depth_policy = None, False, 0, "static"
            kv_mode = None
            fused = True
            sim_bw = None
            draft_arch, spec_k = None, None
            sched, prefill_chunk = "monolithic", 0
            stages, stage_axis, stage_plan = 1, "layer", ()
            for name, was in (("quant", self.quant),
                              ("kv_mode", self.kv_mode),
                              ("sim_bw", self.sim_bw),
                              ("warm", self.warm),
                              ("depth", self.depth),
                              ("draft_arch", self.draft_arch),
                              ("spec_k", self.spec_k),
                              ("sched", self.sched),
                              ("prefill_chunk", self.prefill_chunk),
                              ("stages", self.stages),
                              ("stage_axis", self.stage_axis)):
                if was is not None:
                    prov[name] = (f"dropped ({was!r}): the resident engine "
                                  f"streams nothing over the link")
            if self.depth_policy != "static":
                prov["depth_policy"] = ("dropped ('adaptive'): no preload "
                                        "window on the resident engine")
            prov.setdefault("warm", "n/a: resident engine has no pipeline")
            prov.setdefault("depth", "n/a: resident engine has no window")
        else:
            quant = self.quant
            if self.kv_mode is None:
                kv_mode = "fp32"
                prov["kv_mode"] = ("auto: cache streams at compute "
                                   "precision (pass --kv-mode int4 for "
                                   "packed KV rows)")
            else:
                kv_mode = self.kv_mode
                prov["kv_mode"] = f"explicit: kv_mode={kv_mode!r}"
            if self.warm is None:
                warm = self.pipeline == "performance"
                prov["warm"] = (
                    "auto: performance pipeline keeps the scheduler warm "
                    "across decode steps (cross-step preload)"
                    if warm else
                    f"auto: {self.pipeline} pipeline has no cross-step "
                    f"preload")
            else:
                warm = bool(self.warm)
                prov["warm"] = f"explicit: warm={warm}"
            if self.depth is not None:
                depth = self.depth
                prov["depth"] = (f"explicit: depth={self.depth} (engines "
                                 f"clamp to their schedulable unit count)")
            elif self.pipeline != "performance":
                depth = 1
                prov["depth"] = (f"auto: {self.pipeline} pipeline pins a "
                                 f"single-layer window")
            else:
                d, why = serving_depth_decision(
                    cfg, b_max=self.b_max, max_len=self.max_len,
                    quant=quant, kv_mode=kv_mode,
                    spill_cap=self.spill_cap,
                    placement=placement, budget=budget)
                depth = d
                prov["depth"] = f"auto: {why}"
                if trace is not None:
                    # the memory model's fit is the cap; within it the
                    # simulated argmin from the recorded trace wins
                    from repro.core.replay import ReplayError
                    try:
                        d, why = replay_depth_decision(
                            trace, depth_cap=max(1, d), quant=quant,
                            kv_mode=kv_mode, sim_bw=self.sim_bw)
                        depth = d
                        prov["depth"] = f"replay: {why}"
                    except ReplayError as e:
                        prov["depth"] += (f"; trace given but not "
                                          f"replayable ({e}), kept the "
                                          f"heuristic depth")
            depth_policy = self.depth_policy
            if depth_policy == "adaptive":
                prov["depth_policy"] = (
                    "adaptive: window re-sized between decode steps from "
                    "live KV/spill pressure (requests in flight, longest "
                    "position used, retained spills) via "
                    "memory_model.live_depth; the static fit above is the "
                    "initial depth")
            if quant != "int4":
                fused = True
                prov["fused_int4"] = "n/a: no INT4 streaming"
            elif self.fused_int4 is None:
                fused = self.b_max < 16
                prov["fused_int4"] = (
                    f"auto (§3.5): batch {self.b_max} "
                    f"{'<' if fused else '>='} 16 — "
                    f"{'fused dequant-matmul' if fused else 'dequant-first'}")
            else:
                fused = bool(self.fused_int4)
                prov["fused_int4"] = f"explicit: fused_int4={fused}"
            sim_bw = self.sim_bw
            draft_arch = self.draft_arch
            if draft_arch is None:
                spec_k = None
            else:
                prov["draft_arch"] = (
                    f"explicit: device-resident draft {draft_arch!r} "
                    f"proposes, the streamed target verifies k+1 positions "
                    f"in one ragged decode step")
                if self.spec_k is None:
                    spec_k = 4
                    prov["spec_k"] = ("auto: 4 proposals per verify pass "
                                      "(the acceptance-length sweet spot on "
                                      "weight-dominated links; see "
                                      "benchmarks serving_spec_decode)")
                else:
                    spec_k = int(self.spec_k)
                    prov["spec_k"] = f"explicit: spec_k={spec_k}"

            # ---- traffic scheduling policy ----
            sched = self.sched
            if sched is None:
                sched = "monolithic"
                prov["sched"] = ("auto: monolithic prefill (chunked "
                                 "admission is opt-in via --sched "
                                 "online|offline)")
            elif sched != "monolithic":
                ccap = chunked_prefill_capability(cfg)
                if ccap is not None:
                    prov["sched"] = (
                        f"dropped ({sched!r}): chunked prefill needs a "
                        f"dense global-attention stack (failing "
                        f"capability: {ccap}); monolithic")
                    sched = "monolithic"
                else:
                    prov["sched"] = f"explicit: sched={sched!r}"
            else:
                prov["sched"] = "explicit: sched='monolithic'"
            if sched == "online":
                if self.prefill_chunk is None:
                    prefill_chunk = 32
                    prov["prefill_chunk"] = (
                        "auto: 32 prompt tokens per engine step (bounds "
                        "the per-step decode stall; see docs/TUNING.md)")
                else:
                    prefill_chunk = int(self.prefill_chunk)
                    prov["prefill_chunk"] = (
                        f"explicit: {prefill_chunk} tokens/step")
            elif sched == "offline":
                if self.prefill_chunk is None:
                    prefill_chunk = self.max_len
                    prov["prefill_chunk"] = (
                        "auto: whole-prompt chunks (run-to-completion "
                        "throughput regime; chunks still share the decode "
                        "step's weight window)")
                else:
                    prefill_chunk = int(self.prefill_chunk)
                    prov["prefill_chunk"] = (
                        f"explicit: {prefill_chunk} tokens/step")
            else:
                prefill_chunk = 0
                if self.prefill_chunk is not None:
                    prov["prefill_chunk"] = (
                        f"dropped ({self.prefill_chunk}): monolithic "
                        f"prefill has no chunks")

            # ---- pipeline-parallel stages (StagePlan) ----
            stage_axis = self.stage_axis or "layer"
            if self.stage_axis is not None:
                prov["stage_axis"] = "explicit: stage_axis='layer'"
            n_units = (cfg.num_periods * len(cfg.pattern)
                       + len(cfg.remainder))
            dense_cap = _dense_global_attn_capability(cfg)
            stages = 1 if self.stages is None else max(1, int(self.stages))
            if stages > 1 and dense_cap is not None:
                prov["stages"] = (
                    f"dropped ({self.stages}): pipeline-parallel staging "
                    f"needs a dense global-attention decoder stack "
                    f"(failing capability: {dense_cap}); single stage")
                stages = 1
            elif stages > 1 and draft_arch is not None:
                prov["stages"] = (
                    f"dropped ({self.stages}): speculative verify runs the "
                    f"accept logic against one device-resident draft; "
                    f"per-stage speculation is future work — single stage")
                stages = 1
            elif stages > 1 and sched != "monolithic":
                prov["stages"] = (
                    f"dropped ({self.stages}): chunked admission "
                    f"({sched!r}) is not staged yet; single stage")
                stages = 1
            elif stages > 1:
                if stages > n_units:
                    prov["stages"] = (
                        f"explicit: {self.stages} clamped to the "
                        f"{n_units} schedulable units")
                    stages = n_units
                else:
                    prov["stages"] = (
                        f"explicit: {stages} contiguous layer ranges, one "
                        f"tiered weight/KV store + scheduler per stage "
                        f"(aggregate link bandwidth scales with stages)")
            elif self.stages is not None:
                prov["stages"] = "explicit: stages=1 (single-stage pipeline)"
            else:
                prov["stages"] = ("auto: single stage (pass --stages N to "
                                  "partition the stack across a mesh)")
            # joint (stages, depth) argmin: a trace RECORDED from a staged
            # run re-resolves both knobs through the simulator; a
            # single-stage trace keeps the established replay-depth path
            # above bit-for-bit
            depth_src_replay = False
            if (trace is not None and self.stages is None
                    and int(trace.meta.get("stages") or 1) > 1
                    and self.depth is None
                    and self.pipeline == "performance"
                    and dense_cap is None and draft_arch is None
                    and sched == "monolithic"):
                from repro.core.replay import ReplayError, best_stage_depth
                try:
                    (sb, db), _ = best_stage_depth(
                        trace, stage_cap=min(4, n_units),
                        depth_cap=max(1, depth))
                    stages, depth = sb, db
                    depth_src_replay = True
                    prov["stages"] = (
                        f"replay: joint (stages, depth) argmin over the "
                        f"recorded staged trace -> {sb} stage(s)")
                    prov["depth"] = (
                        f"replay: depth {db} at {sb} stage(s) minimizes "
                        f"simulated steady-state step time")
                except ReplayError as e:
                    prov["stages"] += (f"; staged trace given but not "
                                       f"replayable ({e})")
            stage_plan = ()
            if stages > 1:
                if depth_policy == "adaptive":
                    depth_policy = "static"
                    prov["depth_policy"] = (
                        "dropped ('adaptive'): per-stage windows are "
                        "statically sized from the budget split "
                        "(adaptive staging is future work)")
                # accelerate-style max_memory-per-rank split: each stage
                # resolves its own §3.5 depth fit against 1/stages of the
                # device (and host) budget, so stage windows auto-size
                # independently of the global plan
                bounds = [round(s * n_units / stages)
                          for s in range(stages + 1)]
                dev_each = budget.device // stages
                sbud = MemoryBudget(device=dev_each,
                                    host=budget.host // stages)
                plans = []
                for s in range(stages):
                    lo, hi = bounds[s], bounds[s + 1]
                    if self.depth is not None:
                        sd, swhy = self.depth, (f"explicit: depth="
                                                f"{self.depth} every stage")
                    elif depth_src_replay:
                        sd, swhy = depth, (f"replay: joint argmin depth "
                                           f"{depth}")
                    else:
                        sd, swhy = serving_depth_decision(
                            cfg, b_max=self.b_max, max_len=self.max_len,
                            quant=quant, kv_mode=kv_mode,
                            spill_cap=self.spill_cap,
                            placement=placement, budget=sbud)
                        swhy = (f"stage {s} (§3.5 on the 1/{stages} "
                                f"budget split): {swhy}")
                    sd = max(1, min(int(sd), max(1, hi - lo - 1)))
                    plans.append(StagePlan(stage=s, layer_lo=lo,
                                           layer_hi=hi, depth=sd,
                                           device_budget=dev_each,
                                           why=swhy))
                stage_plan = tuple(plans)
                depth = max(p.depth for p in plans)
                prov["stage_plan"] = (
                    f"{n_units} units tiled contiguously over {stages} "
                    f"stages; device budget split {stages} x {dev_each} B "
                    f"(per-stage §3.5 depth fit)")
                if self.depth is None and not depth_src_replay:
                    prov["depth"] = (
                        f"auto: max per-stage fit {depth} (see stage_plan; "
                        f"each stage sized on its budget split)")

        # ---- resident-only fields ----
        if self.moe_quant is None:
            moe_quant = None
        elif engine == "resident":
            moe_quant = self.moe_quant
            prov["moe_quant"] = (
                "explicit: resident expert stacks packed INT4 once at "
                "load (~1/7 the f32 bytes incl. scales); compute unpacks "
                "through the fused-int4 path")
        else:
            moe_quant = None
            prov["moe_quant"] = (
                f"dropped ({self.moe_quant!r}): the offloaded engine "
                f"streams experts through the unit quant path (--quant)")

        if self.block_bytes is None:
            block_bytes = 8 << 20
            prov["block_bytes"] = ("auto: 8MiB blocks (Appendix A: disk "
                                   "bandwidth saturates at 8-32MiB)")
        else:
            block_bytes = int(self.block_bytes)
        disk_root = self.disk_root or "/tmp/pipo_serve_disk"
        if not self.disk_root:
            prov["disk_root"] = "auto: default /tmp/pipo_serve_disk"

        return ResolvedPlan(
            arch=self.arch, scaled=self.scaled, engine=engine,
            b_max=self.b_max, max_len=self.max_len, seed=self.seed,
            placement=placement, pipeline=self.pipeline, quant=quant,
            kv_mode=kv_mode, fused_int4=fused, moe_quant=moe_quant,
            warm=warm, depth=depth,
            depth_policy=depth_policy, spill_cap=self.spill_cap,
            cache_on=self.cache_on, disk_root=disk_root,
            block_bytes=block_bytes, n_io_threads=self.n_io_threads,
            cold_reads=self.cold_reads, sim_bw=sim_bw,
            draft_arch=draft_arch, spec_k=spec_k,
            sched=sched, prefill_chunk=prefill_chunk,
            stages=stages, stage_axis=stage_axis, stage_plan=stage_plan,
            device_budget=budget.device, host_budget=budget.host,
            provenance=prov, cfg=self.cfg)


# ---------------------------------------------------------------------------
# ResolvedPlan — materialized execution plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StagePlan:
    """One pipeline-parallel stage's slice of a resolved plan: the
    contiguous schedulable-unit range ``[layer_lo, layer_hi)`` it owns,
    the preload depth its OWN §3.5 fit resolved on its share of the
    split device budget, and the why string recording that decision.
    JSON round-trips inside ``ResolvedPlan.stage_plan`` (``asdict``
    nests it as a dict; ``ResolvedPlan.__post_init__`` rehydrates)."""

    stage: int
    layer_lo: int
    layer_hi: int
    depth: int
    device_budget: int
    why: str = ""


@dataclass(frozen=True)
class ResolvedPlan:
    """A fully-materialized engine plan: no Nones-meaning-auto left, and
    ``provenance[field]`` records why each auto field got its value.
    JSON round-trips (``to_json``/``from_json``); ``cfg`` (the ad-hoc
    config override) is excluded from JSON and equality, so a plan is
    file-shippable iff its arch is registry-resolvable."""

    arch: str
    scaled: bool
    engine: str                  # "resident" | "offloaded"
    b_max: int
    max_len: int
    seed: int
    placement: str               # device|host|disk
    pipeline: str
    quant: Optional[str]
    kv_mode: Optional[str]       # fp32|int4 streamed KV; None on resident
    fused_int4: bool
    moe_quant: Optional[str]     # int4-resident expert stacks; resident only
    warm: bool
    depth: int                   # 0 on the resident engine
    depth_policy: str
    spill_cap: int
    cache_on: str
    disk_root: str
    block_bytes: int
    n_io_threads: int
    cold_reads: bool
    sim_bw: Optional[float]
    draft_arch: Optional[str]    # device-resident draft; None = no speculation
    spec_k: Optional[int]        # proposals per verify pass; None = off
    sched: str = "monolithic"    # monolithic | online | offline
    prefill_chunk: int = 0       # prompt tokens per engine step; 0 = n/a
    stages: int = 1              # pipeline-parallel stage count
    stage_axis: str = "layer"    # the partition axis (layer stacks only)
    stage_plan: Tuple = ()       # per-stage StagePlan slices; () single-stage
    # the budget the plan was resolved under (bytes) — recorded so the
    # plan is auditable and so AdaptiveDepth re-sizes against the SAME
    # budget at run time
    device_budget: int = MemoryBudget.device
    host_budget: int = MemoryBudget.host
    provenance: Dict[str, str] = field(default_factory=dict)
    cfg: Optional[ModelConfig] = field(default=None, compare=False,
                                       repr=False)

    def __post_init__(self):
        # JSON round-trip rehydration: asdict() serialized each StagePlan
        # as a nested dict (and the tuple as a list) — normalize back so
        # equality and attribute access work on a from_json'd plan
        sp = tuple(StagePlan(**p) if isinstance(p, dict) else p
                   for p in self.stage_plan)
        object.__setattr__(self, "stage_plan", sp)

    def to_json(self) -> Dict[str, Any]:
        return _json_dict(self)

    @classmethod
    def from_json(cls, d: "Dict[str, Any] | str") -> "ResolvedPlan":
        return _from_json_dict(cls, d, require_all=True)

    def model_config(self) -> ModelConfig:
        return _registry_config(self.arch, self.scaled, self.cfg)

    def summary(self) -> str:
        return (f"{self.arch}{'(scaled)' if self.scaled else ''} "
                f"engine={self.engine} placement={self.placement} "
                f"pipeline={self.pipeline} warm={self.warm} "
                f"depth={self.depth}({self.depth_policy}) "
                f"quant={self.quant or 'fp32'} "
                f"kv={self.kv_mode or 'n/a'} b_max={self.b_max} "
                f"max_len={self.max_len}"
                + (f" draft={self.draft_arch} spec_k={self.spec_k}"
                   if self.draft_arch else "")
                + (f" sched={self.sched} chunk={self.prefill_chunk}"
                   if self.sched != "monolithic" else "")
                + (f" stages={self.stages}" if self.stages > 1 else ""))


# ---------------------------------------------------------------------------
# PreloadPolicy seam
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Pressure:
    """Live load snapshot the engine hands the preload policy between
    decode steps."""
    active: int                  # requests in flight (occupied slots)
    max_pos: int                 # longest KV position actually written
    spills: int = 0              # slot-spill namespaces retained on host
    # exact per-layer live KV_LOAD bytes (TieredKVStore.load_nbytes at
    # the live extent); None falls back to the modeled slab — with it the
    # adaptive window's KV pricing is measured, not modeled
    kv_layer_bytes: Optional[int] = None


class PreloadPolicy:
    """Decides the preload window.  ``max_depth()`` sizes the transfer
    pool at engine build time; ``depth(pressure)`` is consulted before
    every decode step (main thread; must be cheap)."""

    def max_depth(self) -> int:
        raise NotImplementedError

    def depth(self, pressure: Pressure) -> int:
        raise NotImplementedError


class StaticDepth(PreloadPolicy):
    """Today's behavior, bit for bit: a fixed window, whatever the
    load.  ``StaticDepth(plan.depth)`` reproduces the pre-spec engines
    exactly (token parity asserted per depth x quant in tests)."""

    def __init__(self, depth: int):
        self._depth = max(1, int(depth))

    def max_depth(self) -> int:
        return self._depth

    def depth(self, pressure: Pressure) -> int:
        return self._depth

    def __repr__(self):
        return f"StaticDepth({self._depth})"


class AdaptiveDepth(PreloadPolicy):
    """Re-sizes the window between decode steps from live KV/spill
    pressure (ROADMAP gap: "depth is static per engine").  Light load —
    few requests in flight, short contexts — leaves device headroom the
    static worst-case sizing can't see, so the window deepens; as
    requests and positions ramp (or spills pile onto the host) the same
    §3.5 capacity model shrinks it back, bottoming out at the paper's
    depth-1 pipeline.  The transfer pool is sized once for
    ``depth_cap``, so deepening never needs new threads.

    Measured-bandwidth feedback (closes the ROADMAP loop "feed measured
    link bandwidth into the policy"): the engine calls ``observe()``
    between decode steps with the step's Trace deltas — transfer bytes,
    merged transfer busy seconds, compute busy seconds, layer count.
    The policy EWMAs the observed link bandwidth and per-layer compute
    time; ``depth()`` then asks for only as much window as the OBSERVED
    link needs to hide behind compute (``ceil(t_link_layer /
    t_compute_layer)``), capped by the memory fit.  A link that slows
    mid-run (contention, thermal, page-cache miss streaks) deepens the
    window; a link faster than budgeted stops wasting residency on
    preloads compute never waits for.  Before any observation the policy
    resolves exactly as the memory model alone (the pre-feedback
    behavior)."""

    def __init__(self, cfg: ModelConfig, *, b_max: int, max_len: int,
                 quant: Optional[str] = None,
                 kv_mode: Optional[str] = None, placement: str = "host",
                 budget: Optional[MemoryBudget] = None, depth_cap: int = 8,
                 ewma_alpha: float = 0.5):
        from repro.core.memory_model import host_pinned_bytes
        self.cfg = cfg
        self.b_max = b_max
        self.max_len = max_len
        self.quant = quant
        self.kv_mode = kv_mode
        self.placement = placement
        self.budget = budget or MemoryBudget()
        self.depth_cap = max(1, int(depth_cap))
        self.ewma_alpha = float(ewma_alpha)
        # measured state (None until the first observation)
        self.bw_ewma: Optional[float] = None          # link bytes/s
        self.compute_ewma: Optional[float] = None     # s per layer
        # mean streamed bytes per layer (weights); the engine sets it at
        # build time from the real store manifests via set_link_profile
        self.layer_link_bytes: Optional[int] = None
        # the host-guard terms don't depend on live load — precompute
        # once; depth() runs on the main thread between decode steps
        self._host_fixed, self._per_spill = host_pinned_bytes(
            cfg, b_max=b_max, max_len=max_len, quant=quant,
            kv_mode=kv_mode, placement=placement)

    def max_depth(self) -> int:
        return self.depth_cap

    def set_link_profile(self, layer_link_bytes: int):
        """Mean streamed weight bytes per schedulable layer (engine
        build time, from the tiered store's manifests — packed bytes
        under INT4)."""
        self.layer_link_bytes = int(layer_link_bytes)

    def observe(self, *, transfer_bytes: int, transfer_busy_s: float,
                compute_busy_s: float, layers: int):
        """Fold one decode step's Trace deltas into the bandwidth /
        compute EWMAs (main thread, between steps; cheap)."""
        a = self.ewma_alpha
        if transfer_busy_s > 0 and transfer_bytes > 0:
            bw = transfer_bytes / transfer_busy_s
            self.bw_ewma = bw if self.bw_ewma is None else \
                a * bw + (1 - a) * self.bw_ewma
        if layers > 0 and compute_busy_s > 0:
            c = compute_busy_s / layers
            self.compute_ewma = c if self.compute_ewma is None else \
                a * c + (1 - a) * self.compute_ewma

    def _bw_depth(self, pressure: Pressure) -> Optional[int]:
        """Window the MEASURED link needs: with D transfers in flight the
        steady-state per-layer wait is ~t_link/D, hidden once D >=
        t_link / t_compute.  None until both EWMAs and the link profile
        exist."""
        if not (self.bw_ewma and self.compute_ewma
                and self.layer_link_bytes):
            return None
        per_layer = self.layer_link_bytes + (pressure.kv_layer_bytes or 0)
        t_link = per_layer / self.bw_ewma
        return max(1, math.ceil(t_link / max(1e-12, self.compute_ewma)))

    def depth(self, pressure: Pressure) -> int:
        from repro.core.memory_model import live_depth
        d_mem = live_depth(self.cfg, active=pressure.active,
                           pos_used=pressure.max_pos, b_max=self.b_max,
                           max_len=self.max_len, quant=self.quant,
                           kv_mode=self.kv_mode, spills=pressure.spills,
                           placement=self.placement,
                           device_budget=self.budget.device,
                           host_budget=self.budget.host,
                           depth_cap=self.depth_cap,
                           host_fixed=self._host_fixed,
                           per_spill=self._per_spill,
                           kv_layer_bytes=pressure.kv_layer_bytes)
        d_bw = self._bw_depth(pressure)
        if d_bw is None:
            return d_mem
        return max(1, min(d_mem, d_bw))

    def __repr__(self):
        return (f"AdaptiveDepth(cap={self.depth_cap}, "
                f"quant={self.quant or 'fp32'}, "
                f"kv={self.kv_mode or 'fp32'}, "
                f"bw={'%.2e' % self.bw_ewma if self.bw_ewma else 'unmeasured'})")


def preload_policy_for(plan: ResolvedPlan,
                       cfg: Optional[ModelConfig] = None,
                       budget: Optional[MemoryBudget] = None
                       ) -> PreloadPolicy:
    """The plan's preload policy instance (engine build time).  The
    adaptive policy re-sizes against the budget the plan was resolved
    under (recorded on the plan), not whatever the defaults are now."""
    if plan.depth_policy == "adaptive":
        if budget is None:
            budget = MemoryBudget(device=plan.device_budget,
                                  host=plan.host_budget)
        return AdaptiveDepth(cfg or plan.model_config(), b_max=plan.b_max,
                             max_len=plan.max_len, quant=plan.quant,
                             kv_mode=plan.kv_mode,
                             placement=plan.placement, budget=budget)
    return StaticDepth(max(1, plan.depth))


# ---------------------------------------------------------------------------
# DraftPolicy seam
# ---------------------------------------------------------------------------


class DraftPolicy:
    """Speculative-decoding seam: WHO proposes and HOW MANY tokens per
    verify pass.  The policy is resolved from the plan like
    ``PreloadPolicy``/``QuantPolicy`` (``draft_arch``/``spec_k`` fields,
    provenance-stamped); ``build()`` constructs the fully
    device-resident draft model (``core.draft.ResidentDraft``) sized to
    the engine's slots.  Engines treat the draft as an opaque proposer
    (``prefill_slot``/``propose``), so tests can inject a fake draft —
    greedy accept/reject is correct for ANY proposal stream, and the
    parity matrix exercises exactly that."""

    def __init__(self, arch: str, scaled: bool, k: int, *, seed: int = 0):
        if k < 1:
            raise SpecError(f"spec_k must be >= 1, got {k}")
        self.arch = arch
        self.scaled = scaled
        self.k = int(k)
        self.seed = int(seed)

    def build(self, *, b_max: int, max_len: int):
        from repro.core.draft import ResidentDraft
        cfg = _registry_config(self.arch, self.scaled, None)
        return ResidentDraft(cfg, b_max=b_max, max_len=max_len,
                             seed=self.seed)

    def __repr__(self):
        return (f"DraftPolicy({self.arch!r}"
                f"{'(scaled)' if self.scaled else ''}, k={self.k})")


def draft_policy_for(plan: ResolvedPlan) -> Optional[DraftPolicy]:
    """The plan's draft policy, or None when the plan doesn't
    speculate (``draft_arch`` unset, or dropped by a resident
    resolution)."""
    if plan.draft_arch is None:
        return None
    return DraftPolicy(plan.draft_arch, plan.scaled, plan.spec_k or 1,
                       seed=plan.seed)


# ---------------------------------------------------------------------------
# SchedPolicy seam
# ---------------------------------------------------------------------------


class SchedPolicy:
    """Traffic-scheduling seam: HOW a new request's prefill meets the
    streamed weight window.  The base policy is today's behavior bit for
    bit — a dedicated monolithic b=1 prefill pass at admission that
    blanks the warm window.  Chunking policies instead split the prompt
    into per-step chunks that ride the SAME ``generate`` call (and the
    same WEIGHT_LOADs) as the active batch's decode; ``chunk_cap()`` is
    the per-engine-step token budget a chunk may consume."""

    name = "monolithic"
    chunked = False

    def chunk_cap(self) -> int:
        """Prompt tokens a prefill chunk may take per engine step
        (0 = no chunking: monolithic prefill at admission)."""
        return 0

    def __repr__(self):
        return f"{type(self).__name__}()"


class OnlineSLO(SchedPolicy):
    """Latency regime: admit eagerly (FIFO), cap prefill tokens per
    engine step so every step still advances the decode batch — the
    chunk's compute bounds the decode stall (TBT) and queued requests
    start streaming KV immediately instead of waiting for a window
    restart (TTFT)."""

    name = "online"
    chunked = True

    def __init__(self, chunk: int):
        if chunk < 1:
            raise SpecError(f"prefill chunk must be >= 1, got {chunk}")
        self.chunk = int(chunk)

    def chunk_cap(self) -> int:
        return self.chunk

    def __repr__(self):
        return f"OnlineSLO(chunk={self.chunk})"


class OfflineThroughput(SchedPolicy):
    """Throughput regime (the PipeMax batch case): run-to-completion
    admission with whole-prompt chunks — the entire prefill rides one
    decode step's weight window, so the streamed weights are amortized
    over the largest possible token count and tok/s tracks the
    steady-state decode rate."""

    name = "offline"
    chunked = True

    def __init__(self, chunk: int):
        if chunk < 1:
            raise SpecError(f"prefill chunk must be >= 1, got {chunk}")
        self.chunk = int(chunk)

    def chunk_cap(self) -> int:
        return self.chunk

    def __repr__(self):
        return f"OfflineThroughput(chunk={self.chunk})"


def sched_policy_for(plan: ResolvedPlan) -> SchedPolicy:
    """The plan's traffic-scheduling policy instance (engine build
    time), mirroring ``preload_policy_for``/``quant_policy_for``."""
    if plan.sched == "online":
        return OnlineSLO(plan.prefill_chunk or 32)
    if plan.sched == "offline":
        return OfflineThroughput(plan.prefill_chunk or plan.max_len)
    return SchedPolicy()


# ---------------------------------------------------------------------------
# QuantPolicy seam
# ---------------------------------------------------------------------------


class QuantPolicy:
    """What lives or crosses the link quantized.  ``weight_mode`` feeds
    ``TieredWeightStore`` (packing + dequant-on-load); ``prepare_unit``
    packs a unit's tensors host-side at build time; ``kv_mode`` feeds
    ``core.kvstore.TieredKVStore`` — ``"fp32"`` streams the cache at
    compute precision (bit-exact with the pre-store engines), ``"int4"``
    stores/streams cache rows group-quantized (packed nibbles + scales,
    dequantized post-link on the transfer thread).  ``moe_quant``
    (resident engine) packs the routed expert stacks ONCE at load
    (``prepare_moe_params``); compute unpacks them per step through the
    fused-int4 path (``models.layers._dequant_moe_stacks``)."""

    name = "none"
    weight_mode: Optional[str] = None

    def __init__(self, kv_mode: Optional[str] = "fp32",
                 moe_quant: Optional[str] = None):
        self.kv_mode = kv_mode or "fp32"
        if self.kv_mode not in ("fp32", "int4"):
            raise SpecError(f"kv_mode {kv_mode!r} not in {KV_MODES}")
        self.moe_quant = moe_quant
        if self.moe_quant not in QUANT_MODES:
            raise SpecError(f"moe_quant {moe_quant!r} not in {QUANT_MODES}")

    def prepare_unit(self, tensors: Dict[str, Any]) -> Dict[str, Any]:
        return tensors

    def prepare_moe_params(self, params: Dict[str, Any]) -> Dict[str, Any]:
        """Pack the resident model's routed expert stacks as INT4
        (``moe_quant='int4'``; identity otherwise): every MoE layer
        table (marked by its router ``wg``) gets its eligible
        ``w_gate``/``w_up``/``w_down`` stacks replaced by ``#q``/``#s``
        leaves — all three or none, so the consuming dequant never sees
        a half-packed table.  Router and shared experts stay at compute
        precision (tiny, and consumed every step)."""
        if self.moe_quant != "int4":
            return params
        from repro.quant.int4 import quantize_int4_stack, stack_eligible
        stacks = ("w_gate", "w_up", "w_down")

        def pack(table):
            if "wg" not in table or not all(
                    name in table and stack_eligible(table[name].shape)
                    for name in stacks):
                return table
            out = dict(table)
            for name in stacks:
                packed, scale = quantize_int4_stack(out.pop(name))
                out[name + "#q"], out[name + "#s"] = packed, scale
            return out

        out = dict(params)
        for part in ("pat", "rem"):
            if part in out:
                out[part] = tuple(pack(t) if isinstance(t, dict) else t
                                  for t in out[part])
        return out


class WeightsInt4(QuantPolicy):
    """Paper §3.4: eligible 2-D projections stored as packed nibbles +
    groupwise scales; only packed bytes cross the link, the dequant runs
    on a transfer thread."""

    name = "int4"
    weight_mode = "int4"

    def prepare_unit(self, tensors: Dict[str, Any]) -> Dict[str, Any]:
        from repro.core.transfer import quantize_unit
        return quantize_unit(tensors)


def quant_policy_for(quant: Optional[str],
                     kv_mode: Optional[str] = "fp32",
                     moe_quant: Optional[str] = None) -> QuantPolicy:
    if quant == "int4":
        return WeightsInt4(kv_mode, moe_quant)
    if quant is None:
        return QuantPolicy(kv_mode, moe_quant)
    raise SpecError(f"quant {quant!r} not in {QUANT_MODES}")


# ---------------------------------------------------------------------------
# Engine construction — the single path
# ---------------------------------------------------------------------------


def create_engine(plan: "ResolvedPlan | EngineSpec"):
    """The one serving-engine constructor: dispatches a resolved plan to
    ``ServingEngine`` (resident) or ``OffloadedServingEngine``
    (streamed).  Accepts an unresolved ``EngineSpec`` as a convenience
    (resolved against the default budget)."""
    if isinstance(plan, EngineSpec):
        plan = plan.resolve()
    from repro.serving.engine import ServingEngine
    from repro.serving.offload_engine import OffloadedServingEngine
    if plan.engine == "offloaded":
        return OffloadedServingEngine(plan)
    return ServingEngine(plan)


def build_lm(plan: "ResolvedPlan | EngineSpec"):
    """Batch-generation twin of ``create_engine``: a ``PipelinedLM``
    configured from the plan (``b_max`` is its batch; the resident case
    maps to placement='device').  ``kv_mode`` routes through the same
    ``TieredKVStore`` serving uses — live-row slicing and INT4 KV
    streaming apply to batch generation too (host cache; a
    device-resident cache never crosses the link, so ``kv_mode='int4'``
    with ``cache_on='device'`` is rejected as contradictory)."""
    if isinstance(plan, EngineSpec):
        plan = plan.resolve()
    if plan.kv_mode == "int4" and plan.cache_on == "device":
        raise SpecError(
            "kv_mode='int4' streams the cache over the link; with "
            "cache_on='device' nothing crosses — drop kv_mode or use "
            "cache_on='host'")
    from repro.core.engine import PipelinedLM
    return PipelinedLM(plan)


# ---------------------------------------------------------------------------
# CLI flag <-> spec field table (launch.serve generates argparse from it;
# tools/check_docs.py cross-checks it against argparse AND the dataclass)
# ---------------------------------------------------------------------------


_NO_CLI_DEFAULT = object()     # sentinel: CLI default == spec field default


@dataclass(frozen=True)
class FlagSpec:
    """One CLI flag bound to one EngineSpec field.  ``kind``:
    "value" (typed argument), "true" (store_true), "false"
    (store_false, e.g. --no-warm -> warm=False).  ``cli_default``
    applies when the flag is absent and no --spec-json base was given
    (where the CLI's historical default differs from the spec's)."""

    flag: str
    field: str
    kind: str = "value"
    type: Any = str
    choices: Optional[Tuple] = None
    cli_default: Any = _NO_CLI_DEFAULT
    metavar: Optional[str] = None
    help: str = ""


CLI_FLAGS: Tuple[FlagSpec, ...] = (
    FlagSpec("--arch", "arch", help="registry architecture id"),
    FlagSpec("--scaled", "scaled", kind="true",
             help="use the scaled-down smoke config"),
    FlagSpec("--b-max", "b_max", type=int,
             help="decode slot count (continuous-batching width)"),
    FlagSpec("--max-len", "max_len", type=int, cli_default=128,
             help="per-slot KV capacity"),
    FlagSpec("--seed", "seed", type=int, help="parameter init seed"),
    FlagSpec("--offload", "offload", kind="true", cli_default=False,
             help="stream weights from host/disk via the PIPO pipeline "
                  "instead of keeping them resident"),
    FlagSpec("--placement", "placement", choices=("auto", "host", "disk"),
             help="weight tier for --offload (auto: Eq. 1 memory model)"),
    FlagSpec("--pipeline", "pipeline", choices=PIPELINE_MODES,
             help="PIPO scheduling mode for --offload"),
    FlagSpec("--quant", "quant", choices=("int4",),
             help="stream weights as packed INT4 (--offload only); ~1/4 "
                  "the link bytes, dequant overlapped on the transfer "
                  "pool"),
    FlagSpec("--kv-mode", "kv_mode", choices=("fp32", "int4"),
             help="KV-cache streaming precision (--offload only): fp32 "
                  "ships cache rows at compute precision; int4 stores "
                  "and streams them group-quantized (~1/3 the bf16 "
                  "bytes after group scales, dequant fused into decode "
                  "compute — see docs/TUNING.md)"),
    FlagSpec("--moe-quant", "moe_quant", choices=("int4",),
             help="pack the resident engine's routed expert stacks as "
                  "INT4 once at load (~1/7 the f32 resident bytes incl. "
                  "scales); compute unpacks through the fused-int4 path "
                  "(MoE archs only — see docs/TUNING.md)"),
    FlagSpec("--no-warm", "warm", kind="false",
             help="disable cross-step preloading (cold per-step "
                  "pipeline, the pre-warm baseline)"),
    FlagSpec("--preload-depth", "depth", type=int, metavar="D",
             help="layers kept in flight beyond the computing one "
                  "(--offload, performance pipeline); default: sized "
                  "from the memory budget (see docs/TUNING.md)"),
    FlagSpec("--depth-policy", "depth_policy",
             choices=DEPTH_POLICIES,
             help="static: fixed window; adaptive: re-sized between "
                  "decode steps from live KV/spill pressure"),
    FlagSpec("--spill-cap", "spill_cap", type=int,
             help="LRU cap on retained slot spills (parked requests "
                  "pinned)"),
    FlagSpec("--sim-bw", "sim_bw", type=float,
             help="simulated link bandwidth floor in bytes/s "
                  "(deterministic transfer timing; see "
                  "docs/BENCHMARKS.md)"),
    FlagSpec("--draft-arch", "draft_arch",
             help="speculative decoding (--offload only): registry arch "
                  "of a fully device-resident draft model; the draft "
                  "proposes --spec-k tokens, the streamed target scores "
                  "all k+1 positions in ONE ragged decode step and "
                  "greedy accept/reject keeps the non-speculative token "
                  "stream bit-exact (see docs/TUNING.md)"),
    FlagSpec("--spec-k", "spec_k", type=int, metavar="K",
             help="draft proposals per verify pass (needs --draft-arch; "
                  "default 4 — the link amortization grows with the "
                  "acceptance length)"),
    FlagSpec("--sched", "sched",
             choices=("online", "offline", "monolithic"),
             help="prefill scheduling policy (--offload only): online "
                  "admits eagerly and caps prefill tokens per engine "
                  "step (--prefill-chunk) so chunks share the decode "
                  "step's weight window (bounded decode stall, low "
                  "TTFT); offline runs whole-prompt chunks for maximum "
                  "throughput; monolithic (default) is the dedicated "
                  "b=1 prefill pass (see docs/TUNING.md)"),
    FlagSpec("--prefill-chunk", "prefill_chunk", type=int, metavar="T",
             help="prompt tokens prefillable per engine step (needs "
                  "--sched online/offline; defaults: 32 under online, "
                  "whole prompt under offline)"),
    FlagSpec("--stages", "stages", type=int, metavar="N",
             help="pipeline-parallel stage count (--offload only): "
                  "partition the layer stack into N contiguous stages, "
                  "each with its OWN tiered weight/KV stores, transfer "
                  "pool and preload window sized on a 1/N budget split — "
                  "aggregate host->device bandwidth scales with N and "
                  "microbatched activations hand stage to stage (see "
                  "docs/TUNING.md)"),
)

# EngineSpec fields deliberately without a CLI flag (engine-internal or
# kwargs-only knobs; the parity check closes over this set)
NO_FLAG_FIELDS = frozenset({
    "fused_int4", "cache_on", "disk_root", "block_bytes", "n_io_threads",
    "cold_reads", "stage_axis", "cfg",
})

# launch.serve flags that are workload/IO, not spec fields
WORKLOAD_FLAGS = frozenset({"--requests", "--spec-json", "--plan-json",
                            "--help"})


def add_spec_args(parser) -> None:
    """Generate the spec half of an argparse CLI from ``CLI_FLAGS``.
    All defaults are SUPPRESS so ``spec_from_args`` can tell explicit
    flags from absent ones (explicit flags override a --spec-json
    base)."""
    import argparse
    for f in CLI_FLAGS:
        kw = dict(dest=f.field, default=argparse.SUPPRESS, help=f.help)
        if f.kind == "true":
            parser.add_argument(f.flag, action="store_true", **kw)
        elif f.kind == "false":
            parser.add_argument(f.flag, action="store_false", **kw)
        else:
            if f.choices is not None:
                kw["choices"] = f.choices
            if f.metavar is not None:
                kw["metavar"] = f.metavar
            parser.add_argument(f.flag, type=f.type, **kw)


def spec_from_args(args, base: Optional[EngineSpec] = None) -> EngineSpec:
    """Build an EngineSpec from parsed args: start from ``base`` (a
    --spec-json load) or from the spec defaults overlaid with the
    table's CLI defaults, then apply every explicitly-given flag."""
    if base is None:
        cli_defaults = {f.field: f.cli_default for f in CLI_FLAGS
                        if f.cli_default is not _NO_CLI_DEFAULT}
        base = EngineSpec(**cli_defaults)
    given = {f.field: getattr(args, f.field) for f in CLI_FLAGS
             if hasattr(args, f.field)}
    return dataclasses.replace(base, **given)
