"""Traffic workloads for the serving engines: arrival traces, a real-
engine driver, and a deterministic traffic simulator.

Three pieces, smallest first:

  * ``ArrivalTrace`` — a seeded, fully deterministic request schedule
    (``poisson_trace`` / ``ramp_trace`` generators, JSON round-trip for
    replayed traces).  Arrival times are in *trace seconds*; drivers
    scale them onto their own clock.
  * ``run_trace(eng, trace)`` — drives a REAL engine (resident or
    offloaded) step by step, submitting each request once its arrival
    time passes so queue wait is charged to the request
    (``Request.t_arrive`` is the scheduled arrival, not the submit
    call).  Per-request TTFT/TBT/e2e series land in
    ``eng.trace.meta["latency"]`` where ``Trace.report()`` summarizes
    them as p50/p95/p99.
  * ``TrafficSim`` — a discrete-event simulator of the slot-engine
    serving loop on a virtual clock, with a three-number cost model
    (full weight sweep, per-decode-token compute, per-prefill-token
    compute).  It reproduces the scheduling semantics that matter for
    latency — monolithic prefill pays a dedicated weight sweep per
    admission, a chunked prefill rides the decode batch's sweeps — so
    policy comparisons (OnlineSLO vs OfflineThroughput vs monolithic)
    are exact and hardware-free.  Its trace meta carries the arrival
    schedule and knobs, so ``core.replay.replay_traffic`` can re-run
    the same traffic under what-if chunk/policy settings.
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.tasks import Trace, TraceEvent, VirtualClock
from repro.serving.base import Request

__all__ = ["Arrival", "ArrivalTrace", "poisson_trace", "ramp_trace",
           "latency_series", "run_trace", "SimCosts", "SimResult",
           "TrafficSim"]


# ---------------------------------------------------------------------------
# Arrival traces
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Arrival:
    t: float                   # arrival time (trace seconds, from 0)
    rid: int
    prompt: tuple              # token ids (immutable -> hashable/JSON)
    max_new: int = 8


@dataclass
class ArrivalTrace:
    """A deterministic request schedule.  ``meta`` records how it was
    generated (kind, seed, rates) so a benchmark row can name its
    workload; replayed-JSON traces round-trip through
    ``to_json``/``from_json`` byte-for-byte."""

    arrivals: List[Arrival] = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)

    def requests(self) -> List[Request]:
        """Fresh ``Request`` objects in arrival order (prompt arrays are
        newly allocated — safe to reuse the trace across engines)."""
        return [Request(rid=a.rid, prompt=np.asarray(a.prompt, np.int32),
                        max_new=a.max_new)
                for a in sorted(self.arrivals, key=lambda a: a.t)]

    def to_json(self) -> Dict[str, Any]:
        return {"meta": dict(self.meta),
                "arrivals": [{"t": a.t, "rid": a.rid,
                              "prompt": list(map(int, a.prompt)),
                              "max_new": a.max_new}
                             for a in self.arrivals]}

    @classmethod
    def from_json(cls, d: "Dict[str, Any] | str") -> "ArrivalTrace":
        if isinstance(d, str):
            d = json.loads(d)
        return cls(arrivals=[Arrival(t=float(a["t"]), rid=int(a["rid"]),
                                     prompt=tuple(int(x)
                                                  for x in a["prompt"]),
                                     max_new=int(a.get("max_new", 8)))
                             for a in d.get("arrivals", [])],
                   meta=dict(d.get("meta", {})))


def _gen(rates: Sequence[float], *, seed: int, vocab: int,
         prompt_len, max_new: int, kind: str, extra: dict) -> ArrivalTrace:
    """Shared generator: one exponential inter-arrival per request at
    that request's rate (req/s), seeded prompts."""
    rng = np.random.default_rng(seed)
    lo, hi = ((prompt_len, prompt_len) if isinstance(prompt_len, int)
              else prompt_len)
    t, arrivals = 0.0, []
    for rid, rate in enumerate(rates):
        t += float(rng.exponential(1.0 / max(1e-9, rate)))
        s = int(rng.integers(lo, hi + 1))
        prompt = tuple(int(x) for x in rng.integers(0, vocab, (s,)))
        arrivals.append(Arrival(t=t, rid=rid, prompt=prompt,
                                max_new=max_new))
    return ArrivalTrace(arrivals=arrivals,
                        meta=dict(kind=kind, seed=seed, n=len(arrivals),
                                  vocab=vocab, prompt_len=[lo, hi],
                                  max_new=max_new, **extra))


def poisson_trace(n: int, rate: float, *, seed: int = 0, vocab: int = 256,
                  prompt_len=(6, 12), max_new: int = 8) -> ArrivalTrace:
    """``n`` arrivals with exponential inter-arrivals at a constant
    ``rate`` (requests per trace second)."""
    return _gen([rate] * n, seed=seed, vocab=vocab, prompt_len=prompt_len,
                max_new=max_new, kind="poisson", extra=dict(rate=rate))


def ramp_trace(n: int, rate0: float, rate1: float, *, seed: int = 0,
               vocab: int = 256, prompt_len=(6, 12),
               max_new: int = 8) -> ArrivalTrace:
    """``n`` arrivals whose rate ramps linearly from ``rate0`` to
    ``rate1`` across the trace — the load-buildup regime where queue
    wait dominates TTFT tails."""
    rates = [rate0 + (rate1 - rate0) * (i / max(1, n - 1))
             for i in range(n)]
    return _gen(rates, seed=seed, vocab=vocab, prompt_len=prompt_len,
                max_new=max_new, kind="ramp",
                extra=dict(rate0=rate0, rate1=rate1))


# ---------------------------------------------------------------------------
# Real-engine driver
# ---------------------------------------------------------------------------


def latency_series(done: Sequence[Request]) -> Dict[str, List[float]]:
    """Per-request latency series (seconds): TTFT (arrival -> first
    token), TBT (gaps between consecutive emitted tokens), e2e
    (arrival -> completion)."""
    return {
        "ttft": [r.t_first_token - r.t_arrive for r in done],
        "tbt": [b - a for r in done
                for a, b in zip(r.t_tokens, r.t_tokens[1:])],
        "e2e": [r.t_done - r.t_arrive for r in done],
    }


def run_trace(eng, atrace: ArrivalTrace, *, time_scale: float = 1.0,
              max_steps: int = 100_000) -> List[Request]:
    """Drive a real engine through an arrival trace (main thread,
    blocking).  Each request is submitted once its scaled arrival time
    passes on the wall clock, with ``t_arrive`` stamped to the SCHEDULED
    arrival so queue wait counts; the engine then steps until every
    request drains.  Idle gaps (engine empty, next arrival in the
    future) sleep the wall clock forward.  Latency series are stamped
    into ``eng.trace.meta["latency"]`` when the engine records a trace,
    and the completed requests are returned either way."""
    arrivals = sorted(atrace.arrivals, key=lambda a: a.t)
    reqs = {a.rid: a for a in arrivals}
    assert len(reqs) == len(arrivals), "arrival rids must be unique"
    eng._epoch += 1                    # fresh spill namespaces, like run()
    done: List[Request] = []
    t0 = time.perf_counter()
    i = 0
    for _ in range(max_steps):
        now = time.perf_counter() - t0
        while i < len(arrivals) and arrivals[i].t * time_scale <= now:
            a = arrivals[i]
            i += 1
            req = Request(rid=a.rid,
                          prompt=np.asarray(a.prompt, np.int32),
                          max_new=a.max_new)
            req.t_arrive = t0 + a.t * time_scale
            eng.submit(req)
        if eng.idle():
            if i >= len(arrivals):
                break
            dt = t0 + arrivals[i].t * time_scale - time.perf_counter()
            if dt > 0:
                time.sleep(dt)
            continue
        eng.step(done)
    trace = getattr(eng, "trace", None)
    if trace is not None:
        trace.meta["latency"] = latency_series(done)
    return done


# ---------------------------------------------------------------------------
# TrafficSim — deterministic policy comparison on a virtual clock
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimCosts:
    """Three-number cost model for one engine step.  A step (one
    ``generate`` sweep) streams every layer's weights once —
    ``sweep_s`` — overlapped with its compute: ``tok_s`` per active
    decode row plus ``prefill_tok_s`` per prompt token carried (chunk
    or monolithic).  Step time is the max of the two (the pipeline
    overlaps transfers with compute); the offloading regime has
    ``sweep_s`` dominating, which is exactly why a chunk riding an
    existing decode sweep is nearly free while a monolithic prefill
    pays a whole dedicated sweep."""

    sweep_s: float = 1.0
    tok_s: float = 0.02
    prefill_tok_s: float = 0.01


@dataclass
class SimResult:
    trace: Trace
    done: List[Dict[str, Any]]         # per-request records (rid, ttft, ...)
    tokens_out: int
    sweeps: int
    span_s: float

    @property
    def tok_per_s(self) -> float:
        return self.tokens_out / self.span_s if self.span_s > 0 else 0.0

    def report(self) -> Dict[str, Any]:
        return self.trace.report()


class TrafficSim:
    """Discrete-event simulation of ``SlotEngineBase``'s serving loop
    under a scheduling policy: ``sched`` in {"monolithic", "online",
    "offline"} with ``chunk`` the per-step prefill-token cap (online;
    offline and monolithic derive theirs).  Semantics mirror the real
    engines: FIFO admission into ``b_max`` slots; monolithic prefill is
    a dedicated sweep at admission; chunked prefill claims the slot and
    feeds ``<= cap`` prompt tokens per step into the shared sweep, at
    most one in flight; every active slot emits one token per step; the
    first token of a chunked request lands when its last chunk
    completes.  All time is virtual — identical inputs give identical
    latency numbers on any machine."""

    def __init__(self, atrace: ArrivalTrace, *, b_max: int = 2,
                 sched: str = "monolithic", chunk: int = 0,
                 costs: SimCosts = SimCosts()):
        if sched not in ("monolithic", "online", "offline"):
            raise ValueError(f"unknown sched policy {sched!r}")
        self.atrace = atrace
        self.b_max = int(b_max)
        self.sched = sched
        self.chunk = int(chunk)
        self.costs = costs

    def _cap(self, plen: int) -> int:
        if self.sched == "online":
            return max(1, self.chunk or 32)
        return plen                    # offline: the whole prompt rides once

    def run(self) -> SimResult:
        c = self.costs
        arrivals = sorted(self.atrace.arrivals, key=lambda a: a.t)
        clock = VirtualClock()
        tr = Trace(clock=clock)
        queue: List[Arrival] = []
        slots: List[Optional[dict]] = [None] * self.b_max
        ck: Optional[dict] = None      # in-flight chunked prefill
        recs: List[Dict[str, Any]] = []
        t, i, sweeps, toks_out, step_id = 0.0, 0, 0, 0, 0

        def drain_arrivals():
            nonlocal i
            while i < len(arrivals) and arrivals[i].t <= t:
                queue.append(arrivals[i])
                i += 1

        def emit(ev_kind, name, dt):
            nonlocal t, sweeps
            tr._events.append(TraceEvent(ev_kind, name, t, t + dt, "main"))
            t += dt
            sweeps += 1
            clock.advance_to(t)

        def first_token(rec, a):
            nonlocal toks_out
            rec.update(ttft=t - a.t, t_first=t, t_tokens=[t], emitted=1)
            toks_out += 1

        def finish(s):
            nonlocal toks_out
            rec = slots[s]
            rec["e2e"] = t - rec["a"].t
            recs.append(rec)
            slots[s] = None

        while i < len(arrivals) or queue or any(slots):
            drain_arrivals()
            # admission (FIFO; chunked policies claim at most one slot
            # for prefill at a time, like the engines' CHUNK_BUSY gate)
            while queue and None in slots:
                s = slots.index(None)
                a = queue[0]
                rec = dict(rid=a.rid, a=a, emitted=0, active=False,
                           t_tokens=[])
                if self.sched == "monolithic":
                    queue.pop(0)
                    slots[s] = rec
                    emit("prefill_sweep", f"prefill[{a.rid}]",
                         max(c.sweep_s, len(a.prompt) * c.prefill_tok_s))
                    first_token(rec, a)
                    rec["active"] = True
                    if rec["emitted"] >= a.max_new:
                        finish(s)
                    drain_arrivals()
                else:
                    if ck is not None:
                        break          # one chunked prefill in flight
                    queue.pop(0)
                    slots[s] = rec
                    ck = dict(slot=s, a=a, done=0, need=len(a.prompt))
            active = [s for s in range(self.b_max)
                      if slots[s] is not None and slots[s]["active"]]
            n_ck = 0
            if ck is not None:
                n_ck = min(self._cap(ck["need"]), ck["need"] - ck["done"])
            if not active and n_ck == 0:
                if i < len(arrivals):
                    t = max(t, arrivals[i].t)   # idle: jump to next arrival
                    clock.advance_to(t)
                    continue
                break
            # one shared sweep carries the decode batch + the chunk
            emit("decode_step", f"step[{step_id}]",
                 max(c.sweep_s,
                     len(active) * c.tok_s + n_ck * c.prefill_tok_s))
            step_id += 1
            for s in active:
                rec = slots[s]
                rec["emitted"] += 1
                rec["t_tokens"].append(t)
                toks_out += 1
                if rec["emitted"] >= rec["a"].max_new:
                    finish(s)
            if ck is not None:
                ck["done"] += n_ck
                if ck["done"] >= ck["need"]:
                    s, a = ck["slot"], ck["a"]
                    ck = None
                    first_token(slots[s], a)
                    slots[s]["active"] = True
                    if slots[s]["emitted"] >= a.max_new:
                        finish(s)

        lat = {
            "ttft": [r["ttft"] for r in recs],
            "tbt": [b - a for r in recs
                    for a, b in zip(r["t_tokens"], r["t_tokens"][1:])],
            "e2e": [r["e2e"] for r in recs],
        }
        tr.meta.update(
            latency=lat, tokens_out=toks_out, sweeps=sweeps,
            traffic=dict(sched=self.sched, chunk=self.chunk,
                         b_max=self.b_max, costs=asdict(self.costs),
                         arrivals=self.atrace.to_json()))
        for r in recs:
            r.pop("a", None)
            r.pop("active", None)
        return SimResult(trace=tr, done=recs, tokens_out=toks_out,
                         sweeps=sweeps, span_s=tr.span())
