"""Config dataclasses for the repro framework.

A ``ModelConfig`` fully describes one architecture: the layer *pattern*
(a period of heterogeneous layers scanned ``num_periods`` times plus an
unrolled remainder), attention flavour, MoE/SSM parameters, and modality
frontend stubs.  Every assigned architecture is one instance of this.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Layer kinds composing a pattern period.
# ---------------------------------------------------------------------------
ATTN = "attn"            # full (causal) attention
ATTN_LOCAL = "attn_local"  # sliding-window attention
MLA = "mla"              # DeepSeek multi-head latent attention
SSM = "ssm"              # Mamba2 / SSD layer
CROSS = "cross"          # encoder-decoder cross attention (decoder side)
ENC = "enc"              # bidirectional encoder self attention

MIXER_KINDS = (ATTN, ATTN_LOCAL, MLA, SSM, CROSS, ENC)

DENSE = "dense"          # plain (Swi)GLU MLP
MOE = "moe"              # routed mixture of experts


@dataclass(frozen=True)
class LayerSpec:
    """One layer = a (mixer, ffn) pair."""

    mixer: str = ATTN
    ffn: str = DENSE

    def __post_init__(self):
        assert self.mixer in MIXER_KINDS, self.mixer
        assert self.ffn in (DENSE, MOE), self.ffn


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    expert_d_ff: int = 0          # per-expert hidden size
    num_shared: int = 0           # shared (always-on) experts
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense|moe|ssm|hybrid|vlm|audio
    # -- core dims ---------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0             # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    max_seq_len: int = 131072
    # -- layer pattern -----------------------------------------------------
    # ``pattern`` repeats ``num_periods`` times, then ``remainder`` unrolls.
    # len(pattern) * num_periods + len(remainder) == num_layers.
    pattern: Sequence[LayerSpec] = (LayerSpec(),)
    num_periods: int = 0          # 0 -> num_layers // len(pattern)
    remainder: Sequence[LayerSpec] = ()
    # -- attention ---------------------------------------------------------
    rope_theta: float = 10000.0
    window: int = 0               # sliding window for ATTN_LOCAL
    qk_norm: bool = False         # qwen3-style per-head q/k RMSNorm
    mrope_sections: Sequence[int] = ()  # qwen2-vl M-RoPE (t,h,w) split
    logit_softcap: float = 0.0
    # -- sub-configs -------------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # -- enc-dec -----------------------------------------------------------
    enc_dec: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500   # whisper frames after conv stub
    # -- modality frontend stub --------------------------------------------
    frontend: str = "tokens"      # tokens|embeds (vlm/audio stubs feed embeds)
    # -- norm/activation ---------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # store big 2-D projections as packed INT4 (+ groupwise scales); the
    # dequant is VREG-fused on TPU (kernels/int4_matmul.py) — the paper's
    # W4 technique as a pod-scale dry-run variant (§Perf A2).
    quant_weights: bool = False

    # -- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_periods == 0 and len(self.pattern):
            per = (self.num_layers - len(self.remainder)) // len(self.pattern)
            object.__setattr__(self, "num_periods", per)
        total = len(self.pattern) * self.num_periods + len(self.remainder)
        assert total == self.num_layers, (
            f"{self.name}: pattern*periods+remainder={total} != num_layers={self.num_layers}")

    # ---- parameter counting (used by autoconfig + roofline MODEL_FLOPS) --
    def mixer_params(self, spec: LayerSpec) -> int:
        d, hd = self.d_model, self.head_dim
        if spec.mixer in (ATTN, ATTN_LOCAL, ENC):
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o
        if spec.mixer == CROSS:  # self-attn + cross-attn
            self_p = self.mixer_params(LayerSpec(ATTN, spec.ffn))
            cross = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
            return self_p + cross
        if spec.mixer == MLA:
            m = self.mla
            q = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * (
                m.qk_nope_head_dim + m.qk_rope_head_dim)
            kv = d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank * \
                self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            o = self.num_heads * m.v_head_dim * d
            return q + kv + o
        if spec.mixer == SSM:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            in_proj = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
            conv = (d_in + 2 * s.n_groups * s.d_state) * s.d_conv
            out = d_in * d
            return in_proj + conv + out + 2 * nheads  # A_log, D
        raise ValueError(spec.mixer)

    def ffn_params(self, spec: LayerSpec, active_only: bool = False) -> int:
        d = self.d_model
        if spec.ffn == DENSE:
            return 3 * d * self.d_ff
        m = self.moe
        n_routed = m.top_k if active_only else m.num_experts
        routed = n_routed * 3 * d * m.expert_d_ff
        shared = m.num_shared * 3 * d * m.shared_d_ff
        router = d * m.num_experts
        return routed + shared + router

    def _all_specs(self):
        return list(self.pattern) * self.num_periods + list(self.remainder)

    def param_count(self, active_only: bool = False) -> int:
        n = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        for spec in self._all_specs():
            n += self.mixer_params(spec) + self.ffn_params(spec, active_only)
            n += 2 * self.d_model  # norms
        if self.enc_dec:
            enc_spec = LayerSpec(ENC, DENSE)
            n += self.num_encoder_layers * (
                self.mixer_params(enc_spec) + self.ffn_params(enc_spec) + 2 * self.d_model)
        return n

    def kv_bytes_per_token_layer(self, p: int = 2) -> int:
        """bytes of KV cache one token adds in one attention layer."""
        if self.mla is not None:
            return p * (self.mla.kv_lora_rank + self.mla.qk_rope_head_dim)
        return p * 2 * self.num_kv_heads * self.head_dim

    def attn_layer_indices(self):
        return [i for i, s in enumerate(self._all_specs())
                if s.mixer in (ATTN, ATTN_LOCAL, MLA, CROSS)]


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch is exercised on its own shape set.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# Archs for which long_500k runs (sub-quadratic mixers); others skip (full attn).
LONG_CONTEXT_OK = ("mamba2-1.3b", "jamba-1.5-large-398b", "gemma3-4b")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: long_500k needs sub-quadratic mixer"
    return True, ""


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    # capacity_factor = num_experts makes the smoke configs dropless
    # (capacity >= T*k): prefill->decode consistency then holds exactly.
    # Production configs keep cf=1.25 (capacity drops are inherent to
    # capacity-based MoE and are load-balanced away in trained models).
    moe = cfg.moe and dataclasses.replace(
        cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
        top_k=min(cfg.moe.top_k, 2), expert_d_ff=64,
        shared_d_ff=64 if cfg.moe.num_shared else 0,
        capacity_factor=float(min(cfg.moe.num_experts, 4)))
    mla = cfg.mla and dataclasses.replace(
        cfg.mla, q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
        qk_rope_head_dim=8, v_head_dim=8)
    ssm = cfg.ssm and dataclasses.replace(
        cfg.ssm, d_state=16, head_dim=8, chunk_size=32)
    pattern = cfg.pattern
    remainder = cfg.remainder
    num_layers = len(pattern) * 2 + len(remainder)  # two periods + remainder
    d_model = 64
    num_heads = 4
    num_kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4
    base = dataclasses.replace(
        cfg, num_layers=num_layers, num_periods=2, d_model=d_model,
        num_heads=num_heads, num_kv_heads=num_kv, head_dim=16, d_ff=128,
        vocab_size=256, max_seq_len=512, window=min(cfg.window, 64) if cfg.window else 0,
        moe=moe, mla=mla, ssm=ssm,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 24),
        mrope_sections=(4, 2, 2) if cfg.mrope_sections else (),
    )
    if overrides:
        base = dataclasses.replace(base, **overrides)
    return base
