"""``--arch <id>`` registry: the 10 assigned architectures + paper models."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable
from repro.configs.deepseek_v3_671b import CONFIG as DEEPSEEK_V3
from repro.configs.gemma3_4b import CONFIG as GEMMA3_4B
from repro.configs.granite_8b import CONFIG as GRANITE_8B
from repro.configs.jamba_1_5_large_398b import CONFIG as JAMBA_1_5
from repro.configs.llama4_scout_17b_a16e import CONFIG as LLAMA4_SCOUT
from repro.configs.mamba2_1_3b import CONFIG as MAMBA2_1_3B
from repro.configs.paper_models import PAPER_MODELS
from repro.configs.qwen2_vl_72b import CONFIG as QWEN2_VL_72B
from repro.configs.qwen3_8b import CONFIG as QWEN3_8B
from repro.configs.tinyllama_1_1b import CONFIG as TINYLLAMA
from repro.configs.whisper_base import CONFIG as WHISPER_BASE

ASSIGNED: dict[str, ModelConfig] = {c.name: c for c in (
    GRANITE_8B, TINYLLAMA, GEMMA3_4B, QWEN3_8B, QWEN2_VL_72B,
    JAMBA_1_5, LLAMA4_SCOUT, DEEPSEEK_V3, MAMBA2_1_3B, WHISPER_BASE)}

REGISTRY: dict[str, ModelConfig] = {**ASSIGNED, **PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def list_archs() -> list[str]:
    return sorted(ASSIGNED)


def all_cells():
    """Every (arch, shape, runnable, skip_reason) cell — 40 total."""
    out = []
    for a in list_archs():
        cfg = ASSIGNED[a]
        for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            ok, why = shape_applicable(cfg, SHAPES[s])
            out.append((a, s, ok, why))
    return out
