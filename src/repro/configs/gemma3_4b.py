"""gemma3-4b [dense]: 34L d=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt].
Pattern: 5 periods of [5x local(window=1024), 1x global] + remainder
[3x local, 1x global] = 34 layers, 6 global total.
"""
from repro.configs.base import ATTN, ATTN_LOCAL, DENSE, LayerSpec, ModelConfig

_L = LayerSpec(ATTN_LOCAL, DENSE)
_G = LayerSpec(ATTN, DENSE)

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    pattern=(_L, _L, _L, _L, _L, _G),
    num_periods=5,
    remainder=(_L, _L, _L, _G),
    window=1024,
    rope_theta=1000000.0,
    tie_embeddings=True,
)
