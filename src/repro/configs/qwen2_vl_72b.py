"""qwen2-vl-72b [vlm]: 80L d=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

M-RoPE + dynamic resolution [arXiv:2409.12191].  The vision frontend is a
STUB per assignment: ``input_specs()`` provides precomputed patch embeddings
of shape (batch, seq, d_model) plus 3-component (t, h, w) M-RoPE position
ids; only the transformer backbone is built.
"""
from repro.configs.base import ATTN, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    pattern=(LayerSpec(ATTN, DENSE),),
    mrope_sections=(16, 24, 24),  # halves of head_dim (64) split t/h/w
    rope_theta=1000000.0,
    frontend="embeds",
)
