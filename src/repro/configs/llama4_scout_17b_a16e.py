"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E].  Every layer MoE with one shared
expert (early-fusion multimodality handled at token level; text backbone).
"""
from repro.configs.base import ATTN, MOE, LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(LayerSpec(ATTN, MOE),),
    moe=MoEConfig(num_experts=16, top_k=1, expert_d_ff=8192,
                  num_shared=1, shared_d_ff=8192),
    rope_theta=500000.0,
)
