from repro.configs.base import (ATTN, ATTN_LOCAL, CROSS, DENSE, ENC, MLA, MOE,
                                SSM, LayerSpec, MLAConfig, ModelConfig,
                                MoEConfig, SSMConfig, ShapeConfig, SHAPES,
                                scaled_down, shape_applicable)
from repro.configs.registry import (ASSIGNED, REGISTRY, all_cells, get_config,
                                    get_shape, list_archs)

__all__ = [
    "ATTN", "ATTN_LOCAL", "CROSS", "DENSE", "ENC", "MLA", "MOE", "SSM",
    "LayerSpec", "MLAConfig", "ModelConfig", "MoEConfig", "SSMConfig",
    "ShapeConfig", "SHAPES", "scaled_down", "shape_applicable",
    "ASSIGNED", "REGISTRY", "all_cells", "get_config", "get_shape",
    "list_archs",
]
