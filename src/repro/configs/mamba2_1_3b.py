"""mamba2-1.3b [ssm]: 48L d=2048 attn-free, vocab=50280, ssm_state=128.

SSD (state-space duality) [arXiv:2405.21060].  No FFN (d_ff=0): each layer
is a single Mamba2 block.  d_inner = 2*2048 = 4096, head_dim 64 -> 64 heads.
"""
from repro.configs.base import DENSE, SSM, LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    pattern=(LayerSpec(SSM, DENSE),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    tie_embeddings=True,
)
