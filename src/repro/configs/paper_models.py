"""Models evaluated in the PIPO paper itself (Figures 5-12, Tables 1-6).

These back the paper-table benchmarks; on this CPU container they run via
``scaled_down`` variants, while the full configs feed the autoconfig memory
model (Appendix B validation).
"""
from repro.configs.base import (ATTN, DENSE, MOE, LayerSpec, ModelConfig,
                                MoEConfig)

LLAMA31_8B = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    pattern=(LayerSpec(ATTN, DENSE),),
    rope_theta=500000.0,
)

LLAMA31_70B = ModelConfig(
    name="llama3.1-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    pattern=(LayerSpec(ATTN, DENSE),),
    rope_theta=500000.0,
)

LLAMA32_1B = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    pattern=(LayerSpec(ATTN, DENSE),),
    rope_theta=500000.0,
    tie_embeddings=True,
)


def _opt(name, layers, d, heads, vocab=50272):
    # OPT uses MHA + a 2-matrix 4d ReLU MLP (8d^2 params).  Our DENSE block is
    # 3-matrix SwiGLU, so size d_ff = 8d/3 (rounded to 128) to keep the layer
    # parameter count — and therefore the offloading memory model — faithful.
    d_ff = max(128, int(8 * d / 3) // 128 * 128)
    return ModelConfig(
        name=name, family="dense", num_layers=layers, d_model=d,
        num_heads=heads, num_kv_heads=heads, head_dim=d // heads,
        d_ff=d_ff, vocab_size=vocab, pattern=(LayerSpec(ATTN, DENSE),),
    )


OPT_1_3B = _opt("opt-1.3b", 24, 2048, 32)
OPT_6_7B = _opt("opt-6.7b", 32, 4096, 32)
OPT_13B = _opt("opt-13b", 40, 5120, 40)
OPT_30B = _opt("opt-30b", 48, 7168, 56)
OPT_66B = _opt("opt-66b", 64, 9216, 72)

MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    pattern=(LayerSpec(ATTN, MOE),),
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=14336),
    rope_theta=1000000.0,
)

PAPER_MODELS = {m.name: m for m in (
    LLAMA31_8B, LLAMA31_70B, LLAMA32_1B, OPT_1_3B, OPT_6_7B, OPT_13B,
    OPT_30B, OPT_66B, MIXTRAL_8X7B)}
