"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2.  Mamba+attn 1:7 interleave [arXiv:2403.19887].

Period of 8 layers: attention at position 4, SSM elsewhere; MoE on odd
positions (1:1 MoE:dense alternation).  9 periods = 72 layers.
"""
from repro.configs.base import (ATTN, DENSE, MOE, SSM, LayerSpec, ModelConfig,
                                MoEConfig, SSMConfig)

_SD = LayerSpec(SSM, DENSE)
_SM = LayerSpec(SSM, MOE)
_AD = LayerSpec(ATTN, DENSE)
_AM = LayerSpec(ATTN, MOE)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=(_SD, _SM, _SD, _SM, _AD, _SM, _SD, _SM),
    num_periods=9,
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=24576),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=128, chunk_size=256),
    rope_theta=10000.0,
)
