"""deepseek-v3-671b [moe]: 61L d=7168 128H (MLA) d_ff=2048/expert
vocab=129280, MoE 256e top-8 + 1 shared [arXiv:2412.19437].

MLA: q_lora 1536, kv_lora 512, nope 128, rope 64, v 128.  Per the assigned
config all 61 layers are MoE with uniform expert d_ff=2048 (the real model's
first-3 dense layers are omitted — noted in DESIGN.md).  MTP head is not part
of the assigned config.  Active params ~= 37B.
"""
from repro.configs.base import MLA, MOE, LayerSpec, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    vocab_size=129280,
    pattern=(LayerSpec(MLA, MOE),),
    moe=MoEConfig(num_experts=256, top_k=8, expert_d_ff=2048,
                  num_shared=1, shared_d_ff=2048),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    rope_theta=10000.0,
)
