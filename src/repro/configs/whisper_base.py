"""whisper-base [audio]: enc-dec, 6L d=512 8H d_ff=2048 vocab=51865.

[arXiv:2212.04356].  The conv audio frontend is a STUB per assignment:
``input_specs()`` provides precomputed frame embeddings (batch, 1500, 512)
for the encoder.  Decoder layers = self-attn + cross-attn + MLP.
"""
from repro.configs.base import CROSS, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    pattern=(LayerSpec(CROSS, DENSE),),
    enc_dec=True,
    num_encoder_layers=6,
    encoder_seq_len=1500,
    frontend="embeds",
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions; we use sinusoidal
)
