"""Fault-tolerant training runner: checkpoint/restart, failure injection,
straggler detection, elastic resume.

At 1000+ nodes the dominant failure mode is a host dying mid-step; the
contract here:
  * state = (params, opt, step) only — the data pipeline is step-indexed
    (data/pipeline.py), so resume needs NO iterator state;
  * async checkpoint every ``ckpt_every`` steps, atomic rename (a crash
    during save leaves the previous checkpoint intact);
  * on restart, `TrainRunner.run` restores the latest step and continues —
    in tests a ``FailureInjector`` kills the loop mid-run and a fresh
    runner reproduces the uninterrupted loss trajectory exactly;
  * ``StragglerDetector`` keeps per-step wall times; on a real pod each
    host contributes its time via an all_gather and slow hosts (z-score
    or x-median rule) are reported to the scheduler for eviction /
    re-sharding — here the detection logic is exercised with injected
    delays;
  * elastic: restore accepts a different mesh (checkpoint stores logical
    arrays; shardings are re-applied), so shrink/grow = rebuild Dist +
    restore.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint)


class FailureInjector(Exception):
    """Raised inside the loop to simulate a host loss."""


@dataclass
class RunnerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 10
    keep: int = 3
    max_steps: int = 100


class StragglerDetector:
    """Per-step wall-time ring buffer + robust outlier rule.

    multi-host: feed ``observe`` with the all-gathered per-host step
    times; ``stragglers`` returns host indices slower than
    ``factor`` x median (the standard eviction trigger).
    """

    def __init__(self, window: int = 32, factor: float = 2.0):
        self.window = window
        self.factor = factor
        self.times: deque = deque(maxlen=window)

    def observe(self, per_host_seconds):
        self.times.append(np.asarray(per_host_seconds, np.float64))

    def stragglers(self) -> list[int]:
        if not self.times:
            return []
        avg = np.mean(np.stack(self.times), axis=0)
        med = np.median(avg)
        return [int(i) for i in np.nonzero(avg > self.factor * med)[0]]

    def step_stats(self) -> dict:
        if not self.times:
            return {}
        t = np.stack(self.times)
        return {"mean_s": float(t.mean()), "p50_s": float(np.median(t)),
                "max_s": float(t.max())}


class TrainRunner:
    """Drives step_fn with checkpoint/restart.

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics)
    """

    def __init__(self, cfg: RunnerConfig, step_fn: Callable,
                 init_state: Callable[[], tuple], data,
                 shardings: Optional[tuple] = None,
                 fail_at: Optional[int] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.init_state = init_state
        self.data = data
        self.shardings = shardings
        self.fail_at = fail_at
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.detector = StragglerDetector()
        self.history: list[float] = []

    def _restore_or_init(self):
        last = latest_step(self.cfg.ckpt_dir)
        params, opt_state = self.init_state()
        if last is None:
            return params, opt_state, 0
        tree = {"params": params, "opt": opt_state}
        sh = None
        if self.shardings is not None:
            sh = {"params": self.shardings[0], "opt": self.shardings[1]}
        restored, manifest = restore_checkpoint(
            self.cfg.ckpt_dir, last, tree, shardings=sh)
        return restored["params"], restored["opt"], int(manifest["step"])

    def run(self) -> dict:
        params, opt_state, start = self._restore_or_init()
        step = start
        while step < self.cfg.max_steps:
            batch = self.data.batch_at(step)
            t0 = time.perf_counter()
            if self.fail_at is not None and step == self.fail_at:
                raise FailureInjector(f"injected failure at step {step}")
            params, opt_state, metrics = self.step_fn(
                params, opt_state,
                {k: v for k, v in batch.items() if k != "step"})
            loss = float(metrics["loss"])
            self.history.append(loss)
            dt = time.perf_counter() - t0
            self.detector.observe([dt])
            step += 1
            if step % self.cfg.ckpt_every == 0 or step == self.cfg.max_steps:
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               meta={"loss": loss})
        self.ckpt.wait()
        return {"final_step": step, "losses": self.history,
                "timing": self.detector.step_stats()}
