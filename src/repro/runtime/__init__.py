from repro.runtime.compression import (compress_int8, decompress_int8,
                                       ErrorFeedbackCompressor)
from repro.runtime.fault_tolerance import (StragglerDetector, TrainRunner,
                                           RunnerConfig)

__all__ = ["compress_int8", "decompress_int8", "ErrorFeedbackCompressor",
           "StragglerDetector", "TrainRunner", "RunnerConfig"]
