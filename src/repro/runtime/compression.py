"""Gradient compression for the cross-pod (DCN) data-parallel reduction.

int8 quantization with error feedback (Seide et al. / 1-bit-Adam lineage):
the residual of each round is added back before the next quantization, so
the long-run bias vanishes — convergence is preserved while the pod axis
all-reduce moves 4x fewer bytes over the slow DCN links.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_int8(x):
    """x (any shape) -> (int8 values, f32 scale).  Symmetric per-tensor."""
    m = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(m / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


class ErrorFeedbackCompressor:
    """Stateful per-leaf error feedback around compress/decompress.

    usage per step (pure-functional):
        comp, residuals = ef.compress(grads, residuals)
        # all-reduce comp over the pod axis (int8) ...
        grads = ef.decompress(comp)
    """

    def init(self, grads: Any):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress(self, grads: Any, residuals: Any):
        def one(g, r):
            x = g.astype(jnp.float32) + r
            q, s = compress_int8(x)
            err = x - decompress_int8(q, s)
            return (q, s), err
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(residuals)
        pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        comp = treedef.unflatten([p[0] for p in pairs])
        new_r = treedef.unflatten([p[1] for p in pairs])
        return comp, new_r

    def decompress(self, comp: Any, dtype=jnp.float32):
        return jax.tree.map(lambda qs: decompress_int8(*qs, dtype=dtype),
                            comp, is_leaf=lambda x: isinstance(x, tuple))
