"""jit-able step functions: train / prefill / decode."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import Dist
from repro.models.model import Model
from repro.optim import AdamW, apply_updates


def make_train_step(model: Model, dist: Dist, opt: AdamW):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.train_loss(p, batch, dist))(params)
        updates, opt_state, gnorm = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_prefill_step(model: Model, dist: Dist, cache_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, dist, cache_len)
    return prefill_step


def make_decode_step(model: Model, dist: Dist):
    def decode_step(params, batch, caches):
        return model.decode_step(params, batch, caches, dist)
    return decode_step
