"""Serving launcher: continuous-batching engine over a registry arch.

Resident weights (default):
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --scaled --requests 10

Offloaded weights through the PIPO pipeline (models larger than device
memory; see serving/offload_engine.py):
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --scaled --offload --placement disk --pipeline performance
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--scaled", action="store_true")
    ap.add_argument("--b-max", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--offload", action="store_true",
                    help="stream weights from host/disk via the PIPO "
                         "pipeline instead of keeping them resident")
    ap.add_argument("--placement", default="host",
                    choices=("host", "disk"),
                    help="weight tier for --offload")
    ap.add_argument("--pipeline", default="performance",
                    choices=("performance", "memory", "sequential"),
                    help="PIPO scheduling mode for --offload")
    args = ap.parse_args()

    from repro.configs import get_config, scaled_down
    from repro.serving import (OffloadedServingEngine, Request, ServingEngine)

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = scaled_down(cfg)
    if args.offload:
        eng = OffloadedServingEngine(cfg, b_max=args.b_max,
                                     max_len=args.max_len,
                                     placement=args.placement,
                                     pipeline=args.pipeline)
    else:
        eng = ServingEngine(cfg, b_max=args.b_max, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, (8 + i % 8,)).astype(np.int32),
            max_new=8))
    done = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in done)
    print(f"completed={len(done)} tokens={total} tok_s={total / dt:.1f} "
          f"stats={eng.stats}")
    if args.offload:
        rep = eng.pipeline_report()
        busy = {k: f"{v['busy_s']:.2f}s" for k, v in rep["per_kind"].items()}
        print(f"pipeline[{args.pipeline}] compute_util={rep['compute_util']:.2f} "
              f"bubble_frac={rep['bubble_frac']:.2f} busy={busy}")
        eng.shutdown()


if __name__ == "__main__":
    main()
