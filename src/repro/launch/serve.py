"""Serving launcher: continuous-batching engine over a registry arch.

The CLI is generated from the one flag<->field table in
``serving.spec.CLI_FLAGS`` — every engine flag maps to exactly one
``EngineSpec`` field (cross-checked three ways by tools/check_docs.py).
Flags build a spec, ``resolve()`` materializes the plan against the
memory budget, and ``create_engine(plan)`` dispatches to the resident or
offloaded engine — the same path tests and benchmarks construct through.

Resident weights (default):
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --scaled --requests 10

Offloaded weights through the PIPO pipeline (models larger than device
memory; see serving/offload_engine.py).  The pipeline stays warm across
decode steps by default (--no-warm for the cold per-step baseline),
keeps a budget-sized window of layers in flight (--preload-depth to
override, --depth-policy adaptive to re-size it from live KV/spill
pressure AND the measured link-bandwidth EWMA; docs/TUNING.md walks the
sizing), --quant int4 streams packed INT4 weights over the offload
link, and --kv-mode int4 packs the KV-cache rows the same way (the
tiered KV store ships live rows either way; see docs/ARCHITECTURE.md
"The KV tier"):
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --scaled --offload --placement disk --pipeline performance
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --scaled --offload --quant int4 --kv-mode int4

Plans are first-class: --plan-json resolves the spec and dumps the
fully-materialized plan (every auto field + why it got its value)
WITHOUT building an engine; --spec-json loads an EngineSpec JSON as the
base (explicit flags still override its fields):
  PYTHONPATH=src python -m repro.launch.serve --scaled --offload \
      --quant int4 --plan-json -
  PYTHONPATH=src python -m repro.launch.serve --spec-json my_spec.json
"""
import argparse
import json
import time

import numpy as np

from repro.serving.spec import (EngineSpec, SpecError, add_spec_args,
                                spec_from_args)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="PIPO serving launcher (spec-driven: flags -> "
                    "EngineSpec -> ResolvedPlan -> create_engine)")
    add_spec_args(ap)                       # generated from CLI_FLAGS
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic request count for the demo workload")
    ap.add_argument("--spec-json", metavar="FILE",
                    help="load an EngineSpec JSON as the base "
                         "(explicitly-given flags override its fields)")
    ap.add_argument("--plan-json", nargs="?", const="-", metavar="FILE",
                    help="resolve and dump the plan JSON (stdout when no "
                         "FILE), then exit without serving — the plan "
                         "dry-run")
    return ap


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    base = None
    try:
        if args.spec_json:
            with open(args.spec_json) as f:
                base = EngineSpec.from_json(f.read())
        spec = spec_from_args(args, base=base)
        plan = spec.resolve()
    except (SpecError, OSError, json.JSONDecodeError) as e:
        ap.error(str(e))
    if args.plan_json:
        payload = json.dumps(plan.to_json(), indent=2)
        if args.plan_json == "-":
            print(payload)
        else:
            with open(args.plan_json, "w") as f:
                f.write(payload + "\n")
            print(f"plan written to {args.plan_json}")
        return

    from repro.serving import Request
    from repro.serving.spec import create_engine

    print(f"plan: {plan.summary()}")
    eng = create_engine(plan)
    cfg = eng.cfg
    offloaded = plan.engine == "offloaded"
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, (8 + i % 8,)).astype(np.int32),
            max_new=8))
    done = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in done)
    print(f"completed={len(done)} tokens={total} tok_s={total / dt:.1f} "
          f"stats={eng.stats}")
    if offloaded:
        rep = eng.pipeline_report()
        busy = {k: f"{v['busy_s']:.2f}s" for k, v in rep["per_kind"].items()}
        print(f"pipeline[{plan.pipeline}] depth={eng.sched.depth} "
              f"compute_util={rep['compute_util']:.2f} "
              f"bubble_frac={rep['bubble_frac']:.2f} busy={busy}")
    eng.shutdown()


if __name__ == "__main__":
    main()
